"""sim/config.py error paths (satellite): extends cycles, unknown builtins,
malformed ``pipeline:``/``tiling:`` sections — every bad input raises a clear
``ConfigError``, never a KeyError/TypeError."""
import pytest

from repro.sim import ConfigError, SimConfig, builtin_config_path, load_config


# ------------------------------------------------------------- from_dict
def test_non_mapping_sections_rejected():
    for section in ("cache", "vpu", "ecpu", "pipeline", "memory"):
        with pytest.raises(ConfigError, match="must be a mapping"):
            SimConfig.from_dict({section: [1, 2]})
        with pytest.raises(ConfigError, match="must be a mapping"):
            SimConfig.from_dict({section: "fast"})


def test_unknown_keys_rejected_with_expectations():
    with pytest.raises(ConfigError, match=r"unknown key pipeline\.chunk"):
        SimConfig.from_dict({"pipeline": {"chunk": 4}})
    with pytest.raises(ConfigError, match="unknown top-level keys"):
        SimConfig.from_dict({"pipelines": {}})


def test_malformed_tiling_sections():
    with pytest.raises(ConfigError, match=r"pipeline\.tiling must be a "
                                          r"mapping"):
        SimConfig.from_dict({"pipeline": {"tiling": 4}})
    with pytest.raises(ConfigError, match=r"pipeline\.tiling must be a "
                                          r"mapping"):
        SimConfig.from_dict({"pipeline": {"tiling": [4, 8]}})
    with pytest.raises(ConfigError, match=r"unknown key pipeline\.tiling\.row"):
        SimConfig.from_dict({"pipeline": {"tiling": {"row": 4}}})
    with pytest.raises(ConfigError, match=r"tiling\.rows must be a "
                                          r"non-negative integer"):
        SimConfig.from_dict({"pipeline": {"tiling": {"rows": -1}}})
    with pytest.raises(ConfigError, match="non-negative integer"):
        SimConfig.from_dict({"pipeline": {"tiling": {"cols": "wide"}}})
    # an empty/None tiling mapping is a no-op, not an error
    assert SimConfig.from_dict({"pipeline": {"tiling": None}}).tiling is None
    assert SimConfig.from_dict({"pipeline": {"tiling": {}}}).tiling is None


def test_on_off_knobs_normalise_and_reject():
    assert SimConfig.from_dict({"pipeline": {"dataflow": "off"}}) \
        .dataflow is False
    assert SimConfig.from_dict(
        {"pipeline": {"reuse": "on", "dataflow": "on"}}).reuse is True
    with pytest.raises(ConfigError, match=r"pipeline\.dataflow must be "
                                          r"on/off"):
        SimConfig.from_dict({"pipeline": {"dataflow": "sideways"}})
    with pytest.raises(ConfigError, match=r"pipeline\.reuse must be on/off"):
        SimConfig.from_dict({"pipeline": {"reuse": "maybe"}})


def test_tiling_reuse_require_dataflow():
    with pytest.raises(ConfigError, match="require pipeline.dataflow"):
        SimConfig.from_dict({"pipeline": {"dataflow": "off",
                                          "tiling": {"cols": 8}}})
    with pytest.raises(ConfigError, match="require pipeline.dataflow"):
        SimConfig(dataflow=False, reuse=True)


def test_positive_geometry_enforced():
    with pytest.raises(ConfigError, match="n_vpus must be positive"):
        SimConfig(n_vpus=0)
    with pytest.raises(ConfigError, match="row_chunk must be >= 0"):
        SimConfig(row_chunk=-2)


def test_unknown_scheduler_name():
    with pytest.raises(ConfigError, match="unknown scheduler"):
        SimConfig(n_vpus=1, vregs_per_vpu=4, vlen_bytes=256,
                  memory_bytes=1 << 16).make_runtime("quantum")


# ----------------------------------------------------------- file loading
def test_unknown_builtin_lists_available():
    with pytest.raises(ConfigError, match="no builtin config 'warp9'"):
        builtin_config_path("warp9")
    with pytest.raises(ConfigError) as ei:
        load_config("warp9")
    assert "arcane-default" in str(ei.value)
    assert "arcane-8vpu" in str(ei.value)


def test_extends_cycle_detected(tmp_path):
    pytest.importorskip("yaml")
    (tmp_path / "a.yaml").write_text("extends: b.yaml\n")
    (tmp_path / "b.yaml").write_text("extends: c.yaml\n")
    (tmp_path / "c.yaml").write_text("extends: a.yaml\n")
    with pytest.raises(ConfigError, match="cyclic extends chain"):
        load_config(str(tmp_path / "a.yaml"))
    # self-extension is the degenerate cycle
    (tmp_path / "self.yaml").write_text("extends: self.yaml\n")
    with pytest.raises(ConfigError, match="cyclic"):
        load_config(str(tmp_path / "self.yaml"))


def test_extends_target_missing(tmp_path):
    pytest.importorskip("yaml")
    (tmp_path / "orphan.yaml").write_text("extends: nowhere.yaml\n")
    with pytest.raises(ConfigError, match="extends target not found"):
        load_config(str(tmp_path / "orphan.yaml"))
    (tmp_path / "ghost.yaml").write_text("extends: not-a-builtin\n")
    with pytest.raises(ConfigError, match="no builtin config"):
        load_config(str(tmp_path / "ghost.yaml"))


def test_non_mapping_yaml_rejected(tmp_path):
    pytest.importorskip("yaml")
    (tmp_path / "list.yaml").write_text("- 1\n- 2\n")
    with pytest.raises(ConfigError, match="top level must be a mapping"):
        load_config(str(tmp_path / "list.yaml"))


def test_malformed_tiling_through_yaml(tmp_path):
    pytest.importorskip("yaml")
    (tmp_path / "bad.yaml").write_text(
        "extends: arcane-default\npipeline: {tiling: {rows: two}}\n")
    with pytest.raises(ConfigError, match="non-negative integer"):
        load_config(str(tmp_path / "bad.yaml"))
    # deep-merge composes tiling overrides from a base before validation
    (tmp_path / "base.yaml").write_text(
        "extends: arcane-default\npipeline: {tiling: {rows: 2, cols: 8}}\n")
    (tmp_path / "child.yaml").write_text(
        "extends: base.yaml\npipeline: {tiling: {cols: 16}}\n")
    cfg = load_config(str(tmp_path / "child.yaml"))
    assert cfg.tiling == (2, 16)
