"""Pallas kernel suite vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

# The kernel wrappers target the renamed pallas TPU compiler-params API
# (jax >= 0.5, `pltpu.CompilerParams`); on older installs every test would
# fail inside pallas_call, so skip the module with a capability probe
# rather than a brittle version string compare.
pltpu = pytest.importorskip("jax.experimental.pallas.tpu")
if not hasattr(pltpu, "CompilerParams"):
    pytest.skip("installed jax's pallas.tpu lacks CompilerParams "
                "(kernel suite needs the renamed jax>=0.5 API)",
                allow_module_level=True)

from repro.kernels import (conv_layer, decode_attention, flash_attention,
                           gemm, leakyrelu, maxpool)
from repro.kernels.convlayer.ref import conv_layer_ref
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import (attention_chunked_ref,
                                               attention_ref)
from repro.kernels.gemm.ref import gemm_ref
from repro.kernels.leakyrelu.ref import leakyrelu_ref
from repro.kernels.maxpool.ref import maxpool_ref


# ------------------------------------------------------------------ gemm
@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (100, 70, 130), (128, 128, 128),
                                   (33, 257, 65), (1, 64, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_gemm_sweep(rng, m, k, n, dtype):
    if dtype == jnp.int8:
        a = jnp.array(rng.integers(-8, 8, (m, k)), dtype)
        b = jnp.array(rng.integers(-8, 8, (k, n)), dtype)
        out = gemm(a, b, block_m=32, block_n=128, block_k=128)
        np.testing.assert_array_equal(out, gemm_ref(a, b))
    else:
        a = jnp.array(rng.standard_normal((m, k)), dtype)
        b = jnp.array(rng.standard_normal((k, n)), dtype)
        out = gemm(a, b, block_m=32, block_n=128, block_k=128)
        ref = gemm_ref(a, b)
        atol = 1e-4 if dtype == jnp.float32 else 0.1
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=atol,
                                   rtol=1e-2)


def test_gemm_alpha_beta(rng):
    a = jnp.array(rng.standard_normal((48, 32)), jnp.float32)
    b = jnp.array(rng.standard_normal((32, 40)), jnp.float32)
    c = jnp.array(rng.standard_normal((48, 40)), jnp.float32)
    out = gemm(a, b, c, alpha=0.5, beta=-1.5, block_m=16, block_n=128,
               block_k=128)
    np.testing.assert_allclose(out, gemm_ref(a, b, c, alpha=0.5, beta=-1.5),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------- conv layer
@pytest.mark.parametrize("h,w,kk,nf,br", [(16, 16, 3, 1, 4), (33, 29, 5, 2, 8),
                                          (64, 64, 7, 4, 16)])
@pytest.mark.parametrize("dtype", [jnp.int8, jnp.float32])
def test_conv_layer_sweep(rng, h, w, kk, nf, br, dtype):
    if dtype == jnp.int8:
        x = jnp.array(rng.integers(-5, 5, (3, h, w)), dtype)
        f = jnp.array(rng.integers(-3, 3, (nf, 3, kk, kk)), dtype)
    else:
        x = jnp.array(rng.standard_normal((3, h, w)), dtype)
        f = jnp.array(rng.standard_normal((nf, 3, kk, kk)), dtype)
    out = conv_layer(x, f, negative_slope=0.125, block_rows=br)
    ref = conv_layer_ref(x, f, negative_slope=0.125)
    if dtype == jnp.int8:
        np.testing.assert_array_equal(out, ref)
    else:
        np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3)


# ------------------------------------------------------------ pool / relu
@pytest.mark.parametrize("win,stride", [(2, 2), (3, 2), (3, 3), (4, 1)])
def test_maxpool_sweep(rng, win, stride):
    x = jnp.array(rng.integers(-100, 100, (37, 53)), jnp.int32)
    np.testing.assert_array_equal(
        maxpool(x, win=win, stride=stride, block_rows=8),
        maxpool_ref(x, win=win, stride=stride))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_leakyrelu_sweep(rng, dtype):
    if dtype == jnp.int8:
        x = jnp.array(rng.integers(-100, 100, (17, 300)), dtype)
    else:
        x = jnp.array(rng.standard_normal((17, 300)), dtype)
    np.testing.assert_array_equal(
        leakyrelu(x, negative_slope=0.2),
        leakyrelu_ref(x, negative_slope=0.2))


# -------------------------------------------------------- flash attention
@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=37),
    dict(causal=True, softcap=30.0),
    dict(causal=True, window=17, softcap=20.0),
])
def test_flash_attention_variants(rng, kwargs):
    B, Hq, Hkv, S, D = 2, 8, 2, 129, 64
    q = jnp.array(rng.standard_normal((B, Hq, S, D)), jnp.float32)
    k = jnp.array(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.array(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    ref = attention_ref(q, k, v, **kwargs)
    out = flash_attention(q, k, v, block_q=64, block_k=64, **kwargs)
    np.testing.assert_allclose(out, ref, atol=2e-3, rtol=1e-3)
    chk = attention_chunked_ref(q, k, v, chunk=64, **kwargs)
    np.testing.assert_allclose(chk, ref, atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("sq,skv", [(64, 64), (128, 256), (8, 8), (100, 52)])
def test_flash_attention_shapes(rng, sq, skv):
    B, Hq, Hkv, D = 1, 4, 4, 32
    q = jnp.array(rng.standard_normal((B, Hq, sq, D)), jnp.float32)
    k = jnp.array(rng.standard_normal((B, Hkv, skv, D)), jnp.float32)
    v = jnp.array(rng.standard_normal((B, Hkv, skv, D)), jnp.float32)
    ref = attention_ref(q, k, v, causal=False)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    np.testing.assert_allclose(out, ref, atol=2e-3, rtol=1e-3)


def test_flash_attention_bf16(rng):
    B, H, S, D = 1, 2, 64, 32
    q = jnp.array(rng.standard_normal((B, H, S, D)), jnp.bfloat16)
    k = jnp.array(rng.standard_normal((B, H, S, D)), jnp.bfloat16)
    v = jnp.array(rng.standard_normal((B, H, S, D)), jnp.bfloat16)
    ref = attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2,
                               rtol=3e-2)


# -------------------------------------------------------- decode attention
@pytest.mark.parametrize("window", [None, 50, 16])
def test_decode_attention_sweep(rng, window):
    B, Hq, Hkv, S, D = 2, 8, 2, 200, 64
    lengths = jnp.array([37, 190])
    k = jnp.array(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    v = jnp.array(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
    q = jnp.array(rng.standard_normal((B, Hq, D)), jnp.float32)
    out = decode_attention(q, k, v, lengths, window=window, block_k=64)
    ref = decode_attention_ref(q.reshape(B, Hkv, Hq // Hkv, D), k, v, lengths,
                               window=window).reshape(B, Hq, D)
    np.testing.assert_allclose(out, ref, atol=2e-3, rtol=1e-3)


def test_decode_attention_mha_and_softcap(rng):
    B, H, S, D = 3, 4, 77, 32
    lengths = jnp.array([1, 40, 77])
    k = jnp.array(rng.standard_normal((B, H, S, D)), jnp.float32)
    v = jnp.array(rng.standard_normal((B, H, S, D)), jnp.float32)
    q = jnp.array(rng.standard_normal((B, H, D)), jnp.float32)
    out = decode_attention(q, k, v, lengths, softcap=25.0, block_k=16)
    ref = decode_attention_ref(q.reshape(B, H, 1, D), k, v, lengths,
                               softcap=25.0).reshape(B, H, D)
    np.testing.assert_allclose(out, ref, atol=2e-3, rtol=1e-3)
