"""Continuous-batching serving scenario over the open runtime session.

Covers ``repro.sim.serving`` (arrival processes, the slot-discipline
driver, the per-request programs) and the request-lifecycle layer in
``repro.sim.metrics`` (RequestLog, exact percentiles):

  * **determinism** — the same arrival tape on a fresh runtime reproduces
    the summary and the makespan bit for bit;
  * **cross-scheduler agreement** — serial and pipelined runtimes generate
    the same tokens per request (batch composition may differ — per-slot
    decode math must not);
  * **functional spot-check** — after a prefill, the KV key buffer holds
    exactly the weight columns the tape appended;
  * **saturation** — more simultaneous requests than slots ⇒ FIFO
    admission and non-zero queue waits feeding TTFT.

Distinct from ``tests/test_serving.py``, which exercises the jax LM
serving engine (``repro.serving.engine``) this scenario's slot discipline
mirrors.
"""
import numpy as np
import pytest

from repro.core.program import ProgramError, np_dtype
from repro.core.runtime import CacheRuntime
from repro.sim import PipelinedRuntime
from repro.sim.metrics import MetricsError, RequestLog
from repro.sim.serving import (Request, ServingConfig, ServingDriver,
                               bursty_arrivals, poisson_arrivals)

CFG = ServingConfig(kv_max=24, slots=3)
ARRIVAL_KW = dict(prompt_range=(3, 6), new_range=(2, 4))


def _gather(rt, addrs, name, rows, cols, width):
    rt.cache.flush_all()
    dt = np_dtype(width)
    nbytes = rows * cols * dt.itemsize
    raw = rt.memory.data[addrs[name]:addrs[name] + nbytes]
    return raw.copy().view(dt).reshape(rows, cols)


# ------------------------------------------------------- arrival processes
def test_poisson_arrivals_deterministic_and_bounded():
    a = poisson_arrivals(20, 5_000, seed=7, **ARRIVAL_KW)
    b = poisson_arrivals(20, 5_000, seed=7, **ARRIVAL_KW)
    assert a == b                                  # seeded: replayable
    assert [r.rid for r in a] == list(range(20))
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))
    assert all(3 <= r.prompt_len <= 6 and 2 <= r.max_new <= 4 for r in a)
    # mean gap lands in the right decade (20 draws: loose 3x band)
    gaps = np.diff([0] + [r.arrival for r in a])
    assert 5_000 / 3 < gaps.mean() < 5_000 * 3
    assert poisson_arrivals(20, 5_000, seed=8, **ARRIVAL_KW) != a


def test_bursty_arrivals_structure():
    reqs = bursty_arrivals(12, 4, 100_000, spread=50, seed=3, **ARRIVAL_KW)
    assert len(reqs) == 12
    assert sorted(r.rid for r in reqs) == list(range(12))
    assert all(x.arrival <= y.arrival for x, y in zip(reqs, reqs[1:]))
    for r in reqs:
        base = (r.rid // 4) * 100_000
        assert base <= r.arrival < base + 50      # jitter stays in-burst


@pytest.mark.parametrize("make_rt", [
    lambda: CacheRuntime(n_vpus=2),
    lambda: PipelinedRuntime(n_vpus=2, metrics=True),
], ids=["serial", "pipelined"])
def test_request_exceeding_kv_capacity_rejected(make_rt):
    """Admission control: an oversized request is rejected at arrival —
    counted in `serving.rejected` — instead of failing mid-tape, and the
    well-sized requests around it still finish."""
    drv = ServingDriver(make_rt(), ServingConfig(kv_max=8))
    s = drv.run([
        Request(rid=0, arrival=0, prompt_len=7, max_new=3),     # 7+3 > 8+1
        Request(rid=1, arrival=10, prompt_len=4, max_new=2),
        Request(rid=2, arrival=20, prompt_len=9, max_new=1),    # prompt > 8
    ])
    assert s["requests"] == 3
    assert s["rejected"] == 2
    assert s["finished"] == 1
    assert s["tokens_generated"] == 2
    rec = drv.log.records[0]
    assert rec.rejected is not None and rec.admitted is None


# ---------------------------------------------------------------- driving
def test_single_request_prefill_writes_weight_columns():
    """One request, max_new=1 (prefill only): the KV key buffer's first
    ``prompt_len`` columns are exactly the wq columns the tape copies in
    (leakyrelu alpha=1 pass-through), the rest untouched zeros."""
    cfg = ServingConfig(kv_max=16, slots=2)
    drv = ServingDriver(PipelinedRuntime(n_vpus=2, metrics=True), cfg)
    s = drv.run([Request(rid=0, arrival=100, prompt_len=5, max_new=1)])
    assert s["requests"] == s["finished"] == 1
    assert s["tokens_generated"] == 1
    assert s["ttft_p50"] == s["ttft_p99"] > 0
    rt = drv.session.rt
    wq = _gather(rt, drv.addrs, "wq", cfg.d, cfg.d, cfg.width)
    kt = _gather(rt, drv.addrs, "r0_kt", cfg.d, cfg.kv_max, cfg.width)
    for s_pos in range(5):
        np.testing.assert_array_equal(kt[:, s_pos], wq[:, s_pos % cfg.d])
    assert not kt[:, 5:].any()


@pytest.mark.parametrize("make_rt", [
    pytest.param(lambda: CacheRuntime(n_vpus=2), id="serial"),
    pytest.param(lambda: PipelinedRuntime(n_vpus=2, metrics=True),
                 id="pipelined"),
])
def test_driver_deterministic(make_rt):
    reqs = poisson_arrivals(6, 4_000, seed=1, **ARRIVAL_KW)
    runs = []
    for _ in range(2):
        drv = ServingDriver(make_rt(), CFG)
        s = drv.run(reqs)
        runs.append((s, drv.session.now(), drv.steps_issued))
    assert runs[0] == runs[1]
    s = runs[0][0]
    assert s["finished"] == s["requests"] == 6
    assert s["tokens_generated"] == sum(r.max_new for r in reqs)
    assert s["ttft_p99"] >= s["ttft_p50"] > 0
    assert s["goodput_tokens_per_kcycle"] > 0


def test_serial_and_pipelined_agree_per_request():
    """Batch composition differs between schedulers (completion timing
    drives grouping) but every request's token count — and the KV image it
    leaves behind — must agree."""
    reqs = poisson_arrivals(5, 3_000, seed=2, **ARRIVAL_KW)
    drvs = {}
    for key, rt in (("serial", CacheRuntime(n_vpus=2)),
                    ("pipelined", PipelinedRuntime(n_vpus=2, metrics=True))):
        drvs[key] = drv = ServingDriver(rt, CFG)
        drv.run(reqs)
    ser, pip = drvs["serial"], drvs["pipelined"]
    tok_s = {r["rid"]: r["tokens"] for r in ser.log.summary()["per_request"]}
    tok_p = {r["rid"]: r["tokens"] for r in pip.log.summary()["per_request"]}
    assert tok_s == tok_p == {r.rid: r.max_new for r in reqs}
    for r in reqs:
        kv = r.prompt_len + r.max_new - 1
        for name, rows, cols in ((f"r{r.rid}_kt", CFG.d, CFG.kv_max),
                                 (f"r{r.rid}_v", CFG.kv_max, CFG.d)):
            np.testing.assert_array_equal(
                _gather(ser.rt, ser.addrs, name, rows, cols, CFG.width),
                _gather(pip.rt, pip.addrs, name, rows, cols, CFG.width),
                err_msg=f"{name} diverged between schedulers (kv_len {kv})")
    assert pip.rt.metrics.stalls.conservation_ok()


def test_saturation_fifo_admission_and_queue_wait():
    """A burst wider than the slot count: admissions happen in rid order
    as slots free, every overflow request records a positive queue wait,
    and the waits feed TTFT (ttft >= queue_wait per request)."""
    cfg = ServingConfig(kv_max=16, slots=2)
    drv = ServingDriver(PipelinedRuntime(n_vpus=2, metrics=True), cfg)
    reqs = [Request(rid=i, arrival=10 + i, prompt_len=3, max_new=2)
            for i in range(6)]
    s = drv.run(reqs)
    assert s["finished"] == 6
    per = {r["rid"]: r for r in s["per_request"]}
    admits = [per[i]["admitted"] for i in range(6)]
    assert admits == sorted(admits)               # FIFO admission order
    for i in range(2, 6):                         # overflow: waited for slot
        assert per[i]["queue_wait"] > 0
        assert per[i]["ttft"] >= per[i]["queue_wait"]
    assert s["queue_wait_p99"] > 0
    assert drv.session.rt.metrics.stalls.conservation_ok()


def test_bursty_load_drains_without_deadlock():
    cfg = ServingConfig(kv_max=16, slots=2)
    drv = ServingDriver(PipelinedRuntime(n_vpus=4, metrics=True), cfg)
    reqs = bursty_arrivals(8, 4, 150_000, spread=40, seed=5, **ARRIVAL_KW)
    s = drv.run(reqs)
    assert s["finished"] == 8 and not drv.active and not drv.waiting
    assert drv.session.rt.metrics.stalls.conservation_ok()
    # two bursts 150k apart: the makespan spans both
    assert drv.session.now() >= 150_000


# -------------------------------------------------------- request lifecycle
def test_request_log_lifecycle_math():
    log = RequestLog(PipelinedRuntime(n_vpus=1, metrics=True).metrics)
    log.arrive(0, prompt_len=4, max_new=3, t=100)
    log.admit(0, t=150)
    log.first_token(0, t=400)
    log.token(0)
    log.token(0)
    log.finish(0, t=1000)
    r = log.records[0]
    assert r.queue_wait == 50
    assert r.ttft == 300                  # arrival -> first token
    assert r.tpot == pytest.approx(600 / 2)   # 2 gaps after the first token
    s = log.summary(now=1000)
    assert s["finished"] == 1 and s["tokens_generated"] == 3
    assert s["ttft_p50"] == s["ttft_p99"] == 300
    assert s["goodput_tokens_per_kcycle"] == pytest.approx(3.0)


def test_request_log_duplicate_rid_raises():
    log = RequestLog(PipelinedRuntime(n_vpus=1, metrics=True).metrics)
    log.arrive(7, prompt_len=1, max_new=1, t=0)
    with pytest.raises(MetricsError, match="already arrived"):
        log.arrive(7, prompt_len=1, max_new=1, t=5)


def test_request_log_percentiles_exact():
    log = RequestLog(PipelinedRuntime(n_vpus=1, metrics=True).metrics)
    for i, ttft in enumerate([100, 200, 300, 400, 1000]):
        log.arrive(i, prompt_len=1, max_new=1, t=0)
        log.admit(i, t=0)
        log.first_token(i, t=ttft)
        log.finish(i, t=ttft)
    s = log.summary(now=1000)
    assert s["ttft_p50"] == 300           # nearest-rank on raw values
    assert s["ttft_p99"] == 1000
    assert s["ttft_mean"] == pytest.approx(400.0)
