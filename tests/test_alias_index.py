"""AliasIndex oracle tests: the bucketed index must agree with brute force.

The index is a pure accelerator over exact ``StridedRegion.overlaps`` — any
divergence from an exhaustive pairwise scan is a correctness bug, not a
performance artifact. The oracle here is ``AliasIndex.brute_query`` (an
uncached full scan); the tests drive random and adversarial
insert/remove/query sequences and demand identical answers, including under
the ``brute_force_queries`` switch the benchmark baseline uses.
"""
import numpy as np
import pytest

from repro.core.alias_index import AliasIndex, brute_force_queries
from repro.core.regions import StridedRegion, contains_cached, overlaps_cached


def _rand_region(rng) -> StridedRegion:
    addr = int(rng.integers(0, 1 << 20))
    rows = int(rng.integers(1, 12))
    row_bytes = int(rng.integers(1, 300))
    stride = row_bytes + int(rng.integers(0, 200)) if rows > 1 else 0
    if rows == 1:
        stride = row_bytes
    return StridedRegion(addr=addr, rows=rows, row_bytes=row_bytes,
                         stride_bytes=stride)


def test_query_matches_brute_force_exhaustive():
    """Dense battery of adversarial shapes: interleaved strips, contained
    runs, giant coarse spans, adjacent-but-disjoint intervals."""
    idx = AliasIndex(bucket_bits=6, coarse_limit=4)   # tiny buckets: exercise
    shapes = [                                        # multi-bucket + coarse
        StridedRegion(0, 1, 64, 64),
        StridedRegion(0, 8, 16, 64),                  # strip 0
        StridedRegion(16, 8, 16, 64),                 # interleaved strip 1
        StridedRegion(32, 8, 16, 64),                 # interleaved strip 2
        StridedRegion(64, 1, 1, 1),
        StridedRegion(0, 4, 512, 513),                # coarse (spans >4*64B)
        StridedRegion(10_000, 3, 33, 100),
        StridedRegion(9_000, 2, 2_000, 2_100),        # coarse, overlaps above
        StridedRegion(1 << 18, 1, 1 << 14, 1 << 14),  # far away, wide
    ]
    for k, r in enumerate(shapes):
        idx.insert(k, r)
    probes = shapes + [
        StridedRegion(48, 8, 16, 64),                 # 4th interleaved strip
        StridedRegion(63, 1, 1, 1),
        StridedRegion(65, 1, 1, 1),
        StridedRegion(0, 1, 1 << 19, 1 << 19),        # coarse-span probe
        StridedRegion(5_000_000, 2, 64, 128),         # hits nothing
    ]
    for probe in probes:
        assert idx.query(probe) == idx.brute_query(probe)
        with brute_force_queries():
            assert idx.query(probe) == idx.brute_query(probe)
    # Interval queries reduce to single-row regions.
    for start, end in [(0, 1), (15, 17), (63, 64), (0, 1 << 20), (5, 5)]:
        want = (idx.brute_query(StridedRegion(start, 1, end - start,
                                              end - start))
                if end > start else [])
        assert idx.query_interval(start, end) == want


@pytest.mark.parametrize("seed", range(20))
def test_random_insert_remove_query_sequences(seed):
    """Seeded random operation tapes: the index and a shadow dict must agree
    through arbitrary insert/replace/remove churn."""
    rng = np.random.default_rng(seed)
    idx = AliasIndex(bucket_bits=int(rng.integers(4, 14)),
                     coarse_limit=int(rng.integers(1, 64)))
    shadow: dict[int, StridedRegion] = {}
    for _ in range(120):
        op = rng.random()
        if op < 0.45 or not shadow:
            k = int(rng.integers(0, 40))
            r = _rand_region(rng)
            idx.insert(k, r)           # replaces silently, like the callers
            shadow[k] = r
        elif op < 0.65:
            k = list(shadow)[int(rng.integers(0, len(shadow)))]
            idx.remove(k)
            del shadow[k]
        else:
            probe = _rand_region(rng)
            got = idx.query(probe)
            want = sorted(k for k, r in shadow.items()
                          if r.overlaps(probe))
            assert got == want, f"seed {seed}: {probe}"
    assert len(idx) == len(shadow)
    for k, r in shadow.items():
        assert k in idx and idx.region(k) == r


def test_remove_is_strict_discard_is_not():
    idx = AliasIndex()
    idx.insert("a", StridedRegion(0, 1, 8, 8))
    idx.remove("a")
    with pytest.raises(KeyError):
        idx.remove("a")
    idx.discard("a")                   # tolerant
    assert len(idx) == 0


def test_insert_replaces_previous_region():
    idx = AliasIndex(bucket_bits=4, coarse_limit=2)
    r1 = StridedRegion(0, 1, 8, 8)
    r2 = StridedRegion(1 << 12, 1, 8, 8)
    idx.insert(7, r1)
    idx.insert(7, r2)                  # same key, elsewhere
    assert idx.query(r1) == []
    assert idx.query(r2) == [7]
    assert len(idx) == 1


def test_counters_track_queries():
    idx = AliasIndex()
    idx.insert(0, StridedRegion(0, 1, 8, 8))
    before = idx.queries
    idx.query(StridedRegion(0, 1, 4, 4))
    idx.query_interval(100, 90)        # empty interval still counts a query
    assert idx.queries == before + 2


def test_memoized_region_decisions_match_direct():
    """The pairwise memo helpers must agree with the uncached methods over a
    random sample (they feed every hot confirmation loop)."""
    rng = np.random.default_rng(123)
    regions = [_rand_region(rng) for _ in range(60)]
    for a in regions[:20]:
        for b in regions:
            assert overlaps_cached(a, b) == a.overlaps(b)
            assert contains_cached(a, b) == a.contains(b)


def test_hypothesis_property_sequences():
    """Hypothesis tape over insert/remove/query with shrinking."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    region_st = st.builds(
        lambda addr, rows, rb, pad: StridedRegion(
            addr=addr, rows=rows, row_bytes=rb,
            stride_bytes=(rb + pad) if rows > 1 else rb),
        st.integers(0, 1 << 16), st.integers(1, 8),
        st.integers(1, 128), st.integers(0, 128))
    op_st = st.one_of(
        st.tuples(st.just("ins"), st.integers(0, 15), region_st),
        st.tuples(st.just("del"), st.integers(0, 15), region_st),
        st.tuples(st.just("qry"), st.integers(0, 15), region_st))

    @settings(max_examples=60, deadline=None)
    @given(st.lists(op_st, max_size=60),
           st.integers(4, 13), st.integers(1, 32))
    def prop(ops, bits, coarse):
        idx = AliasIndex(bucket_bits=bits, coarse_limit=coarse)
        shadow: dict[int, StridedRegion] = {}
        for kind, key, region in ops:
            if kind == "ins":
                idx.insert(key, region)
                shadow[key] = region
            elif kind == "del":
                idx.discard(key)
                shadow.pop(key, None)
            else:
                assert idx.query(region) == sorted(
                    k for k, r in shadow.items() if r.overlaps(region))
        # Every tracked region starts below 2^21, so a whole-space interval
        # probe must return exactly the live key set.
        assert idx.query(StridedRegion(0, 1, 1 << 21, 1 << 21)) \
            == sorted(shadow)

    prop()
