"""SSM scan correctness (chunk invariance, naive-ref parity) + MoE invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import LayerSpec, MambaConfig, ModelConfig, MoEConfig
from repro.core.engine import ArcaneEngine
from repro.models.mamba import mamba_forward, mamba_init
from repro.models.moe import moe, moe_init
from repro.models.rwkv6 import rwkv_init, rwkv_time_mix

ENGINE = ArcaneEngine(backend="ref")


def _mamba_cfg(chunk):
    return ModelConfig(
        name="m", family="ssm", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=64,
        pattern=(LayerSpec(kind="mamba"),),
        mamba=MambaConfig(d_state=4, d_conv=4, expand=2, chunk=chunk),
        param_dtype="float32", compute_dtype="float32")


def test_mamba_chunk_invariance(rng):
    """The chunked scan must be invariant to chunk size (math identity)."""
    x = jnp.asarray(rng.standard_normal((2, 32, 32)), jnp.float32)
    p = mamba_init(jax.random.key(0), _mamba_cfg(32))
    outs = []
    for chunk in (4, 8, 16, 32):
        cfg = _mamba_cfg(chunk)
        y, h = mamba_forward(ENGINE, p, cfg, x)
        outs.append((np.asarray(y), np.asarray(h)))
    for y, h in outs[1:]:
        np.testing.assert_allclose(y, outs[0][0], atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(h, outs[0][1], atol=1e-4, rtol=1e-4)


def test_mamba_matches_naive_recurrence(rng):
    """Associative-scan implementation vs a step-by-step reference."""
    cfg = _mamba_cfg(8)
    p = mamba_init(jax.random.key(1), cfg)
    x = jnp.asarray(rng.standard_normal((1, 16, 32)), jnp.float32)
    y, h_last = mamba_forward(ENGINE, p, cfg, x)

    # naive: replicate the terms then a python recurrence
    from repro.models.mamba import _causal_conv, _selective_terms
    from repro.models.layers import dense
    xz = dense(ENGINE, p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(p, xi)
    xc = jax.nn.silu(xc).astype(x.dtype)
    decay, contrib, cmat = _selective_terms(ENGINE, p, cfg, xc)
    h = np.zeros(decay.shape[2:], np.float32)          # (di, ds)
    ys = []
    for t in range(16):
        h = np.asarray(decay[0, t]) * h + np.asarray(contrib[0, t])
        ys.append(h @ np.asarray(cmat[0, t]))
    ys = np.stack(ys)                                   # (S, di)
    ys = ys + np.asarray(p["D"]) * np.asarray(xc[0])
    ref = ys * np.asarray(jax.nn.silu(z[0]))
    got_pre = dense(ENGINE, p["out_proj"],
                    jnp.asarray(ref[None], jnp.float32))
    np.testing.assert_allclose(np.asarray(y), np.asarray(got_pre),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_last[0]), h, atol=1e-4)


def test_rwkv_chunk_invariance(rng):
    cfg = get_smoke_config("rwkv6-1.6b")
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    p = rwkv_init(jax.random.key(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    outs = []
    for chunk in (4, 16, 32):
        cfg2 = dataclasses.replace(
            cfg, rwkv=dataclasses.replace(cfg.rwkv, chunk=chunk))
        y, S, _ = rwkv_time_mix(ENGINE, p, cfg2, x)
        outs.append((np.asarray(y), np.asarray(S)))
    for y, S in outs[1:]:
        np.testing.assert_allclose(y, outs[0][0], atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(S, outs[0][1], atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------------- MoE
def _moe_cfg(cap=8.0, e=4, k=2):
    return ModelConfig(
        name="moe", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=48, vocab=64,
        pattern=(LayerSpec(kind="attn", moe=True),),
        moe=MoEConfig(n_experts=e, top_k=k, capacity_factor=cap),
        param_dtype="float32", compute_dtype="float32")


def test_moe_matches_dense_reference_at_high_capacity(rng):
    """With no drops, capacity dispatch must equal the dense top-k formula."""
    cfg = _moe_cfg(cap=16.0)
    p = moe_init(jax.random.key(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 8, 32)), jnp.float32)
    out, aux = moe(ENGINE, p, cfg, x)
    # dense reference: every expert computes everything, weighted combine
    t = x.reshape(-1, 32)
    logits = t @ np.asarray(p["router"]["w"])
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    w, ids = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    ref = np.zeros_like(t)
    for e in range(4):
        g = np.tanh(0)  # placeholder
        ge = jax.nn.silu(t @ p["gate"][e]) * (t @ p["up"][e])
        ye = np.asarray(ge @ p["down"][e])
        for slot in range(2):
            mask = (np.asarray(ids[:, slot]) == e)
            ref[mask] += np.asarray(w[:, slot])[mask, None] * ye[mask]
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 32), ref,
                               atol=2e-4, rtol=2e-3)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_tokens(rng):
    """Tiny capacity must drop contributions (outputs differ from cap=16)."""
    p = moe_init(jax.random.key(0), _moe_cfg())
    x = jnp.asarray(rng.standard_normal((4, 16, 32)), jnp.float32)
    hi, _ = moe(ENGINE, p, _moe_cfg(cap=16.0), x)
    lo, _ = moe(ENGINE, p, _moe_cfg(cap=0.25), x)
    assert not np.allclose(np.asarray(hi), np.asarray(lo))


def test_moe_aux_loss_uniform_router_near_one(rng):
    """Balanced routing → aux ≈ coef (E · Σ 1/E · k/E · ... normalised)."""
    cfg = _moe_cfg()
    p = moe_init(jax.random.key(2), cfg)
    x = jnp.asarray(rng.standard_normal((8, 32, 32)), jnp.float32)
    _, aux = moe(ENGINE, p, cfg, x)
    # with near-uniform routing aux ≈ coef * E * (1/E) * k = coef * k
    assert 0.0 < float(aux) < 4 * cfg.moe.router_aux_coef * cfg.moe.top_k
