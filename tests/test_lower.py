"""The model→tape lowering frontends: golden tapes, knob sweeps, traces.

Three layers of assurance on every lowered program:

  * **golden tape** — the flushed memory image after simulation matches the
    sequential numpy oracle (``reference_images``) bit for bit, and the
    CNN front layer additionally matches the jnp ``conv_layer_ref`` model
    oracle (lowering → simulation → flush reproduces the model's numbers);
  * **scheduler bit-identity** — lowered programs are a differential corpus:
    serial ≡ pipelined across scheduler-knob combinations (reusing the
    fuzzer's ``check_identity`` harness);
  * **trace round-trip** — ``loads(dumps(prog)) == prog`` for every lowered
    program, and malformed trace files fail with ``TraceFormatError`` naming
    the offending line.
"""
import numpy as np
import pytest

from repro.core import ElemWidth, ProgramError, reference_images, run_program
from repro.core.runtime import CacheRuntime
from repro.lower import (CNNSpec, DecodeSpec, MoESpec, TraceFormatError,
                         decode_step_from_config, dumps, loads, load_program,
                         lower_cnn, lower_decode_step, lower_moe_burst,
                         moe_burst_from_config, save_program)

from test_differential import check_identity

RT = dict(n_vpus=4, vregs_per_vpu=64, vlen_bytes=1024)


def corpus():
    """The lowered-program corpus the knob sweeps and trace tests run over."""
    return [
        lower_cnn(CNNSpec(name="cnn32")),
        lower_cnn(CNNSpec(name="cnn-deep", h=24, w=24, width=ElemWidth.B,
                          depth=2, classes=8, batch=2)),
        # small register file: forces multi-strip decomposition
        lower_cnn(CNNSpec(name="cnn-strips", h=32, w=32),
                  vregs_per_vpu=16, vlen_bytes=512),
        lower_decode_step(DecodeSpec(name="dec", d=24, ff=64, kv=16,
                                     layers=2, vocab=32)),
        lower_moe_burst(MoESpec(name="moe", d=24, ff=64, tokens=4,
                                experts=3)),
    ]


# ------------------------------------------------------------ golden tapes
@pytest.mark.parametrize("prog", corpus(), ids=lambda p: p.name)
def test_flushed_memory_matches_numpy_oracle(prog):
    ref = reference_images(prog)
    run = run_program(CacheRuntime(**RT), prog)
    imgs = run.flushed_images()
    for name, arr in ref.items():
        np.testing.assert_array_equal(imgs[name], arr,
                                      err_msg=f"{prog.name}/{name}")


def test_cnn_front_layer_matches_jnp_model_oracle():
    """Lowering → simulation → flush reproduces the jnp model's conv layer
    (the paper's fused conv+pool+ReLU) numerically."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.convlayer.ref import conv_layer_ref

    spec = CNNSpec(name="golden", h=16, w=20, k=3)
    prog = lower_cnn(spec, vregs_per_vpu=16, vlen_bytes=512)  # multi-strip
    run = run_program(CacheRuntime(**RT), prog)
    x = prog.buffer("x0").materialize(prog.width)
    f = prog.buffer("f0").materialize(prog.width)
    ref = np.asarray(conv_layer_ref(
        jnp.asarray(x.reshape(3, spec.h, spec.w)),
        jnp.asarray(f.reshape(1, 3, spec.k, spec.k))))[0]
    np.testing.assert_array_equal(run.flushed_images()["l0_out0"], ref)


def test_decode_residual_beta_path():
    """The decode step's residual adds run through GeMM's β-accumulate; the
    layer output therefore differs from the MLP branch alone and equals the
    oracle's sum."""
    prog = lower_decode_step(DecodeSpec(name="resid", d=16, ff=32, kv=8))
    ref = reference_images(prog)
    x1 = ref["x1"]
    h2 = ref["h2_0"]
    xa = ref["xa0"]
    np.testing.assert_array_equal(
        x1, (h2.astype(np.int64) + xa).astype(x1.dtype))


# ------------------------------------------------- scheduler bit-identity
KNOBS = [
    dict(row_chunk=0, dataflow=True, tiling=None, reuse=False, wakeup=True),
    dict(row_chunk=3, dataflow=True, tiling=(2, 4), reuse=True, wakeup=True),
    dict(row_chunk=8, dataflow=False, tiling=None, reuse=False, wakeup=False),
]


@pytest.mark.parametrize("knobs", KNOBS,
                         ids=["plain", "tiled-reuse", "legacy-rescan"])
def test_lowered_corpus_serial_pipelined_identity(knobs):
    for prog in corpus():
        check_identity(prog, RT, knobs, tag=prog.name)


# ------------------------------------------------------- configs frontend
def test_decode_from_config_shapes():
    prog, spec = decode_step_from_config("stablelm-3b", scale=64, kv=16)
    assert spec.d >= 8 and spec.d % 4 == 0 and spec.ff % 4 == 0
    assert prog.name == "decode-stablelm-3b"
    # executes + matches the oracle like any other program
    run = run_program(CacheRuntime(**RT), prog)
    ref = reference_images(prog)
    np.testing.assert_array_equal(run.flushed_images()["x1"], ref["x1"])


def test_moe_from_config_uses_top_k_and_rejects_dense():
    from repro.configs import get_config
    prog, spec = moe_burst_from_config("granite-moe-1b-a400m", scale=32)
    assert spec.experts == get_config("granite-moe-1b-a400m").moe.top_k
    assert prog.n_ops == 3 * spec.experts
    with pytest.raises(ProgramError):
        moe_burst_from_config("stablelm-3b")


def test_degenerate_shapes_rejected():
    with pytest.raises(ProgramError):
        lower_cnn(CNNSpec(h=3, w=3, k=3))   # conv output < pool window
    with pytest.raises(ProgramError):
        lower_decode_step(DecodeSpec(d=1))
    with pytest.raises(ProgramError):
        lower_moe_burst(MoESpec(experts=0))


# --------------------------------------------------------- trace files
@pytest.mark.parametrize("prog", corpus(), ids=lambda p: p.name)
def test_trace_round_trip(prog):
    assert loads(dumps(prog)) == prog


def test_trace_file_round_trip(tmp_path):
    prog = lower_cnn(CNNSpec(name="file", h=16, w=16))
    path = save_program(prog, str(tmp_path / "prog.jsonl"))
    assert load_program(path) == prog


def test_malformed_traces_fail_with_line_numbers(tmp_path):
    good = dumps(lower_cnn(CNNSpec(name="m", h=16, w=16)))
    lines = good.splitlines()

    with pytest.raises(TraceFormatError, match="no header"):
        loads("")
    with pytest.raises(TraceFormatError, match="line 1"):
        loads("not json\n")
    with pytest.raises(TraceFormatError, match="before the"):
        loads("\n".join(lines[1:]))            # header dropped
    with pytest.raises(TraceFormatError, match="duplicate header"):
        loads(lines[0] + "\n" + good)
    with pytest.raises(TraceFormatError, match="format"):
        loads(lines[0].replace("arcane-kernel-trace", "other-trace"))
    with pytest.raises(TraceFormatError, match="version"):
        loads(lines[0].replace('"version": 1', '"version": 99'))
    with pytest.raises(TraceFormatError, match="unknown record"):
        loads(lines[0] + '\n{"record": "mystery"}\n')
    with pytest.raises(TraceFormatError, match="bad op record"):
        loads(lines[0] + '\n{"record": "op", "kernel": "gemm"}\n')
    # structurally fine but semantically invalid -> ProgramError from
    # validation, still raised at load time (never mid-schedule)
    bad = good.replace('"kernel": "conv_layer"', '"kernel": "fft"')
    with pytest.raises(ProgramError):
        loads(bad)
