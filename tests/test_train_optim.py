"""Optimizer, schedules, grad accumulation, convergence, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev extra; suite runs without it
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.core.engine import ArcaneEngine
from repro.models.transformer import LM
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, lr_at
from repro.optim.compression import dequantize, quantize
from repro.train.step import make_train_step

ENGINE = ArcaneEngine(backend="ref")


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(lr_at(cfg, jnp.asarray(100))) - 0.1) < 1e-6
    mid = float(lr_at(cfg, jnp.asarray(55)))
    assert 0.1 < mid < 1.0


def test_adamw_matches_reference_math():
    """One update vs hand-computed Adam step."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      clip_norm=1e9, warmup_steps=0, total_steps=1,
                      min_lr_ratio=1.0)
    params = {"w": jnp.array([1.0, -2.0])}
    grads = {"w": jnp.array([0.5, 0.25])}
    state = adamw_init(cfg, params)
    new_params, state, m = adamw_update(cfg, grads, state, params)
    g = np.array([0.5, 0.25])
    m1 = 0.1 * g
    v1 = 0.01 * g * g
    upd = (m1 / 0.1) / (np.sqrt(v1 / 0.01) + 1e-8)
    ref = np.array([1.0, -2.0]) - 0.1 * upd
    np.testing.assert_allclose(np.asarray(new_params["w"]), ref, rtol=1e-5)


def test_grad_clipping():
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, total_steps=1,
                      min_lr_ratio=1.0)
    params = {"w": jnp.zeros(3)}
    grads = {"w": jnp.array([3.0, 4.0, 0.0])}   # norm 5
    state = adamw_init(cfg, params)
    _, _, metrics = adamw_update(cfg, grads, state, params)
    assert abs(float(metrics["grad_norm"]) - 5.0) < 1e-5


def test_grad_accumulation_equivalence(rng):
    """microbatches=4 must match microbatches=1 on the same global batch."""
    cfg = get_smoke_config("stablelm-3b")
    import dataclasses
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    model = LM(cfg, ENGINE)
    params = model.init_params(jax.random.key(0))
    opt_cfg = AdamWConfig(total_steps=10, warmup_steps=0)
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab, (8, 16)))}
    s1 = jax.jit(make_train_step(model, opt_cfg, microbatches=1))
    s4 = jax.jit(make_train_step(model, opt_cfg, microbatches=4))
    p1, _, m1 = s1(params, adamw_init(opt_cfg, params), batch)
    p4, _, m4 = s4(params, adamw_init(opt_cfg, params), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_loss_decreases_tiny_task(rng):
    """~50 steps on the structured synthetic stream must cut the loss."""
    from repro.data.pipeline import DataConfig, SyntheticLM
    cfg = get_smoke_config("qwen2.5-32b")
    model = LM(cfg, ENGINE)
    params = model.init_params(jax.random.key(0))
    opt_cfg = AdamWConfig(lr=3e-3, total_steps=50, warmup_steps=5)
    opt = adamw_init(opt_cfg, params)
    step = jax.jit(make_train_step(model, opt_cfg))
    src = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                 global_batch=8))
    losses = []
    for i in range(50):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]


# ------------------------------------------------------------ compression
@given(st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_quantize_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(256) * rng.uniform(0.01, 10))
    q, scale, residual = quantize(g)
    deq = dequantize(q, scale)
    max_err = float(jnp.max(jnp.abs(deq - g)))
    assert max_err <= float(scale) / 2 + 1e-6
    np.testing.assert_allclose(np.asarray(g), np.asarray(deq + residual),
                               rtol=1e-5, atol=1e-6)


def test_error_feedback_unbiased_over_steps(rng):
    """Accumulated (dequantized + residual-carried) updates track the true
    gradient sum — the error-feedback guarantee."""
    true_sum = np.zeros(64)
    carried = np.zeros(64)
    err = None
    applied = np.zeros(64)
    for step in range(200):
        g = rng.standard_normal(64) * 0.1
        true_sum += g
        q, scale, err = quantize(jnp.asarray(g), None if err is None
                                 else jnp.asarray(err))
        applied += np.asarray(dequantize(q, scale))
        err = np.asarray(err)
    # residual is bounded, so applied ≈ true_sum within one quantization step
    assert np.max(np.abs(applied + err - true_sum)) < 1e-4
    assert np.max(np.abs(applied - true_sum)) < 0.05
