"""Checkpoint manager + data pipeline: fault-tolerance invariants."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM


def tree_eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32))


def sample_tree():
    return {"params": {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
                       "b": jnp.ones(4, jnp.float32)},
            "opt": {"m": jnp.zeros((3, 4), jnp.float32),
                    "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = sample_tree()
    mgr.save(5, tree, extra={"loss": 1.5})
    assert mgr.latest_step() == 5
    restored, extra = mgr.restore(5, jax.eval_shape(lambda: tree))
    tree_eq(tree, restored)
    assert extra["loss"] == 1.5


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = sample_tree()
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1
    restored, _ = mgr.restore(1, jax.eval_shape(lambda: tree))
    tree_eq(tree, restored)


def test_atomicity_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = sample_tree()
    for s in (1, 2, 3):
        mgr.save(s, tree)
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("tmp_")]
    assert dirs == []
    assert mgr.latest_step() == 3


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = sample_tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_000000003", "step_000000004"]


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore(1, jax.eval_shape(lambda: {"w": jnp.zeros((3, 3))}))


# ------------------------------------------------------------------- data
def test_data_determinism_and_resume():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4)
    a = SyntheticLM(cfg)
    b = SyntheticLM(cfg)
    np.testing.assert_array_equal(a.batch_at(17)["tokens"],
                                  b.batch_at(17)["tokens"])
    it = a.iterate(start_step=17)
    np.testing.assert_array_equal(next(it)["tokens"],
                                  b.batch_at(17)["tokens"])


def test_data_process_sharding_disjoint():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    p0 = SyntheticLM(cfg, process_index=0, process_count=2)
    p1 = SyntheticLM(cfg, process_index=1, process_count=2)
    b0, b1 = p0.batch_at(3)["tokens"], p1.batch_at(3)["tokens"]
    assert b0.shape == (4, 16) and b1.shape == (4, 16)
    assert not np.array_equal(b0, b1)


def test_data_has_learnable_structure():
    """Repetition structure → unigram entropy < log(vocab)."""
    cfg = DataConfig(vocab=50, seq_len=256, global_batch=8)
    toks = SyntheticLM(cfg).batch_at(0)["tokens"]
    counts = np.bincount(toks.reshape(-1), minlength=50) + 1e-9
    p = counts / counts.sum()
    ent = -(p * np.log(p)).sum()
    assert ent < np.log(50) * 0.9


def test_prefetcher_yields_and_stops():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    pf = Prefetcher(SyntheticLM(cfg), start_step=0)
    b0 = next(pf)
    b1 = next(pf)
    assert b0["tokens"].shape == (2, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    pf.close()
