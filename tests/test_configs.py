"""Config registry, shape grid, and applicability rules (deliverable f)."""
import pytest

from repro.configs import (ARCHS, SHAPES, get_config, get_smoke_config, grid,
                           shape_applicable)


def test_ten_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_exact_published_geometry(arch):
    cfg = get_config(arch)
    expected = {
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


def test_moe_settings():
    g = get_config("granite-moe-1b-a400m").moe
    assert (g.n_experts, g.top_k) == (32, 8)
    l = get_config("llama4-scout-17b-a16e").moe
    assert (l.n_experts, l.top_k) == (16, 1)
    j = get_config("jamba-1.5-large-398b").moe
    assert (j.n_experts, j.top_k) == (16, 2)


def test_jamba_interleave_ratio():
    cfg = get_config("jamba-1.5-large-398b")
    kinds = [s.kind for s in cfg.pattern]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    assert sum(s.moe for s in cfg.pattern) == 4      # MoE every other layer


def test_long_500k_applicability():
    long = SHAPES["long_500k"]
    runs = {a for a in ARCHS if shape_applicable(get_config(a), long)}
    assert runs == {"jamba-1.5-large-398b", "rwkv6-1.6b"}


def test_grid_cell_count():
    total = sum(len(grid(a)) for a in ARCHS)
    assert total == 32          # 10*3 + 2 long_500k


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_same_family(arch):
    full, smoke = get_config(arch), get_smoke_config(arch)
    assert smoke.family == full.family
    assert [s.kind for s in smoke.pattern] == [s.kind for s in full.pattern]
    assert (smoke.moe is None) == (full.moe is None)
    assert smoke.n_layers <= 8 and smoke.d_model <= 128
