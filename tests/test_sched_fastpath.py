"""Fast-path equivalence: indexed/wakeup scheduler vs its slow references.

PR 5 made the scheduler stack fast three ways — alias queries through the
bucketed index, wakeup-driven dispatch instead of full-pending rescans, and
bulk DMA snooping — all of which must be *pure* wall-clock changes. These
tests pin that down:

* the wakeup engine, the legacy rescan engine, and brute-force alias queries
  produce byte-identical memory images and identical makespans;
* reuse-set invalidation through the index evicts exactly the overlapped
  entries (the ``_note_memory_write`` regression the old full-FIFO scan
  masked);
* the bulk/snoop DMA paths agree with pure row-by-row snooping in the
  presence of dirty, clean, and busy cache lines;
* ``PipelineReport`` carries the new simulator-profiling fields.
"""
import numpy as np

from repro.core import ArcaneCoprocessor, ElemWidth
from repro.core.alias_index import brute_force_queries
from repro.core.cache import ArcaneCache, MainMemory
from repro.core.regions import StridedRegion
from repro.sim import PipelinedRuntime


def _strip_program(**kw):
    """Strip-mined leakyrelu over interleaved column strips + a RAW chain."""
    rt = PipelinedRuntime(n_vpus=4, queue_capacity=32, **kw)
    cop = ArcaneCoprocessor(runtime=rt)
    w = ElemWidth.W
    rng = np.random.default_rng(7)
    a = cop.place(rng.integers(-5, 5, (32, 64)).astype(np.int32), w)
    out = cop.malloc(32 * 64 * 4)
    chain = cop.malloc(16 * 16 * 4)
    cop._xmr(w, 0, a, 64, 16, 16)
    cop._xmr(w, 3, chain, 16, 16, 16)
    cop._leakyrelu(w, 3, 0, alpha=0.5)
    for i in range(24):
        c0 = (i % 8) * 8
        cop._xmr(w, 0, a + c0 * 4, 64, 32, 8)
        cop._xmr(w, 3, out + c0 * 4, 64, 32, 8)
        cop._leakyrelu(w, 3, 0, alpha=0.5)
        cop._xmr(w, 0, chain, 16, 16, 16)
        cop._xmr(w, 3, chain, 16, 16, 16)
        cop._leakyrelu(w, 3, 0, alpha=-0.25)
    cop.barrier()
    cop.rt.cache.flush_all()
    return rt.sim_time, bytes(cop.rt.memory.data.tobytes())


def test_wakeup_rescan_and_brute_are_schedule_identical():
    """The three engines must agree on makespan AND the memory image, in
    every pipeline mode combination."""
    for mode in ({}, {"dataflow": False}, {"tiling": (4, 8)},
                 {"tiling": (2, 4), "reuse": True}, {"reuse": True}):
        fast = _strip_program(**mode)
        rescan = _strip_program(wakeup=False, **mode)
        with brute_force_queries():
            brute = _strip_program(wakeup=False, **mode)
        assert fast == rescan == brute, f"diverged in mode {mode}"


def test_reuse_invalidation_evicts_exactly_overlapped_entries():
    """Regression for the PR-5 satellite: a memory write must evict exactly
    the modeled copies it overlaps — across *all* VPUs — and nothing else.
    (The pre-index code scanned every VPU's whole FIFO; the index must reach
    the same set.)"""
    rt = PipelinedRuntime(n_vpus=2, vregs_per_vpu=8, vlen_bytes=1024,
                          reuse=True)
    strips = [StridedRegion(addr=i * 32, rows=8, row_bytes=32,
                            stride_bytes=256) for i in range(4)]
    far = StridedRegion(addr=1 << 16, rows=4, row_bytes=64, stride_bytes=64)
    for v in (0, 1):
        for i, r in enumerate(strips):
            rt._reuse_note(v, r, ready_at=10 * i)
        rt._reuse_note(v, far, ready_at=99)
    # A write landing on strip 1's bytes only (one row segment of strip 1).
    rt._note_memory_write(StridedRegion(addr=32, rows=1, row_bytes=8,
                                        stride_bytes=8))
    for v in (0, 1):
        assert rt._reuse_lookup(v, strips[1]) is None, "overlapped copy kept"
        for i in (0, 2, 3):
            assert rt._reuse_lookup(v, strips[i]) == 10 * i, \
                f"non-overlapped strip {i} wrongly evicted"
        assert rt._reuse_lookup(v, far) == 99
    # Byte accounting must survive the surgical eviction.
    for v in (0, 1):
        assert rt._reuse_bytes[v] == sum(
            e.region.nbytes for e in rt._reuse_entries[v].values())


def test_reuse_invalidation_whole_matrix_write_clears_all_strips():
    rt = PipelinedRuntime(n_vpus=1, vregs_per_vpu=8, reuse=True)
    strips = [StridedRegion(addr=i * 32, rows=8, row_bytes=32,
                            stride_bytes=256) for i in range(4)]
    for i, r in enumerate(strips):
        rt._reuse_note(0, r, ready_at=i)
    rt._note_memory_write(StridedRegion(addr=0, rows=1, row_bytes=2048,
                                        stride_bytes=2048))
    assert all(rt._reuse_lookup(0, r) is None for r in strips)
    assert rt._reuse_bytes[0] == 0 and not rt._reuse_entries[0]


def test_dma_bulk_paths_match_row_by_row_snooping():
    """dma_in_2d / dma_out_2d take a bulk numpy path when they can; the
    result must be indistinguishable from pure per-row snooping with dirty,
    clean, and busy lines scattered over the footprint."""
    def build():
        mem = MainMemory(1 << 16)
        rng = np.random.default_rng(11)
        mem.data[:] = rng.integers(0, 255, mem.size, dtype=np.uint8)
        c = ArcaneCache(mem, n_vpus=2, vregs_per_vpu=4, vlen_bytes=256)
        # Dirty lines over part of the source region (host writes), one
        # clean line (host read), and leave the rest uncached.
        c.host_write(0, rng.integers(0, 255, 300, dtype=np.uint8))  # dirty
        c.host_read(1024, 10)                                       # clean
        return c

    def reference_in(c, addr, rows, rb, sb):
        buf = np.empty(rows * rb, dtype=np.uint8)
        for r in range(rows):
            buf[r * rb:(r + 1) * rb] = c._snooped_read(addr + r * sb, rb)
        return buf

    addr, rows, rb, sb = 16, 8, 96, 192
    c1, c2 = build(), build()
    idxs1 = c1.claim_vregs(0, 3)
    got = c1.dma_in_2d(0, idxs1, addr, rows, rb, sb)
    want = reference_in(c2, addr, rows, rb, sb)
    assert got == rows * rb
    np.testing.assert_array_equal(
        c1._gather_from_lines(idxs1, rows * rb), want)

    # Write-back: bulk + snoop patch must leave cache+memory observationally
    # identical to the pure loop (flush both and compare full memory).
    c1, c2 = build(), build()
    i1, i2 = c1.claim_vregs(0, 3), c2.claim_vregs(0, 3)
    payload = np.random.default_rng(5).integers(
        0, 255, rows * rb, dtype=np.uint8)
    c1._scatter_to_lines(i1, payload)
    c2._scatter_to_lines(i2, payload)
    c1.dma_out_2d(0, i1, addr, rows, rb, sb)
    for r in range(rows):                      # reference: pure row loop
        c2._snooped_write(addr + r * sb, payload[r * rb:(r + 1) * rb])
    c1.release_vregs(i1)
    c2.release_vregs(i2)
    c1.flush_all()
    c2.flush_all()
    np.testing.assert_array_equal(c1.memory.data, c2.memory.data)


def test_report_carries_profiling_fields():
    rt = PipelinedRuntime(n_vpus=2, queue_capacity=8)
    cop = ArcaneCoprocessor(runtime=rt)
    w = ElemWidth.W
    a = cop.place(np.arange(64, dtype=np.int32).reshape(8, 8), w)
    out = cop.malloc(8 * 8 * 4)
    cop._xmr(w, 0, a, 8, 8, 8)
    cop._xmr(w, 3, out, 8, 8, 8)
    cop._leakyrelu(w, 3, 0, alpha=0.5)
    cop.barrier()
    rep = rt.report()
    assert rep.events_processed > 0
    assert rep.sim_seconds > 0.0
    assert rep.alias_queries > 0
    assert rep.alias_queries == rt.alias_queries_served()


def test_free_and_dirty_line_counters_stay_consistent():
    """The incremental per-VPU busy/dirty counters must track the flags."""
    mem = MainMemory(1 << 16)
    c = ArcaneCache(mem, n_vpus=2, vregs_per_vpu=4, vlen_bytes=256)
    rng = np.random.default_rng(3)

    def check():
        for v in range(2):
            assert c.free_line_count(v) == sum(
                1 for i in c.vpu_lines(v) if not c.lines[i].busy_computing)
            assert c.dirty_line_count(v) == sum(
                1 for i in c.vpu_lines(v) if c.lines[i].dirty)

    check()
    c.host_write(0, rng.integers(0, 255, 600, dtype=np.uint8))
    check()
    idxs = c.claim_vregs(0, 2)
    check()
    c.dma_out_2d(0, idxs, 128, 2, 100, 256)
    check()
    c.release_vregs(idxs)
    check()
    c.flush_all()
    check()
