"""Design-space exploration harness: overrides, grids, Pareto, workers.

Covers the four contracts the sweep stack makes:

* the dotted-override layer rejects duplicate keys and path conflicts at
  merge time (``ConfigError``, not a silently-last-wins config);
* grid expansion is deterministic — point IDs are a pure function of the
  grid and survive a rerun byte-for-byte;
* Pareto-front extraction is order-independent and handles the degenerate
  single-point / all-dominated shapes;
* the worker-pool path produces rows bit-identical to the in-process path.
"""
from __future__ import annotations

import pytest

from repro.dse import (SweepGrid, annotate_fronts, dominates, pareto_front,
                       run_points, scenario_kind, scenario_names)
from repro.sim.config import (ConfigError, apply_overrides,
                              config_from_overrides, merge_overrides)


# ------------------------------------------------------------- overrides
def test_apply_overrides_dotted_paths():
    raw = {"cache": {"n_vpus": 4}, "pipeline": {"row_chunk": 8}}
    out = apply_overrides(raw, {"cache.n_vpus": 2,
                                "pipeline.tiling.rows": 4,
                                "pipeline.tiling.cols": 16})
    assert out["cache"]["n_vpus"] == 2
    assert out["pipeline"]["tiling"] == {"rows": 4, "cols": 16}
    assert out["pipeline"]["row_chunk"] == 8
    # the input raw dict must be untouched (deep copy, not aliasing)
    assert raw["cache"]["n_vpus"] == 4 and "tiling" not in raw["pipeline"]


def test_apply_overrides_scalar_descent_raises():
    raw = {"cache": {"n_vpus": 4}}
    with pytest.raises(ConfigError, match="n_vpus"):
        apply_overrides(raw, {"cache.n_vpus.x": 1})


def test_merge_overrides_duplicate_key_raises():
    with pytest.raises(ConfigError, match="cache.n_vpus"):
        merge_overrides({"cache.n_vpus": 2}, {"cache.n_vpus": 4},
                        sources=["axis-a", "axis-b"])


def test_merge_overrides_prefix_conflict_raises():
    # one axis sets the tiling subtree, another a scalar on the same path
    with pytest.raises(ConfigError, match="pipeline.tiling"):
        merge_overrides({"pipeline.tiling": None},
                        {"pipeline.tiling.rows": 4})


def test_config_from_overrides_builds_simconfig():
    cfg = config_from_overrides("arcane-default",
                                {"cache.n_vpus": 2, "pipeline.row_chunk": 4})
    assert cfg.n_vpus == 2 and cfg.row_chunk == 4
    with pytest.raises(ConfigError):
        config_from_overrides("arcane-default", {"cache.bogus_knob": 1})


# ------------------------------------------------------------------ grid
def _grid(**kw):
    base = dict(
        base="arcane-default",
        scenarios=("cnn-small",),
        axes={"vpus": {"2": {"cache.n_vpus": 2}, "4": {"cache.n_vpus": 4}},
              "tile": {"0x0": {"pipeline.tiling.rows": 0,
                               "pipeline.tiling.cols": 0},
                       "4x16": {"pipeline.tiling.rows": 4,
                                "pipeline.tiling.cols": 16}}})
    base.update(kw)
    return SweepGrid(**base)


def test_grid_expansion_deterministic_ids():
    pts = _grid().expand(validate=False)
    ids = [p.point_id for p in pts]
    assert ids == ["cnn-small|vpus=2|tile=0x0", "cnn-small|vpus=2|tile=4x16",
                   "cnn-small|vpus=4|tile=0x0", "cnn-small|vpus=4|tile=4x16"]
    # pure function of the grid: a second expansion is identical
    assert [p.to_spec() for p in _grid().expand(validate=False)] == \
        [p.to_spec() for p in pts]


def test_grid_conflicting_axes_raise_at_expansion():
    g = _grid(axes={"a": {"x": {"cache.n_vpus": 2}},
                    "b": {"y": {"cache.n_vpus": 8}}})
    with pytest.raises(ConfigError, match="cache.n_vpus"):
        g.expand(validate=False)


def test_grid_unknown_scenario_and_bad_override():
    with pytest.raises(ConfigError, match="no-such-scenario"):
        _grid(scenarios=("no-such-scenario",)).expand()
    g = _grid(axes={"vpus": {"0": {"cache.n_vpus": 0}}})
    with pytest.raises(ConfigError):
        g.expand()            # validate=True builds each SimConfig


def test_grid_yaml_round_trip(tmp_path):
    g = _grid()
    d = g.to_dict()
    assert SweepGrid.from_dict(d).to_dict() == d
    import yaml
    p = tmp_path / "sweep.yaml"
    p.write_text(yaml.safe_dump(d))
    assert SweepGrid.from_yaml(str(p)).to_dict() == d


def test_scenario_catalog_lookup():
    assert scenario_kind("cnn-small") == "model"
    assert scenario_kind("serving-poisson") == "serving"
    with pytest.raises(KeyError):
        scenario_kind("nope")
    assert "cnn-paper" in scenario_names()


# ---------------------------------------------------------------- pareto
OBJ = (("makespan", "min"), ("area", "min"))


def _rows():
    return [
        {"point_id": "a", "makespan": 100, "area": 3.0},   # front
        {"point_id": "b", "makespan": 200, "area": 2.0},   # front
        {"point_id": "c", "makespan": 150, "area": 3.5},   # dom by a
        {"point_id": "d", "makespan": 100, "area": 3.0},   # tie with a: front
        {"point_id": "e", "makespan": 300, "area": 4.0},   # dom by a, b, c
    ]


def test_pareto_front_order_independent():
    import itertools
    expected = {"a", "b", "d"}
    rows = _rows()
    for perm in itertools.permutations(rows):
        front = pareto_front(list(perm), OBJ)
        assert {r["point_id"] for r in front} == expected, perm


def test_pareto_front_degenerate():
    one = [{"point_id": "only", "makespan": 10, "area": 1.0}]
    assert pareto_front(one, OBJ) == one
    assert pareto_front([], OBJ) == []
    # None-valued objectives are excluded, not crashed on
    rows = _rows() + [{"point_id": "n", "makespan": None, "area": 1.0}]
    assert "n" not in {r["point_id"] for r in pareto_front(rows, OBJ)}


def test_annotate_fronts_dominators():
    rows = _rows()
    front_ids = annotate_fronts(rows, OBJ)
    assert set(front_ids) == {"a", "b", "d"}
    by = {r["point_id"]: r for r in rows}
    assert by["a"]["on_front"] and by["a"]["dominated_by"] == []
    assert not by["c"]["on_front"] and by["c"]["dominated_by"] == ["a", "d"]
    assert by["e"]["dominated_by"] == ["a", "b", "c", "d"]


def test_dominates_max_sense():
    obj = (("goodput", "max"), ("area", "min"))
    hi = {"goodput": 2.0, "area": 1.0}
    lo = {"goodput": 1.0, "area": 1.0}
    assert dominates(hi, lo, obj) and not dominates(lo, hi, obj)
    assert not dominates(hi, hi, obj)      # equal never dominates


# --------------------------------------------------------------- workers
def test_pool_matches_in_process_bit_for_bit():
    specs = [p.to_spec() for p in
             _grid(axes={"vpus": {"2": {"cache.n_vpus": 2},
                                  "4": {"cache.n_vpus": 4}}}).expand()]
    assert len(specs) == 2
    seq = run_points(specs, in_process=True)
    pool = run_points(specs, jobs=2)
    assert seq == pool
    assert [r["point_id"] for r in pool] == [s["point_id"] for s in specs]
    assert all(r["verified"] and r["conservation_ok"] for r in pool)
