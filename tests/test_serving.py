"""Serving engine: greedy parity with manual decode + continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.engine import ArcaneEngine
from repro.models.transformer import LM
from repro.serving.engine import ServeSession

ENGINE = ArcaneEngine(backend="ref")


def manual_greedy(model, params, prompt, n_new, max_len=128):
    cache = model.init_cache(1, max_len)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(prompt[None])}, cache)
    toks = [int(jnp.argmax(logits, -1)[0])]
    step = jax.jit(model.decode_step)
    pos = len(prompt)
    for _ in range(n_new - 1):
        lg, cache = step(params, jnp.asarray([toks[-1]], jnp.int32),
                         jnp.asarray([pos], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg, -1)[0]))
        pos += 1
    return toks


def test_session_matches_manual_greedy(rng):
    cfg = get_smoke_config("stablelm-3b")
    model = LM(cfg, ENGINE)
    params = model.init_params(jax.random.key(0))
    prompts = [np.asarray(rng.integers(0, cfg.vocab, int(n)), np.int32)
               for n in (5, 9, 13)]
    expected = [manual_greedy(model, params, p, 6) for p in prompts]

    sess = ServeSession(model, params, max_slots=2, max_len=128)
    reqs = [sess.submit(p, max_new_tokens=6) for p in prompts]
    sess.run_to_completion()
    for req, exp in zip(reqs, expected):
        assert req.out_tokens == exp, (req.out_tokens, exp)


def test_continuous_batching_admits_when_slot_frees(rng):
    cfg = get_smoke_config("stablelm-3b")
    model = LM(cfg, ENGINE)
    params = model.init_params(jax.random.key(0))
    sess = ServeSession(model, params, max_slots=2, max_len=64)
    for i in range(5):
        sess.submit(rng.integers(0, cfg.vocab, 4), max_new_tokens=3)
    done = sess.run_to_completion()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 3 for r in done)


def test_ragged_lengths_isolated(rng):
    """Slot contents must not leak across sequences: same prompt in slot 0
    decodes identically regardless of the neighbour in slot 1."""
    cfg = get_smoke_config("gemma2-9b")
    model = LM(cfg, ENGINE)
    params = model.init_params(jax.random.key(0))
    p = np.asarray(rng.integers(0, cfg.vocab, 7), np.int32)
    other1 = np.asarray(rng.integers(0, cfg.vocab, 3), np.int32)
    other2 = np.asarray(rng.integers(0, cfg.vocab, 15), np.int32)

    def run_with(other):
        sess = ServeSession(model, params, max_slots=2, max_len=64)
        r = sess.submit(p, max_new_tokens=5)
        sess.submit(other, max_new_tokens=5)
        sess.run_to_completion()
        return r.out_tokens

    assert run_with(other1) == run_with(other2)
