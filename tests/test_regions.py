"""Exact 2D strided-region algebra: oracle equivalence + aliasing semantics."""
import pytest

from repro.core.encoding import ElemWidth
from repro.core.matrix import MatrixMap
from repro.core.regions import StridedRegion, footprints_overlap


def brute_overlap(a: StridedRegion, b: StridedRegion) -> bool:
    """Byte-set oracle (only viable for tiny regions)."""
    sa = {a.addr + i * a.stride_bytes + j
          for i in range(a.rows) for j in range(a.row_bytes)}
    sb = {b.addr + i * b.stride_bytes + j
          for i in range(b.rows) for j in range(b.row_bytes)}
    return bool(sa & sb)


# ------------------------------------------------------------ constructions
def test_validation():
    with pytest.raises(ValueError):
        StridedRegion(0, 0, 4, 4)
    with pytest.raises(ValueError):
        StridedRegion(0, 2, 0, 4)
    with pytest.raises(ValueError):
        StridedRegion(0, 2, 4, 0)          # multi-row needs a stride
    StridedRegion(0, 1, 4, 0)              # single row: stride unused


def test_geometry_properties():
    r = StridedRegion(addr=100, rows=3, row_bytes=8, stride_bytes=32)
    assert (r.start, r.end) == (100, 100 + 2 * 32 + 8)
    assert r.nbytes == 24
    assert r.row_interval(2) == (164, 172)
    with pytest.raises(IndexError):
        r.row_interval(3)


# ------------------------------------------------------- hand-picked cases
def test_equal_stride_column_strips_disjoint():
    left = StridedRegion(0, 4, 8, 32)
    right = StridedRegion(8, 4, 8, 32)
    assert not left.overlaps(right) and not right.overlaps(left)
    dense = StridedRegion(0, 4, 32, 32)
    assert left.overlaps(dense) and dense.overlaps(right)


def test_unequal_stride_interleaving_no_alias():
    """The case the old equal-stride-only refinement got wrong: different
    strides whose bounding intervals interleave but whose bytes never meet.
    a touches [0,8) mod 64; b touches [32,40) mod 128 — gcd(64,128)=64 and
    the residues keep them 24 bytes apart at closest approach."""
    a = StridedRegion(0, 8, 8, 64)
    b = StridedRegion(32, 4, 8, 128)
    assert a.start < b.end and b.start < a.end      # intervals do interleave
    assert not a.overlaps(b) and not b.overlaps(a)
    assert not brute_overlap(a, b)


def test_unequal_stride_true_alias_detected():
    a = StridedRegion(0, 8, 8, 48)
    b = StridedRegion(140, 3, 12, 100)              # row 1 of b hits row 5 of a
    assert brute_overlap(a, b)
    assert a.overlaps(b) and b.overlaps(a)


def test_band_wrapping_stride_period():
    """Bands wider than their phase window wrap the period — the old
    refinement refused to refine these; the algebra stays exact."""
    a = StridedRegion(28, 4, 10, 32)                # wraps: 28+10 > 32
    b = StridedRegion(8, 4, 10, 32)
    assert a.overlaps(b) == brute_overlap(a, b)
    c = StridedRegion(6, 4, 10, 32)                 # [6,16) vs [28,38)%32
    assert c.overlaps(a) == brute_overlap(c, a)


def test_self_overlapping_rows():
    """stride < row_bytes (rows overlap in memory) is legal for the algebra."""
    a = StridedRegion(0, 4, 10, 4)
    b = StridedRegion(20, 1, 2, 0)
    assert a.overlaps(b) == brute_overlap(a, b)


def test_partial_row_band_interval_checks():
    r = StridedRegion(100, 4, 8, 32)
    assert r.overlaps_interval(100, 101)            # first byte
    assert not r.overlaps_interval(108, 132)        # gap after row 0
    assert r.overlaps_interval(131, 133)            # clips row 1's first byte
    assert not r.overlaps_interval(0, 100)
    assert not r.overlaps_interval(100, 100)        # empty interval
    assert r.overlaps_interval(*r.row_interval(3))


def test_functional_form():
    assert footprints_overlap(0, 4, 8, 32, 8, 4, 8, 32) is False
    assert footprints_overlap(0, 4, 8, 32, 4, 4, 8, 32) is True


# -------------------------------------------------------- exhaustive sweeps
def test_exhaustive_small_regions_match_oracle():
    """Every (addr, rows, row_bytes, stride) pair in a small box — the
    decision procedure must agree with the byte-set oracle everywhere,
    including unequal strides, partial bands and wrap-arounds."""
    shapes = [(rows, rb, st)
              for rows in (1, 2, 3)
              for rb in (1, 2, 5)
              for st in (1, 3, 4, 7)]
    regions = [StridedRegion(addr, rows, rb, st)
               for addr in (0, 2, 5) for rows, rb, st in shapes]
    for a in regions:
        for b in regions:
            assert a.overlaps(b) == brute_overlap(a, b), (a, b)


def test_property_random_regions_match_oracle():
    hypothesis = pytest.importorskip("hypothesis")  # dev extra
    from hypothesis import given, settings, strategies as st

    region = st.builds(
        StridedRegion,
        addr=st.integers(0, 60),
        rows=st.integers(1, 8),
        row_bytes=st.integers(1, 12),
        stride_bytes=st.integers(1, 20),
    )

    @given(region, region)
    @settings(max_examples=300, deadline=None)
    def check(a, b):
        got = a.overlaps(b)
        assert got == brute_overlap(a, b)
        assert got == b.overlaps(a)                 # symmetry

    check()


# ----------------------------------------------- MatrixBinding integration
def test_matrix_binding_delegates_to_region():
    mm = MatrixMap()
    a = mm.reserve(0, addr=0, rows=8, cols=2, stride=16, width=ElemWidth.W)
    b = mm.reserve(1, addr=32, rows=4, cols=2, stride=32, width=ElemWidth.W)
    # a touches [0,8) mod 64; b touches [32,40) mod 128 — no shared byte
    # even though strides differ and the intervals interleave.
    assert not a.overlaps(b) and not b.overlaps(a)
    assert a.region.overlaps(a.region)
    # overlaps_range is exact too: the gap between a's rows is free
    assert not a.overlaps_range(8, 16)
    assert a.overlaps_range(0, 1) and a.overlaps_range(64, 65)
