"""Unified metrics layer: stall attribution, conservation, critical path.

Covers the observability tentpole's three invariants:

  * **conservation** — for every retired kernel, ``busy + Σ stall_bins ==
    dispatch-to-retire latency`` (``KernelStall.conserved``), across all five
    library kernels and serial / pipelined / tiled / reuse scheduler modes;
  * **critical-path bounds** — the extracted path's segments tile
    ``[0, makespan]`` exactly (``total == makespan``), busy cp cycles never
    exceed the makespan, and a pure RAW chain yields an idle-free path;
  * **observational purity** — a metrics-on run books the exact same
    schedule (makespan, resource intervals, memory image) as metrics-off.
"""
import numpy as np
import pytest

from repro.core import ArcaneCoprocessor, ElemWidth
from repro.core.runtime import CacheRuntime
from repro.sim import PipelinedRuntime
from repro.sim.metrics import (STALL_BINS, ActivityLog, Counter, Gauge,
                               Histogram, MetricsError, MetricsRegistry,
                               SchedulerMetrics, StallTable,
                               summarize_critical_path)

# ------------------------------------------------------------- workloads
GEOM = {"n_vpus": 2, "vregs_per_vpu": 32, "vlen_bytes": 512}

#: scheduler modes of the conservation sweep (ISSUE: serial / pipelined /
#: tiled / reuse)
MODES = [
    ("serial", None),
    ("pipelined", {}),
    ("pipelined", {"tiling": (4, 8)}),
    ("pipelined", {"tiling": (4, 8), "reuse": True}),
]


def make_cop(mode, pipe, **extra):
    if mode == "serial":
        return ArcaneCoprocessor(runtime=CacheRuntime(**GEOM, **extra))
    return ArcaneCoprocessor(
        runtime=PipelinedRuntime(**GEOM, **(pipe or {}), **extra))


def five_kernel_workload(cop, n=12):
    """One of each library kernel (leakyrelu / maxpool / gemm / conv2d /
    conv_layer) with shared operands — RAW edges plus reuse opportunities."""
    rng = np.random.default_rng(11)
    w = ElemWidth.W
    A = rng.integers(-9, 9, (n, n), dtype=np.int32)
    B = rng.integers(-9, 9, (n, n), dtype=np.int32)
    F = rng.integers(-3, 3, (3 * 3, 3), dtype=np.int32)
    aA, aB, aF = cop.place(A, w), cop.place(B, w), cop.place(F, w)
    aG = cop.malloc(n * n * 4)
    aL = cop.malloc(n * n * 4)
    aP = cop.malloc((n // 2) * (n // 2) * 4)
    aC = cop.malloc((n - 2) * (n - 2) * 4)
    h = n // 3
    om, on = (h - 3 + 1) // 2, (n - 3 + 1) // 2
    aY = cop.malloc(max(om * on * 4, 4))
    cop._xmr_w(0, aA, 0, n, n)
    cop._xmr_w(1, aB, 0, n, n)
    cop._xmr_w(2, aG, 0, n, n)
    cop._gemm_w(2, 0, 1, 2, alpha=1.0, beta=0.0)          # G = A @ B
    cop._xmr_w(0, aG, 0, n, n)
    cop._xmr_w(3, aL, 0, n, n)
    cop._leakyrelu(w, 3, 0, alpha=0.5)                    # L = relu(G): RAW
    cop._xmr_w(0, aL, 0, n, n)
    cop._xmr_w(4, aP, 0, n // 2, n // 2)
    cop._maxpool(w, 4, 0, 2, 2)                           # P = pool(L): RAW
    cop._xmr_w(0, aA, 0, n, n)
    cop._xmr_w(1, aF, 0, 3, 3)
    cop._xmr_w(3, aC, 0, n - 2, n - 2)
    cop._conv2d(w, 3, 0, 1)                               # C = A * f (reuse A)
    cop._xmr(w, 0, aA, n, 3 * h, n)
    cop._xmr_w(1, aF, 0, 9, 3)
    cop._xmr(w, 3, aY, on, om, on)
    cop._conv_layer(w, 3, 0, 1)                           # fused layer
    cop.barrier()
    return cop


def raw_chain_workload(cop, links=6, n=8):
    """Pure RAW chain: kernel i reads kernel i-1's destination."""
    rng = np.random.default_rng(3)
    w = ElemWidth.W
    prev = cop.place(rng.integers(-9, 9, (n, n), dtype=np.int32), w)
    for _ in range(links):
        dst = cop.malloc(n * n * 4)
        cop._xmr_w(0, prev, 0, n, n)
        cop._xmr_w(3, dst, 0, n, n)
        cop._leakyrelu(w, 3, 0, alpha=0.25)
        prev = dst
    cop.barrier()
    return cop


# ------------------------------------------------------ registry unit tests
def test_registry_types_and_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("a.count", "help")
    assert reg.counter("a.count") is c           # create-or-get
    c.inc(); c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("a.level")
    g.set(7); g.inc(); g.dec(3)
    assert g.value == 5
    h = reg.histogram("a.lat")
    for v in (0, 1, 2, 3, 900):
        h.observe(v)
    assert h.count == 5 and h.sum == 906 and h.min == 0 and h.max == 900
    assert h.buckets[0] == 1 and h.buckets[1] == 1 and h.buckets[2] == 2
    with pytest.raises(ValueError):
        h.observe(-2)
    for wrong in (reg.gauge, reg.histogram):
        with pytest.raises(MetricsError):
            wrong("a.count")
    d = reg.to_dict()
    assert d["counters"]["a.count"]["value"] == 4
    assert d["gauges"]["a.level"]["value"] == 5
    assert d["histograms"]["a.lat"]["mean"] == pytest.approx(906 / 5)


def test_stall_table_attribution_and_conservation():
    tab = StallTable()
    tab.decoded(0, ready=100, name="k")
    tab.blocked(0, 100, "raw_dep")       # examined right at ready
    tab.blocked(0, 160, "capacity")      # 60 cycles were raw_dep
    # dispatched at 200: 40 cycles capacity; then lock to 220, drain to 230,
    # piece gated at 250, runs [260, 300) (10 datapath-busy cycles)
    tab.dispatched(0, 200, vpu=1, lock_end=220, dma_start=230,
                   pieces=[(250, 260, 300)])
    rec = tab.retired(0, 300)
    assert rec.bins["raw_dep"] == 60 and rec.bins["capacity"] == 40
    assert rec.bins["cache_lock"] == 20 and rec.bins["drain"] == 10
    assert rec.bins["dma_wait"] == 20 and rec.bins["datapath_busy"] == 10
    assert rec.busy == 40 and rec.conserved() and rec.latency == 200


def test_stall_table_violation_raises():
    tab = StallTable()
    tab.decoded(1, ready=0, name="k")
    tab.dispatched(1, 0, vpu=0, lock_end=0, dma_start=0, pieces=[(0, 0, 10)])
    with pytest.raises(MetricsError, match="conservation"):
        tab.retired(1, 999)              # 989 unattributed cycles


def test_critical_path_tiles_handcrafted_graph():
    log = ActivityLog()
    log.add("decode", "preamble", "ecpu", 0, 100, kernel=0)
    log.add("dma", "allocation", "vpu0.dma", 100, 180, kernel=0, vpu=0)
    log.add("compute", "compute", "vpu0.datapath", 180, 400, kernel=0, vpu=0)
    # a shorter parallel activity that must NOT be on the path
    log.add("other", "compute", "vpu1.datapath", 100, 150, kernel=1, vpu=1)
    segs = log.critical_path(end_time=400)
    assert [s.resource for s in segs] == \
        ["vpu0.datapath", "vpu0.dma", "ecpu"][::-1] or \
        [s.resource for s in segs] == ["ecpu", "vpu0.dma", "vpu0.datapath"]
    summ = summarize_critical_path(segs, makespan=400)
    assert summ["covers_makespan"] and summ["total"] == 400
    assert summ["idle_cycles"] == 0
    assert summ["by_phase"]["compute"]["cycles"] == 220


def test_critical_path_bridges_idle_gaps():
    log = ActivityLog()
    log.add("a", "compute", "r", 0, 50)
    log.add("b", "compute", "r", 80, 120)       # nothing ends at 80
    summ = summarize_critical_path(log.critical_path(end_time=120), 120)
    assert summ["covers_makespan"] and summ["idle_cycles"] == 30


def test_empty_log_reports_none():
    m = SchedulerMetrics(enabled=True)
    rep = m.report(makespan=0)
    assert rep["critical_path"] is None and rep["conservation_ok"]


# --------------------------------------------- conservation across the stack
@pytest.mark.parametrize("mode,pipe", MODES)
def test_conservation_five_kernels(mode, pipe):
    cop = five_kernel_workload(make_cop(mode, pipe))
    rep = cop.rt.metrics_report()
    assert rep["enabled"] and rep["conservation_ok"]
    assert set(rep["kernels"]) == {"gemm", "leakyrelu", "maxpool", "conv2d",
                                   "conv_layer"}
    assert len(rep["per_kernel"]) == cop.rt.stats.kernels_run == 5
    for rec in rep["per_kernel"]:
        assert rec["busy"] > 0
        assert rec["busy"] + sum(rec["stalls"].values()) == rec["latency"] \
            or rec["fallback"]
        assert set(rec["stalls"]) == set(STALL_BINS)
    assert rep["counters"]["kernels.retired"]["value"] == 5


@pytest.mark.parametrize("mode,pipe", MODES[1:])
def test_critical_path_bounds(mode, pipe):
    cop = five_kernel_workload(make_cop(mode, pipe))
    rep = cop.rt.metrics_report()
    cp = rep["critical_path"]
    makespan = cop.rt.sim_time
    assert cp["makespan"] == makespan
    assert cp["cp_cycles"] <= makespan                 # cp lower-bounds it
    assert cp["covers_makespan"] and cp["total"] == makespan
    # segments tile [0, makespan] contiguously
    segs = cp["segments"]
    assert segs[0]["start"] == 0 and segs[-1]["end"] == makespan
    for a, b in zip(segs, segs[1:]):
        assert a["end"] == b["start"]
    assert sum(s["cycles"] for s in segs) == makespan
    fr = sum(d["fraction"] for d in cp["by_resource"].values())
    assert fr <= 1.0 + 1e-9


@pytest.mark.parametrize("pipe", [{}, {"tiling": (4, 8)}])
def test_pure_raw_chain_is_idle_free(pipe):
    """On a pure RAW chain every cycle is on the dependence chain: the
    critical path covers the makespan with zero idle bridging."""
    cop = raw_chain_workload(make_cop("pipelined", pipe))
    cp = cop.rt.metrics_report()["critical_path"]
    assert cp["covers_makespan"] and cp["idle_cycles"] == 0
    assert cp["cp_cycles"] == cop.rt.sim_time


def test_serial_report_has_no_event_timeline():
    cop = five_kernel_workload(make_cop("serial", None))
    rep = cop.rt.metrics_report()
    assert rep["conservation_ok"] and rep["critical_path"] is None
    assert rep["extra"]["kernels_run"] == 5


# -------------------------------------------------------- observational purity
@pytest.mark.parametrize("mode,pipe", MODES[1:])
def test_metrics_off_is_bit_identical(mode, pipe):
    on = five_kernel_workload(make_cop(mode, pipe, metrics=True))
    off = five_kernel_workload(make_cop(mode, pipe, metrics=False))
    assert on.rt.sim_time == off.rt.sim_time
    for r_on, r_off in zip(on.rt._all_resources(), off.rt._all_resources()):
        assert r_on.name == r_off.name
        assert [(iv.start, iv.end) for iv in r_on.intervals] == \
            [(iv.start, iv.end) for iv in r_off.intervals]
    on.rt.cache.flush_all()
    off.rt.cache.flush_all()
    np.testing.assert_array_equal(on.rt.memory.data, off.rt.memory.data)
    # off-mode hooks collected nothing
    rep = off.rt.metrics_report()
    assert not rep["enabled"] and not rep["per_kernel"] \
        and rep["critical_path"] is None


def test_config_metrics_knob():
    from repro.sim.config import SimConfig, load_config, load_raw
    cfg = load_config("arcane-default")
    assert cfg.metrics is True
    from repro.sim.config import builtin_config_path
    raw = load_raw(builtin_config_path("arcane-default"))
    raw["metrics"]["enabled"] = False
    rt = SimConfig.from_dict(raw).make_runtime(scheduler="pipelined")
    assert rt.metrics.enabled is False
    rt2 = cfg.make_runtime(scheduler="serial")
    assert rt2.metrics.enabled is True


# ------------------------------------------------------------ driver report
def test_fig4_report_point_matches_makespan():
    from benchmarks.fig4_speedup import metrics_report_point
    total, mrep = metrics_report_point(16, 3, ElemWidth.B, 4, "pipelined",
                                       tiling=(4, 8), reuse=True)
    assert mrep["conservation_ok"]
    cp = mrep["critical_path"]
    assert cp["covers_makespan"] and cp["total"] == total
    s_total, s_mrep = metrics_report_point(16, 3, ElemWidth.B, 4, "serial")
    assert s_mrep["conservation_ok"] and s_mrep["critical_path"] is None


# --------------------------------------------------- histogram percentiles
def test_histogram_percentile_nearest_rank():
    h = Histogram("lat")
    for v in [3, 10, 10, 100, 1000]:
        h.observe(v)
    # p50 -> rank 3 (the second 10): bucket upper edge 2^4-1 = 15
    assert h.p50 == 15
    # p99 -> rank 5 (1000): bucket [512, 1023], clamped to the observed max
    assert h.p99 == 1000
    assert h.percentile(0) == 3           # rank clamps to 1 -> min's bucket
    assert h.percentile(100) == 1000
    d = h.to_dict()
    assert d["p50"] == 15 and d["p99"] == 1000


def test_histogram_percentile_degenerate_and_bounds():
    h = Histogram("x")
    assert h.p50 == 0 and h.p99 == 0      # empty: 0, not an error
    h.observe(0)
    assert h.p50 == 0 and h.p99 == 0      # zeros live in bucket 0
    h2 = Histogram("y")
    h2.observe(42)
    assert h2.p50 == h2.p99 == 42         # single value: clamped to max
    with pytest.raises(ValueError, match="outside"):
        h2.percentile(101)
    with pytest.raises(ValueError, match="outside"):
        h2.percentile(-1)


def test_histogram_percentile_monotone_and_conservative():
    rng = np.random.default_rng(0)
    h = Histogram("m")
    vals = sorted(int(v) for v in rng.integers(0, 50_000, 500))
    for v in vals:
        h.observe(v)
    qs = [0, 10, 25, 50, 75, 90, 99, 100]
    ps = [h.percentile(q) for q in qs]
    assert ps == sorted(ps)               # monotone in q
    for q, p in zip(qs, ps):
        # conservative: an upper bound within the bucket's 2x resolution
        exact = vals[max(0, -(-len(vals) * q // 100) - 1)]
        assert exact <= p <= max(2 * exact, 1), (q, exact, p)


# ------------------------------------------------------ exact percentiles
def test_exact_percentile_fractional_q():
    """Regression: int(q) used to truncate fractional quantiles, so p99.9
    silently returned p99. Nearest-rank must rank on the float q."""
    from repro.sim.metrics import _exact_percentile
    vals = list(range(1, 1001))           # 1..1000, already the ranks
    assert _exact_percentile(vals, 99) == 990
    assert _exact_percentile(vals, 99.9) == 999
    assert _exact_percentile(vals, 99.9) != _exact_percentile(vals, 99)
    assert _exact_percentile(vals, 50) == 500
    assert _exact_percentile(vals, 100) == 1000
    assert _exact_percentile(vals, 0) == 1        # rank clamps to 1
    assert _exact_percentile([], 99.9) == 0.0
    # 1000 * 99.9 / 100 floats to 999.0000000000001; ceil must not bump the
    # rank to 1000
    assert _exact_percentile(vals, 99.99) == 1000  # ceil(999.9) = rank 1000
    assert _exact_percentile([7], 99.9) == 7
