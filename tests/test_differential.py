"""Differential scheduler fuzzing: random kernel programs, serial oracle.

The generator draws random programs over the whole kernel library (gemm /
conv2d / conv_layer / maxpool / leakyrelu) with random shapes, strided
sub-matrix views, aliased destinations, and random scheduler knobs
(row_chunk / dataflow / tiling / reuse / VPU geometry / queue capacity), then
asserts for every program:

  * **bit-identity** — the pipelined schedule's final memory image equals the
    serial scheduler's, byte for byte (after an LLC flush);
  * **makespan sanity** — the modeled makespan is bounded below by every
    single-server resource's busy cycles (the critical-path lower bound our
    resource model implies) and above by the serial sum of phases;
  * **no deadlock** — the event loop drains the queue, every admitted kernel
    retires, the Address Table empties, and per-resource busy intervals never
    overlap.

The core harness is plain seeded numpy (so it runs without the dev extra);
a hypothesis wrapper adds shrinking when hypothesis is installed. Locally the
loop covers 200 generated programs; under ``HYPOTHESIS_PROFILE=ci`` it is
capped to keep tier-1 inside the CI time budget.
"""
import os

import numpy as np
import pytest

from repro.core import ArcaneCoprocessor, ElemWidth
from repro.core.matrix import np_dtype
from repro.core.runtime import CacheRuntime
from repro.sim import PipelinedRuntime

KERNELS = ("leakyrelu", "maxpool", "gemm", "conv2d", "conv_layer")

#: program count of the seeded sweep: 200 locally (the acceptance floor),
#: capped under the CI profile (the hypothesis wrapper keeps fuzzing there).
N_PROGRAMS = 25 if os.environ.get("HYPOTHESIS_PROFILE") == "ci" else 200


# ------------------------------------------------------------ generation
def _draw_view(rng, pool, rows, cols, fresh_bias=0.5):
    """A (buf, r0, c0, rows, cols) view of shape (rows, cols): a random
    sub-rectangle of an existing pool buffer when one fits (strided /
    aliasing reads), else a fresh placed buffer (sometimes oversized, so the
    view is strided even then)."""
    fits = [i for i, (br, bc, _) in enumerate(pool)
            if br >= rows and bc >= cols]
    if fits and rng.random() > fresh_bias:
        i = int(rng.choice(fits))
        br, bc, _ = pool[i]
        return (i, int(rng.integers(0, br - rows + 1)),
                int(rng.integers(0, bc - cols + 1)), rows, cols)
    pad_r = int(rng.integers(0, 3))
    pad_c = int(rng.integers(0, 3))
    pool.append((rows + pad_r, cols + pad_c, "placed"))
    i = len(pool) - 1
    return (i, int(rng.integers(0, pad_r + 1)),
            int(rng.integers(0, pad_c + 1)), rows, cols)


def _draw_dst(rng, pool, rows, cols):
    """Destination view: usually a fresh exact buffer, sometimes an aliasing
    view over an existing buffer (WAW/WAR pressure)."""
    fits = [i for i, (br, bc, _) in enumerate(pool)
            if br >= rows and bc >= cols]
    if fits and rng.random() < 0.35:
        i = int(rng.choice(fits))
        br, bc, _ = pool[i]
        return (i, int(rng.integers(0, br - rows + 1)),
                int(rng.integers(0, bc - cols + 1)), rows, cols)
    pool.append((rows, cols, "dst"))
    return (len(pool) - 1, 0, 0, rows, cols)


def gen_program(seed: int) -> dict:
    """Draw one random program + scheduler-knob assignment."""
    rng = np.random.default_rng(seed)
    width = (ElemWidth.B, ElemWidth.H, ElemWidth.W)[int(rng.integers(3))]
    pool: list = []      # (rows, cols, origin)
    ops = []
    for _ in range(int(rng.integers(1, 5))):
        kind = KERNELS[int(rng.integers(len(KERNELS)))]
        if kind == "leakyrelu":
            r, c = int(rng.integers(3, 11)), int(rng.integers(3, 11))
            ops.append({"kind": kind,
                        "srcs": [_draw_view(rng, pool, r, c)],
                        "dst": _draw_dst(rng, pool, r, c),
                        "alpha": float(rng.integers(-8, 9)) / 4})
        elif kind == "maxpool":
            r, c = int(rng.integers(4, 11)), int(rng.integers(4, 11))
            win = int(rng.integers(2, min(r, c, 3) + 1))
            stride = int(rng.integers(1, win + 1))
            om, on = (r - win) // stride + 1, (c - win) // stride + 1
            ops.append({"kind": kind,
                        "srcs": [_draw_view(rng, pool, r, c)],
                        "dst": _draw_dst(rng, pool, om, on),
                        "win": win, "stride": stride})
        elif kind == "gemm":
            m, k, n = (int(rng.integers(2, 9)) for _ in range(3))
            ops.append({"kind": kind,
                        "srcs": [_draw_view(rng, pool, m, k),
                                 _draw_view(rng, pool, k, n),
                                 _draw_view(rng, pool, m, n)],
                        "dst": _draw_dst(rng, pool, m, n),
                        "alpha": float(rng.integers(1, 5)) / 2,
                        "beta": float(rng.integers(-2, 3)) / 2})
        elif kind == "conv2d":
            r, c = int(rng.integers(5, 11)), int(rng.integers(5, 11))
            km, kn = int(rng.integers(2, 4)), int(rng.integers(2, 4))
            ops.append({"kind": kind,
                        "srcs": [_draw_view(rng, pool, r, c),
                                 _draw_view(rng, pool, km, kn)],
                        "dst": _draw_dst(rng, pool, r - km + 1, c - kn + 1)})
        else:  # conv_layer
            h, w = int(rng.integers(6, 10)), int(rng.integers(6, 11))
            kk = int(rng.integers(2, 4))
            om, on = (h - kk + 1) // 2, (w - kk + 1) // 2
            ops.append({"kind": kind,
                        "srcs": [_draw_view(rng, pool, 3 * h, w),
                                 _draw_view(rng, pool, 3 * kk, kk)],
                        "dst": _draw_dst(rng, pool, om, on)})
    dataflow = bool(rng.random() < 0.8)
    tiling = (None, (0, 4), (3, 5), (2, 0))[int(rng.integers(4))] \
        if dataflow else None
    return {
        "seed": seed, "width": width, "pool": pool, "ops": ops,
        "rt": {"n_vpus": int(rng.choice((1, 2, 4))),
               "vregs_per_vpu": int(rng.choice((16, 32))),
               "vlen_bytes": int(rng.choice((256, 512))),
               "queue_capacity": int(rng.choice((2, 4, 16)))},
        "pipe": {"row_chunk": int(rng.choice((0, 1, 3, 8))),
                 "dataflow": dataflow, "tiling": tiling,
                 "reuse": bool(dataflow and rng.random() < 0.5),
                 # Both dispatch engines (wakeup-driven and legacy rescan)
                 # must produce the same schedule — fuzz them equally.
                 "wakeup": bool(rng.random() < 0.5)},
    }


def gen_chain_program(seed: int, n_ops: int = 64) -> dict:
    """A long RAW dependency chain: op i reads op i-1's strided result.

    ≥64 instructions — the long-program regime that was too slow to fuzz
    before the indexed wakeup scheduler made the stack fast (PR 5)."""
    rng = np.random.default_rng(seed)
    width = (ElemWidth.B, ElemWidth.H, ElemWidth.W)[int(rng.integers(3))]
    rows, cols = int(rng.integers(6, 10)), int(rng.integers(6, 10))
    pool: list = [(rows, cols, "placed")]
    ops = []
    prev = 0
    for _ in range(n_ops):
        pool.append((rows + 1, cols + 2, "dst"))     # oversized: strided dst
        dst = len(pool) - 1
        ops.append({"kind": "leakyrelu",
                    "srcs": [(prev, 0, 0, rows, cols)],
                    "dst": (dst, 0, 0, rows, cols),
                    "alpha": float(rng.integers(-8, 9)) / 4})
        prev = dst
    return {
        "seed": seed, "width": width, "pool": pool, "ops": ops,
        "rt": {"n_vpus": int(rng.choice((2, 4))),
               "vregs_per_vpu": 32,
               "vlen_bytes": int(rng.choice((256, 512))),
               "queue_capacity": int(rng.choice((16, 64)))},
        "pipe": {"row_chunk": int(rng.choice((0, 3, 8))),
                 "dataflow": True,
                 "tiling": (None, (2, 4))[int(rng.integers(2))],
                 "reuse": bool(rng.random() < 0.5),
                 "wakeup": bool(rng.random() < 0.5)},
    }


def _replay(prog: dict, cop) -> None:
    """Issue ``prog``'s instruction stream on an existing coprocessor."""
    width = prog["width"]
    eb = width.nbytes
    dt = np_dtype(width)
    data_rng = np.random.default_rng(prog["seed"] + 1)
    addrs, dims = [], []
    for rows, cols, origin in prog["pool"]:
        if origin == "placed":
            arr = data_rng.integers(-9, 9, (rows, cols)).astype(dt)
            addrs.append(cop.place(arr, width))
        else:
            addrs.append(cop.malloc(rows * cols * eb))
        dims.append((rows, cols))

    def bind(reg, view):
        buf, r0, c0, rows, cols = view
        bc = dims[buf][1]
        addr = addrs[buf] + (r0 * bc + c0) * eb
        cop._xmr(width, reg, addr, bc, rows, cols)

    for op in prog["ops"]:
        for reg, view in enumerate(op["srcs"]):
            bind(reg, view)
        bind(3, op["dst"])
        if op["kind"] == "leakyrelu":
            cop._leakyrelu(width, 3, 0, alpha=op["alpha"])
        elif op["kind"] == "maxpool":
            cop._maxpool(width, 3, 0, op["stride"], op["win"])
        elif op["kind"] == "gemm":
            cop._gemm(width, 3, 0, 1, 2, alpha=op["alpha"], beta=op["beta"])
        elif op["kind"] == "conv2d":
            cop._conv2d(width, 3, 0, 1)
        else:
            cop._conv_layer(width, 3, 0, 1)
    cop.barrier()


def run_program(prog: dict, scheduler: str):
    """Execute ``prog`` on a fresh runtime; returns the coprocessor."""
    if scheduler == "serial":
        cop = ArcaneCoprocessor(runtime=CacheRuntime(**prog["rt"]))
    else:
        cop = ArcaneCoprocessor(runtime=PipelinedRuntime(
            **prog["rt"], **prog["pipe"]))
    _replay(prog, cop)
    return cop


# -------------------------------------------------------------- the oracle
def check_program(seed: int, gen=gen_program):
    prog = gen(seed)
    cop_s = run_program(prog, "serial")
    cop_p = run_program(prog, "pipelined")
    rt = cop_p.rt

    # bit-identity of the full memory image (LLC flushed: write-back cache)
    cop_s.rt.cache.flush_all()
    rt.cache.flush_all()
    np.testing.assert_array_equal(cop_s.rt.memory.data, rt.memory.data,
                                  err_msg=f"seed {seed}: memory diverged")

    # no deadlock: queue drained, every kernel retired, AT empty
    assert not rt.queue, f"seed {seed}: queue not drained"
    assert rt.stats.kernels_run == len(prog["ops"]) \
        == cop_s.rt.stats.kernels_run
    assert rt.at.live_count() == 0
    assert not rt.tracker.runnable()     # no dangling dependency state

    # makespan bounds: >= every resource's busy time (single-server critical
    # path), >= the decode serialization, <= the serial sum of phases
    for r in rt._all_resources():
        ivs = sorted(r.intervals, key=lambda iv: (iv.start, iv.end))
        for a, b in zip(ivs, ivs[1:]):
            assert a.end <= b.start, \
                f"seed {seed}: {r.name} intervals overlap"
        assert r.busy_cycles <= rt.sim_time, \
            f"seed {seed}: {r.name} busier than the makespan"
        if ivs:
            assert ivs[-1].end <= rt.sim_time
    assert rt.sim_time >= len(prog["ops"]) * rt.geometry.decode_cycles
    assert rt.sim_time <= cop_s.rt.stats.total_cycles, \
        f"seed {seed}: pipelined makespan exceeded the serial schedule"


# ---------------------------------------------------------------- entries
@pytest.mark.parametrize("batch", range(8))
def test_differential_fuzz_seeded(batch):
    """Seeded sweep: N_PROGRAMS random programs against the serial oracle
    (8 parametrized batches so a failure pins a narrow seed range)."""
    per = (N_PROGRAMS + 7) // 8
    for seed in range(batch * per, min((batch + 1) * per, N_PROGRAMS)):
        check_program(seed)


def test_differential_long_chain():
    """≥64-instruction RAW chains against the serial oracle — the scenario
    the pre-index scheduler was too slow to fuzz routinely. Covers both
    dispatch engines (the generator draws `wakeup` at random)."""
    for seed in range(4):
        check_program(seed, gen=lambda s: gen_chain_program(s, 64 + 8 * s))


def test_differential_fuzz_hypothesis():
    """Hypothesis-driven wrapper over the same oracle: free shrinking to a
    minimal failing seed when the dev extra is installed."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=10 ** 6, max_value=2 ** 32 - 1))
    def prop(seed):
        check_program(seed)

    prop()


def test_differential_metrics_identity():
    """Metrics collection is purely observational: for random programs the
    metrics-off schedule is bit-identical to the metrics-on one — same
    makespan, same per-resource intervals, same flushed memory image — and
    the metrics-on run satisfies stall-cycle conservation."""
    for seed in range(12):
        prog = gen_program(seed)
        cops = {}
        for metrics in (True, False):
            cops[metrics] = cop = ArcaneCoprocessor(
                runtime=PipelinedRuntime(**prog["rt"], **prog["pipe"],
                                         metrics=metrics))
            _replay(prog, cop)
        on, off = cops[True].rt, cops[False].rt
        assert on.sim_time == off.sim_time, f"seed {seed}: makespan diverged"
        for r_on, r_off in zip(on._all_resources(), off._all_resources()):
            assert [(iv.start, iv.end) for iv in r_on.intervals] == \
                [(iv.start, iv.end) for iv in r_off.intervals], \
                f"seed {seed}: {r_on.name} schedule diverged"
        cops[True].rt.cache.flush_all()
        cops[False].rt.cache.flush_all()
        np.testing.assert_array_equal(
            on.memory.data, off.memory.data,
            err_msg=f"seed {seed}: memory diverged under metrics")
        rep = on.metrics_report()
        assert rep["conservation_ok"], f"seed {seed}: conservation violated"
        cp = rep.get("critical_path")
        if cp is not None:
            assert cp["covers_makespan"] and cp["total"] == on.sim_time, \
                f"seed {seed}: critical path does not tile the makespan"


def test_generator_covers_the_space():
    """The drawn programs genuinely mix kernels, widths, knobs, and aliased
    destinations — guards against the generator silently collapsing."""
    kinds, widths, aliased_dst = set(), set(), 0
    tilings, reuses, dataflows, wakeups = set(), set(), set(), set()
    for seed in range(80):
        prog = gen_program(seed)
        widths.add(prog["width"])
        tilings.add(prog["pipe"]["tiling"])
        reuses.add(prog["pipe"]["reuse"])
        dataflows.add(prog["pipe"]["dataflow"])
        wakeups.add(prog["pipe"]["wakeup"])
        for op in prog["ops"]:
            kinds.add(op["kind"])
            if prog["pool"][op["dst"][0]][2] == "placed" \
                    or op["dst"][1] or op["dst"][2]:
                aliased_dst += 1
    assert kinds == set(KERNELS)
    assert len(widths) == 3
    assert len(tilings) >= 3 and reuses == {True, False} \
        and dataflows == {True, False} and wakeups == {True, False}
    assert aliased_dst > 5
