"""Differential scheduler fuzzing: random kernel programs, serial oracle.

The generator draws random :class:`repro.core.KernelProgram` tapes over the
whole kernel library (gemm / conv2d / conv_layer / maxpool / leakyrelu) with
random shapes, strided sub-matrix views, aliased destinations, and random
scheduler knobs (row_chunk / dataflow / tiling / reuse / VPU geometry /
queue capacity), then asserts for every program:

  * **bit-identity** — the pipelined schedule's final memory image equals
    the serial scheduler's, byte for byte (after an LLC flush), and both
    match ``repro.core.reference_images`` — the sequential numpy oracle that
    executes the same tape with no cache, scheduler, or DMA model at all;
  * **makespan sanity** — the modeled makespan is bounded below by every
    single-server resource's busy cycles (the critical-path lower bound our
    resource model implies) and above by the serial sum of phases;
  * **no deadlock** — the event loop drains the queue, every admitted kernel
    retires, the Address Table empties, and per-resource busy intervals
    never overlap.

Programs are built and executed exclusively through the shared IR
(``repro.core.program``) — the replay loop that used to live here is now
``repro.core.run_program``, the same entry point the benchmarks and
examples use. The core harness is plain seeded numpy (so it runs without
the dev extra); a hypothesis wrapper adds shrinking when hypothesis is
installed. Locally the loop covers 200 generated programs; under
``HYPOTHESIS_PROFILE=ci`` it is capped to keep tier-1 inside the CI budget.
"""
import os

import numpy as np
import pytest

from repro.core import (ArcaneCoprocessor, Buffer, ElemWidth, KernelOp,
                        KernelProgram, View, reference_images, run_program)
from repro.core.runtime import CacheRuntime
from repro.sim import PipelinedRuntime

KERNELS = ("leakyrelu", "maxpool", "gemm", "conv2d", "conv_layer")

#: program count of the seeded sweep: 200 locally (the acceptance floor),
#: capped under the CI profile (the hypothesis wrapper keeps fuzzing there).
N_PROGRAMS = 25 if os.environ.get("HYPOTHESIS_PROFILE") == "ci" else 200


# ------------------------------------------------------------ generation
def _name(i: int) -> str:
    return f"b{i}"


def _draw_view(rng, pool, rows, cols, fresh_bias=0.5) -> View:
    """A view of shape (rows, cols): a random sub-rectangle of an existing
    pool buffer when one fits (strided / aliasing reads), else a fresh
    placed buffer (sometimes oversized, so the view is strided even then)."""
    fits = [i for i, (br, bc, _) in enumerate(pool)
            if br >= rows and bc >= cols]
    if fits and rng.random() > fresh_bias:
        i = int(rng.choice(fits))
        br, bc, _ = pool[i]
        return View(buf=_name(i), rows=rows, cols=cols,
                    row0=int(rng.integers(0, br - rows + 1)),
                    col0=int(rng.integers(0, bc - cols + 1)))
    pad_r = int(rng.integers(0, 3))
    pad_c = int(rng.integers(0, 3))
    pool.append((rows + pad_r, cols + pad_c, "placed"))
    return View(buf=_name(len(pool) - 1), rows=rows, cols=cols,
                row0=int(rng.integers(0, pad_r + 1)),
                col0=int(rng.integers(0, pad_c + 1)))


def _draw_dst(rng, pool, rows, cols) -> View:
    """Destination view: usually a fresh exact buffer, sometimes an aliasing
    view over an existing buffer (WAW/WAR pressure)."""
    fits = [i for i, (br, bc, _) in enumerate(pool)
            if br >= rows and bc >= cols]
    if fits and rng.random() < 0.35:
        i = int(rng.choice(fits))
        br, bc, _ = pool[i]
        return View(buf=_name(i), rows=rows, cols=cols,
                    row0=int(rng.integers(0, br - rows + 1)),
                    col0=int(rng.integers(0, bc - cols + 1)))
    pool.append((rows, cols, "dst"))
    return View(buf=_name(len(pool) - 1), rows=rows, cols=cols)


def _freeze(name: str, seed: int, width: ElemWidth, pool, ops
            ) -> KernelProgram:
    """Assemble the drawn pool/ops into a validated KernelProgram (placed
    buffers get per-buffer random seeds; dst buffers stay zeros)."""
    buffers = tuple(
        Buffer(name=_name(i), rows=r, cols=c,
               init="random" if origin == "placed" else "zeros",
               seed=seed * 4096 + i, lo=-9, hi=9)
        for i, (r, c, origin) in enumerate(pool))
    return KernelProgram(name=name, width=width, buffers=buffers,
                         ops=tuple(ops)).validate()


def gen_program(seed: int) -> dict:
    """Draw one random program + scheduler-knob assignment."""
    rng = np.random.default_rng(seed)
    width = (ElemWidth.B, ElemWidth.H, ElemWidth.W)[int(rng.integers(3))]
    pool: list = []      # (rows, cols, origin)
    ops = []
    for _ in range(int(rng.integers(1, 5))):
        kind = KERNELS[int(rng.integers(len(KERNELS)))]
        if kind == "leakyrelu":
            r, c = int(rng.integers(3, 11)), int(rng.integers(3, 11))
            ops.append(KernelOp(
                kernel=kind, srcs=(_draw_view(rng, pool, r, c),),
                dst=_draw_dst(rng, pool, r, c),
                params={"alpha": float(rng.integers(-8, 9)) / 4}))
        elif kind == "maxpool":
            r, c = int(rng.integers(4, 11)), int(rng.integers(4, 11))
            win = int(rng.integers(2, min(r, c, 3) + 1))
            stride = int(rng.integers(1, win + 1))
            om, on = (r - win) // stride + 1, (c - win) // stride + 1
            ops.append(KernelOp(
                kernel=kind, srcs=(_draw_view(rng, pool, r, c),),
                dst=_draw_dst(rng, pool, om, on),
                params={"stride": stride, "win_size": win}))
        elif kind == "gemm":
            m, k, n = (int(rng.integers(2, 9)) for _ in range(3))
            ops.append(KernelOp(
                kernel=kind,
                srcs=(_draw_view(rng, pool, m, k),
                      _draw_view(rng, pool, k, n),
                      _draw_view(rng, pool, m, n)),
                dst=_draw_dst(rng, pool, m, n),
                params={"alpha": float(rng.integers(1, 5)) / 2,
                        "beta": float(rng.integers(-2, 3)) / 2}))
        elif kind == "conv2d":
            r, c = int(rng.integers(5, 11)), int(rng.integers(5, 11))
            km, kn = int(rng.integers(2, 4)), int(rng.integers(2, 4))
            ops.append(KernelOp(
                kernel=kind,
                srcs=(_draw_view(rng, pool, r, c),
                      _draw_view(rng, pool, km, kn)),
                dst=_draw_dst(rng, pool, r - km + 1, c - kn + 1)))
        else:  # conv_layer
            h, w = int(rng.integers(6, 10)), int(rng.integers(6, 11))
            kk = int(rng.integers(2, 4))
            om, on = (h - kk + 1) // 2, (w - kk + 1) // 2
            ops.append(KernelOp(
                kernel=kind,
                srcs=(_draw_view(rng, pool, 3 * h, w),
                      _draw_view(rng, pool, 3 * kk, kk)),
                dst=_draw_dst(rng, pool, om, on)))
    dataflow = bool(rng.random() < 0.8)
    tiling = (None, (0, 4), (3, 5), (2, 0))[int(rng.integers(4))] \
        if dataflow else None
    return {
        "seed": seed,
        "program": _freeze(f"fuzz{seed}", seed, width, pool, ops),
        "rt": {"n_vpus": int(rng.choice((1, 2, 4))),
               "vregs_per_vpu": int(rng.choice((16, 32))),
               "vlen_bytes": int(rng.choice((256, 512))),
               "queue_capacity": int(rng.choice((2, 4, 16)))},
        "pipe": {"row_chunk": int(rng.choice((0, 1, 3, 8))),
                 "dataflow": dataflow, "tiling": tiling,
                 "reuse": bool(dataflow and rng.random() < 0.5),
                 # Both dispatch engines (wakeup-driven and legacy rescan)
                 # must produce the same schedule — fuzz them equally.
                 "wakeup": bool(rng.random() < 0.5)},
    }


def gen_chain_program(seed: int, n_ops: int = 64) -> dict:
    """A long RAW dependency chain: op i reads op i-1's strided result.

    ≥64 instructions — the long-program regime that was too slow to fuzz
    before the indexed wakeup scheduler made the stack fast (PR 5)."""
    rng = np.random.default_rng(seed)
    width = (ElemWidth.B, ElemWidth.H, ElemWidth.W)[int(rng.integers(3))]
    rows, cols = int(rng.integers(6, 10)), int(rng.integers(6, 10))
    pool: list = [(rows, cols, "placed")]
    ops = []
    prev = 0
    for _ in range(n_ops):
        pool.append((rows + 1, cols + 2, "dst"))     # oversized: strided dst
        dst = len(pool) - 1
        ops.append(KernelOp(
            kernel="leakyrelu",
            srcs=(View(buf=_name(prev), rows=rows, cols=cols),),
            dst=View(buf=_name(dst), rows=rows, cols=cols),
            params={"alpha": float(rng.integers(-8, 9)) / 4}))
        prev = dst
    return {
        "seed": seed,
        "program": _freeze(f"chain{seed}", seed, width, pool, ops),
        "rt": {"n_vpus": int(rng.choice((2, 4))),
               "vregs_per_vpu": 32,
               "vlen_bytes": int(rng.choice((256, 512))),
               "queue_capacity": int(rng.choice((16, 64)))},
        "pipe": {"row_chunk": int(rng.choice((0, 3, 8))),
                 "dataflow": True,
                 "tiling": (None, (2, 4))[int(rng.integers(2))],
                 "reuse": bool(rng.random() < 0.5),
                 "wakeup": bool(rng.random() < 0.5)},
    }


def _run(prog: dict, scheduler: str):
    """Execute the program on a fresh runtime through the shared IR entry
    point; returns the :class:`repro.core.ProgramRun`."""
    if scheduler == "serial":
        rt = CacheRuntime(**prog["rt"])
    else:
        rt = PipelinedRuntime(**prog["rt"], **prog["pipe"])
    return run_program(rt, prog["program"])


# -------------------------------------------------------------- the oracle
def check_identity(program: KernelProgram, rt_kwargs: dict,
                   pipe_kwargs: dict, tag: str = "") -> None:
    """Serial ≡ pipelined ≡ functional-oracle bit-identity for one program
    (shared with the lowered-program corpus in test_lower.py)."""
    prog = {"program": program, "rt": rt_kwargs, "pipe": pipe_kwargs}
    run_s = _run(prog, "serial")
    run_p = _run(prog, "pipelined")
    run_s.rt.cache.flush_all()
    run_p.rt.cache.flush_all()
    np.testing.assert_array_equal(run_s.rt.memory.data, run_p.rt.memory.data,
                                  err_msg=f"{tag}: memory diverged")
    ref = reference_images(program)
    imgs = run_p.flushed_images()
    for name, arr in ref.items():
        np.testing.assert_array_equal(
            imgs[name], arr,
            err_msg=f"{tag}: buffer {name} diverged from the numpy oracle")


def check_program(seed: int, gen=gen_program):
    prog = gen(seed)
    run_s = _run(prog, "serial")
    run_p = _run(prog, "pipelined")
    rt = run_p.rt
    n_ops = prog["program"].n_ops

    # bit-identity of the full memory image (LLC flushed: write-back cache)
    run_s.rt.cache.flush_all()
    rt.cache.flush_all()
    np.testing.assert_array_equal(run_s.rt.memory.data, rt.memory.data,
                                  err_msg=f"seed {seed}: memory diverged")

    # functional oracle: the scheduled result equals a sequential numpy
    # execution of the same tape (no cache/DMA model at all)
    ref = reference_images(prog["program"])
    imgs = run_p.flushed_images()
    for name, arr in ref.items():
        np.testing.assert_array_equal(
            imgs[name], arr,
            err_msg=f"seed {seed}: buffer {name} diverged from the oracle")

    # no deadlock: queue drained, every kernel retired, AT empty
    assert not rt.queue, f"seed {seed}: queue not drained"
    assert rt.stats.kernels_run == n_ops == run_s.rt.stats.kernels_run
    assert rt.at.live_count() == 0
    assert not rt.tracker.runnable()     # no dangling dependency state

    # makespan bounds: >= every resource's busy time (single-server critical
    # path), >= the decode serialization, <= the serial sum of phases
    for r in rt._all_resources():
        ivs = sorted(r.intervals, key=lambda iv: (iv.start, iv.end))
        for a, b in zip(ivs, ivs[1:]):
            assert a.end <= b.start, \
                f"seed {seed}: {r.name} intervals overlap"
        assert r.busy_cycles <= rt.sim_time, \
            f"seed {seed}: {r.name} busier than the makespan"
        if ivs:
            assert ivs[-1].end <= rt.sim_time
    assert rt.sim_time >= n_ops * rt.geometry.decode_cycles
    assert rt.sim_time <= run_s.rt.stats.total_cycles, \
        f"seed {seed}: pipelined makespan exceeded the serial schedule"


# ---------------------------------------------------------------- entries
@pytest.mark.parametrize("batch", range(8))
def test_differential_fuzz_seeded(batch):
    """Seeded sweep: N_PROGRAMS random programs against the serial oracle
    (8 parametrized batches so a failure pins a narrow seed range)."""
    per = (N_PROGRAMS + 7) // 8
    for seed in range(batch * per, min((batch + 1) * per, N_PROGRAMS)):
        check_program(seed)


def test_differential_long_chain():
    """≥64-instruction RAW chains against the serial oracle — the scenario
    the pre-index scheduler was too slow to fuzz routinely. Covers both
    dispatch engines (the generator draws `wakeup` at random)."""
    for seed in range(4):
        check_program(seed, gen=lambda s: gen_chain_program(s, 64 + 8 * s))


def test_differential_fuzz_hypothesis():
    """Hypothesis-driven wrapper over the same oracle: free shrinking to a
    minimal failing seed when the dev extra is installed."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=10 ** 6, max_value=2 ** 32 - 1))
    def prop(seed):
        check_program(seed)

    prop()


def test_differential_metrics_identity():
    """Metrics collection is purely observational: for random programs the
    metrics-off schedule is bit-identical to the metrics-on one — same
    makespan, same per-resource intervals, same flushed memory image — and
    the metrics-on run satisfies stall-cycle conservation."""
    for seed in range(12):
        prog = gen_program(seed)
        cops = {}
        for metrics in (True, False):
            cops[metrics] = cop = ArcaneCoprocessor(
                runtime=PipelinedRuntime(**prog["rt"], **prog["pipe"],
                                         metrics=metrics))
            run_program(cop, prog["program"])
        on, off = cops[True].rt, cops[False].rt
        assert on.sim_time == off.sim_time, f"seed {seed}: makespan diverged"
        for r_on, r_off in zip(on._all_resources(), off._all_resources()):
            assert [(iv.start, iv.end) for iv in r_on.intervals] == \
                [(iv.start, iv.end) for iv in r_off.intervals], \
                f"seed {seed}: {r_on.name} schedule diverged"
        cops[True].rt.cache.flush_all()
        cops[False].rt.cache.flush_all()
        np.testing.assert_array_equal(
            on.memory.data, off.memory.data,
            err_msg=f"seed {seed}: memory diverged under metrics")
        rep = on.metrics_report()
        assert rep["conservation_ok"], f"seed {seed}: conservation violated"
        cp = rep.get("critical_path")
        if cp is not None:
            assert cp["covers_makespan"] and cp["total"] == on.sim_time, \
                f"seed {seed}: critical path does not tile the makespan"


def test_generator_covers_the_space():
    """The drawn programs genuinely mix kernels, widths, knobs, and aliased
    destinations — guards against the generator silently collapsing."""
    kinds, widths, aliased_dst = set(), set(), 0
    tilings, reuses, dataflows, wakeups = set(), set(), set(), set()
    for seed in range(80):
        prog = gen_program(seed)
        program = prog["program"]
        by_name = {b.name: b for b in program.buffers}
        widths.add(program.width)
        tilings.add(prog["pipe"]["tiling"])
        reuses.add(prog["pipe"]["reuse"])
        dataflows.add(prog["pipe"]["dataflow"])
        wakeups.add(prog["pipe"]["wakeup"])
        for op in program.ops:
            kinds.add(op.kernel)
            if by_name[op.dst.buf].init == "random" \
                    or op.dst.row0 or op.dst.col0:
                aliased_dst += 1
    assert kinds == set(KERNELS)
    assert len(widths) == 3
    assert len(tilings) >= 3 and reuses == {True, False} \
        and dataflows == {True, False} and wakeups == {True, False}
    assert aliased_dst > 5


# ----------------------------------------------------------- fault plans
def gen_fault_schedule(seed: int, n_ops: int):
    """A seeded *recoverable* fault schedule over kernel ids 0..n_ops-1:
    ~70% of kernels take a fault, drawn over all three recoverable kinds
    (single/double-bit ECC, 1–3 corrupt-replay attempts), always within the
    replay budget so no VPU is ever offlined."""
    from repro.sim import FaultConfig
    rng = np.random.default_rng(seed + 999)
    entries = []
    for kid in range(n_ops):
        if rng.random() < 0.3:
            continue
        kind = ("single", "double", "corrupt")[int(rng.integers(3))]
        ent = {"kernel": kid, "kind": kind}
        if kind == "corrupt":
            ent["replays"] = int(rng.integers(1, 4))
        entries.append(ent)
    return FaultConfig(schedule=tuple(entries), max_replays=4,
                       ecc_penalty=17, replay_backoff=23)


def check_fault_program(seed: int, gen=gen_program):
    """Recoverable-fault differential oracle: for both schedulers, a seeded
    fault schedule over a random program must flush a memory image
    byte-identical to the fault-free run, retire every kernel without
    deadlock or offlining, and keep per-kernel stall conservation
    (including the ``fault_replay`` bin) intact."""
    prog = gen(seed)
    n_ops = prog["program"].n_ops
    fc = gen_fault_schedule(seed, n_ops)
    for sched in ("serial", "pipelined"):
        clean = _run(prog, sched)
        if sched == "serial":
            rt = CacheRuntime(**prog["rt"], faults=fc)
        else:
            rt = PipelinedRuntime(**prog["rt"], **prog["pipe"], faults=fc,
                                  metrics=True)
        faulted = run_program(rt, prog["program"])
        clean.rt.cache.flush_all()
        rt.cache.flush_all()
        np.testing.assert_array_equal(
            clean.rt.memory.data, rt.memory.data,
            err_msg=f"seed {seed}: {sched} memory diverged under "
                    f"recoverable faults")
        assert rt.stats.kernels_run == n_ops, \
            f"seed {seed}: {sched} lost kernels under faults"
        assert not rt.queue and not rt.offline
        if sched == "pipelined":
            assert rt.at.live_count() == 0
            assert rt.metrics.stalls.conservation_ok(), \
                f"seed {seed}: fault_replay broke stall conservation"
            if fc.schedule:
                c = rt.metrics_report()["counters"]
                assert c.get("faults.injected", {}).get("value", 0) > 0, \
                    f"seed {seed}: schedule injected nothing"


@pytest.mark.parametrize("batch", range(4))
def test_fault_differential_fuzz(batch):
    """Fuzz: seeded recoverable fault plans over random programs are
    bit-identical to the fault-free runs on both schedulers."""
    per = (max(N_PROGRAMS // 2, 12) + 3) // 4
    for seed in range(batch * per, (batch + 1) * per):
        check_fault_program(seed)


def test_fault_differential_long_chain():
    for seed in range(2):
        check_fault_program(seed, gen=lambda s: gen_chain_program(s, 48))


# --------------------------------------------------- session equivalence
def _session_run(prog: dict, scheduler: str, *, at=None,
                 queue_capacity=None):
    """Issue the whole tape through an *open* RuntimeSession at t0 (or
    ``at``), then drain; returns ``(rt, handle)``."""
    from repro.core.session import RuntimeSession
    rt_kwargs = dict(prog["rt"])
    if queue_capacity is not None:
        rt_kwargs["queue_capacity"] = queue_capacity
    if scheduler == "serial":
        rt = CacheRuntime(**rt_kwargs)
    else:
        rt = PipelinedRuntime(**rt_kwargs, **prog["pipe"])
    sess = RuntimeSession(rt)
    h = sess.issue(prog["program"], at=at)
    sess.drain()
    return rt, h


def check_session_t0(seed: int, gen=gen_program):
    """Open-session-at-t0 vs the legacy batch path, on both runtimes.

    With the tape inside the issue-queue capacity the two paths admit
    identically, so the session run must be **bit-identical**: same
    makespan, same per-resource busy intervals, same flushed memory image.
    With backpressure (capacity < n_ops) the legacy path drains eagerly in
    chunks (settle barriers between them) while the open session hands the
    event scheduler the whole dependency graph — the memory image must
    still match byte for byte and the open makespan can only *improve* on
    the chunked schedule, never exceed it."""
    prog = gen(seed)
    n_ops = prog["program"].n_ops
    ample = max(prog["rt"]["queue_capacity"], n_ops + 1)
    for sched in ("serial", "pipelined"):
        # --- no-backpressure regime: exact bit-identity ---------------
        legacy = _run({**prog, "rt": {**prog["rt"],
                                      "queue_capacity": ample}}, sched)
        rt, h = _session_run(prog, sched, queue_capacity=ample)
        assert h.done and h.kernel_ids and len(h.kernel_ids) == n_ops
        assert rt.stats.kernels_run == n_ops
        if sched == "pipelined":
            assert rt.sim_time == legacy.rt.sim_time, \
                f"seed {seed}: session makespan diverged from batch"
            for r_s, r_l in zip(rt._all_resources(),
                                legacy.rt._all_resources()):
                assert [(iv.start, iv.end) for iv in r_s.intervals] == \
                    [(iv.start, iv.end) for iv in r_l.intervals], \
                    f"seed {seed}: {r_s.name} schedule diverged"
        assert rt.stats.total_cycles == legacy.rt.stats.total_cycles, \
            f"seed {seed}: session cycle count diverged from batch"
        legacy.rt.cache.flush_all()
        rt.cache.flush_all()
        np.testing.assert_array_equal(
            legacy.rt.memory.data, rt.memory.data,
            err_msg=f"seed {seed}: session memory image diverged ({sched})")

        # --- native capacity: backpressure may chunk the legacy path --
        legacy_n = _run(prog, sched)
        rt_n, h_n = _session_run(prog, sched)
        assert h_n.done and rt_n.stats.kernels_run == n_ops
        legacy_n.rt.cache.flush_all()
        rt_n.cache.flush_all()
        np.testing.assert_array_equal(
            legacy_n.rt.memory.data, rt_n.memory.data,
            err_msg=f"seed {seed}: backpressured session memory diverged")
        if sched == "pipelined":
            assert not rt_n.queue and rt_n.at.live_count() == 0
            assert rt_n.sim_time <= legacy_n.rt.sim_time, \
                f"seed {seed}: open admission lost to the chunked schedule"


@pytest.mark.parametrize("batch", range(4))
def test_session_t0_differential_fuzz(batch):
    """Fuzz: a whole program issued at t0 through an open session is
    bit-identical to the legacy batch path (see check_session_t0)."""
    per = (max(N_PROGRAMS // 2, 12) + 3) // 4
    for seed in range(batch * per, (batch + 1) * per):
        check_session_t0(seed)


def test_session_staggered_arrivals_no_deadlock():
    """Programs injected at spaced future sim times — idle gaps between
    them — must all retire (no deadlock), produce oracle-identical buffer
    images, and keep per-kernel stall attribution conserved across the
    gaps."""
    from repro.core.session import RuntimeSession
    seeds = (3, 11, 27, 42)
    gap = 50_000                      # far beyond any single tape's makespan
    for sched in ("serial", "pipelined"):
        if sched == "serial":
            rt = CacheRuntime(n_vpus=2, queue_capacity=8)
        else:
            rt = PipelinedRuntime(n_vpus=2, queue_capacity=8, metrics=True)
        sess = RuntimeSession(rt)
        handles, done_log = [], []

        def arrive(prog, t):
            h = sess.issue(prog["program"],
                           on_done=lambda tt: done_log.append(tt))
            handles.append((prog, h))

        for i, seed in enumerate(seeds):
            prog = gen_program(seed)
            sess.post(i * gap, lambda t, p=prog: arrive(p, t))
        sess.drain()

        # every arrival fired, every program retired, nothing wedged
        assert len(handles) == len(seeds) == len(done_log)
        total_ops = sum(p["program"].n_ops for p, _ in handles)
        assert rt.stats.kernels_run == total_ops
        assert not rt.queue
        for p, h in handles:
            assert h.done and h.done_at >= h.issued_at
        # arrivals at i*gap: each program's completion lands in its own gap
        for i, (p, h) in enumerate(handles):
            assert h.issued_at >= i * gap
            assert h.done_at < (i + 1) * gap, \
                "a tape leaked across its idle gap"

        # oracle identity per program (buffers placed per-issue, so gather
        # through each handle's own address map)
        rt.cache.flush_all()
        from repro.core.program import np_dtype
        for p, h in handles:
            ref = reference_images(p["program"])
            dt = np_dtype(p["program"].width)
            for b in p["program"].buffers:
                a = h.addrs[b.name]
                raw = rt.memory.data[a:a + b.nbytes(p["program"].width)]
                np.testing.assert_array_equal(
                    raw.copy().view(dt).reshape(b.rows, b.cols), ref[b.name],
                    err_msg=f"{sched}: {b.name} diverged after staggered run")

        if sched == "pipelined":
            assert rt.at.live_count() == 0
            assert rt.sim_time >= (len(seeds) - 1) * gap
            # stall conservation must hold across the idle gaps: every
            # kernel's latency tiles exactly into busy + attributed stalls
            assert rt.metrics.stalls.conservation_ok(), \
                "stall attribution leaked across idle gaps"


def test_session_advance_respects_horizon():
    """advance(until=t) runs exactly the work due by t: an op posted later
    stays pending, the clock lands on t, and a later drain finishes it."""
    from repro.core.session import RuntimeSession
    prog1, prog2 = gen_program(5), gen_program(9)
    rt = PipelinedRuntime(n_vpus=2, queue_capacity=8, metrics=True)
    sess = RuntimeSession(rt)
    h1 = sess.issue(prog1["program"])
    issued = []
    sess.post(200_000, lambda t: issued.append(
        sess.issue(prog2["program"])))
    sess.advance(until=100_000)
    assert h1.done and h1.done_at <= 100_000
    assert sess.now() == 100_000
    assert not issued                      # the posted arrival is still due
    sess.drain()
    assert issued and issued[0].done
    assert issued[0].issued_at >= 200_000
    assert rt.metrics.stalls.conservation_ok()
