"""Dependency tracking + renaming (the paper's hazard checker)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev extra; suite runs without it
from hypothesis import given, settings, strategies as st

from repro.core.encoding import ElemWidth
from repro.core.hazards import DependencyTracker
from repro.core.matrix import MatrixMap


def bind(mm, logical, addr, rows=4, cols=4):
    return mm.reserve(logical, addr, rows, cols, cols, ElemWidth.W)


def test_raw_dependency():
    mm, tr = MatrixMap(), DependencyTracker()
    a = bind(mm, 0, 0)
    b = bind(mm, 1, 1000)
    d = bind(mm, 2, 2000)
    k0 = tr.admit([a, b], d)                 # d = f(a, b)
    e = bind(mm, 3, 3000)
    k1 = tr.admit([mm.lookup(2)], e)         # e = g(d) → RAW on d
    assert k0.kernel_id in k1.depends_on
    assert not tr.ready(k1.kernel_id)
    tr.complete(k0.kernel_id)
    assert tr.ready(k1.kernel_id)


def test_renaming_removes_war_waw():
    """xmr rebinding a logical register mints a fresh physical id, so a
    kernel reading the OLD binding does not conflict with a kernel writing
    the NEW one (different memory)."""
    mm, tr = MatrixMap(), DependencyTracker()
    a_old = bind(mm, 0, 0)
    dst1 = bind(mm, 1, 1000)
    k0 = tr.admit([a_old], dst1)
    # program reuses m0 for a DIFFERENT matrix (new xmr, new address)
    a_new = bind(mm, 0, 4000)
    assert a_new.phys_id != a_old.phys_id
    dst2 = bind(mm, 2, 2000)
    k1 = tr.admit([a_new], dst2)
    assert k0.kernel_id not in k1.depends_on   # renamed: no false WAR


def test_waw_same_physical_destination():
    mm, tr = MatrixMap(), DependencyTracker()
    a = bind(mm, 0, 0)
    d = bind(mm, 1, 1000)
    k0 = tr.admit([a], d)
    k1 = tr.admit([a], d)                      # same physical dst, no re-xmr
    assert k0.kernel_id in k1.depends_on


def test_memory_aliasing_dependency():
    mm, tr = MatrixMap(), DependencyTracker()
    a = bind(mm, 0, 0)
    d1 = bind(mm, 1, 1000)
    k0 = tr.admit([a], d1)
    # new binding overlapping d1's footprint (bytes [1000, 1064))
    alias = bind(mm, 2, 1032)
    d2 = bind(mm, 3, 5000)
    k1 = tr.admit([alias], d2)                 # reads memory k0 writes
    assert k0.kernel_id in k1.depends_on


@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5),
                          st.integers(0, 5)), min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_dag_acyclic_and_drains(ops):
    """Property: any admission sequence yields an acyclic DAG that fully
    drains when completing ready kernels repeatedly."""
    mm, tr = MatrixMap(), DependencyTracker()
    addr = [i * 512 for i in range(6)]
    for s1, s2, d in ops:
        a = bind(mm, s1, addr[s1])
        b = bind(mm, s2, addr[s2])
        dst = bind(mm, d, addr[d])
        tr.admit([a, b], dst)
        assert not tr.has_cycle()
    steps = 0
    while tr.pending_count():
        ready = tr.runnable()
        assert ready, "deadlock: pending kernels but none runnable"
        for k in ready:
            tr.complete(k)
        steps += 1
        assert steps < 1000
