"""Dependency tracking + renaming (the paper's hazard checker)."""
import numpy as np
import pytest

from repro.core.encoding import ElemWidth
from repro.core.hazards import DependencyTracker
from repro.core.matrix import MatrixMap


def bind(mm, logical, addr, rows=4, cols=4):
    return mm.reserve(logical, addr, rows, cols, cols, ElemWidth.W)


def test_raw_dependency():
    mm, tr = MatrixMap(), DependencyTracker()
    a = bind(mm, 0, 0)
    b = bind(mm, 1, 1000)
    d = bind(mm, 2, 2000)
    k0 = tr.admit([a, b], d)                 # d = f(a, b)
    e = bind(mm, 3, 3000)
    k1 = tr.admit([mm.lookup(2)], e)         # e = g(d) → RAW on d
    assert k0.kernel_id in k1.depends_on
    assert not tr.ready(k1.kernel_id)
    tr.complete(k0.kernel_id)
    assert tr.ready(k1.kernel_id)


def test_renaming_removes_war_waw():
    """xmr rebinding a logical register mints a fresh physical id, so a
    kernel reading the OLD binding does not conflict with a kernel writing
    the NEW one (different memory)."""
    mm, tr = MatrixMap(), DependencyTracker()
    a_old = bind(mm, 0, 0)
    dst1 = bind(mm, 1, 1000)
    k0 = tr.admit([a_old], dst1)
    # program reuses m0 for a DIFFERENT matrix (new xmr, new address)
    a_new = bind(mm, 0, 4000)
    assert a_new.phys_id != a_old.phys_id
    dst2 = bind(mm, 2, 2000)
    k1 = tr.admit([a_new], dst2)
    assert k0.kernel_id not in k1.depends_on   # renamed: no false WAR


def test_waw_same_physical_destination():
    mm, tr = MatrixMap(), DependencyTracker()
    a = bind(mm, 0, 0)
    d = bind(mm, 1, 1000)
    k0 = tr.admit([a], d)
    k1 = tr.admit([a], d)                      # same physical dst, no re-xmr
    assert k0.kernel_id in k1.depends_on


def test_memory_aliasing_dependency():
    mm, tr = MatrixMap(), DependencyTracker()
    a = bind(mm, 0, 0)
    d1 = bind(mm, 1, 1000)
    k0 = tr.admit([a], d1)
    # new binding overlapping d1's footprint (bytes [1000, 1064))
    alias = bind(mm, 2, 1032)
    d2 = bind(mm, 3, 5000)
    k1 = tr.admit([alias], d2)                 # reads memory k0 writes
    assert k0.kernel_id in k1.depends_on


def test_dag_acyclic_and_drains():
    """Property: any admission sequence yields an acyclic DAG that fully
    drains when completing ready kernels repeatedly — and once drained (no
    pins outstanding), the tracker retains no per-binding state."""
    hypothesis = pytest.importorskip("hypothesis")  # dev extra
    from hypothesis import given, settings, strategies as st

    @given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5),
                              st.integers(0, 5)), min_size=1, max_size=30))
    @settings(max_examples=50, deadline=None)
    def check(ops):
        _dag_acyclic_and_drains(ops)

    check()


def _dag_acyclic_and_drains(ops):
    mm, tr = MatrixMap(), DependencyTracker()
    addr = [i * 512 for i in range(6)]
    for s1, s2, d in ops:
        a = bind(mm, s1, addr[s1])
        b = bind(mm, s2, addr[s2])
        dst = bind(mm, d, addr[d])
        tr.admit([a, b], dst)
        assert not tr.has_cycle()
    steps = 0
    while tr.pending_count():
        ready = tr.runnable()
        assert ready, "deadlock: pending kernels but none runnable"
        for k in ready:
            tr.complete(k)
        steps += 1
        assert steps < 1000
    assert tr.completed_count() == len(ops)
    assert tr.tracked_state_size() == 0


# ------------------------------------------------------ bounded state (prune)
def test_tracker_prunes_completed_state():
    """Regression: complete() never pruned _writer_of/_readers_of/_bindings,
    so admit()'s aliasing sweep scanned every kernel ever admitted and
    memory grew without bound on long runs."""
    mm, tr = MatrixMap(), DependencyTracker()
    high_water = 0
    for i in range(200):
        a = bind(mm, 0, 0)
        d = bind(mm, 1, 1000)
        rec = tr.admit([a], d)
        high_water = max(high_water, tr.tracked_state_size())
        tr.complete(rec.kernel_id)
    assert tr.pending_count() == 0
    assert tr.completed_count() == 200
    assert tr.tracked_state_size() == 0          # fully pruned
    assert high_water <= 12                      # O(live), not O(history)


def test_tracker_prune_keeps_records_referenced_by_pending():
    mm, tr = MatrixMap(), DependencyTracker()
    a = bind(mm, 0, 0)
    d = bind(mm, 1, 1000)
    k0 = tr.admit([a], d)
    k1 = tr.admit([mm.lookup(1)], bind(mm, 2, 2000))   # RAW on d
    tr.complete(k0.kernel_id)
    # d is still read by pending k1: its binding/writer stamp must survive
    assert tr.binding(d.phys_id) is d
    assert tr.writer_of(d.phys_id) == k0.kernel_id
    assert tr.ready(k1.kernel_id)
    tr.complete(k1.kernel_id)
    assert tr.tracked_state_size() == 0


def test_tracker_pin_keeps_deferred_result_records():
    """The runtime pins cache-resident (deferred) results: their captured
    binding and admission-order stamp must outlive the writer's completion
    so write-backs can replay admission order."""
    mm, tr = MatrixMap(), DependencyTracker()
    a = bind(mm, 0, 0)
    d = bind(mm, 1, 1000)
    rec = tr.admit([a], d)
    tr.pin(d.phys_id)
    tr.complete(rec.kernel_id)
    assert tr.binding(d.phys_id) is d            # pinned: retained
    assert tr.writer_of(d.phys_id) == rec.kernel_id
    assert tr.binding(a.phys_id) is None         # unpinned source: pruned
    tr.unpin(d.phys_id)
    assert tr.binding(d.phys_id) is None
    assert tr.tracked_state_size() == 0
