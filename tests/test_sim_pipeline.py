"""repro.sim: pipelined-vs-serial equivalence, events, configs, traces."""
import numpy as np
import pytest

from repro.core import ArcaneCoprocessor, ElemWidth
from repro.core.runtime import CacheRuntime
from repro.sim import (EventQueue, PipelinedRuntime, Resource, SimConfig,
                       Tracer, deep_merge)
from repro.sim.trace import PHASES


def make_cop(scheduler, **kw):
    kw.setdefault("n_vpus", 4)
    kw.setdefault("vregs_per_vpu", 16)
    kw.setdefault("vlen_bytes", 512)
    cls = PipelinedRuntime if scheduler == "pipelined" else CacheRuntime
    return ArcaneCoprocessor(runtime=cls(**kw))


def gemm_relu_pool_chain(cop, seed=0, batch=2, n=16):
    """GEMM → LeakyReLU → MaxPool per image; returns the pooled outputs."""
    rng = np.random.default_rng(seed)
    outs = []
    addrs = []
    for _ in range(batch):
        A = rng.integers(-9, 9, (n, n), dtype=np.int32)
        aA = cop.place(A, ElemWidth.W)
        aT = cop.malloc(n * n * 4)
        aR = cop.malloc(n * n * 4)
        aP = cop.malloc((n // 2) * (n // 2) * 4)
        cop._xmr_w(0, aA, 0, n, n)
        cop._xmr_w(1, aT, 0, n, n)
        cop._xmr_w(2, aR, 0, n, n)
        cop._xmr_w(3, aP, 0, n // 2, n // 2)
        cop._gemm_w(1, 0, 0, 0, alpha=1.0, beta=0.0)      # T = A @ A
        cop._leakyrelu(ElemWidth.W, 2, 1, alpha=0.25)     # R = lrelu(T)
        cop._maxpool(ElemWidth.W, 3, 2, 2, 2)             # P = maxpool2x2(R)
        addrs.append(aP)
    cop.barrier()
    for aP in addrs:
        outs.append(cop.gather(aP, n // 2, n // 2, ElemWidth.W))
    return outs


# ------------------------------------------------------------- equivalence
def test_serial_pipelined_bit_identical_chain():
    cop_s = make_cop("serial")
    cop_p = make_cop("pipelined")
    outs_s = gemm_relu_pool_chain(cop_s)
    outs_p = gemm_relu_pool_chain(cop_p)
    for a, b in zip(outs_s, outs_p):
        np.testing.assert_array_equal(a, b)
    # oracle for the first image
    rng = np.random.default_rng(0)
    A = rng.integers(-9, 9, (16, 16), dtype=np.int32).astype(np.int64)
    T = (A @ A).astype(np.int32).astype(np.int64)
    R = np.where(T >= 0, T, np.round(0.25 * T)).astype(np.int32)
    P = R.reshape(8, 2, 8, 2).max(axis=(1, 3))
    np.testing.assert_array_equal(outs_s[0], P)


def test_pipelined_makespan_strictly_lower():
    """Acceptance: on a >=2-VPU config the overlapped schedule is strictly
    faster than the serial sum of phases, for the same kernel outputs."""
    cop_s = make_cop("serial")
    cop_p = make_cop("pipelined")
    gemm_relu_pool_chain(cop_s)
    gemm_relu_pool_chain(cop_p)
    serial_total = cop_s.rt.stats.total_cycles
    rep = cop_p.rt.report()
    assert rep.makespan < serial_total
    assert rep.concurrency_speedup > 1.0
    assert rep.kernels_run == cop_s.rt.stats.kernels_run == 6


def test_pipelined_single_kernel_no_miracle():
    """One kernel can't overlap with itself: makespan ~= serial phases."""
    cop = make_cop("pipelined")
    rng = np.random.default_rng(1)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD = cop.malloc(8 * 8 * 4)
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aD, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0)
    cop.barrier()
    s = cop.rt.stats
    # makespan == decode + alloc + compute + wb for the single kernel, which
    # differs from total_cycles only by the xmr-decode preamble slices that
    # never enter the event timeline.
    assert cop.rt.sim_time <= s.total_cycles
    assert cop.rt.sim_time > s.compute_cycles


def test_pipelined_deterministic_replay():
    runs = []
    for _ in range(2):
        cop = make_cop("pipelined")
        gemm_relu_pool_chain(cop)
        runs.append((cop.rt.sim_time, tuple(cop.rt.tracer.records)))
    assert runs[0] == runs[1]


# ----------------------------------------------------------- event engine
def test_event_queue_time_then_insertion_order():
    eq = EventQueue()
    eq.push(5, "a")
    eq.push(5, "b")
    eq.push(3, "c")
    eq.push(5, "d")
    assert [e.kind for e in eq.drain()] == ["c", "a", "b", "d"]


def test_event_queue_rejects_negative_time():
    with pytest.raises(ValueError):
        EventQueue().push(-1, "x")


def test_resource_fifo_occupancy():
    r = Resource("dma")
    iv1 = r.acquire(10, 5)
    iv2 = r.acquire(0, 3)       # requester ready earlier, resource busy
    assert (iv1.start, iv1.end) == (10, 15)
    assert (iv2.start, iv2.end) == (15, 18)
    assert r.busy_cycles == 8
    assert r.idle_at(18) and not r.idle_at(17)


# ---------------------------------------------------------------- configs
def test_config_defaults_make_both_runtimes():
    cfg = SimConfig(n_vpus=2, vregs_per_vpu=8, vlen_bytes=256,
                    memory_bytes=1 << 16)
    assert isinstance(cfg.make_runtime("serial"), CacheRuntime)
    rt = cfg.make_runtime("pipelined")
    assert isinstance(rt, PipelinedRuntime)
    assert rt.cache.n_vpus == 2 and rt.geometry.lanes == 4
    with pytest.raises(Exception):
        cfg.make_runtime("warp-drive")


def test_deep_merge_and_replace():
    base = {"cache": {"n_vpus": 4, "vlen_bytes": 1024}, "vpu": {"lanes": 4}}
    out = deep_merge(base, {"cache": {"n_vpus": 8}})
    assert out["cache"] == {"n_vpus": 8, "vlen_bytes": 1024}
    out = deep_merge(base, {"cache": {"replace": True, "n_vpus": 8}})
    assert out["cache"] == {"n_vpus": 8}
    assert base["cache"]["n_vpus"] == 4      # inputs untouched


def test_yaml_extends_overrides(tmp_path):
    yaml = pytest.importorskip("yaml")  # noqa: F841  (dev extra)
    from repro.sim import load_config
    (tmp_path / "base.yaml").write_text(
        "description: base\n"
        "cache: {n_vpus: 4, vregs_per_vpu: 8, vlen_bytes: 256}\n"
        "vpu: {lanes: 2}\n"
        "memory: {bytes: 65536}\n")
    (tmp_path / "child.yaml").write_text(
        "extends: base.yaml\n"
        "description: child\n"
        "cache: {n_vpus: 8}\n")
    cfg = load_config(str(tmp_path / "child.yaml"))
    assert cfg.description == "child"
    assert cfg.n_vpus == 8                   # overridden
    assert cfg.vregs_per_vpu == 8            # inherited through the merge
    assert cfg.lanes == 2
    assert cfg.memory_bytes == 65536


def test_yaml_extends_builtin_and_cycle(tmp_path):
    pytest.importorskip("yaml")
    from repro.sim import ConfigError, load_config
    cfg = load_config("arcane-8vpu")         # builtin extends builtin
    assert cfg.n_vpus == 8 and cfg.lanes == 8
    assert cfg.vregs_per_vpu == 32           # inherited from arcane-default
    (tmp_path / "a.yaml").write_text("extends: b.yaml\n")
    (tmp_path / "b.yaml").write_text("extends: a.yaml\n")
    with pytest.raises(ConfigError, match="cyclic"):
        load_config(str(tmp_path / "a.yaml"))
    (tmp_path / "bad.yaml").write_text("cache: {warp_cores: 9}\n")
    with pytest.raises(ConfigError, match="unknown key"):
        load_config(str(tmp_path / "bad.yaml"))


# ------------------------------------------------------------------ traces
def test_trace_chrome_schema(tmp_path):
    cop = make_cop("pipelined")
    gemm_relu_pool_chain(cop, batch=1)
    doc = cop.rt.tracer.to_chrome()
    assert set(doc) >= {"traceEvents", "displayTimeUnit"}
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    complete = [e for e in events if e["ph"] == "X"]
    assert complete, "no activities traced"
    named_tids = {e["tid"] for e in meta if e["name"] == "thread_name"}
    for e in complete:
        assert set(e) >= {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                          "args"}
        assert e["cat"] in PHASES
        assert e["ts"] >= 0 and e["dur"] >= 1
        assert e["tid"] in named_tids
    # all four phases appear in a full decode→alloc→compute→wb pipeline
    assert {e["cat"] for e in complete} == set(PHASES)
    out = cop.rt.tracer.dump(str(tmp_path / "trace.json"))
    import json
    with open(out) as f:
        assert json.load(f) == doc


def test_tracer_rejects_unknown_phase():
    with pytest.raises(ValueError):
        Tracer().emit("x", "mystery", "r", 0, 1)


# -------------------------------------------------- runtime regression fixes
@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_cross_vpu_consolidation_releases_at(scheduler, rng):
    """Deferred result consumed via a cross-VPU move must release its DST
    AddressTable registration (regression: stale region stalled host loads)."""
    cop = make_cop(scheduler)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    B = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA, aB = cop.place(A, ElemWidth.W), cop.place(B, ElemWidth.W)
    aT1, aT2 = cop.malloc(8 * 8 * 4), cop.malloc(8 * 8 * 4)
    aO = cop.malloc(8 * 8 * 4)
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aB, 0, 8, 8)
    cop._xmr_w(2, aT1, 0, 8, 8)
    cop._xmr_w(3, aT2, 0, 8, 8)
    cop._xmr_w(4, aO, 0, 8, 8)
    cop._gemm_w(2, 0, 0, 0)                      # T1 = A@A   (VPU x)
    cop._gemm_w(3, 1, 1, 1)                      # T2 = B@B   (VPU y)
    cop._gemm_w(4, 2, 3, 2, alpha=1.0, beta=1.0)  # O = T1@T2 + T1
    cop.barrier()
    assert cop.rt.at.blocks_load(aT2, aT2 + 4) is None
    assert cop.rt.at.live_count() == 0
    T1 = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aT1, 8, 8, ElemWidth.W), T1)


@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_rebound_deferred_result_not_written_back(scheduler, rng):
    """WAW rebinding of the destination register: the superseded deferred
    result must be discarded, not flushed over the newer kernel's output."""
    cop = make_cop(scheduler)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aO = cop.malloc(8 * 8 * 4)
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aO, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0)                      # m1 = A@A
    cop._leakyrelu(ElemWidth.W, 1, 1, alpha=0.25)  # m1 = lrelu(m1): rebinds m1
    cop.barrier()
    T = (A.astype(np.int64) @ A.astype(np.int64))
    ref = np.where(T >= 0, T, np.round(0.25 * T)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aO, 8, 8, ElemWidth.W), ref)
    assert cop.rt.at.live_count() == 0


@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_rebind_to_unrelated_buffer_keeps_deferred_result(scheduler, rng):
    """Rebinding a register to a *non-aliasing* buffer must not discard the
    deferred result — only a later aliasing writer supersedes it."""
    cop = make_cop(scheduler)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aT = cop.malloc(8 * 8 * 4)
    aR = cop.malloc(8 * 8 * 4)
    aZ = cop.malloc(8 * 8 * 4)               # unrelated buffer
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aT, 0, 8, 8)
    cop._xmr_w(2, aR, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0)                  # m1 = A@A -> aT (deferred: read below)
    cop._leakyrelu(ElemWidth.W, 2, 1, alpha=0.25)   # m2 = lrelu(m1) -> aR
    cop._xmr_w(1, aZ, 0, 8, 8)               # metadata rebind of m1 -> aZ
    cop.barrier()
    T = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aT, 8, 8, ElemWidth.W), T)
    ref = np.where(T >= 0, T, np.round(0.25 * T.astype(np.int64))).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aR, 8, 8, ElemWidth.W), ref)


@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_partial_overlap_keeps_non_overlapped_bytes(scheduler, rng):
    """A later kernel writing only *part* of a deferred result's region must
    not lose the non-overlapped bytes: write-backs land in admission order
    (regression: the whole deferred result was discarded on any overlap)."""
    cop = make_cop(scheduler)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aT = cop.malloc(8 * 8 * 4)           # gemm result region [aT, aT+256)
    aR = cop.malloc(8 * 8 * 4)
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aT, 0, 8, 8)
    cop._xmr_w(2, aR, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0)                        # m1 = A@A -> aT (deferred)
    cop._leakyrelu(ElemWidth.W, 2, 1, alpha=0.25)  # consumer: defers m1
    # later kernel overwrites only the second half of aT's region
    cop._xmr_w(3, aT + 128, 0, 4, 8)
    cop._xmr_w(4, aA, 0, 4, 8)                     # top 4 rows of A
    cop._leakyrelu(ElemWidth.W, 3, 4, alpha=0.5)   # m3 = lrelu(A[:4]) -> aT+128
    cop.barrier()
    T = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    got = cop.gather(aT, 8, 8, ElemWidth.W)
    np.testing.assert_array_equal(got[:4], T[:4])  # non-overlapped bytes live
    A4 = A[:4].astype(np.int64)
    newer = np.where(A4 >= 0, A4, np.round(0.5 * A4)).astype(np.int32)
    np.testing.assert_array_equal(got[4:], newer)  # newer write wins overlap


def test_repeated_operand_dispatches_on_tight_vpu():
    """gemm(A, A) needs A's lines once; the capacity check must not count the
    repeated operand twice and starve the event-loop dispatch (regression:
    such kernels silently fell back to the untimed serial path)."""
    # A: 16x16 int32 = 1024 B = 2 lines of 512 B; dst same. 5 vregs/VPU fit
    # need(A) + need(dst) = 4 but not the double-counted 6.
    cop = make_cop("pipelined", n_vpus=2, vregs_per_vpu=5, vlen_bytes=512)
    rng = np.random.default_rng(2)
    A = rng.integers(-9, 9, (16, 16), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD = cop.malloc(16 * 16 * 4)
    cop._xmr_w(0, aA, 0, 16, 16)
    cop._xmr_w(1, aD, 0, 16, 16)
    cop._gemm_w(1, 0, 0, 0)
    cop.barrier()
    ref = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aD, 16, 16, ElemWidth.W), ref)
    # dispatched through the event loop (the serial fallback emits no trace)
    assert any(r.phase == "compute" for r in cop.rt.tracer.records)


def test_strided_column_strips_do_not_alias():
    from repro.core.matrix import MatrixMap
    mm = MatrixMap()
    left = mm.reserve(0, addr=0, rows=4, cols=2, stride=8, width=ElemWidth.W)
    right = mm.reserve(1, addr=8, rows=4, cols=2, stride=8, width=ElemWidth.W)
    dense = mm.reserve(2, addr=0, rows=4, cols=8, stride=8, width=ElemWidth.W)
    assert not left.overlaps(right) and not right.overlaps(left)
    assert left.overlaps(dense) and dense.overlaps(right)
    shifted = mm.reserve(3, addr=4, rows=4, cols=2, stride=8,
                         width=ElemWidth.W)
    assert left.overlaps(shifted)                # byte bands intersect
