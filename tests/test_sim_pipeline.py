"""repro.sim: pipelined-vs-serial equivalence, events, configs, traces."""
import numpy as np
import pytest

from repro.core import ArcaneCoprocessor, ElemWidth
from repro.core.runtime import CacheRuntime
from repro.sim import (EventQueue, PipelinedRuntime, Resource, SimConfig,
                       Tracer, deep_merge)
from repro.sim.trace import PHASES


def make_cop(scheduler, **kw):
    kw.setdefault("n_vpus", 4)
    kw.setdefault("vregs_per_vpu", 16)
    kw.setdefault("vlen_bytes", 512)
    cls = PipelinedRuntime if scheduler == "pipelined" else CacheRuntime
    return ArcaneCoprocessor(runtime=cls(**kw))


def gemm_relu_pool_chain(cop, seed=0, batch=2, n=16):
    """GEMM → LeakyReLU → MaxPool per image; returns the pooled outputs."""
    rng = np.random.default_rng(seed)
    outs = []
    addrs = []
    for _ in range(batch):
        A = rng.integers(-9, 9, (n, n), dtype=np.int32)
        aA = cop.place(A, ElemWidth.W)
        aT = cop.malloc(n * n * 4)
        aR = cop.malloc(n * n * 4)
        aP = cop.malloc((n // 2) * (n // 2) * 4)
        cop._xmr_w(0, aA, 0, n, n)
        cop._xmr_w(1, aT, 0, n, n)
        cop._xmr_w(2, aR, 0, n, n)
        cop._xmr_w(3, aP, 0, n // 2, n // 2)
        cop._gemm_w(1, 0, 0, 0, alpha=1.0, beta=0.0)      # T = A @ A
        cop._leakyrelu(ElemWidth.W, 2, 1, alpha=0.25)     # R = lrelu(T)
        cop._maxpool(ElemWidth.W, 3, 2, 2, 2)             # P = maxpool2x2(R)
        addrs.append(aP)
    cop.barrier()
    for aP in addrs:
        outs.append(cop.gather(aP, n // 2, n // 2, ElemWidth.W))
    return outs


# ------------------------------------------------------------- equivalence
def test_serial_pipelined_bit_identical_chain():
    cop_s = make_cop("serial")
    cop_p = make_cop("pipelined")
    outs_s = gemm_relu_pool_chain(cop_s)
    outs_p = gemm_relu_pool_chain(cop_p)
    for a, b in zip(outs_s, outs_p):
        np.testing.assert_array_equal(a, b)
    # oracle for the first image
    rng = np.random.default_rng(0)
    A = rng.integers(-9, 9, (16, 16), dtype=np.int32).astype(np.int64)
    T = (A @ A).astype(np.int32).astype(np.int64)
    R = np.where(T >= 0, T, np.round(0.25 * T)).astype(np.int32)
    P = R.reshape(8, 2, 8, 2).max(axis=(1, 3))
    np.testing.assert_array_equal(outs_s[0], P)


def test_pipelined_makespan_strictly_lower():
    """Acceptance: on a >=2-VPU config the overlapped schedule is strictly
    faster than the serial sum of phases, for the same kernel outputs."""
    cop_s = make_cop("serial")
    cop_p = make_cop("pipelined")
    gemm_relu_pool_chain(cop_s)
    gemm_relu_pool_chain(cop_p)
    serial_total = cop_s.rt.stats.total_cycles
    rep = cop_p.rt.report()
    assert rep.makespan < serial_total
    assert rep.concurrency_speedup > 1.0
    assert rep.kernels_run == cop_s.rt.stats.kernels_run == 6


def test_pipelined_single_kernel_no_miracle():
    """One kernel can't overlap with itself: makespan ~= serial phases."""
    cop = make_cop("pipelined")
    rng = np.random.default_rng(1)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD = cop.malloc(8 * 8 * 4)
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aD, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0)
    cop.barrier()
    s = cop.rt.stats
    # makespan == decode + alloc + compute + wb for the single kernel, which
    # differs from total_cycles only by the xmr-decode preamble slices that
    # never enter the event timeline.
    assert cop.rt.sim_time <= s.total_cycles
    assert cop.rt.sim_time > s.compute_cycles


def test_pipelined_deterministic_replay():
    runs = []
    for _ in range(2):
        cop = make_cop("pipelined")
        gemm_relu_pool_chain(cop)
        runs.append((cop.rt.sim_time, tuple(cop.rt.tracer.records)))
    assert runs[0] == runs[1]


# ----------------------------------------------------------- event engine
def test_event_queue_time_then_insertion_order():
    eq = EventQueue()
    eq.push(5, "a")
    eq.push(5, "b")
    eq.push(3, "c")
    eq.push(5, "d")
    assert [e.kind for e in eq.drain()] == ["c", "a", "b", "d"]


def test_event_queue_rejects_negative_time():
    with pytest.raises(ValueError):
        EventQueue().push(-1, "x")


def test_resource_fifo_occupancy():
    r = Resource("dma")
    iv1 = r.acquire(10, 5)
    iv2 = r.acquire(0, 3)       # requester ready earlier, resource busy
    assert (iv1.start, iv1.end) == (10, 15)
    assert (iv2.start, iv2.end) == (15, 18)
    assert r.busy_cycles == 8
    assert r.idle_at(18) and not r.idle_at(17)


# ---------------------------------------------------------------- configs
def test_config_defaults_make_both_runtimes():
    cfg = SimConfig(n_vpus=2, vregs_per_vpu=8, vlen_bytes=256,
                    memory_bytes=1 << 16)
    assert isinstance(cfg.make_runtime("serial"), CacheRuntime)
    rt = cfg.make_runtime("pipelined")
    assert isinstance(rt, PipelinedRuntime)
    assert rt.cache.n_vpus == 2 and rt.geometry.lanes == 4
    with pytest.raises(Exception):
        cfg.make_runtime("warp-drive")


def test_deep_merge_and_replace():
    base = {"cache": {"n_vpus": 4, "vlen_bytes": 1024}, "vpu": {"lanes": 4}}
    out = deep_merge(base, {"cache": {"n_vpus": 8}})
    assert out["cache"] == {"n_vpus": 8, "vlen_bytes": 1024}
    out = deep_merge(base, {"cache": {"replace": True, "n_vpus": 8}})
    assert out["cache"] == {"n_vpus": 8}
    assert base["cache"]["n_vpus"] == 4      # inputs untouched


def test_yaml_extends_overrides(tmp_path):
    yaml = pytest.importorskip("yaml")  # noqa: F841  (dev extra)
    from repro.sim import load_config
    (tmp_path / "base.yaml").write_text(
        "description: base\n"
        "cache: {n_vpus: 4, vregs_per_vpu: 8, vlen_bytes: 256}\n"
        "vpu: {lanes: 2}\n"
        "memory: {bytes: 65536}\n")
    (tmp_path / "child.yaml").write_text(
        "extends: base.yaml\n"
        "description: child\n"
        "cache: {n_vpus: 8}\n")
    cfg = load_config(str(tmp_path / "child.yaml"))
    assert cfg.description == "child"
    assert cfg.n_vpus == 8                   # overridden
    assert cfg.vregs_per_vpu == 8            # inherited through the merge
    assert cfg.lanes == 2
    assert cfg.memory_bytes == 65536


def test_row_chunk_knob_threads_to_runtime(tmp_path):
    cfg = SimConfig(n_vpus=2, vregs_per_vpu=8, vlen_bytes=256,
                    memory_bytes=1 << 16, row_chunk=0)
    rt = cfg.make_runtime("pipelined")
    assert rt.row_chunk == 0
    assert SimConfig().row_chunk == 8            # default granularity
    from repro.sim import ConfigError
    with pytest.raises(ConfigError, match="row_chunk"):
        SimConfig(row_chunk=-1)
    with pytest.raises(ValueError):
        PipelinedRuntime(n_vpus=1, vregs_per_vpu=4, vlen_bytes=256,
                         row_chunk=-2)


def test_row_chunk_yaml_knob(tmp_path):
    pytest.importorskip("yaml")
    from repro.sim import load_config
    assert load_config("arcane-default").row_chunk == 8
    assert load_config("arcane-8vpu").row_chunk == 4
    (tmp_path / "c.yaml").write_text(
        "extends: arcane-default\npipeline: {row_chunk: 2}\n")
    assert load_config(str(tmp_path / "c.yaml")).row_chunk == 2
    (tmp_path / "bad.yaml").write_text("pipeline: {chunk_rows: 2}\n")
    from repro.sim import ConfigError
    with pytest.raises(ConfigError, match="unknown key"):
        load_config(str(tmp_path / "bad.yaml"))


def test_geometry_vlen_threaded_from_config():
    """Regression: compute_cycles hardcoded a 1024-byte VLEN for the issue
    overhead while vlen_bytes was a config knob — non-default configs
    silently modeled the wrong vector length."""
    from repro.core.isa import KernelCost
    from repro.core.vpu import VPUGeometry
    cost = KernelCost(macs=4096)
    small = VPUGeometry(lanes=4, vlen_bytes=128)
    big = VPUGeometry(lanes=4, vlen_bytes=2048)
    # shorter vectors -> more vector instructions -> more issue overhead
    assert small.compute_cycles(cost, ElemWidth.W) > \
        big.compute_cycles(cost, ElemWidth.W)
    cfg = SimConfig(n_vpus=1, vregs_per_vpu=4, vlen_bytes=512,
                    memory_bytes=1 << 16)
    assert cfg.geometry().vlen_bytes == 512
    rt = CacheRuntime(n_vpus=1, vregs_per_vpu=4, vlen_bytes=256)
    assert rt.geometry.vlen_bytes == 256         # ctor default geometry too


def test_yaml_extends_builtin_and_cycle(tmp_path):
    pytest.importorskip("yaml")
    from repro.sim import ConfigError, load_config
    cfg = load_config("arcane-8vpu")         # builtin extends builtin
    assert cfg.n_vpus == 8 and cfg.lanes == 8
    assert cfg.vregs_per_vpu == 32           # inherited from arcane-default
    (tmp_path / "a.yaml").write_text("extends: b.yaml\n")
    (tmp_path / "b.yaml").write_text("extends: a.yaml\n")
    with pytest.raises(ConfigError, match="cyclic"):
        load_config(str(tmp_path / "a.yaml"))
    (tmp_path / "bad.yaml").write_text("cache: {warp_cores: 9}\n")
    with pytest.raises(ConfigError, match="unknown key"):
        load_config(str(tmp_path / "bad.yaml"))


# ------------------------------------------------------------------ traces
def test_trace_chrome_schema(tmp_path):
    cop = make_cop("pipelined")
    gemm_relu_pool_chain(cop, batch=1)
    doc = cop.rt.tracer.to_chrome()
    assert set(doc) >= {"traceEvents", "displayTimeUnit"}
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    complete = [e for e in events if e["ph"] == "X"]
    assert complete, "no activities traced"
    named_tids = {e["tid"] for e in meta if e["name"] == "thread_name"}
    for e in complete:
        assert set(e) >= {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                          "args"}
        assert e["cat"] in PHASES
        assert e["ts"] >= 0 and e["dur"] >= 1
        assert e["tid"] in named_tids
    # all four phases appear in a full decode→alloc→compute→wb pipeline
    assert {e["cat"] for e in complete} == set(PHASES)
    out = cop.rt.tracer.dump(str(tmp_path / "trace.json"))
    import json
    with open(out) as f:
        assert json.load(f) == doc


def test_tracer_rejects_unknown_phase():
    with pytest.raises(ValueError):
        Tracer().emit("x", "mystery", "r", 0, 1)


# -------------------------------------------------- runtime regression fixes
@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_cross_vpu_consolidation_releases_at(scheduler, rng):
    """Deferred result consumed via a cross-VPU move must release its DST
    AddressTable registration (regression: stale region stalled host loads)."""
    cop = make_cop(scheduler)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    B = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA, aB = cop.place(A, ElemWidth.W), cop.place(B, ElemWidth.W)
    aT1, aT2 = cop.malloc(8 * 8 * 4), cop.malloc(8 * 8 * 4)
    aO = cop.malloc(8 * 8 * 4)
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aB, 0, 8, 8)
    cop._xmr_w(2, aT1, 0, 8, 8)
    cop._xmr_w(3, aT2, 0, 8, 8)
    cop._xmr_w(4, aO, 0, 8, 8)
    cop._gemm_w(2, 0, 0, 0)                      # T1 = A@A   (VPU x)
    cop._gemm_w(3, 1, 1, 1)                      # T2 = B@B   (VPU y)
    cop._gemm_w(4, 2, 3, 2, alpha=1.0, beta=1.0)  # O = T1@T2 + T1
    cop.barrier()
    assert cop.rt.at.blocks_load(aT2, aT2 + 4) is None
    assert cop.rt.at.live_count() == 0
    T1 = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aT1, 8, 8, ElemWidth.W), T1)


@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_rebound_deferred_result_not_written_back(scheduler, rng):
    """WAW rebinding of the destination register: the superseded deferred
    result must be discarded, not flushed over the newer kernel's output."""
    cop = make_cop(scheduler)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aO = cop.malloc(8 * 8 * 4)
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aO, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0)                      # m1 = A@A
    cop._leakyrelu(ElemWidth.W, 1, 1, alpha=0.25)  # m1 = lrelu(m1): rebinds m1
    cop.barrier()
    T = (A.astype(np.int64) @ A.astype(np.int64))
    ref = np.where(T >= 0, T, np.round(0.25 * T)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aO, 8, 8, ElemWidth.W), ref)
    assert cop.rt.at.live_count() == 0


@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_rebind_to_unrelated_buffer_keeps_deferred_result(scheduler, rng):
    """Rebinding a register to a *non-aliasing* buffer must not discard the
    deferred result — only a later aliasing writer supersedes it."""
    cop = make_cop(scheduler)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aT = cop.malloc(8 * 8 * 4)
    aR = cop.malloc(8 * 8 * 4)
    aZ = cop.malloc(8 * 8 * 4)               # unrelated buffer
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aT, 0, 8, 8)
    cop._xmr_w(2, aR, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0)                  # m1 = A@A -> aT (deferred: read below)
    cop._leakyrelu(ElemWidth.W, 2, 1, alpha=0.25)   # m2 = lrelu(m1) -> aR
    cop._xmr_w(1, aZ, 0, 8, 8)               # metadata rebind of m1 -> aZ
    cop.barrier()
    T = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aT, 8, 8, ElemWidth.W), T)
    ref = np.where(T >= 0, T, np.round(0.25 * T.astype(np.int64))).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aR, 8, 8, ElemWidth.W), ref)


@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_partial_overlap_keeps_non_overlapped_bytes(scheduler, rng):
    """A later kernel writing only *part* of a deferred result's region must
    not lose the non-overlapped bytes: write-backs land in admission order
    (regression: the whole deferred result was discarded on any overlap)."""
    cop = make_cop(scheduler)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aT = cop.malloc(8 * 8 * 4)           # gemm result region [aT, aT+256)
    aR = cop.malloc(8 * 8 * 4)
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aT, 0, 8, 8)
    cop._xmr_w(2, aR, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0)                        # m1 = A@A -> aT (deferred)
    cop._leakyrelu(ElemWidth.W, 2, 1, alpha=0.25)  # consumer: defers m1
    # later kernel overwrites only the second half of aT's region
    cop._xmr_w(3, aT + 128, 0, 4, 8)
    cop._xmr_w(4, aA, 0, 4, 8)                     # top 4 rows of A
    cop._leakyrelu(ElemWidth.W, 3, 4, alpha=0.5)   # m3 = lrelu(A[:4]) -> aT+128
    cop.barrier()
    T = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    got = cop.gather(aT, 8, 8, ElemWidth.W)
    np.testing.assert_array_equal(got[:4], T[:4])  # non-overlapped bytes live
    A4 = A[:4].astype(np.int64)
    newer = np.where(A4 >= 0, A4, np.round(0.5 * A4)).astype(np.int32)
    np.testing.assert_array_equal(got[4:], newer)  # newer write wins overlap


def test_repeated_operand_dispatches_on_tight_vpu():
    """gemm(A, A) needs A's lines once; the capacity check must not count the
    repeated operand twice and starve the event-loop dispatch (regression:
    such kernels silently fell back to the untimed serial path)."""
    # A: 16x16 int32 = 1024 B = 2 lines of 512 B; dst same. 5 vregs/VPU fit
    # need(A) + need(dst) = 4 but not the double-counted 6.
    cop = make_cop("pipelined", n_vpus=2, vregs_per_vpu=5, vlen_bytes=512)
    rng = np.random.default_rng(2)
    A = rng.integers(-9, 9, (16, 16), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD = cop.malloc(16 * 16 * 4)
    cop._xmr_w(0, aA, 0, 16, 16)
    cop._xmr_w(1, aD, 0, 16, 16)
    cop._gemm_w(1, 0, 0, 0)
    cop.barrier()
    ref = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aD, 16, 16, ElemWidth.W), ref)
    # dispatched through the event loop (the serial fallback emits no trace)
    assert any(r.phase == "compute" for r in cop.rt.tracer.records)


def test_strided_column_strips_do_not_alias():
    from repro.core.matrix import MatrixMap
    mm = MatrixMap()
    left = mm.reserve(0, addr=0, rows=4, cols=2, stride=8, width=ElemWidth.W)
    right = mm.reserve(1, addr=8, rows=4, cols=2, stride=8, width=ElemWidth.W)
    dense = mm.reserve(2, addr=0, rows=4, cols=8, stride=8, width=ElemWidth.W)
    assert not left.overlaps(right) and not right.overlaps(left)
    assert left.overlaps(dense) and dense.overlaps(right)
    shifted = mm.reserve(3, addr=4, rows=4, cols=2, stride=8,
                         width=ElemWidth.W)
    assert left.overlaps(shifted)                # byte bands intersect


@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_aliased_read_of_deferred_result_sees_fresh_bytes(scheduler, rng):
    """A kernel reading a *distinct* binding that aliases a deferred dirty
    result must observe the result, not stale main memory: the deferred
    write-back has to consolidate before the source DMA-in (regression: the
    RAW edge only ordered the read after the writer *completed*, so the DMA
    loaded pre-kernel sentinel bytes)."""
    cop = make_cop(scheduler)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD, aO1, aO2 = (cop.malloc(8 * 8 * 4) for _ in range(3))
    cop.store(aD, np.full((8, 8), 7, np.int32), ElemWidth.W)   # sentinel
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aD, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0)                  # k0: m1 = A@A -> aD
    cop._xmr_w(3, aD, 0, 8, 8)               # distinct binding, same bytes
    cop._xmr_w(4, aO1, 0, 8, 8)
    cop._leakyrelu(ElemWidth.W, 4, 3, alpha=0.0)   # k1: reads the alias
    cop._xmr_w(5, aO2, 0, 8, 8)
    cop._leakyrelu(ElemWidth.W, 5, 1, alpha=0.0)   # k2: reads m1 -> k0 defers
    cop.barrier()
    T = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    ref = np.maximum(T, 0)
    np.testing.assert_array_equal(cop.gather(aD, 8, 8, ElemWidth.W), T)
    np.testing.assert_array_equal(cop.gather(aO1, 8, 8, ElemWidth.W), ref)
    np.testing.assert_array_equal(cop.gather(aO2, 8, 8, ElemWidth.W), ref)


@pytest.mark.parametrize("keep_deferred", [False, True])
@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_aliasing_writer_invalidates_stale_source_copy(scheduler,
                                                       keep_deferred, rng):
    """The mirror direction: a *clean* resident source copy must not survive
    a later aliasing writer (distinct phys binding, same bytes) — whether
    the writer's result already landed in memory (the landing evicts stale
    copies) or is still deferred dirty (the read lands it first). Regression:
    the re-read returned the pre-writer bytes on both schedulers."""
    cop = make_cop(scheduler)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    B = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aP = cop.place(A, ElemWidth.W)           # bytes p: hold A initially
    aB = cop.place(B, ElemWidth.W)
    aO1, aO2, aO3 = (cop.malloc(8 * 8 * 4) for _ in range(3))
    cop._xmr_w(0, aP, 0, 8, 8)               # m0: binding a over p
    cop._xmr_w(1, aO1, 0, 8, 8)
    cop._leakyrelu(ElemWidth.W, 1, 0, alpha=0.5)   # k0: reads m0, a resident
    cop._xmr_w(2, aP, 0, 8, 8)               # fresh binding over the same p
    cop._xmr_w(3, aB, 0, 8, 8)
    cop._leakyrelu(ElemWidth.W, 2, 3, alpha=0.0)   # k1: p = relu(B)
    cop._xmr_w(4, aO2, 0, 8, 8)
    cop._leakyrelu(ElemWidth.W, 4, 0, alpha=0.0)   # k2: re-reads m0 (stale?)
    if keep_deferred:
        # k3 reads k1's result, so it is still deferred dirty when k2 reads
        cop._xmr_w(5, aO3, 0, 8, 8)
        cop._leakyrelu(ElemWidth.W, 5, 2, alpha=0.0)
    cop.barrier()
    A64, B64 = A.astype(np.int64), B.astype(np.int64)
    p_new = np.maximum(B, 0)
    np.testing.assert_array_equal(
        cop.gather(aO1, 8, 8, ElemWidth.W),
        np.where(A >= 0, A64, np.round(0.5 * A64)).astype(np.int32))
    np.testing.assert_array_equal(cop.gather(aO2, 8, 8, ElemWidth.W), p_new)
    np.testing.assert_array_equal(cop.gather(aP, 8, 8, ElemWidth.W), p_new)
    if keep_deferred:
        np.testing.assert_array_equal(cop.gather(aO3, 8, 8, ElemWidth.W),
                                      p_new)


def test_consolidation_books_on_owning_vpu_port():
    """Consolidation DMA runs on the port of the VPU holding the resident;
    booking it on the dispatch VPU's port would model contention on the
    wrong resource (and skew utilization)."""
    cop = make_cop("pipelined")
    rng = np.random.default_rng(0)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    B = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA, aB = cop.place(A, ElemWidth.W), cop.place(B, ElemWidth.W)
    aT1, aT2, aO = (cop.malloc(8 * 8 * 4) for _ in range(3))
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aB, 0, 8, 8)
    cop._xmr_w(2, aT1, 0, 8, 8)
    cop._xmr_w(3, aT2, 0, 8, 8)
    cop._xmr_w(4, aO, 0, 8, 8)
    cop._gemm_w(2, 0, 0, 0)                      # T1 on VPU x
    cop._gemm_w(3, 1, 1, 1)                      # T2 on VPU y
    cop._gemm_w(4, 2, 3, 2, alpha=1.0, beta=1.0)  # dispatches to T1's VPU;
    cop.barrier()                                 # consolidates T2 from y
    consolidates = [r for r in cop.rt.tracer.records
                    if "consolidate" in r.name]
    assert consolidates, "cross-VPU move produced no consolidation interval"
    for r in consolidates:
        assert r.resource == f"vpu{dict(r.args)['vpu']}.dma"
    # the consolidated operand (T2) lived on a different VPU than the
    # dispatching kernel ran on
    k2_compute = [r for r in cop.rt.tracer.records if r.phase == "compute"
                  and dict(r.args).get("kernel") == 2]
    dispatch_vpu = dict(k2_compute[0].args)["vpu"]
    assert any(dict(r.args)["vpu"] != dispatch_vpu for r in consolidates)


# --------------------------------------- exact aliasing: unequal strides
def test_unequal_stride_strips_no_false_edge():
    """Two disjoint views of one buffer with *different* strides (all rows /
    cols 0-3 vs even rows / cols 4-11) must not produce an aliasing edge —
    the case the old interval-overlap fallback serialized."""
    from repro.core.hazards import DependencyTracker
    from repro.core.matrix import MatrixMap
    mm, tr = MatrixMap(), DependencyTracker()
    src1 = mm.reserve(0, addr=8192, rows=16, cols=4, stride=4,
                      width=ElemWidth.W)
    src2 = mm.reserve(1, addr=12288, rows=8, cols=8, stride=8,
                      width=ElemWidth.W)
    # strip A: every row of the 16-wide buffer, columns 0-3
    dstA = mm.reserve(2, addr=0, rows=16, cols=4, stride=16,
                      width=ElemWidth.W)
    # strip B: even rows only, columns 4-11 (stride 32 elems = 2 rows)
    dstB = mm.reserve(3, addr=16, rows=8, cols=8, stride=32,
                      width=ElemWidth.W)
    assert not dstA.overlaps(dstB)               # exact algebra: disjoint
    k0 = tr.admit([src1], dstA)
    k1 = tr.admit([src2], dstB)
    assert k0.kernel_id not in k1.depends_on     # no false WAW edge
    assert tr.ready(k1.kernel_id)


@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_unequal_stride_interleaved_strips_bit_identical(scheduler, rng):
    """Aliased strip workload: two kernels write disjoint unequal-stride
    strips of ONE destination buffer, a third reads the dense union (true
    RAW on both). Serial and pipelined must agree bit for bit, and the
    untouched odd-row right-half bytes must survive."""
    cop = make_cop(scheduler)
    n = 16
    A = rng.integers(-9, 9, (n, 4), dtype=np.int32)
    B = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA, aB = cop.place(A, ElemWidth.W), cop.place(B, ElemWidth.W)
    aD = cop.malloc(n * n * 4)                   # shared 16x16 buffer
    aO = cop.malloc(n * n * 4)
    sentinel = np.full((n, n), 7, np.int32)
    cop.store(aD, sentinel, ElemWidth.W)
    cop._xmr_w(0, aA, 0, n, 4)
    cop._xmr_w(1, aB, 0, 8, 8)
    cop._xmr_w(2, aD, n, n, 4)                   # strip A: all rows, cols 0-3
    cop._xmr_w(3, aD + 16, 2 * n, 8, 8)          # strip B: even rows, cols 4-11
    cop._leakyrelu(ElemWidth.W, 2, 0, alpha=0.5)
    cop._leakyrelu(ElemWidth.W, 3, 1, alpha=0.25)
    cop._xmr_w(4, aD, 0, n, n)                   # dense union view (RAW both)
    cop._xmr_w(5, aO, 0, n, n)
    cop._leakyrelu(ElemWidth.W, 5, 4, alpha=0.0)
    cop.barrier()
    got = cop.gather(aD, n, n, ElemWidth.W)
    ref = sentinel.copy()
    A64, B64 = A.astype(np.int64), B.astype(np.int64)
    ref[:, :4] = np.where(A >= 0, A64, np.round(0.5 * A64)).astype(np.int32)
    ref[0::2, 4:12] = np.where(B >= 0, B64,
                               np.round(0.25 * B64)).astype(np.int32)
    np.testing.assert_array_equal(got, ref)
    out = cop.gather(aO, n, n, ElemWidth.W)
    np.testing.assert_array_equal(out, np.maximum(ref, 0))


def test_unequal_stride_strips_overlap_in_pipelined_schedule():
    """The two unequal-stride strip writers must actually run concurrently:
    with a false aliasing edge kernel 1 could only claim the allocator after
    kernel 0 retired; exact aliasing lets it claim while kernel 0 is still
    streaming/computing."""
    cop = make_cop("pipelined")
    rng = np.random.default_rng(3)
    n = 64
    A = rng.integers(-9, 9, (n, 16), dtype=np.int32)
    B = rng.integers(-9, 9, (32, 32), dtype=np.int32)
    aA, aB = cop.place(A, ElemWidth.W), cop.place(B, ElemWidth.W)
    aD = cop.malloc(n * n * 4)
    cop._xmr_w(0, aA, 0, n, 16)
    cop._xmr_w(1, aB, 0, 32, 32)
    cop._xmr_w(2, aD, n, n, 16)                  # all rows, cols 0-15
    cop._xmr_w(3, aD + 64, 2 * n, 32, 32)        # even rows, cols 16-47
    cop._leakyrelu(ElemWidth.W, 2, 0, alpha=0.5)
    cop._leakyrelu(ElemWidth.W, 3, 1, alpha=0.25)
    cop.barrier()
    recs = cop.rt.tracer.records
    k0_compute_end = max(r.start + r.duration for r in recs
                         if r.phase == "compute"
                         and dict(r.args).get("kernel") == 0)
    k1_claim_start = min(r.start for r in recs
                         if "claim" in r.name
                         and dict(r.args).get("kernel") == 1)
    assert k1_claim_start < k0_compute_end, "strips serialized by false edge"


# ------------------------------------------------ row-chunked DMA/compute
def chunked_cop(row_chunk):
    return ArcaneCoprocessor(runtime=PipelinedRuntime(
        row_chunk=row_chunk, n_vpus=4, vregs_per_vpu=16, vlen_bytes=512))


def lrelu_chain(cop, seed=5, batch=3, n=16):
    """Independent LeakyReLU kernels on fresh inputs — elementwise dataflow,
    so every operand DMA is row-chunkable and the chunks legitimately gate
    compute piece-for-piece."""
    rng = np.random.default_rng(seed)
    outs, addrs = [], []
    for i in range(batch):
        X = rng.integers(-9, 9, (n, n), dtype=np.int32)
        aX = cop.place(X, ElemWidth.W)
        aO = cop.malloc(n * n * 4)
        cop._xmr_w(2 * i % 8, aX, 0, n, n)
        cop._xmr_w((2 * i + 1) % 8, aO, 0, n, n)
        cop._leakyrelu(ElemWidth.W, (2 * i + 1) % 8, 2 * i % 8, alpha=0.25)
        addrs.append(aO)
    cop.barrier()
    for aO in addrs:
        outs.append(cop.gather(aO, n, n, ElemWidth.W))
    return outs


def test_row_chunked_overlap_reduces_makespan_same_outputs():
    outs, makespans = {}, {}
    for rc in (0, 4):
        cop = chunked_cop(rc)
        outs[rc] = lrelu_chain(cop, seed=5)
        makespans[rc] = cop.rt.sim_time
    for a, b in zip(outs[0], outs[4]):
        np.testing.assert_array_equal(a, b)      # timing model only
    assert makespans[4] < makespans[0], makespans


def test_row_chunked_dma_and_compute_intervals():
    """With row_chunk=4 a 16-row elementwise operand DMA splits into 4 chunk
    intervals, and the first compute piece starts before the last DMA chunk
    ends — intra-instruction pipelining in the trace."""
    cop = chunked_cop(4)
    rng = np.random.default_rng(7)
    A = rng.integers(-9, 9, (16, 16), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD = cop.malloc(16 * 16 * 4)
    cop._xmr_w(0, aA, 0, 16, 16)
    cop._xmr_w(1, aD, 0, 16, 16)
    cop._leakyrelu(ElemWidth.W, 1, 0, alpha=0.25)
    cop.barrier()
    dma = [r for r in cop.rt.tracer.records
           if r.phase == "allocation" and "dma-in" in r.name]
    comp = [r for r in cop.rt.tracer.records if r.phase == "compute"]
    assert len(dma) == 4 and len(comp) == 4
    assert comp[0].start < dma[-1].start + dma[-1].duration
    # chunk cycles conserve the un-chunked totals
    s = cop.rt.stats
    assert sum(r.duration for r in dma) + 120 == s.allocation_cycles
    assert sum(r.duration for r in comp) == s.compute_cycles
    A64 = A.astype(np.int64)
    ref = np.where(A >= 0, A64, np.round(0.25 * A64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aD, 16, 16, ElemWidth.W), ref)


def test_row_chunk_zero_single_interval():
    cop = chunked_cop(0)
    rng = np.random.default_rng(7)
    A = rng.integers(-9, 9, (16, 16), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD = cop.malloc(16 * 16 * 4)
    cop._xmr_w(0, aA, 0, 16, 16)
    cop._xmr_w(1, aD, 0, 16, 16)
    cop._gemm_w(1, 0, 0, 0)
    cop.barrier()
    dma = [r for r in cop.rt.tracer.records
           if r.phase == "allocation" and "dma-in" in r.name]
    assert len(dma) == 1


def test_split_helpers():
    from repro.sim import row_chunks, split_proportional
    assert row_chunks(10, 4) == [4, 4, 2]
    assert row_chunks(10, 0) == [10]
    assert row_chunks(0, 4) == []
    parts = split_proportional(103, [4, 4, 2])
    assert sum(parts) == 103 and len(parts) == 3
    assert split_proportional(0, [1, 2]) == [0, 0]
    with pytest.raises(ValueError):
        split_proportional(10, [0, 0])


# ------------------------------------------- trace/PhaseStats consistency
def test_trace_phase_totals_match_phase_stats():
    """Regression: consolidation write-back cycles used to be booked inside
    the 'dma-in' allocation interval, so trace phase totals disagreed with
    PhaseStats. The cross-VPU move workload exercises consolidation."""
    cop = make_cop("pipelined")
    rng = np.random.default_rng(0)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    B = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA, aB = cop.place(A, ElemWidth.W), cop.place(B, ElemWidth.W)
    aT1, aT2, aO = (cop.malloc(8 * 8 * 4) for _ in range(3))
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aB, 0, 8, 8)
    cop._xmr_w(2, aT1, 0, 8, 8)
    cop._xmr_w(3, aT2, 0, 8, 8)
    cop._xmr_w(4, aO, 0, 8, 8)
    cop._gemm_w(2, 0, 0, 0)                      # T1 on one VPU
    cop._gemm_w(3, 1, 1, 1)                      # T2 on another
    cop._gemm_w(4, 2, 3, 2, alpha=1.0, beta=1.0)  # consumes both: cross-VPU
    cop.barrier()
    phase = cop.rt.tracer.phase_cycles()
    s = cop.rt.stats
    assert phase["allocation"] == s.allocation_cycles
    assert phase["compute"] == s.compute_cycles
    assert phase["writeback"] == s.writeback_cycles
    # consolidation emitted as its own writeback-phase interval
    assert any("consolidate" in r.name for r in cop.rt.tracer.records)
    # xmr decode slices never enter the event timeline
    assert phase["preamble"] <= s.preamble_cycles
