import os

# Tests see the real (single-device) CPU topology; only dryrun.py forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

try:
    # Capped hypothesis profiles keep tier-1 inside the CI time budget: the
    # workflow exports HYPOTHESIS_PROFILE=ci (25 examples/test); a plain
    # local run keeps hypothesis's own defaults. The dev extra may be absent
    # — property tests importorskip hypothesis per-module.
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", max_examples=25, deadline=None)
    _hyp_settings.register_profile("thorough", max_examples=500,
                                   deadline=None)
    if os.environ.get("HYPOTHESIS_PROFILE"):
        _hyp_settings.load_profile(os.environ["HYPOTHESIS_PROFILE"])
except ImportError:  # pragma: no cover - dev extra absent
    pass


@pytest.fixture
def rng():
    return np.random.default_rng(0)
