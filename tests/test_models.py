"""Per-arch smoke tests (deliverable f) + the golden incremental-decode test."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.core.engine import ArcaneEngine
from repro.models.transformer import LM
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.step import make_train_step

ENGINE = ArcaneEngine(backend="ref")


def make_batch(cfg, rng, b=2, s=32, dtype=None):
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab, (b, s)))}
    dt = dtype or cfg.cdtype
    if cfg.vision_prefix:
        batch["vision_embeds"] = jnp.array(
            rng.standard_normal((b, cfg.vision_prefix, cfg.d_model)), dt)
    if cfg.enc_dec:
        batch["audio_embeds"] = jnp.array(
            rng.standard_normal((b, s, cfg.d_model)), dt)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch, rng):
    """Reduced config: one forward + one train step, shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    model = LM(cfg, ENGINE)
    params = model.init_params(jax.random.key(0))
    batch = make_batch(cfg, rng)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    opt_cfg = AdamWConfig(total_steps=10, warmup_steps=2)
    opt = adamw_init(opt_cfg, params)
    step = jax.jit(make_train_step(model, opt_cfg))
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_golden_incremental_decode(arch, rng):
    """Prefill + token-by-token decode must match the parallel forward."""
    cfg = get_smoke_config(arch)
    repl = dict(param_dtype="float32", compute_dtype="float32")
    if cfg.moe is not None:   # avoid capacity-drop divergence between paths
        repl["moe"] = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    cfg = dataclasses.replace(cfg, **repl)
    model = LM(cfg, ENGINE)
    params = model.init_params(jax.random.key(1))
    B, S = 2, 16
    toks = jnp.array(rng.integers(0, cfg.vocab, (B, S)))
    batch = make_batch(cfg, rng, B, S, dtype=jnp.float32)
    batch["tokens"] = toks
    logits_full, _ = jax.jit(model.forward)(params, batch)
    P = S - 4
    off = cfg.vision_prefix
    pb = dict(batch)
    pb["tokens"] = toks[:, :P]
    enc = S if cfg.enc_dec else 0
    cache = model.init_cache(B, 64, dtype=jnp.float32, enc_len=enc)
    lg, cache = jax.jit(model.prefill)(params, pb, cache)
    errs = [float(jnp.max(jnp.abs(lg - logits_full[:, P - 1])))]
    step = jax.jit(lambda p, t, po, c: model.decode_step(p, t, po, c,
                                                         enc_len=enc))
    for i in range(P, S):
        pos = jnp.full((B,), off + i, jnp.int32)
        lg, cache = step(params, toks[:, i], pos, cache)
        if i < S - 1:
            errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, i]))))
    assert max(errs) < 2e-3, f"{arch}: {errs}"


def test_full_configs_param_counts():
    """Full (non-smoke) configs expose sane analytic parameter counts."""
    expect = {
        "granite-moe-1b-a400m": (1.0e9, 1.7e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
        "stablelm-3b": (2.5e9, 3.8e9),
        "gemma2-9b": (8.0e9, 11e9),
        "minicpm3-4b": (3.4e9, 5.0e9),
        "qwen2.5-32b": (30e9, 36e9),
        "internvl2-1b": (0.4e9, 1.2e9),
        "jamba-1.5-large-398b": (330e9, 440e9),
        "rwkv6-1.6b": (1.3e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_moe_active_lt_total():
    for arch in ("granite-moe-1b-a400m", "llama4-scout-17b-a16e",
                 "jamba-1.5-large-398b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < cfg.param_count()


def test_engine_trace_records_xmnmc_words(rng):
    eng = ArcaneEngine(backend="ref", record=True)
    cfg = get_smoke_config("qwen2.5-32b")
    model = LM(cfg, eng)
    params = model.init_params(jax.random.key(0))
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab, (1, 8)))}
    model.forward(params, batch)   # trace eagerly
    assert len(eng.trace) > 0
    mnems = {t.mnemonic for t in eng.trace}
    assert any(m.startswith("xmk0") for m in mnems)   # GeMM dispatches
    for t in eng.trace:
        assert t.word & 0x7F == 0x5B                  # all Custom-2


def test_ring_decode_matches_forward(rng):
    """Ring-buffer local KV cache (§Perf iteration 5) must be decode-exact."""
    cfg = get_smoke_config("gemma2-9b")
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32",
                              ring_local_cache=True, local_window=8)
    model = LM(cfg, ENGINE)
    params = model.init_params(jax.random.key(1))
    B, S = 2, 24
    toks = jnp.array(rng.integers(0, cfg.vocab, (B, S)))
    logits_full, _ = jax.jit(model.forward)(params, {"tokens": toks})
    P = S - 8
    cache = model.init_cache(B, 64, dtype=jnp.float32)
    assert cache[0]["k"].shape[3] == 8      # local layer ring is window-sized
    lg, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :P]}, cache)
    errs = [float(jnp.max(jnp.abs(lg - logits_full[:, P - 1])))]
    step = jax.jit(model.decode_step)
    for i in range(P, S):
        pos = jnp.full((B,), i, jnp.int32)
        lg, cache = step(params, toks[:, i], pos, cache)
        if i < S - 1:
            errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, i]))))
    assert max(errs) < 2e-3, errs
