"""Column-tiled 2D dataflow + cross-instruction operand reuse (tentpole).

Covers the TILED descriptor algebra, TileTrain gating, bit-identity of the
tiled/reused schedules against the serial oracle, the strip-mined-GEMM reuse
win (B re-fetch eliminated), the per-operand FULL lower bound under tiling,
and the config/YAML/trace surfaces of the new knobs.
"""
import numpy as np
import pytest

from repro.core import ArcaneCoprocessor, ElemWidth
from repro.core.dataflow import (ELEMENTWISE, FULL, FlowKind, OperandFlow,
                                 TILED, windowed)
from repro.core.regions import StridedRegion
from repro.core.runtime import CacheRuntime
from repro.sim import PipelinedRuntime, SimConfig, TileTrain, tile_entries


def make_cop(scheduler, **kw):
    kw.setdefault("n_vpus", 2)
    kw.setdefault("vregs_per_vpu", 32)
    kw.setdefault("vlen_bytes", 512)
    if scheduler == "serial":
        for k in ("tiling", "reuse", "row_chunk", "dataflow"):
            kw.pop(k, None)
        return ArcaneCoprocessor(runtime=CacheRuntime(**kw))
    return ArcaneCoprocessor(runtime=PipelinedRuntime(**kw))


# ----------------------------------------------------------- TILED algebra
def test_tiled_combines_axis_policies():
    b_flow = TILED(FULL, ELEMENTWISE)
    assert b_flow.kind is FlowKind.FULL
    assert b_flow.col_kind is FlowKind.ELEMENTWISE
    conv = TILED(windowed(3, blocks=3), windowed(2))
    assert conv.blocks == 3 and conv.window_rows == 3
    assert conv.col_kind is FlowKind.WINDOWED and conv.window_cols == 2
    # 1D flows are 2D flows with a FULL column axis
    assert ELEMENTWISE.col_kind is FlowKind.FULL
    with pytest.raises(ValueError, match="window_cols"):
        OperandFlow(FlowKind.FULL, col_kind=FlowKind.ELEMENTWISE,
                    window_cols=2)
    with pytest.raises(ValueError, match="plain 1-axis"):
        TILED(FULL, windowed(2, blocks=3))


def test_cols_required_math():
    f = TILED(FULL, ELEMENTWISE)
    assert f.cols_required(0, 4, 16) == 4
    assert f.cols_required(3, 4, 16) == 16
    assert FULL.cols_required(0, 4, 16) == 16          # column axis FULL
    w = TILED(ELEMENTWISE, windowed(3))
    assert w.cols_required(0, 4, 16) == 7
    assert w.cols_required(3, 4, 16) == 16


def test_library_tile_policies():
    from repro.core.isa import default_library
    lib = default_library()
    a, b, c = lib.lookup(0).dataflow(((4, 8), (8, 6), (4, 6)), {}, ElemWidth.W)
    assert (a.kind, a.col_kind) == (FlowKind.ELEMENTWISE, FlowKind.FULL)
    assert (b.kind, b.col_kind) == (FlowKind.FULL, FlowKind.ELEMENTWISE)
    assert (c.kind, c.col_kind) == (FlowKind.ELEMENTWISE,
                                    FlowKind.ELEMENTWISE)
    (x, f) = lib.lookup(3).dataflow(((8, 8), (3, 4)), {}, ElemWidth.W)
    assert x.col_kind is FlowKind.WINDOWED and x.window_cols == 4
    assert f.col_kind is FlowKind.FULL
    (cl, _) = lib.lookup(4).dataflow(((24, 8), (9, 3)), {}, ElemWidth.W)
    assert cl.col_kind is FlowKind.WINDOWED and cl.window_cols == 5


# ------------------------------------------------------- TileTrain gating
def test_tile_train_2d_gate():
    # One block, 2 bands x 2 col tiles; tiles land at distinct times.
    tr = TileTrain(cum_rows=[[4, 8]], cum_cols=[8, 16],
                   end_times=[[[10, 40], [20, 50]]])
    assert tr.pace == 2 and tr.col_pace == 2
    assert tr.piece_weights() == [4, 4] and tr.col_weights() == [8, 8]
    ew2d = TILED(ELEMENTWISE, ELEMENTWISE)
    # piece (0,0) needs rows<=4, cols<=8 -> tile (0,0) only
    assert tr.gate(ew2d, 0, 2, 0, 2) == 10
    # piece (0,1) needs all cols of band 0
    assert tr.gate(ew2d, 0, 2, 1, 2) == 40
    # piece (1,0) needs both bands' first tiles
    assert tr.gate(ew2d, 1, 2, 0, 2) == 20
    assert tr.gate(ew2d, 1, 2, 1, 2) == 50
    # row-FULL/col-streamed (GEMM B): piece (0,0) needs whole col tile 0
    bf = TILED(FULL, ELEMENTWISE)
    assert tr.gate(bf, 0, 2, 0, 2) == 20
    assert tr.gate(bf, 0, 2, 1, 2) == 50
    # 1D call signature still works (single implicit col piece = everything)
    assert tr.gate(ELEMENTWISE, 0, 2) == 40


def test_tile_entries_orders():
    # band-major: all col tiles of a band before the next band
    assert tile_entries([[4, 4]], [8, 8]) == [
        (0, 0, 0), (0, 0, 1), (0, 1, 0), (0, 1, 1)]
    # col-major (row-FULL operands): whole col tile first
    assert tile_entries([[4, 4]], [8, 8], col_major=True) == [
        (0, 0, 0), (0, 1, 0), (0, 0, 1), (0, 1, 1)]
    # blocks round-robin at band granularity
    assert tile_entries([[2, 2], [2, 2]], [4]) == [
        (0, 0, 0), (1, 0, 0), (0, 1, 0), (1, 1, 0)]


# ----------------------------------------------------- region containment
def test_region_contains_exact_cases():
    dense = StridedRegion(addr=0, rows=8, row_bytes=32, stride_bytes=32)
    assert dense.contains(StridedRegion(64, 2, 32, 32))      # sub-band
    assert dense.contains(StridedRegion(10, 1, 5, 5))        # arbitrary run
    assert not dense.contains(StridedRegion(0, 8, 32, 64))   # pokes past end
    strided = StridedRegion(addr=0, rows=8, row_bytes=16, stride_bytes=64)
    assert strided.contains(strided)
    assert strided.contains(StridedRegion(128, 2, 16, 64))   # row sub-band
    assert strided.contains(StridedRegion(4, 8, 8, 64))      # column tile
    assert not strided.contains(StridedRegion(8, 8, 16, 64))  # spills to gap
    assert not strided.contains(StridedRegion(0, 8, 16, 32))  # hits gaps
    assert not strided.contains(StridedRegion(16, 1, 8, 8))  # inside a gap
    # unequal strides decided row-by-row
    assert strided.contains(StridedRegion(0, 4, 16, 128))
    assert not strided.contains(StridedRegion(0, 4, 16, 96))


def test_region_contains_oracle():
    """Exhaustive byte-set oracle over a small parameter sweep."""
    def byteset(r):
        out = set()
        for i in range(r.rows):
            s = r.addr + i * r.stride_bytes
            out.update(range(s, s + r.row_bytes))
        return out

    regions = [StridedRegion(a, rows, rb, sb)
               for a in (0, 3, 7)
               for rows in (1, 2, 3)
               for rb in (2, 4)
               for sb in (2, 4, 6, 8)]
    for ra in regions:
        sa = byteset(ra)
        for rb_ in regions:
            assert ra.contains(rb_) == (byteset(rb_) <= sa), (ra, rb_)


# --------------------------------------------------- workloads + identity
def strip_gemm(cop, strips=6, m=4, k=32, n=32, seed=3):
    """Strip-mined GEMM: thin A strips against one shared B (DMA-bound, so
    the repeated B fetch sits on the critical path)."""
    rng = np.random.default_rng(seed)
    B = rng.integers(-9, 9, (k, n), dtype=np.int32)
    aB = cop.place(B, ElemWidth.W)
    outs = []
    for _ in range(strips):
        A = rng.integers(-9, 9, (m, k), dtype=np.int32)
        aA = cop.place(A, ElemWidth.W)
        aD = cop.malloc(m * n * 4)
        cop._xmr_w(0, aA, 0, m, k)
        cop._xmr_w(1, aB, 0, k, n)
        cop._xmr_w(2, aD, 0, m, n)
        cop._gemm_w(2, 0, 1, 2, alpha=1.0, beta=0.0)
        outs.append((aD, A, B, (m, n)))
    cop.barrier()
    return outs


def check_strip_gemm(cop, outs):
    for aD, A, B, shape in outs:
        ref = (A.astype(np.int64) @ B.astype(np.int64)).astype(np.int32)
        np.testing.assert_array_equal(
            cop.gather(aD, *shape, ElemWidth.W), ref)


MODES = [
    {},                                        # PR-3 row trains
    {"tiling": (4, 8)},                        # 2D tiles
    {"tiling": (0, 8)},                        # col tiles, row_chunk bands
    {"reuse": True},                           # reuse without tiling
    {"tiling": (4, 8), "reuse": True},         # both
]


@pytest.mark.parametrize("mode", MODES)
def test_strip_gemm_bit_identical_and_bounded(mode):
    cop_s = make_cop("serial")
    outs_s = strip_gemm(cop_s)
    check_strip_gemm(cop_s, outs_s)
    cop_p = make_cop("pipelined", **mode)
    outs_p = strip_gemm(cop_p)
    check_strip_gemm(cop_p, outs_p)
    cop_s.rt.cache.flush_all()      # write-back LLC: land host-dirty lines
    cop_p.rt.cache.flush_all()
    np.testing.assert_array_equal(cop_s.rt.memory.data,
                                  cop_p.rt.memory.data)
    assert cop_p.rt.sim_time <= cop_s.rt.stats.total_cycles


from tests.test_dataflow import LIBRARY_KERNELS, _issue_kernel  # noqa: E402


@pytest.mark.parametrize("kernel", LIBRARY_KERNELS)
@pytest.mark.parametrize("mode", [{"tiling": (4, 8)},
                                  {"tiling": (2, 4), "reuse": True}])
def test_all_kernels_bit_identical_under_tiling(kernel, mode):
    cop_s = make_cop("serial")
    rng = np.random.default_rng(11)
    aD, shape, ref = _issue_kernel(cop_s, kernel, rng)
    cop_s.barrier()
    np.testing.assert_array_equal(cop_s.gather(aD, *shape, ElemWidth.W), ref)
    cop_p = make_cop("pipelined", **mode)
    rng = np.random.default_rng(11)
    aD, shape, ref = _issue_kernel(cop_p, kernel, rng)
    cop_p.barrier()
    np.testing.assert_array_equal(cop_p.gather(aD, *shape, ElemWidth.W), ref)
    cop_s.rt.cache.flush_all()
    cop_p.rt.cache.flush_all()
    np.testing.assert_array_equal(cop_s.rt.memory.data, cop_p.rt.memory.data)
    assert cop_p.rt.sim_time <= cop_s.rt.stats.total_cycles


# ------------------------------------------------------------ reuse wins
def test_strip_gemm_reuse_strictly_faster():
    """Acceptance: reuse on eliminates the repeated B fetch — the makespan is
    strictly below reuse off, outputs stay bit-identical, and the hits are
    counted in PhaseStats."""
    cop_off = make_cop("pipelined")
    strip_gemm(cop_off)
    cop_on = make_cop("pipelined", reuse=True)
    outs = strip_gemm(cop_on)
    check_strip_gemm(cop_on, outs)
    cop_off.rt.cache.flush_all()
    cop_on.rt.cache.flush_all()
    np.testing.assert_array_equal(cop_off.rt.memory.data,
                                  cop_on.rt.memory.data)
    assert cop_on.rt.sim_time < cop_off.rt.sim_time
    assert cop_on.rt.stats.reuse_hits > 0
    assert cop_on.rt.stats.reused_dma_cycles > 0
    assert cop_on.rt.report().reuse_hits == cop_on.rt.stats.reuse_hits
    # the skipped transfers left the allocation phase
    assert cop_on.rt.stats.allocation_cycles \
        == cop_off.rt.stats.allocation_cycles \
        - cop_on.rt.stats.reused_dma_cycles
    # reuse skips are visible as instant markers on the port's operand lane
    marks = [r for r in cop_on.rt.tracer.records if r.instant]
    assert len(marks) == cop_on.rt.stats.reuse_hits
    assert all(r.duration == 0 and "reuse[" in r.name for r in marks)


def test_reuse_invalidated_by_overwrite():
    """A host store over the shared operand's region must kill the modeled
    copy: the next strip re-streams (no stale-hit), and outputs follow the
    new bytes."""
    cop = make_cop("pipelined", reuse=True)
    rng = np.random.default_rng(5)
    n = 16
    B = rng.integers(-9, 9, (n, n), dtype=np.int32)
    aB = cop.place(B, ElemWidth.W)

    def strip(tag):
        A = rng.integers(-9, 9, (n, n), dtype=np.int32)
        aA = cop.place(A, ElemWidth.W)
        aD = cop.malloc(n * n * 4)
        cop._xmr_w(0, aA, 0, n, n)
        cop._xmr_w(1, aB, 0, n, n)
        cop._xmr_w(2, aD, 0, n, n)
        cop._gemm_w(2, 0, 1, 2, alpha=1.0, beta=0.0)
        return aD, A

    run1 = [strip(i) for i in range(3)]
    cop.barrier()
    hits_before = cop.rt.stats.reuse_hits
    B2 = rng.integers(-9, 9, (n, n), dtype=np.int32)
    cop.store(aB, B2, ElemWidth.W)               # invalidates every copy
    run2 = [strip(i) for i in range(2)]
    cop.barrier()
    for aD, A in run1:
        ref = (A.astype(np.int64) @ B.astype(np.int64)).astype(np.int32)
        np.testing.assert_array_equal(cop.gather(aD, n, n, ElemWidth.W), ref)
    for aD, A in run2:
        ref = (A.astype(np.int64) @ B2.astype(np.int64)).astype(np.int32)
        np.testing.assert_array_equal(cop.gather(aD, n, n, ElemWidth.W), ref)
    # run2's first strips on each VPU re-streamed B (no hit off a dead copy);
    # the *data* correctness above is the real guard — reuse is timing-only,
    # so a stale entry would show up as a wrong makespan, never wrong bytes.
    first_dispatches = min(2, cop.rt.cache.n_vpus)
    assert cop.rt.stats.reuse_hits - hits_before <= 2 - first_dispatches + 1


def test_reuse_capacity_evicts_oldest():
    rt = PipelinedRuntime(n_vpus=1, vregs_per_vpu=4, vlen_bytes=256,
                          reuse=True)
    cap = 4 * 256
    r1 = StridedRegion(0, 1, 600, 600)
    r2 = StridedRegion(4096, 1, 600, 600)
    rt._reuse_note(0, r1, 10)
    rt._reuse_note(0, r2, 20)                    # 1200 B > cap: r1 falls out
    assert rt._reuse_lookup(0, r1) is None
    assert rt._reuse_lookup(0, r2) == 20
    assert sum(e.region.nbytes
               for e in rt._reuse_entries[0].values()) <= cap
    assert rt._reuse_bytes[0] == sum(e.region.nbytes
                                     for e in rt._reuse_entries[0].values())


# ------------------------------------------- FULL lower bound under tiles
def test_tiled_gemm_respects_per_operand_lower_bound():
    """PR-3 regression carried into the tile model: no GEMM compute piece
    (i, j) may start before ALL of B's rows for column tile j have landed —
    the tile model must never report a makespan below the per-operand bound."""
    cop = make_cop("pipelined", tiling=(4, 8))
    outs = strip_gemm(cop, strips=3)
    check_strip_gemm(cop, outs)
    recs = cop.rt.tracer.records
    kernels = {dict(r.args)["kernel"] for r in recs
               if r.phase == "compute"}
    for kid in kernels:
        b_dma = [r for r in recs if "dma-in" in r.name
                 and dict(r.args).get("kernel") == kid
                 and dict(r.args).get("operand") == 1]
        comp = [r for r in recs if r.phase == "compute"
                and dict(r.args).get("kernel") == kid]
        assert b_dma and comp
        n_tiles = max(dict(r.args)["tile"] for r in b_dma) + 1
        assert n_tiles > 1, "B was not column-tiled"
        for c in comp:
            pj = dict(c.args)["tile"]
            # compute tile (i, j) waits for B's column tiles 0..j in full
            # (B's rows are FULL; its col tiles pace the compute columns 1:1)
            need = [r for r in b_dma if dict(r.args)["tile"] <= pj]
            assert c.start >= max(r.start + r.duration for r in need), \
                f"k{kid} piece tile {pj} beat B's column tile"
        # B streams column-tile-major: every chunk of tile 0 before any of 1
        t0_end = max(r.start + r.duration for r in b_dma
                     if dict(r.args)["tile"] == 0)
        t1_start = min(r.start for r in b_dma if dict(r.args)["tile"] == 1)
        assert t0_end <= t1_start


def test_tiled_compute_starts_before_full_operand_lands():
    """The win side: with column tiling the first GEMM piece starts once B's
    FIRST column tile lands — strictly before B's whole train ends (the
    untiled model's earliest start). gemm(A, B, A) keeps the accumulator off
    the DMA port (repeated operand) so B's tail tiles are the last stream."""
    cop = make_cop("pipelined", tiling=(4, 8))
    rng = np.random.default_rng(3)
    m, k = 4, 32
    A = rng.integers(-9, 9, (m, k), dtype=np.int32)
    B = rng.integers(-9, 9, (k, k), dtype=np.int32)
    aA, aB = cop.place(A, ElemWidth.W), cop.place(B, ElemWidth.W)
    aD = cop.malloc(m * k * 4)
    cop._xmr_w(0, aA, 0, m, k)
    cop._xmr_w(1, aB, 0, k, k)
    cop._xmr_w(2, aD, 0, m, k)
    cop._gemm_w(2, 0, 1, 0, alpha=1.0, beta=1.0)
    cop.barrier()
    ref = (A.astype(np.int64) @ B.astype(np.int64)
           + A.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aD, m, k, ElemWidth.W), ref)
    recs = cop.rt.tracer.records
    b_dma = [r for r in recs if "dma-in" in r.name
             and dict(r.args).get("operand") == 1]
    comp = [r for r in recs if r.phase == "compute"]
    b_end = max(r.start + r.duration for r in b_dma)
    assert len({dict(r.args)["tile"] for r in b_dma}) > 1
    assert min(r.start for r in comp) < b_end


# ---------------------------------------------------------- config knobs
def test_tiling_requires_dataflow():
    with pytest.raises(ValueError, match="dataflow"):
        PipelinedRuntime(n_vpus=1, vregs_per_vpu=4, vlen_bytes=256,
                         dataflow=False, tiling=(4, 8))
    # (0, 0) means both axes disabled — normalized to None, so it composes
    # with dataflow=False exactly like the SimConfig.tiling property
    rt = PipelinedRuntime(n_vpus=1, vregs_per_vpu=4, vlen_bytes=256,
                          dataflow=False, tiling=(0, 0))
    assert rt.tiling is None
    with pytest.raises(ValueError, match="dataflow"):
        PipelinedRuntime(n_vpus=1, vregs_per_vpu=4, vlen_bytes=256,
                         dataflow=False, reuse=True)
    with pytest.raises(ValueError, match="tiling"):
        PipelinedRuntime(n_vpus=1, vregs_per_vpu=4, vlen_bytes=256,
                         tiling=(-1, 4))


def test_tiling_knob_threads_to_runtime():
    cfg = SimConfig(n_vpus=2, vregs_per_vpu=8, vlen_bytes=256,
                    memory_bytes=1 << 16, tile_rows=4, tile_cols=16,
                    reuse=True)
    rt = cfg.make_runtime("pipelined")
    assert rt.tiling == (4, 16) and rt.reuse is True
    assert SimConfig().tiling is None and SimConfig().reuse is False
    assert SimConfig(reuse="on").reuse is True
    assert SimConfig(reuse="off").reuse is False


def test_tiling_yaml_knob(tmp_path):
    pytest.importorskip("yaml")
    from repro.sim import load_config
    assert load_config("arcane-default").tiling is None
    assert load_config("arcane-default").reuse is False
    cfg8 = load_config("arcane-8vpu")
    assert cfg8.tiling == (4, 32) and cfg8.reuse is True
    (tmp_path / "c.yaml").write_text(
        "extends: arcane-default\n"
        "pipeline: {tiling: {rows: 2, cols: 8}, reuse: on}\n")
    cfg = load_config(str(tmp_path / "c.yaml"))
    assert cfg.tiling == (2, 8) and cfg.reuse is True
    rt = cfg.make_runtime("pipelined")
    assert rt.tiling == (2, 8) and rt.reuse is True


def test_per_tile_trace_lanes_in_chrome_export():
    cop = make_cop("pipelined", tiling=(4, 8))
    outs = strip_gemm(cop, strips=1)
    check_strip_gemm(cop, outs)
    doc = cop.rt.tracer.to_chrome()
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    # B's per-column-tile lanes render as their own thread rows
    assert any(".c0" in n for n in names)
    assert any(".c1" in n for n in names)


def test_fig4_benchmark_tile_reuse_path():
    from benchmarks.fig4_speedup import arcane_cycles
    base, _, _, _ = arcane_cycles(32, 32, 3, ElemWidth.B, 4, "pipelined")
    tiled, _, _, _ = arcane_cycles(32, 32, 3, ElemWidth.B, 4, "pipelined",
                                   tiling=(4, 16), reuse=True)
    assert base > 0 and tiled > 0
    serial, _, _, _ = arcane_cycles(32, 32, 3, ElemWidth.B, 4, "serial")
    assert tiled <= serial
