"""C-RT end-to-end: offload → decode → schedule → allocate → execute → WB."""
import numpy as np
import pytest

from repro.core import (ArcaneCoprocessor, ElemWidth, KernelDef, KernelError,
                        fx_encode)
from repro.core.address_table import RegionKind


def conv2_ref(x, f):
    m, n = x.shape
    km, kn = f.shape
    out = np.zeros((m - km + 1, n - kn + 1), np.int64)
    for i in range(km):
        for j in range(kn):
            out += f[i, j].astype(np.int64) * x[i:i + m - km + 1,
                                                j:j + n - kn + 1]
    return out


@pytest.fixture
def cop():
    return ArcaneCoprocessor(n_vpus=4, vregs_per_vpu=16, vlen_bytes=512)


def test_gemm_int32(cop, rng):
    A = rng.integers(-9, 9, (12, 8), dtype=np.int32)
    B = rng.integers(-9, 9, (8, 10), dtype=np.int32)
    C = rng.integers(-9, 9, (12, 10), dtype=np.int32)
    aA, aB, aC = (cop.place(x, ElemWidth.W) for x in (A, B, C))
    aD = cop.malloc(12 * 10 * 4)
    cop._xmr_w(0, aA, 0, 12, 8)
    cop._xmr_w(1, aB, 0, 8, 10)
    cop._xmr_w(2, aC, 0, 12, 10)
    cop._xmr_w(3, aD, 0, 12, 10)
    cop._gemm_w(3, 0, 1, 2, alpha=1.0, beta=1.0)
    cop.barrier()
    D = cop.gather(aD, 12, 10, ElemWidth.W)
    ref = (A.astype(np.int64) @ B.astype(np.int64) + C).astype(np.int32)
    np.testing.assert_array_equal(D, ref)


@pytest.mark.parametrize("width,np_dt", [(ElemWidth.B, np.int8),
                                         (ElemWidth.H, np.int16),
                                         (ElemWidth.W, np.int32)])
def test_conv_layer_all_widths(cop, rng, width, np_dt):
    H, W, K = 16, 16, 3
    X = rng.integers(-5, 5, (3 * H, W)).astype(np_dt)
    F = rng.integers(-3, 3, (3 * K, K)).astype(np_dt)
    aX, aF = cop.place(X, width), cop.place(F, width)
    om, on = (H - K + 1) // 2, (W - K + 1) // 2
    aR = cop.malloc(om * on * width.nbytes)
    cop._xmr(width, 4, aX, 0, 3 * H, W)
    cop._xmr(width, 5, aF, 0, 3 * K, K)
    cop._xmr(width, 6, aR, 0, om, on)
    cop._conv_layer(width, 6, 4, 5)
    cop.barrier()
    R = cop.gather(aR, om, on, width)
    acc = sum(conv2_ref(X[c * H:(c + 1) * H].astype(np.int64),
                        F[c * K:(c + 1) * K].astype(np.int64))
              for c in range(3))
    pooled = acc[:om * 2, :on * 2].reshape(om, 2, on, 2).max(axis=(1, 3))
    ref = np.maximum(pooled, 0).astype(np_dt)
    np.testing.assert_array_equal(R, ref)


def test_chained_kernels_deferred_writeback(cop, rng):
    """gemm → leakyrelu chain: intermediate stays VPU-resident."""
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aT = cop.malloc(8 * 8 * 4)
    aO = cop.malloc(8 * 8 * 4)
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aT, 0, 8, 8)
    cop._xmr_w(2, aO, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0, alpha=1.0, beta=0.0)
    cop._leakyrelu(ElemWidth.W, 2, 1, alpha=0.25)
    cop.barrier()
    O = cop.gather(aO, 8, 8, ElemWidth.W)
    t = (A.astype(np.int64) @ A.astype(np.int64))
    ref = np.where(t >= 0, t, np.round(0.25 * t)).astype(np.int32)
    np.testing.assert_array_equal(O, ref)
    assert cop.rt.stats.kernels_run == 2


def test_raw_hazard_host_load_forces_completion(cop, rng):
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD = cop.malloc(8 * 8 * 4)
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aD, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0, alpha=1.0, beta=0.0)
    # no explicit barrier — the host load hits the AT and must stall+drain
    D = cop.gather(aD, 8, 8, ElemWidth.W)
    ref = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(D, ref)
    assert cop.rt.at.blocks_load(aD, aD + 1) is None   # region released


def test_war_hazard_host_store(cop, rng):
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD = cop.malloc(8 * 8 * 4)
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aD, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0)
    # store to the source region: must not corrupt the queued kernel
    cop.store(aA, np.zeros((8, 8), np.int32), ElemWidth.W)
    cop.barrier()
    D = cop.gather(aD, 8, 8, ElemWidth.W)
    ref = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(D, ref)


def test_xmr_rejects_invalid_nonzero_stride(cop):
    """Table I: stride 0 means dense; a nonzero stride below cols is a
    programming error (rows would overlap in memory). Regression: it was
    silently clamped to dense, changing which bytes the program addressed."""
    a = cop.malloc(1024)
    with pytest.raises(KernelError, match="stride"):
        cop._xmr_w(0, a, 2, 4, 4)        # 0 < stride(2) < cols(4): reject
    cop._xmr_w(0, a, 0, 4, 4)            # 0 = dense: ok
    cop._xmr_w(0, a, 4, 4, 4)            # stride == cols: ok
    cop._xmr_w(0, a, 9, 4, 4)            # padded rows: ok
    assert cop.rt.matrix_map.lookup(0).stride == 9


def test_host_store_into_strided_gap_does_not_stall(cop, rng):
    """AT entries carry the exact strided footprint: a host store into the
    bytes *between* a queued kernel's source rows is hazard-free and must
    not force a drain (the old interval entries stalled it)."""
    A = rng.integers(-9, 9, (8, 4), dtype=np.int32)
    base = cop.malloc(8 * 16 * 4)        # an 8x16 int32 arena
    # place A as a strided strip: all 8 rows, cols 0-3 of the arena
    for r in range(8):
        cop.store(base + r * 64, A[r], ElemWidth.W)
    aD = cop.malloc(8 * 4 * 4)
    cop._xmr_w(0, base, 16, 8, 4)        # strided source strip
    cop._xmr_w(1, aD, 0, 8, 4)
    cop._leakyrelu(ElemWidth.W, 1, 0, alpha=0.5)
    assert cop.rt.tracker.pending_count() == 1
    # store into cols 8-11 — inside the bounding interval, outside the strip
    cop.store(base + 32, np.ones((1, 4), np.int32), ElemWidth.W)
    assert cop.rt.tracker.pending_count() == 1   # no forced drain
    # store overlapping the strip itself DOES stall-drain (WAR)
    cop.store(base + 64, np.zeros((1, 4), np.int32), ElemWidth.W)
    assert cop.rt.tracker.pending_count() == 0
    A64 = A.astype(np.int64)
    ref = np.where(A >= 0, A64, np.round(0.5 * A64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aD, 8, 4, ElemWidth.W), ref)


def test_preamble_rejects_bad_shapes(cop):
    aA = cop.malloc(64)
    cop._xmr_w(0, aA, 0, 4, 4)
    cop._xmr_w(1, aA + 64, 0, 3, 4)
    cop._xmr_w(2, aA + 128, 0, 4, 4)
    with pytest.raises(KernelError):
        cop._gemm_w(2, 0, 1, 0)     # inner dims 4 vs 3


def test_software_isa_extension(cop, rng):
    """Register a new xmk at runtime — the software-defined ISA property."""
    def pre(shapes, params, width):
        from repro.core.isa import KernelCost
        (m, n) = shapes[0]
        return (m, n), KernelCost(elementwise=m * n)

    def body(sources, params, width):
        return (sources[0].astype(np.int64) * 2).astype(sources[0].dtype)

    cop.rt.library.register(KernelDef(7, "double", 1, pre, body))
    A = rng.integers(-9, 9, (6, 6), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD = cop.malloc(6 * 6 * 4)
    cop._xmr_w(0, aA, 0, 6, 6)
    cop._xmr_w(1, aD, 0, 6, 6)
    cop.xmk(7, ElemWidth.W, md=1, ms1=0)
    cop.barrier()
    np.testing.assert_array_equal(cop.gather(aD, 6, 6, ElemWidth.W), A * 2)


def test_phase_stats_accumulate(cop, rng):
    A = rng.integers(-9, 9, (16, 16), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD = cop.malloc(16 * 16 * 4)
    cop._xmr_w(0, aA, 0, 16, 16)
    cop._xmr_w(1, aD, 0, 16, 16)
    cop._gemm_w(1, 0, 0, 0)
    cop.barrier()
    s = cop.rt.stats
    assert s.preamble_cycles > 0
    assert s.allocation_cycles > 0
    assert s.compute_cycles > 0
    assert s.writeback_cycles > 0
    shares = s.shares()
    assert abs(sum(shares.values()) - 1.0) < 1e-9
