"""LLC functional model: coherence property tests against a flat-memory oracle."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev extra; suite runs without it
from hypothesis import given, settings, strategies as st

from repro.core.cache import (ArcaneCache, CacheLocked, LineBusy, MainMemory,
                              ResourceStall)

MEM = 1 << 14
VLEN = 256


def make_cache(n_vpus=2, vregs=4, vlen=VLEN):
    mem = MainMemory(MEM)
    return ArcaneCache(mem, n_vpus=n_vpus, vregs_per_vpu=vregs,
                       vlen_bytes=vlen), mem


ops_strategy = st.lists(
    st.tuples(
        st.sampled_from(["read", "write", "flush"]),
        st.integers(0, MEM - 64),
        st.integers(1, 64),
    ),
    min_size=1, max_size=60,
)


@given(ops=ops_strategy, data=st.data())
@settings(max_examples=60, deadline=None)
def test_cache_coherence_vs_flat_memory(ops, data):
    """Any sequence of host reads/writes/flushes observes flat-memory
    semantics — the fundamental cache invariant."""
    cache, mem = make_cache()
    oracle = np.zeros(MEM, dtype=np.uint8)
    counter = 0
    for kind, addr, n in ops:
        if kind == "write":
            counter += 1
            buf = np.full(n, counter % 251, np.uint8)
            cache.host_write(addr, buf)
            oracle[addr : addr + n] = buf
        elif kind == "read":
            got = cache.host_read(addr, n)
            np.testing.assert_array_equal(got, oracle[addr : addr + n])
        else:
            cache.flush_all()
            np.testing.assert_array_equal(mem.data, oracle)
    cache.flush_all()
    np.testing.assert_array_equal(mem.data, oracle)


def test_writeback_on_eviction():
    cache, mem = make_cache(n_vpus=1, vregs=2)   # only 2 lines
    cache.host_write(0, np.full(8, 7, np.uint8))
    cache.host_write(VLEN, np.full(8, 8, np.uint8))
    assert mem.data[0] == 0                      # still dirty in cache
    cache.host_read(2 * VLEN, 8)                 # forces eviction
    cache.host_read(3 * VLEN, 8)
    assert mem.data[0] == 7 or mem.data[VLEN] == 8   # one was written back
    cache.flush_all()
    assert mem.data[0] == 7 and mem.data[VLEN] == 8


def test_lru_victim_order():
    cache, _ = make_cache(n_vpus=1, vregs=2)
    cache.host_read(0, 4)          # line A
    cache.host_read(VLEN, 4)       # line B
    cache.host_read(0, 4)          # touch A → B is LRU
    cache.host_read(2 * VLEN, 4)   # evicts B
    assert cache.lookup(0) is not None
    assert cache.lookup(VLEN) is None


def test_lock_blocks_host():
    cache, _ = make_cache()
    assert cache.acquire_lock()
    with pytest.raises(CacheLocked):
        cache.host_read(0, 4)
    assert not cache.acquire_lock()   # not granted twice
    cache.release_lock()
    cache.host_read(0, 4)


def test_busy_computing_lines_stall_host_and_survive_eviction():
    cache, _ = make_cache(n_vpus=1, vregs=2)
    cache.host_read(0, 4)
    idxs = cache.claim_vregs(0, 1)
    with pytest.raises(ResourceStall):
        cache.claim_vregs(0, 2)      # only 1 line left not busy
    # a non-busy line can still be evicted; a miss with ALL lines busy stalls
    idxs2 = cache.claim_vregs(0, 1)  # now both lines busy-computing
    with pytest.raises(ResourceStall):
        cache.host_read(5 * VLEN, 4)
    cache.release_vregs(idxs + idxs2)
    cache.host_read(5 * VLEN, 4)     # now fine


def test_dma_2d_roundtrip():
    cache, mem = make_cache()
    rows, row_b, stride = 6, 24, 40
    base = 512
    src = np.arange(rows * stride, dtype=np.uint8)
    cache.host_write(base, src)
    idxs = cache.claim_vregs(0, 1)
    moved = cache.dma_in_2d(0, idxs, base, rows, row_b, stride)
    assert moved == rows * row_b
    packed = cache._gather_from_lines(idxs, rows * row_b)
    for r in range(rows):
        np.testing.assert_array_equal(
            packed[r * row_b : (r + 1) * row_b],
            src[r * stride : r * stride + row_b])
    # write back to a different region
    out_base = 4096
    cache.dma_out_2d(0, idxs, out_base, rows, row_b, stride)
    cache.release_vregs(idxs)
    got = cache.host_read(out_base, rows * stride)
    for r in range(rows):
        np.testing.assert_array_equal(
            got[r * stride : r * stride + row_b],
            src[r * stride : r * stride + row_b])


def test_stats_hits_misses():
    cache, _ = make_cache()
    cache.host_read(0, 4)
    assert cache.stats.misses == 1
    cache.host_read(1, 4)
    assert cache.stats.hits == 1
