"""Structural validation of exported Chrome traces (satellite).

For serial-equivalent and pipelined runs across the gating/tiling/reuse
modes: every complete event is well-formed and lands on a named thread row,
per-resource busy intervals never overlap, and the trace's per-phase cycle
totals equal ``PhaseStats`` — including runs where reuse skips DMA-ins (the
skipped cycles appear in neither side, only in the instant markers and the
``reused_dma_cycles`` tally).
"""
import json

import numpy as np
import pytest

from repro.core import ArcaneCoprocessor, ElemWidth
from repro.sim import PipelinedRuntime
from repro.sim.trace import PHASES, Tracer


def make_cop(**kw):
    kw.setdefault("n_vpus", 2)
    kw.setdefault("vregs_per_vpu", 32)
    kw.setdefault("vlen_bytes", 512)
    return ArcaneCoprocessor(runtime=PipelinedRuntime(**kw))


def mixed_workload(cop, strips=4, n=16):
    """GEMM strips over a shared B + an elementwise/pool chain: exercises
    DMA trains, consolidations, deferred drains, and (when on) reuse skips."""
    rng = np.random.default_rng(7)
    B = rng.integers(-9, 9, (n, n), dtype=np.int32)
    aB = cop.place(B, ElemWidth.W)
    for i in range(strips):
        A = rng.integers(-9, 9, (n, n), dtype=np.int32)
        aA = cop.place(A, ElemWidth.W)
        aT = cop.malloc(n * n * 4)
        aP = cop.malloc((n // 2) * (n // 2) * 4)
        cop._xmr_w(0, aA, 0, n, n)
        cop._xmr_w(1, aB, 0, n, n)
        cop._xmr_w(2, aT, 0, n, n)
        cop._gemm_w(2, 0, 1, 2, alpha=1.0, beta=0.0)
        cop._xmr_w(4, aP, 0, n // 2, n // 2)
        cop._maxpool(ElemWidth.W, 4, 2, 2, 2)
    cop.barrier()
    return cop


MODES = [
    {"row_chunk": 0},                          # serial-equivalent granularity
    {},                                        # PR-3 row trains
    {"dataflow": False},                       # legacy concatenated gating
    {"tiling": (4, 8)},                        # 2D tile trains
    {"tiling": (4, 8), "reuse": True},         # tiles + reuse skips
    {"reuse": True},                           # reuse on row trains
]


@pytest.mark.parametrize("mode", MODES)
def test_chrome_export_schema(mode, tmp_path):
    cop = mixed_workload(make_cop(**mode))
    doc = cop.rt.tracer.to_chrome()
    events = doc["traceEvents"]
    named = {e["tid"]: e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    complete = [e for e in events if e["ph"] == "X"]
    instants = [e for e in events if e["ph"] == "i"]
    assert complete
    for e in complete:
        assert set(e) >= {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                          "args"}
        assert e["cat"] in PHASES
        assert e["ts"] >= 0 and e["dur"] >= 1
        assert e["tid"] in named
    for e in instants:
        assert e["s"] == "t" and e["tid"] in named and e["cat"] in PHASES
    if mode.get("reuse"):
        assert len(instants) == cop.rt.stats.reuse_hits > 0
    # round-trips through the dump path
    out = cop.rt.tracer.dump(str(tmp_path / "t.json"))
    with open(out) as f:
        assert json.load(f) == doc


@pytest.mark.parametrize("mode", MODES)
def test_per_resource_intervals_never_overlap(mode):
    cop = mixed_workload(make_cop(**mode))
    by_resource: dict = {}
    for r in cop.rt.tracer.records:
        by_resource.setdefault(r.resource, []).append(r)
    assert len(by_resource) >= 3      # ecpu, lock, vpu ports at minimum
    for name, recs in by_resource.items():
        recs = sorted(recs, key=lambda r: (r.start, r.start + r.duration))
        for a, b in zip(recs, recs[1:]):
            assert a.start + a.duration <= b.start, \
                f"{name}: {a.name} overlaps {b.name}"


@pytest.mark.parametrize("mode", MODES)
def test_trace_phase_totals_equal_phase_stats(mode):
    """The trace is a complete account of the modeled cycles: per-phase sums
    equal PhaseStats for every scheduler mode. With reuse on, skipped DMA-ins
    contribute to neither side — their cycles live only in
    ``reused_dma_cycles`` — so the identity still holds."""
    cop = mixed_workload(make_cop(**mode))
    phase = cop.rt.tracer.phase_cycles()
    s = cop.rt.stats
    assert phase["allocation"] == s.allocation_cycles
    assert phase["compute"] == s.compute_cycles
    assert phase["writeback"] == s.writeback_cycles
    # xmr decode slices never enter the event timeline
    assert phase["preamble"] <= s.preamble_cycles
    if mode.get("reuse"):
        assert s.reuse_hits > 0 and s.reused_dma_cycles > 0
        # an identical run without reuse pays exactly the skipped cycles more
        base = {k: v for k, v in mode.items() if k != "reuse"}
        cop_off = mixed_workload(make_cop(**base))
        assert cop_off.rt.stats.allocation_cycles \
            == s.allocation_cycles + s.reused_dma_cycles
    else:
        assert s.reuse_hits == 0 and s.reused_dma_cycles == 0


def test_serial_run_keeps_stats_but_no_trace():
    """The serial scheduler carries the same PhaseStats (shared steps) but
    books no trace activities — PhaseStats is the single accounting source
    both schedulers agree on."""
    from repro.core.runtime import CacheRuntime
    cop = ArcaneCoprocessor(runtime=CacheRuntime(
        n_vpus=2, vregs_per_vpu=32, vlen_bytes=512))
    mixed_workload(cop)
    assert cop.rt.stats.total_cycles > 0
    assert cop.rt.stats.kernels_run == 8
    assert not hasattr(cop.rt, "tracer")
    cop_p = mixed_workload(make_cop())
    assert cop_p.rt.stats.kernels_run == cop.rt.stats.kernels_run


def test_instant_emit_validation():
    tr = Tracer()
    with pytest.raises(ValueError, match="instant"):
        tr.emit("x", "allocation", "r", 0, 5, instant=True)
    rec = tr.emit("x", "allocation", "r", 3, 0, instant=True)
    assert rec.instant and rec.duration == 0
    assert tr.phase_cycles()["allocation"] == 0


@pytest.mark.parametrize("mode", MODES)
def test_counter_tracks_and_flow_events(mode):
    """The enriched export: well-formed counter samples ("ph": "C") tracking
    AT slots / per-VPU occupancy, and flow arrows ("ph": "s"/"f") whose
    endpoints land on rows that carry complete events."""
    cop = mixed_workload(make_cop(**mode))
    tr = cop.rt.tracer
    assert tr.counters, "no counter samples recorded"
    names = {c.name for c in tr.counters}
    assert "at.free_slots" in names
    assert any(n.startswith("vpu") and n.endswith(".lines") for n in names)
    doc = cop.rt.tracer.to_chrome()
    events = doc["traceEvents"]
    rows_with_slices = {e["tid"] for e in events if e["ph"] == "X"}
    counters = [e for e in events if e["ph"] == "C"]
    assert len(counters) == len(tr.counters)
    for e in counters:
        assert e["cat"] == "counter" and e["ts"] >= 0
        assert e["args"] and all(isinstance(v, int)
                                 for v in e["args"].values())
    starts = {e["id"]: e for e in events if e["ph"] == "s"}
    finishes = [e for e in events if e["ph"] == "f"]
    assert len(starts) == len(finishes) == len(tr.flows)
    for e in finishes:
        s = starts[e["id"]]
        assert e["bp"] == "e" and s["ts"] <= e["ts"]
        assert s["tid"] in rows_with_slices
        assert e["tid"] in rows_with_slices
    if mode.get("tiling") and not mode.get("reuse"):
        # tile trains strictly gate compute pieces -> at least one arrow
        assert tr.flows


def test_counter_and_flow_validation():
    tr = Tracer()
    with pytest.raises(ValueError, match="series"):
        tr.counter("empty", 0)
    with pytest.raises(ValueError, match="phase"):
        tr.flow("x", "nope", "a", 0, "b", 1)
    off = Tracer(enabled=False)
    assert off.counter("c", 0, v=1) is None
    assert off.flow("x", "compute", "a", 0, "b", 1) is None
    tr.counter("c", 5, used=3, free=1)
    tr.flow("x", "compute", "a", 0, "b", 9)
    tr.clear()
    assert not tr.counters and not tr.flows and not tr.records


def test_chrome_export_is_deterministically_sorted():
    cop = mixed_workload(make_cop(tiling=(4, 8)))
    events = cop.rt.tracer.to_chrome()["traceEvents"]
    metas = [e for e in events if e["ph"] == "M"]
    assert events[:len(metas)] == metas, "metadata must lead the stream"
    ph_rank = {"C": 0, "X": 1, "i": 2, "s": 3, "f": 4}
    keys = [(e["ts"], e["tid"], ph_rank[e["ph"]], e["name"], e.get("id", -1))
            for e in events[len(metas):]]
    assert keys == sorted(keys)
    # byte-identical across a re-export
    assert json.dumps(cop.rt.tracer.to_chrome()) == json.dumps(
        {"traceEvents": events, "displayTimeUnit": "ms",
         "otherData": {"source": "repro.sim.PipelinedRuntime"}})


def test_dump_creates_parent_directories(tmp_path):
    cop = mixed_workload(make_cop())
    out = cop.rt.tracer.dump(str(tmp_path / "deep" / "nested" / "t.json"))
    with open(out) as f:
        assert json.load(f) == cop.rt.tracer.to_chrome()
