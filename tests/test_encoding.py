"""xmnmc instruction encoding: bit-exact round-trips + properties."""
import pytest
pytest.importorskip("hypothesis")  # dev extra; suite runs without it
from hypothesis import given, settings, strategies as st

from repro.core.encoding import (ElemWidth, IllegalInstruction, InstrWord,
                                 Offload, Operands, OPCODE_CUSTOM2,
                                 XMR_FUNC5, encode_xmk, encode_xmr)


def test_opcode_is_custom2():
    w = InstrWord(func5=0, width=ElemWidth.W).encode()
    assert w & 0x7F == 0x5B


def test_mnemonics():
    assert encode_xmr(ElemWidth.W, 0, 0, 0, 4, 4).instr.mnemonic == "xmr.w"
    assert encode_xmk(0, ElemWidth.B, md=1).instr.mnemonic == "xmk0.b"
    assert encode_xmk(4, ElemWidth.H, md=1).instr.mnemonic == "xmk4.h"


@given(func5=st.integers(0, 31),
       width=st.sampled_from(list(ElemWidth)),
       rs1=st.integers(0, 31), rs2=st.integers(0, 31), rd=st.integers(0, 31))
def test_word_roundtrip(func5, width, rs1, rs2, rd):
    w = InstrWord(func5=func5, width=width, rs1=rs1, rs2=rs2, rd=rd)
    assert InstrWord.decode(w.encode()) == w


@given(addr=st.integers(0, 0xFFFFFFFF), stride=st.integers(0, 0xFFFF),
       md=st.integers(0, 31), cols=st.integers(1, 0xFFFF),
       rows=st.integers(1, 0xFFFF))
def test_xmr_operand_roundtrip(addr, stride, md, cols, rows):
    off = encode_xmr(ElemWidth.W, addr, stride, md, cols, rows)
    ops = off.operands
    assert ops.xmr_addr == addr
    assert ops.xmr_stride == stride
    assert ops.xmr_md == md
    assert ops.xmr_cols == cols
    assert ops.xmr_rows == rows
    assert off.instr.is_xmr


@given(md=st.integers(0, 31), ms1=st.integers(0, 31), ms2=st.integers(0, 31),
       ms3=st.integers(0, 31), alpha=st.integers(0, 0xFFFF),
       beta=st.integers(0, 0xFFFF))
def test_xmk_operand_roundtrip(md, ms1, ms2, ms3, alpha, beta):
    off = encode_xmk(0, ElemWidth.H, md, ms1, ms2, ms3, alpha, beta)
    ops = off.operands
    assert (ops.md, ops.ms1, ops.ms2, ops.ms3) == (md, ms1, ms2, ms3)
    assert (ops.alpha, ops.beta) == (alpha, beta)


def test_illegal_instructions():
    with pytest.raises(IllegalInstruction):
        InstrWord.decode(0x33)            # wrong major opcode
    with pytest.raises(IllegalInstruction):
        # wrong fmt sub-space
        InstrWord.decode((0 << 27) | (0b01 << 25) | OPCODE_CUSTOM2)
    with pytest.raises(IllegalInstruction):
        # invalid width suffix (funct3 = 5)
        InstrWord.decode((0b10 << 25) | (5 << 12) | OPCODE_CUSTOM2)


def test_xmk_index_bounds():
    with pytest.raises(ValueError):
        encode_xmk(31, ElemWidth.W, md=0)   # 31 is reserved for xmr
    with pytest.raises(ValueError):
        encode_xmr(ElemWidth.W, 0, 0, 32, 1, 1)  # md out of range
