"""Multi-device tests (8 host devices via subprocess — device count is locked
at first jax init, so each scenario runs in its own interpreter)."""
import os
import subprocess
import sys
import textwrap

import pytest

# The sharding scenarios use explicit-mode meshes (`jax.sharding.AxisType`,
# jax >= 0.5); on older installs every subprocess dies with the same
# AttributeError, so probe the capability once and skip the module cleanly.
# (Importing jax here is safe — device counts are locked per subprocess.)
jax = pytest.importorskip("jax")
if not hasattr(jax.sharding, "AxisType"):
    pytest.skip("installed jax lacks jax.sharding.AxisType "
                "(explicit-mode mesh API, jax>=0.5)",
                allow_module_level=True)

ENV = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": "src"}


def run_py(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=ENV,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_tp_sharded_train_step_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_smoke_config
        from repro.core.engine import ArcaneEngine
        from repro.models.transformer import LM
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.train.step import make_train_step
        from repro.distributed.sharding import (param_pspecs, batch_pspecs,
                                                to_shardings, zero_pspecs)
        from repro.launch.mesh import make_host_mesh

        cfg = get_smoke_config("qwen2.5-32b")
        cfg = dataclasses.replace(cfg, param_dtype="float32",
                                  compute_dtype="float32")
        model = LM(cfg, ArcaneEngine(backend="ref"))
        params = model.init_params(jax.random.key(0))
        opt_cfg = AdamWConfig(total_steps=10, warmup_steps=0)
        opt = adamw_init(opt_cfg, params)
        rngn = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rngn.integers(0, cfg.vocab, (8, 32)))}
        step = make_train_step(model, opt_cfg)
        # single device reference
        p_ref, _, m_ref = jax.jit(step)(params, opt, batch)
        # sharded (2 data x 4 model)
        mesh = make_host_mesh(model_axis=4)
        with mesh:
            p_sh = to_shardings(param_pspecs(params, mesh), mesh)
            o_sh = to_shardings(zero_pspecs(opt, mesh), mesh)
            b_sh = to_shardings(batch_pspecs(batch, mesh), mesh)
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
            p_out, _, m_out = fn(params, opt, batch)
        assert abs(float(m_ref["loss"]) - float(m_out["loss"])) < 1e-4
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_out)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=2e-3)
        print("TP_OK")
    """)
    assert "TP_OK" in out


def test_compressed_dp_converges_like_uncompressed():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_smoke_config
        from repro.core.engine import ArcaneEngine
        from repro.models.transformer import LM
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.distributed.collectives import (make_compressed_dp_step,
                                                   init_error_feedback)
        from repro.launch.mesh import make_host_mesh
        from repro.data.pipeline import DataConfig, SyntheticLM

        cfg = get_smoke_config("stablelm-3b")
        cfg = dataclasses.replace(cfg, param_dtype="float32",
                                  compute_dtype="float32")
        model = LM(cfg, ArcaneEngine(backend="ref"))
        mesh = make_host_mesh(model_axis=1)   # 8-way DP
        opt_cfg = AdamWConfig(lr=3e-3, total_steps=30, warmup_steps=3)
        src = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                     global_batch=8))

        def train(compress):
            params = model.init_params(jax.random.key(0))
            opt = adamw_init(opt_cfg, params)
            err = init_error_feedback(params)
            step = make_compressed_dp_step(model, opt_cfg, mesh,
                                           compress=compress)
            with mesh:
                losses = []
                for i in range(30):
                    batch = {k: jnp.asarray(v)
                             for k, v in src.batch_at(i).items()}
                    params, opt, err, m = step(params, opt, err, batch)
                    losses.append(float(m["loss"]))
            return losses

        lc = train(True)
        lu = train(False)
        assert lc[-1] < lc[0] - 0.3, lc
        assert abs(lc[-1] - lu[-1]) < 0.25, (lc[-1], lu[-1])
        print("DP_COMPRESS_OK")
    """)
    assert "DP_COMPRESS_OK" in out


def test_pipeline_parallel_forward_parity():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_forward
        mesh = jax.make_mesh((4,), ("stage",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rngn = np.random.default_rng(0)
        ws = jnp.asarray(rngn.standard_normal((4, 16, 16)) * 0.3,
                         jnp.float32)
        x = jnp.asarray(rngn.standard_normal((8, 16)), jnp.float32)

        def stage_fn(w, h):
            return jnp.tanh(h @ w)

        ref = x
        for i in range(4):
            ref = stage_fn(ws[i], ref)
        out = pipeline_forward(stage_fn, ws, x, mesh=mesh, n_micro=4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)
        print("PP_OK")
    """)
    assert "PP_OK" in out


def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint.manager import CheckpointManager
        from repro.launch.mesh import make_host_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P

        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mgr = CheckpointManager({str(tmp_path)!r})
        mesh8 = make_host_mesh(model_axis=8)
        sh8 = {{"w": NamedSharding(mesh8, P(None, "model"))}}
        tree8 = jax.device_put(tree, sh8["w"])
        mgr.save(1, {{"w": tree8}})
        # restore onto a DIFFERENT mesh layout (2-way model)
        mesh2 = make_host_mesh(model_axis=2)
        sh2 = {{"w": NamedSharding(mesh2, P("model", None))}}
        like = jax.eval_shape(lambda: tree)
        restored, _ = mgr.restore(1, {{"w": like}}, shardings={{"w": sh2}})
        np.testing.assert_array_equal(np.asarray(restored["w"]["w"]),
                                      np.asarray(tree["w"]))
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out
