"""KernelProgram IR: validation, builder, serialization, execution contract.

The IR is the single construction path for every driver in the repo (fuzzer,
benchmarks, examples), so its guarantees are tested directly: a program that
validates runs identically on both runtimes and matches the sequential numpy
oracle; a program that cannot run fails at validation with a ProgramError
naming the problem, never mid-schedule.
"""
import numpy as np
import pytest

from repro.core import (ArcaneCoprocessor, Buffer, ElemWidth, KernelOp,
                        KernelProgram, ProgramBuilder, ProgramError, View,
                        issue_program, place_program, reference_images,
                        run_program)
from repro.core.runtime import CacheRuntime
from repro.sim import PipelinedRuntime


def small_program(width=ElemWidth.W) -> KernelProgram:
    b = ProgramBuilder("small", width)
    b.buffer("x", 6, 8, init="random", seed=3, lo=-6, hi=6)
    b.buffer("y", 6, 8)
    b.buffer("p", 3, 4)
    b.op("leakyrelu", [b.full("x")], b.full("y"), alpha=0.5)
    b.op("maxpool", [b.full("y")], b.full("p"), stride=2, win_size=2)
    return b.build()


# ------------------------------------------------------------- validation
def test_builder_builds_and_validates():
    prog = small_program()
    assert prog.n_ops == 2 and len(prog.buffers) == 3
    assert prog.buffer("x").seed == 3


def test_duplicate_buffer_name_rejected():
    b = ProgramBuilder("dup", ElemWidth.W)
    b.buffer("x", 4, 4)
    with pytest.raises(ProgramError, match="x"):
        b.buffer("x", 4, 4)


def test_unknown_kernel_rejected():
    b = ProgramBuilder("bad", ElemWidth.W)
    b.buffer("x", 4, 4)
    with pytest.raises(ProgramError):
        b.op("fft", [b.full("x")], b.full("x"))
        b.build()


def test_view_out_of_bounds_rejected():
    b = ProgramBuilder("oob", ElemWidth.W)
    b.buffer("x", 4, 4)
    b.buffer("y", 4, 4)
    b.op("leakyrelu", [b.view("x", 4, 4, col0=1)], b.full("y"), alpha=0.5)
    with pytest.raises(ProgramError):
        b.build()


def test_wrong_source_count_rejected():
    b = ProgramBuilder("srcs", ElemWidth.W)
    b.buffer("x", 4, 4)
    b.buffer("y", 4, 4)
    b.op("gemm", [b.full("x")], b.full("y"))
    with pytest.raises(ProgramError):
        b.build()


def test_unknown_param_rejected():
    b = ProgramBuilder("param", ElemWidth.W)
    b.buffer("x", 4, 4)
    b.buffer("y", 4, 4)
    b.op("leakyrelu", [b.full("x")], b.full("y"), gamma=2.0)
    with pytest.raises(ProgramError, match="gamma"):
        b.build()


def test_dst_shape_mismatch_rejected():
    b = ProgramBuilder("shape", ElemWidth.W)
    b.buffer("x", 6, 6)
    b.buffer("p", 6, 6)
    # maxpool 2x2/2 over 6x6 -> 3x3, not 6x6
    b.op("maxpool", [b.full("x")], b.full("p"), stride=2, win_size=2)
    with pytest.raises(ProgramError):
        b.build()


def test_fx_overflow_rejected_at_validation():
    b = ProgramBuilder("fx", ElemWidth.W)
    b.buffer("x", 4, 4)
    b.buffer("y", 4, 4)
    b.op("leakyrelu", [b.full("x")], b.full("y"), alpha=200.0)  # > Q8.8 max
    with pytest.raises(ProgramError):
        b.build()


def test_data_buffer_shape_checked():
    with pytest.raises(ProgramError):
        KernelProgram(name="bad", width=ElemWidth.W,
                      buffers=(Buffer(name="d", rows=3, cols=3, init="data",
                                      data=((1, 0), (0, 1))),),
                      ops=()).validate()


# ----------------------------------------------------------- serialization
def test_obj_round_trip():
    prog = small_program()
    clone = KernelProgram.from_obj(prog.to_obj())
    assert clone == prog
    assert clone.validate() is clone


def test_from_obj_malformed():
    with pytest.raises(ProgramError):
        KernelProgram.from_obj({"name": "x"})
    obj = small_program().to_obj()
    obj["ops"][0]["srcs"] = [["x", 0]]     # truncated view record
    with pytest.raises(ProgramError):
        KernelProgram.from_obj(obj)


# --------------------------------------------------------------- execution
@pytest.mark.parametrize("width", [ElemWidth.B, ElemWidth.H, ElemWidth.W])
def test_run_program_matches_oracle_both_runtimes(width):
    prog = small_program(width)
    ref = reference_images(prog)
    for rt in (CacheRuntime(n_vpus=2), PipelinedRuntime(n_vpus=2)):
        run = run_program(rt, prog)
        imgs = run.flushed_images()
        for name, arr in ref.items():
            np.testing.assert_array_equal(imgs[name], arr, err_msg=name)
        assert run.gather("p").shape == (3, 4)


def test_place_issue_split():
    """place_program is untimed layout; issue_program is the whole offload.
    Splitting them equals run_program bit-for-bit."""
    prog = small_program()
    cop = ArcaneCoprocessor(runtime=PipelinedRuntime(n_vpus=2))
    addrs = place_program(cop, prog)
    assert set(addrs) == {b.name for b in prog.buffers}
    issue_program(cop, prog, addrs)
    ref = reference_images(prog)
    cop.rt.cache.flush_all()
    for name, a in addrs.items():
        buf = prog.buffer(name)
        nb = buf.nbytes(prog.width)
        img = (cop.rt.memory.data[a:a + nb].copy()
               .view(np.int32).reshape(buf.rows, buf.cols))
        np.testing.assert_array_equal(img, ref[name], err_msg=name)


def test_gemm_beta_accumulates():
    """The β-path (residual idiom): dst = alpha*A@B + beta*C."""
    b = ProgramBuilder("beta", ElemWidth.W)
    b.data("a", np.arange(4).reshape(2, 2))
    b.data("i", np.eye(2, dtype=np.int64))
    b.data("c", np.full((2, 2), 10, dtype=np.int64))
    b.buffer("y", 2, 2)
    b.op("gemm", [b.full("a"), b.full("i"), b.full("c")], b.full("y"),
         alpha=1.0, beta=1.0)
    prog = b.build()
    run = run_program(CacheRuntime(n_vpus=1), prog)
    np.testing.assert_array_equal(
        run.gather("y"), np.arange(4).reshape(2, 2) + 10)


def test_strided_views_execute():
    """Sub-rectangle views bind as strided xmr reservations; the quadrant
    writes land in the right place and nowhere else."""
    b = ProgramBuilder("strided", ElemWidth.W)
    b.buffer("x", 8, 8, init="random", seed=7, lo=-5, hi=5)
    b.buffer("y", 8, 8)
    b.op("leakyrelu", [b.view("x", 4, 4, row0=2, col0=3)],
         b.view("y", 4, 4, row0=1, col0=1), alpha=0.25)
    prog = b.build()
    ref = reference_images(prog)
    run = run_program(PipelinedRuntime(n_vpus=2), prog)
    got = run.flushed_images()["y"]
    np.testing.assert_array_equal(got, ref["y"])
    mask = np.ones((8, 8), bool)
    mask[1:5, 1:5] = False
    assert (got[mask] == 0).all()    # untouched region stays zeros
