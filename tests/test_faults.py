"""Fault injection, instruction replay, and graceful VPU degradation.

Covers ``repro.sim.faults`` and its hooks across the scheduler stack:

  * **plan determinism** — fault outcomes are a pure function of
    ``(seed, kernel_id)``; explicit schedule entries override the rates;
  * **recoverable tiers are functionally exact** — ECC single/double-bit
    flips and bounded instruction replay leave the flushed memory image
    bit-identical to the fault-free run on *both* schedulers, with the
    recovery work visible in the ``faults.*`` counters, the
    ``fault_replay`` stall bin, and the replay-latency histogram;
  * **graceful degradation** — replay exhaustion and scheduled hard faults
    offline the victim VPU; every model-catalog scenario still completes on
    the survivors (oracle-identical), serving keeps admitting at reduced
    goodput, and only the *last* VPU dying raises :class:`FaultError`;
  * **drain diagnostics** — a wedged open-session drain raises a structured
    :class:`DeadlockError` naming the stuck kernels, their blocked reasons,
    and per-resource horizons;
  * **DSE integration** — ``faults.*`` dotted overrides run through
    ``repro.dse.run_point`` with the golden-tape verification still green.
"""
import numpy as np
import pytest

from repro.core import ArcaneCoprocessor, reference_images, run_program
from repro.core.program import issue_program, place_program
from repro.core.runtime import CacheRuntime
from repro.core.session import RuntimeSession
from repro.dse import MODEL_SCENARIOS, run_point
from repro.sim import (DeadlockError, FaultConfig, FaultError, FaultPlan,
                       KernelFaults, PipelinedRuntime, Request, ServingConfig,
                       ServingDriver, config_from_overrides)
from repro.sim.faults import as_fault_plan

from test_differential import gen_chain_program, gen_program

TIERS = FaultConfig(max_replays=3, ecc_penalty=17, replay_backoff=23,
                    schedule=({"kernel": 0, "kind": "single"},
                              {"kernel": 1, "kind": "double"},
                              {"kernel": 2, "kind": "corrupt", "replays": 2}))


def _run(prog: dict, scheduler: str, faults=None, metrics=True):
    if scheduler == "serial":
        rt = CacheRuntime(**prog["rt"], faults=faults)
    else:
        rt = PipelinedRuntime(**prog["rt"], **prog["pipe"], faults=faults,
                              metrics=metrics)
    return run_program(rt, prog["program"])


def _counters(rt) -> dict:
    return {name: d["value"]
            for name, d in rt.metrics_report()["counters"].items()
            if name.startswith("faults.")}


# ----------------------------------------------------------------- the plan
def test_plan_deterministic_and_keyed_by_kernel_id():
    cfg = FaultConfig(flip_rate=0.4, corrupt_rate=0.3, seed=11)
    a, b = FaultPlan(cfg), FaultPlan(cfg)
    draws = [a.kernel_faults(kid) for kid in range(64)]
    assert draws == [b.kernel_faults(kid) for kid in range(64)]
    # the rates genuinely produce a mix, including clean kernels
    assert any(d is None for d in draws)
    assert any(d is not None and d.ecc_bits == 1 for d in draws)
    assert any(d is not None and d.ecc_bits == 2 for d in draws)
    assert any(d is not None and d.replays for d in draws)
    # reordering queries does not change outcomes (pure in kid)
    c = FaultPlan(cfg)
    assert [c.kernel_faults(kid) for kid in reversed(range(64))] \
        == list(reversed(draws))
    # a different seed is a different plan
    assert draws != [FaultPlan(FaultConfig(flip_rate=0.4, corrupt_rate=0.3,
                                           seed=12)).kernel_faults(kid)
                     for kid in range(64)]
    # flip positions are per-(kid, salt) and in range
    for salt in (0, 1, 16):
        byte, bit = a.flip_position(3, salt, 40)
        assert 0 <= byte < 40 and 0 <= bit < 8


def test_schedule_overrides_win_over_rates():
    cfg = FaultConfig(flip_rate=1.0, double_bit_fraction=1.0, max_replays=2,
                      schedule=({"kernel": 5, "kind": "corrupt",
                                 "replays": 7},))
    plan = FaultPlan(cfg)
    assert plan.kernel_faults(0) == KernelFaults(ecc_bits=2)
    # replays clamp to the budget; the overflow marks exhaustion
    assert plan.kernel_faults(5) == KernelFaults(replays=2, exhausted=True)


def test_noop_configs_collapse_to_none():
    assert as_fault_plan(None) is None
    assert as_fault_plan(FaultConfig()) is None
    assert as_fault_plan({"flip_rate": 0.0}) is None
    assert as_fault_plan({"flip_rate": 0.5}) is not None
    with pytest.raises(TypeError):
        as_fault_plan("not a config")
    with pytest.raises(ValueError):
        FaultConfig(flip_rate=1.5)
    with pytest.raises(ValueError):
        FaultConfig(schedule=({"kind": "single"},))     # no kernel id


# --------------------------------------------------------- recoverable tiers
@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_recoverable_tiers_bit_identical(scheduler):
    """One kernel through each recovery tier: the flushed memory image is
    bit-identical to the fault-free run and the counters attribute every
    injection to its tier."""
    for seed in (4, 5):                 # ≥3-op programs under these seeds
        prog = gen_program(seed)
        if prog["program"].n_ops < 3:
            continue
        base = _run(prog, scheduler)
        faulted = _run(prog, scheduler, faults=TIERS)
        base.rt.cache.flush_all()
        faulted.rt.cache.flush_all()
        np.testing.assert_array_equal(
            base.rt.memory.data, faulted.rt.memory.data,
            err_msg=f"seed {seed}: recoverable faults changed the image")
        assert faulted.rt.stats.kernels_run == prog["program"].n_ops
        c = _counters(faulted.rt)
        # single-bit: injected + corrected; double-bit: injected + replayed
        # (refetch); corrupt(2): 2 injected + 2 replayed. ECC kernels only
        # count when their fetch actually DMA-ed a source.
        assert c["faults.injected"] >= 3
        assert c["faults.corrected"] >= 1
        assert c["faults.replayed"] >= 2
        assert c.get("faults.offlined", 0) == 0


@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_random_plan_bit_identical(scheduler):
    """Rate-driven plans (no schedule): still bit-identical while faults
    stay recoverable, on a long dependency chain."""
    prog = gen_chain_program(3, 24)
    fc = FaultConfig(flip_rate=0.6, double_bit_fraction=0.5,
                     corrupt_rate=0.4, max_replays=6, seed=5)
    base = _run(prog, scheduler)
    faulted = _run(prog, scheduler, faults=fc)
    assert _counters(faulted.rt).get("faults.offlined", 0) == 0, \
        "test premise: this seed must stay within the replay budget"
    base.rt.cache.flush_all()
    faulted.rt.cache.flush_all()
    np.testing.assert_array_equal(base.rt.memory.data, faulted.rt.memory.data)
    assert _counters(faulted.rt)["faults.injected"] > 0


def test_replay_cycles_land_in_fault_replay_bin():
    """Pipelined conservation: replay backoff + re-execution cycles tile
    into the ``fault_replay`` stall bin (busy + Σ stalls == latency holds),
    and every attempt lands in the replay-latency histogram."""
    prog = gen_chain_program(1, 12)
    fc = FaultConfig(max_replays=3, replay_backoff=40,
                     schedule=({"kernel": 2, "kind": "corrupt", "replays": 2},
                               {"kernel": 7, "kind": "corrupt", "replays": 1}))
    faulted = _run(prog, "pipelined", faults=fc)
    rep = faulted.rt.metrics_report()
    assert rep["conservation_ok"]
    bins = {kid: rec.bins["fault_replay"]
            for kid, rec in faulted.rt.metrics.stalls.records.items()}
    assert bins[2] >= 40 + 80           # two attempts' backoff at least
    assert bins[7] >= 40
    assert all(v == 0 for kid, v in bins.items() if kid not in (2, 7))
    hist = rep["histograms"]["fault.replay_latency_cycles"]
    assert hist["count"] == 3
    # serial accounting: the same plan charges stats.fault_cycles and the
    # kernel_serial fault_replay bin without touching the phase shares
    serial = _run(prog, "serial", faults=fc)
    assert serial.rt.stats.fault_cycles >= 40 + 80 + 40
    assert serial.rt.stats.total_cycles \
        > serial.rt.stats.total_cycles - serial.rt.stats.fault_cycles


# ------------------------------------------------------- graceful degradation
@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_replay_exhaustion_offlines_the_vpu(scheduler):
    """A kernel whose corruption outlasts the replay budget retires (its
    last attempt completes on scrubbed state), then its VPU is fenced; the
    rest of the program completes on the survivors, bit-identically."""
    prog = gen_chain_program(1, 24)
    prog["rt"]["n_vpus"] = 2
    fc = FaultConfig(max_replays=2,
                     schedule=({"kernel": 3, "kind": "hard"},))
    base = _run(prog, scheduler)
    faulted = _run(prog, scheduler, faults=fc)
    assert faulted.rt.stats.kernels_run == prog["program"].n_ops
    assert len(faulted.rt.offline) == 1
    assert _counters(faulted.rt)["faults.offlined"] == 1
    base.rt.cache.flush_all()
    faulted.rt.cache.flush_all()
    np.testing.assert_array_equal(base.rt.memory.data, faulted.rt.memory.data)


@pytest.mark.parametrize("scenario", sorted(MODEL_SCENARIOS))
def test_hard_fault_completes_every_model_scenario(scenario):
    """A mid-run hard VPU fault: every model-catalog scenario completes on
    the surviving VPUs, matches the numpy oracle, and its makespan never
    beats the fault-free run."""
    cfg = config_from_overrides("arcane-default", {})
    prog = MODEL_SCENARIOS[scenario](vregs_per_vpu=cfg.vregs_per_vpu,
                                     vlen_bytes=cfg.vlen_bytes)
    ref = reference_images(prog)

    def execute(faults):
        rt = cfg.make_runtime("pipelined")
        rt.faults = as_fault_plan(faults)
        cop = ArcaneCoprocessor(runtime=rt)
        addrs = place_program(cop, prog)
        issue_program(cop, prog, addrs)
        return rt, addrs

    rt0, _ = execute(None)
    hard_at = max(1, rt0.sim_time // 2)
    rt1, addrs = execute(FaultConfig(hard_at=hard_at, hard_vpu=1))
    assert rt1.stats.kernels_run == prog.n_ops
    assert rt1.offline == {1}
    assert _counters(rt1)["faults.offlined"] == 1
    assert rt1.sim_time >= rt0.sim_time
    rt0.cache.flush_all()
    rt1.cache.flush_all()
    np.testing.assert_array_equal(rt0.memory.data, rt1.memory.data)
    from repro.core.program import np_dtype
    dt = np_dtype(prog.width)
    for b in prog.buffers:
        raw = rt1.memory.data[addrs[b.name]:addrs[b.name]
                              + b.nbytes(prog.width)]
        np.testing.assert_array_equal(
            raw.copy().view(dt).reshape(b.rows, b.cols), ref[b.name],
            err_msg=f"{scenario}: {b.name} diverged after the hard fault")


def test_hard_fault_serial_scheduler():
    cfg = config_from_overrides("arcane-default", {})
    prog = MODEL_SCENARIOS["moe-granite"](vregs_per_vpu=cfg.vregs_per_vpu,
                                          vlen_bytes=cfg.vlen_bytes)
    rt0 = cfg.make_runtime("serial")
    run_program(rt0, prog)
    rt1 = cfg.make_runtime("serial")
    rt1.faults = as_fault_plan(FaultConfig(
        hard_at=max(1, rt0.stats.total_cycles // 2), hard_vpu=1))
    run_program(rt1, prog)
    assert rt1.stats.kernels_run == prog.n_ops and rt1.offline == {1}
    rt0.cache.flush_all()
    rt1.cache.flush_all()
    np.testing.assert_array_equal(rt0.memory.data, rt1.memory.data)


def test_last_vpu_dying_raises_fault_error():
    prog = gen_chain_program(2, 8)
    prog["rt"]["n_vpus"] = 1
    fc = FaultConfig(max_replays=1,
                     schedule=({"kernel": 0, "kind": "hard"},))
    with pytest.raises(FaultError, match="no healthy VPU remains"):
        _run(prog, "pipelined", faults=fc)
    with pytest.raises(FaultError, match="no healthy VPU remains"):
        _run(prog, "serial", faults=fc)


def test_serving_survives_midrun_vpu_offline():
    """Serving keeps admitting and finishing through a mid-run hard fault:
    every request completes on the survivor and goodput stays nonzero."""
    reqs = [Request(rid=i, arrival=i * 9_000,
                    prompt_len=3 + i % 3, max_new=2 + i % 2)
            for i in range(5)]
    base = ServingDriver(PipelinedRuntime(n_vpus=2, metrics=True),
                         ServingConfig(kv_max=16, slots=2))
    s0 = base.run(reqs)
    assert s0["finished"] == len(reqs)
    hard_at = base.session.now() // 2
    drv = ServingDriver(
        PipelinedRuntime(n_vpus=2, metrics=True,
                         faults=FaultConfig(hard_at=hard_at, hard_vpu=1)),
        ServingConfig(kv_max=16, slots=2))
    s1 = drv.run(reqs)
    assert s1["finished"] == s1["requests"] == len(reqs)
    assert s1["tokens_generated"] == s0["tokens_generated"]
    assert s1["goodput_tokens_per_kcycle"] > 0
    assert _counters(drv.session.rt)["faults.offlined"] == 1
    assert drv.session.rt.offline == {1}


# --------------------------------------------------------- drain diagnostics
def test_session_drain_raises_structured_deadlock_error():
    """A drain that stops making progress with kernels still pending raises
    DeadlockError carrying the stuck kernel ids, their last blocked reason
    from the stall tracker, and per-resource free_at horizons."""
    prog = gen_program(0)
    rt = PipelinedRuntime(**prog["rt"], **prog["pipe"], metrics=True)
    sess = RuntimeSession(rt)
    # Sever the dependency tracker: every kernel reports one unmet dep that
    # no kernel will ever retire — a genuine, permanent deadlock (both the
    # dispatch gate and the settle fallback's readiness check).
    rt.tracker.unmet_deps = lambda kid: (10 ** 6,)
    rt.tracker.ready = lambda kid: False
    sess.issue(prog["program"])
    with pytest.raises(DeadlockError) as exc:
        sess.drain()
    err = exc.value
    assert err.pending and err.resources
    for kid, info in err.pending.items():
        assert info["kernel"]
        assert info["blocked_on"] == "raw_dep"
        assert info["unmet_deps"] == [10 ** 6]
    assert any(name.endswith(".datapath") or name.endswith(".dma")
               for name in err.resources)
    assert all(isinstance(v, int) for v in err.resources.values())
    assert "deadlock" in str(err)


# ------------------------------------------------------------ DSE integration
def test_dse_point_with_fault_overrides_stays_verified():
    """``faults.*`` are ordinary dotted-override sweep axes: the DSE golden
    tape (serial ≡ pipelined ≡ oracle) stays green under recoverable faults
    and under a mid-run hard fault — recovery is functionally exact."""
    row = run_point({"point_id": "f0", "scenario": "cnn-small",
                     "overrides": {"faults.flip_rate": 0.5,
                                   "faults.corrupt_rate": 0.3,
                                   "faults.seed": 3}})
    assert row["verified"] and row["conservation_ok"]
    row = run_point({"point_id": "f1", "scenario": "cnn-small",
                     "overrides": {"faults.hard_at": 600,
                                   "faults.hard_vpu": 1}})
    assert row["verified"] and row["conservation_ok"]


def test_yaml_faults_section_round_trip():
    cfg = config_from_overrides(
        "arcane-default",
        {"faults.corrupt_rate": 0.2, "faults.max_replays": 5,
         "faults.seed": 9})
    fc = cfg.fault_config()
    assert fc is not None and fc.corrupt_rate == 0.2 and fc.max_replays == 5
    rt = cfg.make_runtime("pipelined")
    assert rt.faults is not None and rt.faults.cfg.seed == 9
    assert config_from_overrides("arcane-default", {}).fault_config() is None
