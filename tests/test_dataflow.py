"""Kernel-aware per-operand dataflow gating: descriptors, bit-identity,
makespan monotonicity, repeated-operand/capacity/drain regressions."""
import numpy as np
import pytest

from repro.core import ArcaneCoprocessor, ElemWidth
from repro.core.address_table import AddressTable, RegionKind
from repro.core.dataflow import (ELEMENTWISE, FULL, FlowKind, OperandFlow,
                                 resolve, windowed)
from repro.core.isa import KernelError, default_library
from repro.core.runtime import CacheRuntime
from repro.sim import PipelinedRuntime, SimConfig


def make_cop(scheduler, dataflow=True, row_chunk=4, **kw):
    kw.setdefault("n_vpus", 4)
    kw.setdefault("vregs_per_vpu", 16)
    kw.setdefault("vlen_bytes", 512)
    if scheduler == "serial":
        return ArcaneCoprocessor(runtime=CacheRuntime(**kw))
    return ArcaneCoprocessor(runtime=PipelinedRuntime(
        dataflow=dataflow, row_chunk=row_chunk, **kw))


# --------------------------------------------------------------- descriptors
def test_default_descriptor_is_full_per_operand():
    flows = resolve(None, ((4, 4), (4, 4)), {}, ElemWidth.W)
    assert flows == (FULL, FULL)


def test_resolve_rejects_wrong_arity_and_type():
    with pytest.raises(ValueError, match="2 operand flows for 1"):
        resolve(lambda s, p, w: (ELEMENTWISE, FULL), ((4, 4),), {},
                ElemWidth.W)
    with pytest.raises(ValueError, match="OperandFlow"):
        resolve(lambda s, p, w: ("full",), ((4, 4),), {}, ElemWidth.W)


def test_operand_flow_validation():
    with pytest.raises(ValueError, match="window_rows"):
        OperandFlow(FlowKind.ELEMENTWISE, window_rows=2)
    with pytest.raises(ValueError, match="blocks"):
        OperandFlow(FlowKind.FULL, blocks=0)
    with pytest.raises(ValueError, match="window_rows"):
        windowed(-1)


def test_rows_required_math():
    # ELEMENTWISE: proportional share, monotone, last piece needs all rows.
    assert ELEMENTWISE.rows_required(0, 4, 16) == 4
    assert ELEMENTWISE.rows_required(3, 4, 16) == 16
    # FULL: everything before the first piece.
    assert FULL.rows_required(0, 4, 16) == 16
    # WINDOWED: share plus lookahead, clamped to the operand.
    w = windowed(3)
    assert w.rows_required(0, 4, 16) == 7
    assert w.rows_required(3, 4, 16) == 16


def test_library_descriptors_match_issue_table():
    lib = default_library()
    gemm = lib.lookup(0).dataflow(((4, 8), (8, 4), (4, 4)), {}, ElemWidth.W)
    assert [f.kind for f in gemm] == [FlowKind.ELEMENTWISE, FlowKind.FULL,
                                      FlowKind.ELEMENTWISE]
    (lrelu,) = lib.lookup(1).dataflow(((4, 4),), {}, ElemWidth.W)
    assert lrelu.kind is FlowKind.ELEMENTWISE
    (mp,) = lib.lookup(2).dataflow(((8, 8),), {"win_size": 3, "stride": 1},
                                   ElemWidth.W)
    assert mp.kind is FlowKind.WINDOWED and mp.window_rows == 3
    conv = lib.lookup(3).dataflow(((8, 8), (3, 3)), {}, ElemWidth.W)
    assert (conv[0].kind, conv[1].kind) == (FlowKind.WINDOWED, FlowKind.FULL)
    cl = lib.lookup(4).dataflow(((24, 8), (9, 3)), {}, ElemWidth.W)
    assert cl[0].kind is FlowKind.WINDOWED and cl[0].blocks == 3
    assert cl[0].window_rows == 5         # k + 2 pool lookahead
    assert cl[1] is FULL


# ------------------------------------------------- per-kernel fixed oracles
def _issue_kernel(cop, name, rng, n=16):
    """Issue one library kernel on fresh deterministic inputs; returns
    (dst_addr, dst_shape, oracle ndarray)."""
    if name == "gemm":
        A = rng.integers(-9, 9, (n, n), dtype=np.int32)
        B = rng.integers(-9, 9, (n, n), dtype=np.int32)
        C = rng.integers(-9, 9, (n, n), dtype=np.int32)
        aA, aB, aC = (cop.place(M, ElemWidth.W) for M in (A, B, C))
        aD = cop.malloc(n * n * 4)
        cop._xmr_w(0, aA, 0, n, n)
        cop._xmr_w(1, aB, 0, n, n)
        cop._xmr_w(2, aC, 0, n, n)
        cop._xmr_w(3, aD, 0, n, n)
        cop._gemm_w(3, 0, 1, 2, alpha=1.0, beta=1.0)
        ref = (A.astype(np.int64) @ B.astype(np.int64)
               + C.astype(np.int64)).astype(np.int32)
        return aD, (n, n), ref
    if name == "leakyrelu":
        X = rng.integers(-9, 9, (n, n), dtype=np.int32)
        aX = cop.place(X, ElemWidth.W)
        aD = cop.malloc(n * n * 4)
        cop._xmr_w(0, aX, 0, n, n)
        cop._xmr_w(1, aD, 0, n, n)
        cop._leakyrelu(ElemWidth.W, 1, 0, alpha=0.25)
        X64 = X.astype(np.int64)
        ref = np.where(X >= 0, X64, np.round(0.25 * X64)).astype(np.int32)
        return aD, (n, n), ref
    if name == "maxpool":
        X = rng.integers(-9, 9, (n, n), dtype=np.int32)
        aX = cop.place(X, ElemWidth.W)
        aD = cop.malloc((n // 2) * (n // 2) * 4)
        cop._xmr_w(0, aX, 0, n, n)
        cop._xmr_w(1, aD, 0, n // 2, n // 2)
        cop._maxpool(ElemWidth.W, 1, 0, 2, 2)
        ref = X.reshape(n // 2, 2, n // 2, 2).max(axis=(1, 3))
        return aD, (n // 2, n // 2), ref
    if name == "conv2d":
        X = rng.integers(-9, 9, (n, n), dtype=np.int32)
        F = rng.integers(-3, 3, (3, 3), dtype=np.int32)
        aX, aF = cop.place(X, ElemWidth.W), cop.place(F, ElemWidth.W)
        m = n - 2
        aD = cop.malloc(m * m * 4)
        cop._xmr_w(0, aX, 0, n, n)
        cop._xmr_w(1, aF, 0, 3, 3)
        cop._xmr_w(2, aD, 0, m, m)
        cop._conv2d(ElemWidth.W, 2, 0, 1)
        from repro.core.isa import _conv2d_valid
        ref = _conv2d_valid(X, F).astype(np.int32)
        return aD, (m, m), ref
    if name == "conv_layer":
        X = rng.integers(-5, 5, (3 * n, n), dtype=np.int32)
        F = rng.integers(-3, 3, (9, 3), dtype=np.int32)
        aX, aF = cop.place(X, ElemWidth.W), cop.place(F, ElemWidth.W)
        cm = n - 2
        om = cm // 2
        aD = cop.malloc(om * om * 4)
        cop._xmr_w(0, aX, 0, 3 * n, n)
        cop._xmr_w(1, aF, 0, 9, 3)
        cop._xmr_w(2, aD, 0, om, om)
        cop._conv_layer(ElemWidth.W, 2, 0, 1)
        from repro.core.isa import _conv2d_valid
        acc = sum(_conv2d_valid(X[c * n:(c + 1) * n], F[c * 3:(c + 1) * 3])
                  for c in range(3))
        pooled = acc[: om * 2, : om * 2].reshape(om, 2, om, 2).max(axis=(1, 3))
        ref = np.maximum(pooled, 0).astype(np.int32)
        return aD, (om, om), ref
    raise KeyError(name)


LIBRARY_KERNELS = ("gemm", "leakyrelu", "maxpool", "conv2d", "conv_layer")


@pytest.mark.parametrize("kernel", LIBRARY_KERNELS)
def test_bit_identity_and_makespan_monotone_all_kernels(kernel):
    """Serial, pipelined(dataflow=on) and pipelined(dataflow=off) must agree
    bit for bit on every library kernel, and either gating model's makespan
    must stay within the serial sum of phases (gating never un-overlaps past
    serial)."""
    results = {}
    for mode in ("serial", "on", "off"):
        cop = make_cop("serial" if mode == "serial" else "pipelined",
                       dataflow=mode == "on")
        rng = np.random.default_rng(11)
        aD, shape, ref = _issue_kernel(cop, kernel, rng)
        cop.barrier()
        out = cop.gather(aD, *shape, ElemWidth.W)
        np.testing.assert_array_equal(out, ref)
        results[mode] = (out, cop)
    np.testing.assert_array_equal(results["serial"][0], results["on"][0])
    np.testing.assert_array_equal(results["serial"][0], results["off"][0])
    serial_total = results["serial"][1].rt.stats.total_cycles
    for mode in ("on", "off"):
        assert results[mode][1].rt.sim_time <= serial_total, (kernel, mode)


# ----------------------------------------------------------- gemm FULL gate
def gemm_strip_workload(cop, strips=4, n=16):
    rng = np.random.default_rng(3)
    addrs = []
    for i in range(strips):
        A = rng.integers(-9, 9, (n, n), dtype=np.int32)
        B = rng.integers(-9, 9, (n, n), dtype=np.int32)
        aA, aB = cop.place(A, ElemWidth.W), cop.place(B, ElemWidth.W)
        aD = cop.malloc(n * n * 4)
        cop._xmr_w(0, aA, 0, n, n)
        cop._xmr_w(1, aB, 0, n, n)
        cop._xmr_w(2, aD, 0, n, n)
        cop._gemm_w(2, 0, 1, 1)
        addrs.append((aD, A, B))
    cop.barrier()
    return addrs


def test_gemm_gated_on_all_of_b():
    """With dataflow on, no GEMM compute piece starts before B's whole train
    has landed, B streams before A (FULL-first port order), and the strip
    workload's makespan is no better than the old concatenated model."""
    cop = make_cop("pipelined", dataflow=True)
    addrs = gemm_strip_workload(cop)
    for aD, A, B in addrs:
        ref = (A.astype(np.int64) @ B.astype(np.int64)).astype(np.int32)
        np.testing.assert_array_equal(
            cop.gather(aD, 16, 16, ElemWidth.W), ref)
    recs = cop.rt.tracer.records
    for kid in range(len(addrs)):
        dma = [r for r in recs if dict(r.args).get("kernel") == kid
               and "dma-in" in r.name]
        comp = [r for r in recs if dict(r.args).get("kernel") == kid
                and r.phase == "compute"]
        b_end = max(r.start + r.duration for r in dma
                    if dict(r.args)["operand"] == 1)
        a_first = min(r.start for r in dma if dict(r.args)["operand"] == 0)
        b_first = min(r.start for r in dma if dict(r.args)["operand"] == 1)
        assert all(c.start >= b_end for c in comp), f"k{kid} beat B's train"
        assert b_first < a_first, "FULL operand B did not stream ahead of A"

    cop_off = make_cop("pipelined", dataflow=False)
    gemm_strip_workload(cop_off)
    assert cop.rt.sim_time >= cop_off.rt.sim_time
    # and the optimistic model really was optimistic here: its first compute
    # piece starts before the sound model's
    first_on = min(r.start for r in recs if r.phase == "compute")
    first_off = min(r.start for r in cop_off.rt.tracer.records
                    if r.phase == "compute")
    assert first_off < first_on


def test_elementwise_overlap_survives_dataflow_gating():
    """The concurrency-win side: an elementwise kernel's first compute piece
    still starts before its operand's last chunk lands."""
    cop = make_cop("pipelined", dataflow=True)
    rng = np.random.default_rng(5)
    X = rng.integers(-9, 9, (16, 16), dtype=np.int32)
    aX = cop.place(X, ElemWidth.W)
    aD = cop.malloc(16 * 16 * 4)
    cop._xmr_w(0, aX, 0, 16, 16)
    cop._xmr_w(1, aD, 0, 16, 16)
    cop._leakyrelu(ElemWidth.W, 1, 0, alpha=0.5)
    cop.barrier()
    recs = cop.rt.tracer.records
    dma_end = max(r.start + r.duration for r in recs if "dma-in" in r.name)
    first_comp = min(r.start for r in recs if r.phase == "compute")
    assert first_comp < dma_end


def test_convlayer_blocked_train_keeps_overlap():
    """The 3-channel conv-layer input streams as three round-robin block
    trains, so early compute pieces start before the stacked operand's train
    finishes (a plain windowed gate over the stacked layout would degenerate
    to FULL)."""
    cop = make_cop("pipelined", dataflow=True, row_chunk=2)
    rng = np.random.default_rng(9)
    aD, shape, ref = _issue_kernel(cop, "conv_layer", rng)
    cop.barrier()
    np.testing.assert_array_equal(cop.gather(aD, *shape, ElemWidth.W), ref)
    recs = cop.rt.tracer.records
    x_dma = [r for r in recs if "dma-in" in r.name
             and dict(r.args)["operand"] == 0]
    assert len(x_dma) > 3
    x_end = max(r.start + r.duration for r in x_dma)
    first_comp = min(r.start for r in recs if r.phase == "compute")
    assert first_comp < x_end


# ------------------------------------------- repeated operands (satellite 1)
def test_repeated_operand_gates_on_single_train():
    """gemm(A, A): one DMA train serves both operand slots; the FULL policy
    on ms2 must gate every compute piece on that train's end — and nothing
    may wait on a second train that is never scheduled (hang risk)."""
    cop = make_cop("pipelined", dataflow=True)
    rng = np.random.default_rng(2)
    A = rng.integers(-9, 9, (16, 16), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD = cop.malloc(16 * 16 * 4)
    cop._xmr_w(0, aA, 0, 16, 16)
    cop._xmr_w(1, aD, 0, 16, 16)
    cop._gemm_w(1, 0, 0, 0)
    cop.barrier()                      # completes — no gate on missing train
    ref = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aD, 16, 16, ElemWidth.W), ref)
    recs = cop.rt.tracer.records
    dma = [r for r in recs if "dma-in" in r.name]
    comp = [r for r in recs if r.phase == "compute"]
    assert len(dma) == 4               # A streamed once, not once per slot
    train_end = max(r.start + r.duration for r in dma)
    assert all(c.start >= train_end for c in comp)


def test_resident_operand_imposes_no_gate():
    """A source already resident from the producing kernel schedules no DMA
    train and therefore no gate: the consumer's compute must not wait on
    chunks that are never scheduled."""
    cop = make_cop("pipelined", dataflow=True)
    rng = np.random.default_rng(4)
    X = rng.integers(-9, 9, (16, 16), dtype=np.int32)
    aX = cop.place(X, ElemWidth.W)
    aT, aO = cop.malloc(16 * 16 * 4), cop.malloc(16 * 16 * 4)
    cop._xmr_w(0, aX, 0, 16, 16)
    cop._xmr_w(1, aT, 0, 16, 16)
    cop._xmr_w(2, aO, 0, 16, 16)
    cop._leakyrelu(ElemWidth.W, 1, 0, alpha=0.5)    # T resident afterwards
    cop._leakyrelu(ElemWidth.W, 2, 1, alpha=0.25)   # reads resident T
    cop.barrier()
    X64 = X.astype(np.int64)
    T = np.where(X >= 0, X64, np.round(0.5 * X64)).astype(np.int32)
    T64 = T.astype(np.int64)
    ref = np.where(T >= 0, T64, np.round(0.25 * T64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aO, 16, 16, ElemWidth.W), ref)
    recs = cop.rt.tracer.records
    k1_dma = [r for r in recs if "dma-in" in r.name
              and dict(r.args).get("kernel") == 1]
    k1_comp = [r for r in recs if r.phase == "compute"
               and dict(r.args).get("kernel") == 1]
    assert not k1_dma                  # operand was resident — no train
    assert len(k1_comp) == 1           # single ungated piece


# --------------------------------------------- AT capacity (satellite 2)
def test_address_table_overflow_raises_kernel_error():
    from repro.core.regions import StridedRegion
    at = AddressTable(capacity=2)
    at.register(StridedRegion(0, 1, 16, 16), RegionKind.SRC, phys_id=1)
    at.register(StridedRegion(64, 1, 16, 16), RegionKind.DST, phys_id=2)
    with pytest.raises(KernelError, match="Address Table full"):
        at.register(StridedRegion(128, 1, 16, 16), RegionKind.SRC, phys_id=3)


@pytest.mark.parametrize("scheduler", ["serial", "pipelined"])
def test_capacity_pressure_forces_deferred_drain(scheduler, rng):
    """A deferred result pinning a DST entry must not crash a small Address
    Table: decode under pressure forces the deferred write-back to land
    (freeing its entry) and the program completes with correct results."""
    cop = make_cop(scheduler)
    cop.rt.at = AddressTable(capacity=4)
    # Manufacture a deferred dirty result pinning a DST entry (the pipelined
    # scheduler's opportunistic drains would otherwise land it early).
    T = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aT = cop.malloc(8 * 8 * 4)
    bT = cop.rt.matrix_map.reserve(1, addr=aT, rows=8, cols=8, stride=8,
                                   width=ElemWidth.W)
    res = cop.rt._claim(cop.rt.vpus[0], bT)
    cop.rt.vpus[0].load_matrix(res, T)
    res.dirty = True
    cop.rt.at.register(bT.region, RegionKind.DST, bT.phys_id)
    assert cop.rt.at.free_slots() == 3
    # gemm on distinct operands needs 4 fresh slots — only possible after
    # the forced drain
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    B = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    C = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA, aB, aC = (cop.place(M, ElemWidth.W) for M in (A, B, C))
    aD = cop.malloc(8 * 8 * 4)
    cop._xmr_w(3, aA, 0, 8, 8)
    cop._xmr_w(4, aB, 0, 8, 8)
    cop._xmr_w(5, aC, 0, 8, 8)
    cop._xmr_w(6, aD, 0, 8, 8)
    cop._gemm_w(6, 3, 4, 5, alpha=1.0, beta=1.0)   # decode triggers the drain
    assert bT.phys_id not in cop.rt.resident       # deferred result landed
    np.testing.assert_array_equal(cop.gather(aT, 8, 8, ElemWidth.W), T)
    cop.barrier()
    refD = (A.astype(np.int64) @ B.astype(np.int64)
            + C.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aD, 8, 8, ElemWidth.W), refD)
    assert cop.rt.at.live_count() == 0


def test_capacity_drain_stops_at_needed_slots(rng):
    """Pressure relief drains only enough deferred results to free the slots
    the decode needs — the rest keep their residency affinity."""
    cop = make_cop("serial")
    cop.rt.at = AddressTable(capacity=5)
    bindings = []
    for i in range(2):
        T = rng.integers(-9, 9, (8, 8), dtype=np.int32)
        aT = cop.malloc(8 * 8 * 4)
        b = cop.rt.matrix_map.reserve(i, addr=aT, rows=8, cols=8, stride=8,
                                      width=ElemWidth.W)
        res = cop.rt._claim(cop.rt.vpus[0], b)
        cop.rt.vpus[0].load_matrix(res, T)
        res.dirty = True
        cop.rt.at.register(b.region, RegionKind.DST, b.phys_id)
        bindings.append(b)
    assert cop.rt.at.free_slots() == 3
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    B = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    C = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA, aB, aC = (cop.place(M, ElemWidth.W) for M in (A, B, C))
    aD = cop.malloc(8 * 8 * 4)
    cop._xmr_w(3, aA, 0, 8, 8)
    cop._xmr_w(4, aB, 0, 8, 8)
    cop._xmr_w(5, aC, 0, 8, 8)
    cop._xmr_w(6, aD, 0, 8, 8)
    cop._gemm_w(6, 3, 4, 5)          # needs 4 slots: drain exactly one result
    assert bindings[0].phys_id not in cop.rt.resident
    assert bindings[1].phys_id in cop.rt.resident   # affinity survives
    cop.barrier()
    refD = (A.astype(np.int64) @ B.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aD, 8, 8, ElemWidth.W), refD)


def test_capacity_pressure_beyond_drain_raises():
    """When even a full drain cannot free enough entries (table smaller than
    one kernel's operand set) the decode rejects with a clear KernelError —
    but repeated operands count once (register up-refs the shared entry), so
    gemm(A, A, A) fits where distinct operands do not."""
    rng = np.random.default_rng(0)
    cop = make_cop("serial")
    cop.rt.at = AddressTable(capacity=3)
    A = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aA = cop.place(A, ElemWidth.W)
    aD = cop.malloc(8 * 8 * 4)
    cop._xmr_w(0, aA, 0, 8, 8)
    cop._xmr_w(1, aD, 0, 8, 8)
    cop._gemm_w(1, 0, 0, 0)                  # 1 SRC entry (x3 refs) + 1 DST
    cop.barrier()
    ref = (A.astype(np.int64) @ A.astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(cop.gather(aD, 8, 8, ElemWidth.W), ref)
    # distinct operands genuinely need 4 slots: clear rejection
    B = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    C = rng.integers(-9, 9, (8, 8), dtype=np.int32)
    aB, aC = cop.place(B, ElemWidth.W), cop.place(C, ElemWidth.W)
    cop._xmr_w(2, aB, 0, 8, 8)
    cop._xmr_w(3, aC, 0, 8, 8)
    with pytest.raises(KernelError, match="Address Table full"):
        cop._gemm_w(1, 0, 2, 3)


# ------------------------------------------------ drain policy (satellite 3)
def test_deferred_drains_complete_during_run():
    """Deferred results whose consumers finished drain on their owning ports
    during the schedule (least-booked-port sweeps chained off wb_done), not
    in the end-of-program barrier flush."""
    cop = make_cop("pipelined")
    rng = np.random.default_rng(8)
    outs = []
    for i in range(4):
        X = rng.integers(-9, 9, (16, 16), dtype=np.int32)
        aX = cop.place(X, ElemWidth.W)
        aT = cop.malloc(16 * 16 * 4)
        aO = cop.malloc(16 * 16 * 4)
        cop._xmr_w(2 * i % 8, aX, 0, 16, 16)
        r1, r2 = (2 * i + 1) % 8, (2 * i + 2) % 8
        cop._xmr_w(r1, aT, 0, 16, 16)
        cop._leakyrelu(ElemWidth.W, r1, 2 * i % 8, alpha=0.5)
        cop._xmr_w(r2, aO, 0, 16, 16)
        cop._leakyrelu(ElemWidth.W, r2, r1, alpha=0.25)
        outs.append((aO, X))
    cop.barrier()
    for aO, X in outs:
        X64 = X.astype(np.int64)
        T = np.where(X >= 0, X64, np.round(0.5 * X64)).astype(np.int64)
        ref = np.where(T >= 0, T, np.round(0.25 * T)).astype(np.int32)
        np.testing.assert_array_equal(cop.gather(aO, 16, 16, ElemWidth.W),
                                      ref)
    names = [r.name for r in cop.rt.tracer.records]
    assert any(n.startswith("drain phys") for n in names)


def test_drain_order_is_least_booked_port_first():
    """With several drainable residents, bookings follow ascending DMA-port
    free_at on the event timelines, not resident insertion order."""
    from repro.sim.events import EventQueue
    rt = PipelinedRuntime(n_vpus=2, vregs_per_vpu=8, vlen_bytes=256)
    b0 = rt.matrix_map.reserve(0, addr=0, rows=2, cols=8, stride=8,
                               width=ElemWidth.W)
    b1 = rt.matrix_map.reserve(1, addr=256, rows=2, cols=8, stride=8,
                               width=ElemWidth.W)
    # Insertion order: vpu0's resident first — but vpu0's port is the busier
    # one, so the drain sweep must book vpu1's resident first.
    rt._claim(rt.vpus[0], b0).dirty = True
    rt._claim(rt.vpus[1], b1).dirty = True
    rt.at.register(b0.region, RegionKind.DST, b0.phys_id)
    rt.at.register(b1.region, RegionKind.DST, b1.phys_id)
    rt.res_dma[0].acquire(0, 500)
    rt.res_dma[1].acquire(0, 100)
    rt._drain_idle_dma(600, {}, EventQueue())
    drains = [r for r in rt.tracer.records if r.name.startswith("drain phys")]
    assert [dict(r.args)["vpu"] for r in drains] == [1, 0]
    assert rt.at.live_count() == 0


# --------------------------------------------------------------- config knob
def test_dataflow_knob_threads_to_runtime(tmp_path):
    cfg = SimConfig(n_vpus=2, vregs_per_vpu=8, vlen_bytes=256,
                    memory_bytes=1 << 16, dataflow=False)
    assert cfg.make_runtime("pipelined").dataflow is False
    assert SimConfig().dataflow is True
    assert SimConfig(dataflow="on").dataflow is True
    assert SimConfig(dataflow="off").dataflow is False
    from repro.sim import ConfigError
    with pytest.raises(ConfigError, match="dataflow"):
        SimConfig(dataflow="sideways")


def test_dataflow_yaml_knob(tmp_path):
    pytest.importorskip("yaml")
    from repro.sim import load_config
    assert load_config("arcane-default").dataflow is True
    assert load_config("arcane-8vpu").dataflow is True
    (tmp_path / "c.yaml").write_text(
        "extends: arcane-default\npipeline: {dataflow: off}\n")
    cfg = load_config(str(tmp_path / "c.yaml"))
    assert cfg.dataflow is False
    assert cfg.make_runtime("pipelined").dataflow is False


def test_per_operand_dma_lanes_in_chrome_export():
    """dma-in activities carry their operand lane into the Chrome export as
    distinct thread rows under the port's row."""
    cop = make_cop("pipelined", dataflow=True)
    rng = np.random.default_rng(11)
    aD, shape, _ = _issue_kernel(cop, "gemm", rng)
    cop.barrier()
    cop.gather(aD, *shape, ElemWidth.W)
    doc = cop.rt.tracer.to_chrome()
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    lanes = {n for n in names if "/op" in n}
    assert any(n.endswith("/op0") for n in lanes)
    assert any(n.endswith("/op1") for n in lanes)
    tid_of_named = {e["args"]["name"]: e["tid"] for e in doc["traceEvents"]
                    if e["ph"] == "M" and e["name"] == "thread_name"}
    complete = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert all(e["tid"] in set(tid_of_named.values()) for e in complete)


# ------------------------------------------------------- property (optional)
def test_random_chains_bit_identical_across_gating_modes():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.sampled_from(["leakyrelu", "maxpool", "gemm"]),
                    min_size=1, max_size=4),
           st.integers(0, 2 ** 31 - 1))
    def prop(kernels, seed):
        outs = {}
        for mode in ("serial", "on", "off"):
            cop = make_cop("serial" if mode == "serial" else "pipelined",
                           dataflow=mode == "on")
            rng = np.random.default_rng(seed)
            got = []
            for k in kernels:
                aD, shape, ref = _issue_kernel(cop, k, rng, n=8)
                got.append((aD, shape))
            cop.barrier()
            outs[mode] = [cop.gather(aD, *shape, ElemWidth.W)
                          for aD, shape in got]
        for a, b, c in zip(outs["serial"], outs["on"], outs["off"]):
            np.testing.assert_array_equal(a, b)
            np.testing.assert_array_equal(a, c)

    prop()
