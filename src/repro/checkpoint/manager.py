"""Fault-tolerant checkpointing: atomic, async, mesh-independent.

Layout (one directory per step)::

    <dir>/step_000120/
        meta.json        # step, paths, shapes, dtypes, extra metadata
        arrays.npz       # flattened pytree, key = path string
    <dir>/LATEST         # atomically replaced pointer file

Properties needed at 1000-node scale, kept in this single-host
implementation in a shape that generalises:

  * **Atomicity** — writes go to ``<dir>/tmp_<step>`` and are ``os.replace``d
    into place; a crash mid-save never corrupts the latest checkpoint.
  * **Async** — ``save(..., blocking=False)`` snapshots to host memory
    (device_get) then writes in a background thread; training continues.
  * **Elastic / mesh-independent restore** — arrays are stored unsharded;
    ``restore(..., shardings=...)`` device_puts onto *any* mesh, so a job can
    resume at a different pod count (the multi-pod → single-pod path is
    tested). At real scale this becomes per-shard files + an index: the
    manager API (save/restore/latest_step) is the stable surface.
  * **Retention** — ``keep`` most recent checkpoints are retained, older ones
    garbage-collected after a successful save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # ml_dtypes (bf16) don't survive the .npy format — store as f32
            # (lossless widening); restore casts back to the model dtype.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: PyTree, *, extra: Optional[dict] = None,
             blocking: bool = True) -> None:
        self.wait()
        flat = _flatten(tree)   # snapshot (host copy) before going async
        meta = {"step": int(step), "extra": extra or {},
                "keys": sorted(flat)}

        def _write():
            try:
                tmp = os.path.join(self.directory, f"tmp_{step:09d}")
                final = os.path.join(self.directory, f"step_{step:09d}")
                os.makedirs(tmp, exist_ok=True)
                np.savez(os.path.join(tmp, "arrays.npz"), **flat)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                latest_tmp = os.path.join(self.directory, ".LATEST.tmp")
                with open(latest_tmp, "w") as f:
                    f.write(f"step_{step:09d}")
                os.replace(latest_tmp, os.path.join(self.directory, "LATEST"))
                self._gc()
            except BaseException as e:   # surfaced on next wait()/save()
                self._error = e

        if blocking:
            _write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_"))
        for d in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.directory, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip().split("_")[1])

    def restore(self, step: int, like: PyTree, *,
                shardings: Optional[PyTree] = None) -> tuple[PyTree, dict]:
        """Rebuild a pytree shaped like ``like`` (reshard-on-load)."""
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}

        leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(
                            leaves_with_path))
        out = []
        for (path, leaf), shd in zip(leaves_with_path, shard_leaves):
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            arr = arrays[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                                 f"model shape {leaf.shape}")
            arr = arr.astype(jax.numpy.dtype(leaf.dtype))
            out.append(jax.device_put(arr, shd) if shd is not None
                       else jax.numpy.asarray(arr))
        return treedef.unflatten(out), meta["extra"]
