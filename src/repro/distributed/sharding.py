"""Sharding rules: logical model axes → mesh axes (MaxText-style, by path).

Meshes: single-pod ``("data", "model") = (16, 16)``; multi-pod adds a leading
``"pod"`` axis that joins the data-parallel group. Rules are
divisibility-aware: a dim that doesn't divide by the candidate axis size falls
back to the next candidate (or replication), so the same rules drive every
(arch × shape) cell, including awkward ones (e.g. 8 KV heads on a 16-way
model axis → the cache shards its sequence dim instead).

Three parameter modes:
  * tp        — weights TP-sharded over "model", replicated over data
  * fsdp      — additionally shard the largest replicated dim over "data"
                (ZeRO-3 for params; required for ≥ 17B assigned archs)
Optimizer state always gets the fsdp treatment (ZeRO-1 minimum).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % axis_size(mesh, axes) == 0


def shard_dim(dim: int, mesh: Mesh, candidates) -> Optional[Any]:
    """First candidate axis (or axis tuple) whose size divides ``dim``."""
    for c in candidates:
        if c is None:
            return None
        if _fits(dim, mesh, c):
            return c
    return None


# --------------------------------------------------------------------- params
# (regex on the param path, per-dim logical role). Roles: "model" candidates
# try TP; "fsdp" dims are where ZeRO sharding lands.
_PARAM_RULES: list[tuple[str, tuple[str, ...]]] = [
    (r"embed/table$", ("model", "fsdp")),          # (V, d): vocab-TP
    (r"unembed/table$", ("model", "fsdp")),
    (r"(attn|cross)/(q|k|v)/w$", ("fsdp", "model")),   # (d, H*hd): head-TP
    (r"(attn|cross)/(q|k|v)/b$", ("model",)),
    (r"(attn|cross)/o/w$", ("model", "fsdp")),         # (H*hd, d)
    (r"(attn|cross)/o/b$", (None,)),
    # --- MLA
    (r"attn/q_down/w$", ("fsdp", None)),
    (r"attn/q_up/w$", (None, "model")),
    (r"attn/kv_down/w$", ("fsdp", None)),
    (r"attn/(k_up|v_up)$", ("model", None, None)),     # (H, r, hd)
    # --- FFN / MoE
    (r"ffn/(gate|up)/w$", ("fsdp", "model")),
    (r"ffn/down/w$", ("model", "fsdp")),
    (r"ffn/(gate|up|down)/b$", (None,)),
    (r"ffn/router/w$", (None, None)),
    (r"ffn/(gate|up)$", ("model", "fsdp", None)),      # (E, d, ff): EP
    (r"ffn/down$", ("model", "fsdp", None)),           # (E, ff, d)
    # --- Mamba
    (r"mixer/in_proj/w$", ("fsdp", "model")),
    (r"mixer/conv_w$", (None, "model")),
    (r"mixer/conv_b$", ("model",)),
    (r"mixer/x_proj/w$", ("model", None)),
    (r"mixer/dt_proj/w$", (None, "model")),
    (r"mixer/dt_bias$", ("model",)),
    (r"mixer/A_log$", ("model", None)),
    (r"mixer/D$", ("model",)),
    (r"mixer/out_proj/w$", ("model", "fsdp")),
    # --- RWKV
    (r"mixer/(r|k|v|g)/w$", ("fsdp", "model")),
    (r"mixer/o/w$", ("model", "fsdp")),
    (r"mixer/(cm_k|cm_r)/w$", ("fsdp", "model")),
    (r"mixer/cm_v/w$", ("model", "fsdp")),
    (r"mixer/wA$", ("fsdp", None)),
    (r"mixer/wB$", (None, "model")),
    (r"mixer/(w0|u)$", ("model",)),
    (r"mixer/ln_scale$", ("model", None)),
    (r"mixer/(mu|cm_mu)$", (None, None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _spec_for(path: str, shape: tuple[int, ...], mesh: Mesh, *,
              fsdp: bool, stacked: bool) -> P:
    roles: Optional[tuple] = None
    for pat, r in _PARAM_RULES:
        if re.search(pat, path):
            roles = r
            break
    ndim = len(shape)
    offset = 1 if stacked else 0         # leading n_periods axis
    spec: list = [None] * ndim
    if roles is not None:
        used_data = False
        for i, role in enumerate(roles):
            di = i + offset
            if di >= ndim or role is None:
                continue
            if role == "model":
                if _fits(shape[di], mesh, "model"):
                    spec[di] = "model"
            elif role == "fsdp" and fsdp and not used_data:
                dax = batch_axes(mesh)
                if dax and _fits(shape[di], mesh, dax):
                    spec[di] = dax if len(dax) > 1 else dax[0]
                    used_data = True
    return P(*spec)


def param_pspecs(params: PyTree, mesh: Mesh, *, fsdp: bool = False) -> PyTree:
    """PartitionSpec tree matching ``params`` (works on ShapeDtypeStructs)."""

    def fn(path, leaf):
        ps = _path_str(path)
        stacked = "blocks" in ps
        return _spec_for(ps, leaf.shape, mesh, fsdp=fsdp, stacked=stacked)

    return jax.tree_util.tree_map_with_path(fn, params)


def zero_pspecs(params: PyTree, mesh: Mesh) -> PyTree:
    """Optimizer-state sharding: params rules + forced fsdp (ZeRO)."""
    return param_pspecs(params, mesh, fsdp=True)


# --------------------------------------------------------------------- batch
def batch_pspecs(batch: PyTree, mesh: Mesh) -> PyTree:
    bax = batch_axes(mesh)

    def fn(leaf):
        if leaf.ndim == 0:
            return P()
        b = leaf.shape[0]
        ax = shard_dim(b, mesh, [bax, bax[-1:] if bax else None, None])
        if ax is not None and not isinstance(ax, str) and len(ax) == 1:
            ax = ax[0]
        return P(ax, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(fn, batch)


# --------------------------------------------------------------------- cache
def cache_pspecs(cache: PyTree, mesh: Mesh) -> PyTree:
    """Decode-cache sharding: batch over data axes; heads over model when
    divisible, else the sequence (page) dim; SSM states shard their channel
    dim. Leaves have a leading n_periods stack axis."""
    bax = batch_axes(mesh)

    def fn(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape           # (n_periods, B, ...)
        spec: list = [None] * len(shape)
        b = shape[1]
        ax = shard_dim(b, mesh, [bax, bax[-1:] if bax else None, None])
        if ax is not None and not isinstance(ax, str) and len(ax) == 1:
            ax = ax[0]
        spec[1] = ax
        if re.search(r"(^|/)(k|v|xk|xv)$", ps):
            # (L, B, Hkv, S, hd)
            if _fits(shape[2], mesh, "model"):
                spec[2] = "model"
            elif _fits(shape[3], mesh, "model"):
                spec[3] = "model"
        elif re.search(r"/(c|kr)$", ps):           # MLA latent (L, B, S, r)
            if _fits(shape[2], mesh, "model"):
                spec[2] = "model"
        elif ps.endswith("/ssm"):                  # (L, B, di, ds)
            if _fits(shape[2], mesh, "model"):
                spec[2] = "model"
        elif ps.endswith("/conv"):                 # (L, B, K-1, di)
            if _fits(shape[3], mesh, "model"):
                spec[3] = "model"
        elif ps.endswith("/S"):                    # rwkv (L, B, H, N, N)
            if _fits(shape[2], mesh, "model"):
                spec[2] = "model"
        elif ps.endswith(("/tm_x", "/cm_x")):      # (L, B, d)
            if _fits(shape[2], mesh, "model"):
                spec[2] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(fn, cache)


def to_shardings(pspecs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------- activation constraints
# §Perf iteration: without explicit constraints XLA's sharding propagation
# all-gathers layer activations across the model axis (TB/step at the 4k
# train shapes). The launchers opt in via set_activation_mesh(mesh); model
# code calls constrain(x, "batch", None, "model") with logical roles that
# degrade to replication when a dim doesn't divide.
_ACT_MESH: Optional[Mesh] = None


def set_activation_mesh(mesh: Optional[Mesh]) -> None:
    global _ACT_MESH
    _ACT_MESH = mesh


MIN_CONSTRAIN_ELEMS = 1 << 22   # don't pin small (decode-sized) tensors


def constrain(x, *roles):
    """Apply with_sharding_constraint by logical dim roles.

    Roles: "batch" → ("pod","data"); "model" → "model"; None / non-divisible
    dims stay UNCONSTRAINED (never force replication — forcing P(None) on a
    non-divisible head dim was a measured regression: whisper prefill 2.5×
    worse, §Perf iteration 2 postmortem). Tensors under ~4M elements are left
    alone (single-token decode paths must not be re-sharded per layer).
    No-op outside an activation mesh (tests, single-device runs).
    """
    mesh = _ACT_MESH
    if mesh is None or x.ndim != len(roles) or x.size < MIN_CONSTRAIN_ELEMS:
        return x
    spec = []
    pinned = False
    for dim, role in zip(x.shape, roles):
        ax = P.UNCONSTRAINED
        if role == "batch":
            cand = [batch_axes(mesh), batch_axes(mesh)[-1:], None]
            got = shard_dim(dim, mesh, [c for c in cand if c])
            if got is not None:
                ax = got[0] if len(got) == 1 else got
                pinned = True
        elif role == "model" and _fits(dim, mesh, "model"):
            ax = "model"
            pinned = True
        spec.append(ax)
    if not pinned:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
