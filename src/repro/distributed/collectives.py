"""shard_map data-parallel driver with compressed gradient all-reduce.

The pjit path reduces gradients implicitly (XLA inserts the all-reduce /
reduce-scatter). This explicit driver exists for the paper-style
distributed-optimisation tricks that need *manual* collectives:

  * int8 gradient all-reduce with error feedback (4× wire bytes reduction,
    `optim/compression.py`),
  * per-shard optimizer update on replicated params (each replica applies
    the identical update — ZeRO-0 with compressed comms).

Used by tests (8 host devices) and available to the train launcher via
``--dp-driver shardmap``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.optim.adamw import AdamWConfig, adamw_update
from repro.optim.compression import tree_compressed_psum

PyTree = Any


def make_compressed_dp_step(model, opt_cfg: AdamWConfig, mesh: Mesh,
                            *, compress: bool = True, axis: str = "data"):
    """Returns jitted (params, opt_state, err, batch) -> (params, opt, err, m).

    params/opt replicated; batch sharded on ``axis``; gradients all-reduced
    in int8 with error feedback when ``compress``.
    """

    def step(params, opt_state, err, batch):
        def inner(params, opt_state, err, batch):
            (loss, _), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            if compress:
                grads, err = tree_compressed_psum(grads, axis, err)
            else:
                grads = jax.tree.map(
                    lambda g: jax.lax.pmean(g, axis), grads)
            new_params, new_opt, om = adamw_update(opt_cfg, grads, opt_state,
                                                   params)
            loss = jax.lax.pmean(loss, axis)
            return new_params, new_opt, err, {"loss": loss, **om}

        batch_spec = jax.tree.map(lambda _: P(axis), batch)
        rep = jax.tree.map(lambda _: P(), params)
        rep_opt = jax.tree.map(lambda _: P(), opt_state)
        rep_err = jax.tree.map(lambda _: P(), err)
        fn = jax.shard_map(
            inner, mesh=mesh,
            in_specs=(rep, rep_opt, rep_err, batch_spec),
            out_specs=(rep, rep_opt, rep_err, P()),
            check_vma=False,
        )
        return fn(params, opt_state, err, batch)

    return jax.jit(step)


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
