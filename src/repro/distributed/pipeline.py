"""Pipeline parallelism: GPipe-style microbatch pipeline over a mesh axis.

Implemented with ``shard_map`` + ``lax.ppermute`` (the jax-native equivalent
of the paper's dispatcher forwarding work between VPUs): each device on the
``stage`` axis owns one stage's parameters; activations flow stage→stage+1
each tick; with M microbatches and S stages the schedule runs M+S-1 ticks at
bubble fraction (S-1)/(M+S-1).

This module provides the forward pipeline used by depth-dominant serving and
a loss-pipeline wrapper for training experiments; the main train path uses
DP×TP×EP sharding (see distributed/sharding.py) — PP composes on the "pod"
axis for cross-pod depth partitioning where interconnect is thinnest.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def pipeline_forward(
    stage_fn: Callable[[PyTree, jax.Array], jax.Array],
    stage_params: PyTree,
    x: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "stage",
    n_micro: int,
) -> jax.Array:
    """Run ``y = stage_{S-1}(... stage_0(x))`` as a microbatch pipeline.

    stage_params: leaves with leading dim S (one slice per stage).
    x: (batch, ...) with batch % n_micro == 0.
    """
    n_stages = mesh.shape[axis]

    def body(params_local, xs_local):
        # params_local: this stage's params (leading dim consumed by shard_map)
        params_local = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        micro = xs_local.reshape(n_micro, xs_local.shape[0] // n_micro,
                                 *xs_local.shape[1:])
        ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(micro[0])
        out = jnp.zeros_like(micro)

        def tick(t, carry):
            buf, out = carry
            # stage 0 injects microbatch t (if in range); others use received
            inject = jax.lax.dynamic_index_in_dim(
                micro, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            x_in = jnp.where(stage == 0, inject, buf)
            y = stage_fn(params_local, x_in)
            # pass activations down the pipe
            buf_next = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            # last stage collects microbatch t-(S-1)
            slot = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            collect = jnp.logical_and(stage == n_stages - 1,
                                      t >= n_stages - 1)
            out = jnp.where(
                collect,
                jax.lax.dynamic_update_index_in_dim(
                    out, y, slot, 0),
                out)
            return buf_next, out

        buf, out = jax.lax.fori_loop(0, ticks, tick, (buf, out))
        # broadcast result from the last stage to all (psum of one-hot)
        mine = jnp.where(stage == n_stages - 1, 1.0, 0.0)
        out = jax.lax.psum(out * mine.astype(out.dtype), axis)
        return out.reshape(xs_local.shape)

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,   # carry becomes stage-varying after ppermute
    )
    return fn(stage_params, x)
