"""stablelm-3b — 32L d2560 32H (kv=32) d_ff=6912 vocab=50304, partial rotary
(25%) [hf:stabilityai/stablelm-2 family]."""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
        vocab=50304, head_dim=80,
        pattern=(LayerSpec(kind="attn"),),
        rope_fraction=0.25, norm="layernorm",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, head_dim=16,
        pattern=(LayerSpec(kind="attn"),),
        rope_fraction=0.25, norm="layernorm",
        tie_embeddings=False, max_seq_len=128,
    )
