"""internvl2-1b — InternViT frontend (stubbed patch embeddings) + 24L d896
14H (GQA kv=2) d_ff=4864 vocab=151655 LM backbone [arXiv:2404.16821]."""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
        vocab=151655, head_dim=64,
        pattern=(LayerSpec(kind="attn"),),
        qkv_bias=True, vision_prefix=256, rope_theta=1000000.0,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, head_dim=16,
        pattern=(LayerSpec(kind="attn"),),
        qkv_bias=True, vision_prefix=8, tie_embeddings=True, max_seq_len=128,
    )
