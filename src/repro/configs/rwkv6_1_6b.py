"""rwkv6-1.6b ("Finch") — 24L d2048 (attention-free) d_ff=7168 vocab=65536,
data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import LayerSpec, ModelConfig, RWKVConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
        vocab=65536, head_dim=64,
        pattern=(LayerSpec(kind="rwkv"),),
        rwkv=RWKVConfig(head_size=64, decay_lora=64),
        norm="layernorm", tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, head_dim=16,
        pattern=(LayerSpec(kind="rwkv"),),
        rwkv=RWKVConfig(head_size=16, decay_lora=8, chunk=16),
        norm="layernorm", tie_embeddings=False, max_seq_len=128,
    )
