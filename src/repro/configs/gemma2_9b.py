"""gemma2-9b — 42L d3584 16H (GQA kv=8) d_ff=14336 vocab=256000; alternating
local(4096)/global attention, attn softcap 50, final softcap 30
[arXiv:2408.00118]."""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", family="dense",
        n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_ff=14336,
        vocab=256000, head_dim=256,
        pattern=(LayerSpec(kind="attn_local"), LayerSpec(kind="attn")),
        local_window=4096, attn_softcap=50.0, final_softcap=30.0,
        act="gelu", embed_scale=True, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, head_dim=16,
        pattern=(LayerSpec(kind="attn_local"), LayerSpec(kind="attn")),
        local_window=16, attn_softcap=50.0, final_softcap=30.0,
        act="gelu", embed_scale=True, tie_embeddings=True, max_seq_len=128,
    )
