"""minicpm3-4b — 62L d2560 40H d_ff=6400 vocab=73448, Multi-head Latent
Attention (MLA) [hf:openbmb/MiniCPM3-4B]."""
from repro.configs.base import LayerSpec, MLAConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
        vocab=73448, head_dim=96,
        pattern=(LayerSpec(kind="mla"),),
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_head_dim=64, qk_rope_head_dim=32,
                      v_head_dim=64),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, head_dim=24,
        pattern=(LayerSpec(kind="mla"),),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        tie_embeddings=True, max_seq_len=128,
    )
