"""jamba-1.5-large-398b — 72L d8192 64H (GQA kv=8) d_ff=24576 vocab=65536,
Mamba:attention 7:1 interleave, MoE 16e top-2 every other layer
[arXiv:2403.19887]."""
from repro.configs.base import (LayerSpec, MambaConfig, ModelConfig,
                                MoEConfig)

_PERIOD = tuple(
    LayerSpec(kind=("attn" if i == 3 else "mamba"), moe=(i % 2 == 1))
    for i in range(8)
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
        vocab=65536, head_dim=128,
        pattern=_PERIOD,
        moe=MoEConfig(n_experts=16, top_k=2),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab=256, head_dim=16,
        pattern=_PERIOD,
        moe=MoEConfig(n_experts=4, top_k=2),
        mamba=MambaConfig(d_state=4, d_conv=4, expand=2, chunk=16),
        tie_embeddings=False, max_seq_len=128,
    )
