"""qwen2.5-32b — 64L d5120 40H (GQA kv=8) d_ff=27648 vocab=152064, QKV bias
[hf:Qwen/Qwen2.5 family]."""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=27648,
        vocab=152064, head_dim=128,
        pattern=(LayerSpec(kind="attn"),),
        qkv_bias=True, rope_theta=1000000.0, tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, head_dim=16,
        pattern=(LayerSpec(kind="attn"),),
        qkv_bias=True, tie_embeddings=False, max_seq_len=128,
    )
