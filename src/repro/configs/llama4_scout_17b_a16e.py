"""llama4-scout-17b-a16e — 48L d5120 40H (GQA kv=8) d_ff=8192 vocab=202048,
MoE 16 experts top-1, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
        vocab=202048, head_dim=128,
        pattern=(LayerSpec(kind="attn", moe=True),),
        moe=MoEConfig(n_experts=16, top_k=1),
        rope_theta=500000.0, tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab=256, head_dim=16,
        pattern=(LayerSpec(kind="attn", moe=True),),
        moe=MoEConfig(n_experts=4, top_k=1),
        tie_embeddings=False, max_seq_len=128,
    )
