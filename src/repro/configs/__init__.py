"""Config registry: ``--arch <id>`` resolution + the assigned shape grid."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (LayerSpec, MLAConfig, MambaConfig,
                                ModelConfig, MoEConfig, RWKVConfig)

# arch id -> module name
ARCHS: dict[str, str] = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "whisper-large-v3": "whisper_large_v3",
    "stablelm-3b": "stablelm_3b",
    "gemma2-9b": "gemma2_9b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen2.5-32b": "qwen2_5_32b",
    "internvl2-1b": "internvl2_1b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode | long_decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "long_decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.config()


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.smoke()


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k only for sub-quadratic (SSM/hybrid) archs — full-attention
    archs skip it (noted in DESIGN.md §4)."""
    if shape.kind == "long_decode":
        return cfg.family in ("ssm", "hybrid")
    return True


def grid(arch: str) -> list[ShapeConfig]:
    cfg = get_config(arch)
    return [s for s in SHAPES.values() if shape_applicable(cfg, s)]


__all__ = ["ARCHS", "SHAPES", "ShapeConfig", "ModelConfig", "MoEConfig",
           "MLAConfig", "MambaConfig", "RWKVConfig", "LayerSpec",
           "get_config", "get_smoke_config", "shape_applicable", "grid"]
