"""whisper-large-v3 — enc-dec, 32L d1280 20H d_ff=5120 vocab=51866; conv
frontend is a stub: input_specs provides precomputed frame embeddings
[arXiv:2212.04356]."""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
        vocab=51866, head_dim=64,
        pattern=(LayerSpec(kind="attn"),),
        enc_dec=True, n_enc_layers=32, audio_frontend=True,
        norm="layernorm", act="gelu", rope_fraction=0.0,
        tie_embeddings=True, max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, head_dim=16,
        pattern=(LayerSpec(kind="attn"),),
        enc_dec=True, n_enc_layers=2, audio_frontend=True,
        norm="layernorm", act="gelu", rope_fraction=0.0,
        tie_embeddings=True, max_seq_len=128,
    )
