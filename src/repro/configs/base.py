"""Architecture configuration schema.

One frozen dataclass describes every assigned architecture; per-arch modules
in this package instantiate it with the exact published numbers plus a
``smoke()`` reduction of the same family for CPU tests.

``pattern`` is the repeating layer period (MaxText-style scan over periods
keeps the HLO size independent of depth): e.g. gemma2 is ("attn_local",
"attn"); jamba's period of 8 holds one attention layer per seven Mamba layers
with MoE on odd positions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0          # 0 → ceil(d_model / 16)
    chunk: int = 128          # scan chunk for the selective scan


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One position in the repeating layer pattern."""

    kind: str = "attn"            # attn | attn_local | mla | mamba | rwkv
    moe: bool = False             # MoE FFN at this position?


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | audio | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 → d_model // n_heads
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    # --- attention options
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0    # stablelm partial rotary
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    local_window: Optional[int] = None
    # serving: local(sliding-window) layers keep only a window-sized ring
    # cache instead of the full sequence (§Perf iteration 5)
    ring_local_cache: bool = False
    # --- submodule configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # --- encoder/decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    # --- vlm stub
    vision_prefix: int = 0        # number of precomputed patch embeddings
    audio_frontend: bool = False  # input is precomputed frame embeddings
    # --- misc
    act: str = "silu"             # silu | gelu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    tie_embeddings: bool = True
    embed_scale: bool = False     # gemma-style sqrt(d_model) embedding scale
    max_seq_len: int = 524_288
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # ------------------------------------------------------------- derived
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (self.n_layers, self.period)
        return self.n_layers // self.period

    @property
    def pdtype(self):
        import jax.numpy as jnp   # deferred: shape-only users stay jax-free
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        import jax.numpy as jnp
        return jnp.dtype(self.compute_dtype)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token decode? SSM/hybrid: yes (attention
        layers in hybrids keep a full KV cache; pure full-attention: no)."""
        return all(s.kind in ("mamba", "rwkv") for s in self.pattern) or \
            any(s.kind in ("mamba", "rwkv") for s in self.pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        for spec in self.pattern:
            n = self.n_periods
            if spec.kind in ("attn", "attn_local"):
                qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                o = self.n_heads * hd * d
                total += n * (qkv + o)
            elif spec.kind == "mla":
                m = self.mla
                qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                total += n * (
                    d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_head
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads *
                    (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
            elif spec.kind == "mamba":
                mb = self.mamba
                di = mb.expand * d
                dtr = mb.dt_rank or -(-d // 16)
                total += n * (d * 2 * di + di * mb.d_conv
                              + di * (dtr + 2 * mb.d_state) + dtr * di
                              + di * mb.d_state + di + di * d)
            elif spec.kind == "rwkv":
                hd_r = self.rwkv.head_size
                total += n * (4 * d * d + d * d  # r,k,v,g + output
                              + 2 * d * self.rwkv.decay_lora)
            if spec.kind != "rwkv":
                if spec.moe and self.moe is not None:
                    total += n * (d * self.moe.n_experts
                                  + self.moe.n_experts * 3 * d * ff)
                else:
                    total += n * 3 * d * ff
            else:
                total += n * 2 * d * ff  # rwkv channel-mix (2 mats)
        if self.enc_dec:
            # encoder blocks + cross attention in decoder
            qkv = 4 * d * (self.n_heads * hd)
            total += self.n_enc_layers * (qkv + 3 * d * ff)
            total += self.n_layers * qkv  # cross-attn in each decoder layer
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        inactive = 0
        for spec in self.pattern:
            if spec.moe:
                inactive += self.n_periods * (
                    (self.moe.n_experts - self.moe.top_k) * 3 * d * ff)
        return self.param_count() - inactive
