"""granite-moe-1b-a400m — 24L d1024 16H (GQA kv=8) expert_ff=512 vocab=49155,
MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
        vocab=49155, head_dim=64,
        pattern=(LayerSpec(kind="attn", moe=True),),
        moe=MoEConfig(n_experts=32, top_k=8),
        rope_theta=10000.0, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab=256, head_dim=16,
        pattern=(LayerSpec(kind="attn", moe=True),),
        moe=MoEConfig(n_experts=4, top_k=2),
        tie_embeddings=True, max_seq_len=128,
    )
