"""Deterministic, resumable, host-sharded synthetic token pipeline.

Every batch is a pure function of (seed, step, process_index): a counter-based
PRNG stream. Consequences that matter for fault tolerance at scale:

  * **Exact resume** — restart at step N reproduces the byte-identical batch
    stream with no data-loader state in the checkpoint beyond the step.
  * **Elasticity** — the per-process slice is computed from
    (process_index, process_count); relaunching at a different host count
    re-slices the same global stream.
  * **No input stragglers** — generation is O(batch) on-host; the prefetch
    thread keeps one batch ahead (double-buffering), emulating the
    device-feed overlap a real loader needs.

The synthetic distribution is a Zipfian unigram mix with in-sequence
repetition structure, so cross-entropy meaningfully decreases during the
example training runs (a learnable signal, unlike uniform noise).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator, Optional

import jax
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1
    repeat_prob: float = 0.3       # probability of copying an earlier token
    repeat_window: int = 32


class SyntheticLM:
    """Counter-based deterministic batch source."""

    def __init__(self, cfg: DataConfig, *, process_index: int = 0,
                 process_count: int = 1):
        assert cfg.global_batch % process_count == 0
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        self.local_batch = cfg.global_batch // process_count
        # Zipf unigram table (truncated, normalised)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_alpha)
        self._probs = p / p.sum()

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence(
                [cfg.seed, step, self.process_index]))
        b, s = self.local_batch, cfg.seq_len
        tokens = rng.choice(cfg.vocab, size=(b, s), p=self._probs)
        # structured repetition: copy a recent token with repeat_prob
        rep = rng.random((b, s)) < cfg.repeat_prob
        offs = rng.integers(1, cfg.repeat_window, size=(b, s))
        idx = np.maximum(np.arange(s)[None, :] - offs, 0)
        tokens = np.where(rep, np.take_along_axis(tokens, idx, axis=1),
                          tokens)
        return {"tokens": tokens.astype(np.int32)}

    def iterate(self, start_step: int = 0) -> Iterator[dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """One-batch-ahead background prefetch with optional device placement."""

    def __init__(self, source: SyntheticLM, *, start_step: int = 0,
                 sharding=None, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._sharding = sharding
        self._stop = threading.Event()

        def worker():
            it = source.iterate(start_step)
            while not self._stop.is_set():
                batch = next(it)
                if sharding is not None:
                    batch = jax.tree.map(
                        lambda x, s=sharding: jax.device_put(x, s), batch)
                self._q.put(batch)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
