"""Per-event trace capture + Chrome ``trace_event`` export.

Every activity the pipelined scheduler books on a resource is mirrored into a
:class:`Tracer` as a :class:`TraceRecord`. The records can be exported as a
Chrome/Perfetto ``trace_event`` JSON document (open ``chrome://tracing`` or
https://ui.perfetto.dev and load the file): one *thread* row per modeled
resource (eCPU, cache-lock, each VPU datapath and DMA port), one complete
("ph": "X") event per activity, with the kernel id / phase carried in
``args``. Modeled cycles map 1:1 onto the trace's microsecond timestamps —
the absolute unit is meaningless, only the overlap structure matters.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Optional

#: Canonical phase categories — match PhaseStats / Fig. 3 axes.
PHASES = ("preamble", "allocation", "compute", "writeback")


@dataclasses.dataclass(frozen=True)
class TraceRecord:
    name: str              # human label, e.g. "gemm k3 dma-in"
    phase: str             # one of PHASES
    resource: str          # resource/thread name, e.g. "vpu1.dma"
    start: int             # cycles
    duration: int          # cycles
    args: tuple            # sorted (key, value) pairs — keeps records hashable
    lane: Optional[str] = None   # sub-lane within the resource, e.g. "op1" or
                                 # "op1.c2" (per-operand / per-column-tile DMA
                                 # trains) — display only; busy/phase
                                 # accounting stays per resource
    instant: bool = False        # zero-cycle marker (e.g. a reuse-skipped
                                 # DMA-in) — exported as a Chrome instant
                                 # event; contributes nothing to busy/phase
                                 # totals (emit rejects a nonzero duration)

    @property
    def row(self) -> str:
        """Display row in the Chrome export: resource, or resource/lane."""
        return f"{self.resource}/{self.lane}" if self.lane else self.resource


@dataclasses.dataclass(frozen=True)
class CounterRecord:
    """A Chrome counter sample (``"ph": "C"``): one or more named series
    sampled at a cycle timestamp — per-VPU occupancy, AT free slots,
    reuse-FIFO bytes. Counters live on their own tracks and contribute
    nothing to busy/phase accounting."""

    name: str              # counter track name, e.g. "at.free_slots"
    ts: int                # cycles
    series: tuple          # sorted (series_name, value) pairs


@dataclasses.dataclass(frozen=True)
class FlowRecord:
    """A Chrome flow arrow (``"ph": "s"`` → ``"ph": "f"``) linking a DMA
    tile slice to the compute piece it gates. Row names must refer to rows
    that carry at least one TraceRecord (the arrow endpoints bind to the
    enclosing slices on those rows)."""

    name: str
    phase: str
    fid: int               # flow id — unique per tracer
    src_row: str
    src_ts: int
    dst_row: str
    dst_ts: int


class Tracer:
    """Accumulates trace records; exports Chrome trace_event JSON.

    ``enabled=False`` turns the tracer into a sink: ``emit`` returns
    immediately and no records accumulate. Long benchmark sweeps use this —
    record capture is pure overhead (time and memory) when nobody exports
    the trace — while every default construction keeps full capture."""

    def __init__(self, process_name: str = "repro.sim", enabled: bool = True):
        self.process_name = process_name
        self.enabled = enabled
        self.records: list[TraceRecord] = []
        self.counters: list[CounterRecord] = []
        self.flows: list[FlowRecord] = []
        self._resources: list[str] = []   # insertion order -> tid

    def emit(self, name: str, phase: str, resource: str, start: int,
             duration: int, lane: Optional[str] = None, instant: bool = False,
             **args: Any) -> Optional[TraceRecord]:
        if not self.enabled:
            return None
        if phase not in PHASES:
            raise ValueError(f"unknown phase {phase!r}, expected one of {PHASES}")
        if instant and duration:
            raise ValueError(f"instant record carries no duration, "
                             f"got {duration}")
        rec = TraceRecord(name=name, phase=phase, resource=resource,
                          start=int(start), duration=int(duration),
                          args=tuple(sorted(args.items())), lane=lane,
                          instant=instant)
        self.records.append(rec)
        if resource not in self._resources:
            self._resources.append(resource)
        return rec

    def counter(self, name: str, ts: int, **series: Any) -> Optional[CounterRecord]:
        """Sample one or more counter series at ``ts`` (a ``"ph": "C"``
        event in the export — its own track in Perfetto)."""
        if not self.enabled:
            return None
        if not series:
            raise ValueError("counter sample needs at least one series")
        rec = CounterRecord(name=name, ts=int(ts),
                            series=tuple(sorted(series.items())))
        self.counters.append(rec)
        return rec

    def flow(self, name: str, phase: str, src_row: str, src_ts: int,
             dst_row: str, dst_ts: int) -> Optional[FlowRecord]:
        """Link the slice enclosing ``(src_row, src_ts)`` to the slice
        enclosing ``(dst_row, dst_ts)`` with a flow arrow."""
        if not self.enabled:
            return None
        if phase not in PHASES:
            raise ValueError(f"unknown phase {phase!r}, expected one of {PHASES}")
        rec = FlowRecord(name=name, phase=phase, fid=len(self.flows),
                         src_row=src_row, src_ts=int(src_ts),
                         dst_row=dst_row, dst_ts=int(dst_ts))
        self.flows.append(rec)
        return rec

    def clear(self) -> None:
        self.records.clear()
        self.counters.clear()
        self.flows.clear()
        self._resources.clear()

    # ------------------------------------------------------------- exporters
    def to_chrome(self) -> dict:
        """Build the Chrome trace_event JSON object (dict, ready to dump).

        Laned records (per-operand DMA trains) render as their own thread
        rows, grouped directly under their parent resource row."""
        lanes_of: dict[str, list[str]] = {r: [] for r in self._resources}
        for rec in self.records:
            if rec.lane is not None and rec.lane not in lanes_of[rec.resource]:
                lanes_of[rec.resource].append(rec.lane)
        tid_of: dict[str, int] = {}
        for r in self._resources:
            tid_of[r] = len(tid_of)
            for lane in lanes_of[r]:
                tid_of[f"{r}/{lane}"] = len(tid_of)
        meta: list[dict] = [{
            "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
            "args": {"name": self.process_name},
        }]
        for r, tid in tid_of.items():
            meta.append({"name": "thread_name", "ph": "M", "pid": 0,
                         "tid": tid, "args": {"name": r}})
            meta.append({"name": "thread_sort_index", "ph": "M", "pid": 0,
                         "tid": tid, "args": {"sort_index": tid}})
        events: list[dict] = []
        for rec in self.records:
            if rec.instant:
                events.append({
                    "name": rec.name,
                    "cat": rec.phase,
                    "ph": "i",
                    "s": "t",             # thread-scoped instant marker
                    "ts": rec.start,
                    "pid": 0,
                    "tid": tid_of[rec.row],
                    "args": dict(rec.args),
                })
                continue
            events.append({
                "name": rec.name,
                "cat": rec.phase,
                "ph": "X",
                "ts": rec.start,          # 1 modeled cycle == 1 us on screen
                "dur": max(rec.duration, 1),   # zero-width events are invisible
                "pid": 0,
                "tid": tid_of[rec.row],
                "args": dict(rec.args),
            })
        for cr in self.counters:
            events.append({
                "name": cr.name,
                "cat": "counter",
                "ph": "C",
                "ts": cr.ts,
                "pid": 0,
                "tid": 0,
                "args": dict(cr.series),
            })
        for fl in self.flows:
            # Flow endpoints bind to the enclosing slice on the named row;
            # rows referenced here always carry at least one complete event.
            for ph, row, ts in (("s", fl.src_row, fl.src_ts),
                                ("f", fl.dst_row, fl.dst_ts)):
                ev = {
                    "name": fl.name,
                    "cat": fl.phase,
                    "ph": ph,
                    "id": fl.fid,
                    "ts": ts,
                    "pid": 0,
                    "tid": tid_of.get(row, 0),
                }
                if ph == "f":
                    ev["bp"] = "e"        # bind to the enclosing slice
                events.append(ev)
        # Deterministic order so trace files diff cleanly across runs:
        # metadata first (by tid, names before sort indices), then events by
        # (ts, tid, phase-kind, name, flow id).
        ph_rank = {"C": 0, "X": 1, "i": 2, "s": 3, "f": 4}
        events.sort(key=lambda e: (e["ts"], e["tid"], ph_rank[e["ph"]],
                                   e["name"], e.get("id", -1)))
        return {"traceEvents": meta + events, "displayTimeUnit": "ms",
                "otherData": {"source": "repro.sim.PipelinedRuntime"}}

    def dump(self, path: str) -> str:
        """Write the Chrome trace JSON to ``path`` (creating parent
        directories as needed); returns the path."""
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=None, separators=(",", ":"))
        return path

    # --------------------------------------------------------------- queries
    def busy_cycles(self, resource: Optional[str] = None) -> int:
        return sum(r.duration for r in self.records
                   if resource is None or r.resource == resource)

    def phase_cycles(self) -> dict[str, int]:
        out = {p: 0 for p in PHASES}
        for r in self.records:
            out[r.phase] += r.duration
        return out
