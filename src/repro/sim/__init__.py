"""repro.sim — event-driven pipelined C-RT scheduler + trace subsystem.

Layout:
  * :mod:`repro.sim.events`   — deterministic event queue + resource timelines
    (+ :class:`Timeline`, the open-ended clock external events post onto)
  * :mod:`repro.sim.pipeline` — :class:`PipelinedRuntime` (overlapped phases)
  * :mod:`repro.sim.serving`  — continuous-batching workload driver over the
    open-loop session API (arrivals, slots, prefill/decode tapes)
  * :mod:`repro.sim.config`   — YAML configs with ``extends`` composition
  * :mod:`repro.sim.trace`    — Chrome ``trace_event`` export
  * :mod:`repro.sim.metrics`  — stall attribution, critical path, typed
    counters/gauges/histograms (the unified metrics layer)
  * :mod:`repro.sim.faults`   — seeded fault injection (ECC bit flips,
    bounded instruction replay, hard VPU faults with graceful degradation)

The serial :class:`repro.core.runtime.CacheRuntime` and the pipelined
scheduler share the same decode/allocate/compute/retire steps, so their
kernel outputs are bit-identical; only the modeled timing differs.
"""
from repro.sim.config import (ConfigError, SimConfig, apply_overrides,
                              builtin_config_path, config_from_overrides,
                              deep_merge, load_config, load_raw,
                              merge_overrides)
from repro.sim.events import (ChunkTrain, Event, EventQueue, Interval,
                              Resource, TileTrain, Timeline,
                              interleave_blocks, row_chunks,
                              split_proportional, tile_entries)
from repro.sim.metrics import (METRICS_SCHEMA_VERSION, STALL_BINS, Activity,
                               ActivityLog, Counter, CPSegment, Gauge,
                               Histogram, KernelStall, MetricsError,
                               MetricsRegistry, RequestLog, RequestRecord,
                               SchedulerMetrics, StallTable,
                               summarize_critical_path)
from repro.sim.faults import (FaultConfig, FaultError, FaultPlan,
                              KernelFaults)
from repro.sim.pipeline import (DeadlockError, PipelinedRuntime,
                                PipelineReport, ReuseEntry)
from repro.sim.serving import (Request, ServingConfig, ServingDriver,
                               bursty_arrivals, poisson_arrivals)
from repro.sim.trace import (PHASES, CounterRecord, FlowRecord, TraceRecord,
                             Tracer)

__all__ = [
    "ConfigError", "SimConfig", "apply_overrides", "builtin_config_path",
    "config_from_overrides", "deep_merge", "load_config", "load_raw",
    "merge_overrides", "ChunkTrain", "Event", "EventQueue",
    "Interval", "Resource", "TileTrain", "Timeline", "interleave_blocks",
    "row_chunks", "split_proportional", "tile_entries", "DeadlockError",
    "FaultConfig", "FaultError", "FaultPlan", "KernelFaults",
    "PipelinedRuntime",
    "PipelineReport", "ReuseEntry", "Request", "ServingConfig",
    "ServingDriver", "bursty_arrivals", "poisson_arrivals",
    "PHASES", "TraceRecord", "Tracer",
    "CounterRecord", "FlowRecord", "METRICS_SCHEMA_VERSION", "STALL_BINS",
    "Activity", "ActivityLog", "Counter", "CPSegment", "Gauge", "Histogram",
    "KernelStall", "MetricsError", "MetricsRegistry", "RequestLog",
    "RequestRecord", "SchedulerMetrics", "StallTable",
    "summarize_critical_path",
]
