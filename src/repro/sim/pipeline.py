"""Event-driven pipelined C-RT scheduler (paper §IV-B, multi-VPU overlap).

:class:`PipelinedRuntime` schedules the *same* ``QueuedKernel`` DAG as the
serial :class:`~repro.core.runtime.CacheRuntime` it subclasses, but overlaps
the C-RT phases across resources the way the hardware does:

  * the eCPU decodes kernel *k+1* while kernel *k* is in flight;
  * DMA-in for the next ready kernel runs on one VPU's DMA port while another
    VPU's datapath computes;
  * deferred write-backs drain opportunistically on idle DMA ports.

**Bit-identical numerics by construction.** All functional state mutation
(operand DMA-in, kernel execution, write-back) is performed *inline* at
event-handling time, in dependency order — the event queue only decides
*when* each already-correct step is modeled to happen. A kernel is dispatched
only after every DAG predecessor has retired (``DependencyTracker.ready``)
and no earlier-queued pending kernel still reads a memory region it writes
(the in-order WAR-aliasing guarantee the serial loop provides implicitly), so
the data each kernel observes is exactly what the serial schedule produces.

Modeled resources (see :mod:`repro.sim.events`): ``ecpu``, ``cache.lock``,
and per VPU ``vpu{i}.datapath`` + ``vpu{i}.dma``. Every booked activity is
mirrored into a :class:`~repro.sim.trace.Tracer` for Chrome trace export.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Optional

from repro.core.address_table import RegionKind
from repro.core.alias_index import AliasIndex
from repro.core.dataflow import FULL, FlowKind
from repro.core.regions import StridedRegion, contains_cached
from repro.core.runtime import CacheRuntime, QueuedKernel
from repro.sim.events import (EventQueue, Resource, TileTrain, Timeline,
                              row_chunks, split_proportional, tile_entries)
from repro.sim.faults import FaultError
from repro.sim.trace import Tracer


class DeadlockError(RuntimeError):
    """The open-loop session drain stopped making progress with work still
    pending — a genuine dependency deadlock (e.g. a kernel whose RAW edge
    can never be satisfied), not a capacity stall.

    Structured diagnostics ride along for the operator:

    * ``pending`` — ``{kernel_id: {"kernel": name, "blocked_on": reason,
      "unmet_deps": [ids]}}`` for every stuck kernel, with the last blocked
      reason the stall tracker observed (None when metrics are disabled);
    * ``resources`` — ``{resource_name: free_at}`` for every modeled
      resource at the moment the drain wedged.
    """

    def __init__(self, message: str, pending: dict, resources: dict):
        super().__init__(message)
        self.pending = pending
        self.resources = resources


@dataclasses.dataclass(frozen=True)
class PipelineReport:
    """Summary of one pipelined run: makespan vs the serial sum-of-phases.

    ``sim_seconds`` / ``events_processed`` / ``alias_queries`` profile the
    *simulator itself* (wall-clock spent inside the event loops, events
    popped, AliasIndex queries served) — the axes ``bench_scheduler.py``
    tracks and the ``--profile`` benchmark flag surfaces."""

    makespan: int                   # modeled end-to-end cycles (overlapped)
    serial_cycles: int              # sum of per-phase cycles (no overlap)
    kernels_run: int
    resource_busy: dict[str, int]   # resource name -> busy cycles
    utilization: dict[str, float]   # resource name -> busy / makespan
    reuse_hits: int = 0             # operand DMA trains skipped by reuse
    sim_seconds: float = 0.0        # wall-clock inside the scheduler loops
    events_processed: int = 0       # events popped off the EventQueue
    alias_queries: int = 0          # AliasIndex queries served (whole stack)

    @property
    def concurrency_speedup(self) -> float:
        return self.serial_cycles / self.makespan if self.makespan else 1.0


@dataclasses.dataclass
class ReuseEntry:
    """One modeled clean operand copy in a VPU's data array.

    ``region`` is the main-memory footprint the copy mirrors; ``ready_at`` the
    cycle its DMA train completed (a reuse hit gates compute no earlier)."""

    region: StridedRegion
    ready_at: int


class PipelinedRuntime(CacheRuntime):
    """C-RT with an event-driven, resource-accurate pipelined scheduler.

    ``row_chunk`` sets the intra-instruction pipelining granularity
    (NM-Carus-style): each source DMA-in is modeled as chunks of at most
    ``row_chunk`` rows, and the kernel's compute is split into matching
    pieces, each starting only after the chunks it needs have landed — so the
    datapath starts as soon as the first rows arrive instead of waiting for
    the whole operand. ``row_chunk=0`` disables chunking (whole-transfer
    granularity).

    ``dataflow`` selects the gating model. ``True`` (default): each operand
    streams as its *own* tile train and compute piece *i* waits for the
    per-operand tile set the kernel's dataflow descriptor demands
    (:mod:`repro.core.dataflow` — e.g. all of GEMM's B before the first
    piece). ``False``: the legacy concatenated-stream model (piece *i* gated
    on chunk *i* of the sources concatenated in operand order) — optimistic
    for GEMM-like kernels, kept as an A/B reference. Functional state
    mutation is unchanged either way — only the timing model differs, so
    outputs stay bit-identical to the serial scheduler.

    ``tiling=(rows, cols)`` generalizes the 1D row trains to 2D tile trains:
    each operand DMA splits into row bands of at most ``rows`` rows (0 falls
    back to ``row_chunk``) × column tiles of at most ``cols`` columns (0
    keeps whole rows), compute splits into the matching output-tile grid, and
    piece ``(i, j)`` waits only for the operand tiles its dataflow policy
    projects onto it — GEMM output tile ``(i, j)`` needs A-band ``i`` and
    B-column-tile ``j``, not all of B. Operands whose column policy is FULL
    keep single-tile rows (column-splitting them buys no earlier gate).

    ``reuse`` enables cross-instruction operand reuse (NM-Carus keeps
    operands resident in the cache data arrays): the scheduler remembers the
    memory regions whose clean copies it modeled streaming into each VPU, and
    an operand whose region is *contained* in a remembered copy
    (:meth:`repro.core.regions.StridedRegion.contains`) skips its DMA-in
    train entirely — strip-mined GEMM/conv sequences stop paying repeated
    B/weight fetches. Copies are invalidated whenever main memory changes
    under them (consolidations, host stores) and bounded by the VPU register
    file capacity (oldest copies fall out first). Reuse is a *timing* model:
    functional DMA still executes, so outputs stay bit-identical.

    Both ``tiling`` and ``reuse`` require ``dataflow`` gating (the legacy
    concatenated-stream model has no per-operand structure to tile or skip).

    ``wakeup`` selects the dispatch engine. ``True`` (default): wakeup-driven
    — each blocked kernel registers what it waits on (unmet dependencies, the
    earlier-queued WAR readers aliasing its destination, VPU capacity) and is
    re-examined only when a completion/dispatch wakes it. ``False``: the
    legacy full-pending-list rescan after every event. Both engines examine
    kernels in the same queue order under the same pass discipline, so the
    schedule — makespans, traces, memory images — is bit-identical; only the
    simulator's own wall-clock differs (``bench_scheduler.py`` measures the
    gap, and the differential tests assert the equality).
    """

    def __init__(self, *args, tracer: Optional[Tracer] = None,
                 row_chunk: int = 8, dataflow: bool = True,
                 tiling: Optional[tuple[int, int]] = None,
                 reuse: bool = False, wakeup: bool = True, **kwargs):
        super().__init__(*args, **kwargs)
        if row_chunk < 0:
            raise ValueError(f"row_chunk must be >= 0, got {row_chunk}")
        self.row_chunk = row_chunk
        self.dataflow = bool(dataflow)
        if tiling is not None:
            tr, tc = tiling
            if tr < 0 or tc < 0:
                raise ValueError(f"tiling dims must be >= 0, got {tiling}")
            # (0, 0) disables both axes — same normalization as SimConfig.
            tiling = (int(tr), int(tc)) if (tr or tc) else None
        self.tiling = tiling
        self.reuse = bool(reuse)
        if (self.tiling or self.reuse) and not self.dataflow:
            raise ValueError(
                "tiling/reuse require dataflow gating (dataflow=True); the "
                "legacy concatenated-stream model has no per-operand trains")
        self.wakeup = bool(wakeup)
        self.tracer = tracer or Tracer()
        self.sim_time = 0
        self.res_ecpu = Resource("ecpu")
        self.res_lock = Resource("cache.lock")
        self.res_dp = [Resource(f"vpu{v}.datapath")
                       for v in range(self.cache.n_vpus)]
        self.res_dma = [Resource(f"vpu{v}.dma")
                        for v in range(self.cache.n_vpus)]
        self._ready_at: dict[int, int] = {}     # kernel_id -> decode done time
        # Dispatch state: pending kernels by id (ascending == queue order),
        # per-phys pending-reader counts (the _needed_later question), the
        # pending-source footprint index (the WAR dispatch guard), and the
        # wakeup bookkeeping — which kernels to (re)examine, who waits on
        # which completion/dispatch, who waits on VPU capacity.
        self._pending_map: dict[int, QueuedKernel] = {}
        self._pending_src_count: dict[int, int] = {}
        self._war_index = AliasIndex()
        self._wake: set[int] = set()
        self._dep_waiters: dict[int, set[int]] = {}
        self._war_waiters: dict[int, set[int]] = {}
        self._cap_blocked: set[int] = set()
        # Open-ended timeline (persistent across drains): the event queue
        # lives for the whole session so externally posted events (request
        # arrivals) and kernels issued from completion callbacks interleave
        # with in-flight work. ``_inflight`` maps dispatched-but-unretired
        # kernels to their functional state.
        self._timeline = Timeline()
        self._inflight: dict[int, tuple] = {}
        # Simulator self-profiling (PipelineReport / --profile).
        self.events_processed = 0
        self._wall_seconds = 0.0
        # Cross-instruction reuse: per-VPU FIFO of modeled clean copies
        # (insertion-ordered dicts keyed by a global sequence number), bounded
        # by the register-file capacity (oldest copies reclaimed first — the
        # model's stand-in for line reclamation). The footprint index keyed by
        # (vpu, seq) answers both the containment lookups and the
        # invalidation sweeps in O(hits).
        self._reuse_entries: list[dict[int, ReuseEntry]] = [
            {} for _ in range(self.cache.n_vpus)]
        self._reuse_bytes = [0] * self.cache.n_vpus
        self._reuse_index = AliasIndex()
        self._reuse_seq = itertools.count()
        self._reuse_cap = self.cache.vregs_per_vpu * self.cache.vlen_bytes

    # ----------------------------------------------------------- public api
    def _all_resources(self) -> list[Resource]:
        return [self.res_ecpu, self.res_lock, *self.res_dp, *self.res_dma]

    def report(self) -> PipelineReport:
        busy = {r.name: r.busy_cycles for r in self._all_resources()}
        return PipelineReport(
            makespan=self.sim_time,
            serial_cycles=self.stats.total_cycles,
            kernels_run=self.stats.kernels_run,
            resource_busy=busy,
            utilization={n: (b / self.sim_time if self.sim_time else 0.0)
                         for n, b in busy.items()},
            reuse_hits=self.stats.reuse_hits,
            sim_seconds=self._wall_seconds,
            events_processed=self.events_processed,
            alias_queries=self.alias_queries_served(),
        )

    def alias_queries_served(self) -> int:
        return (super().alias_queries_served()
                + self._war_index.queries + self._reuse_index.queries)

    def metrics_report(self) -> dict:
        """Unified metrics report over the pipelined event timeline: typed
        instruments, per-kernel stall attribution, and the critical-path
        breakdown of the makespan (see :mod:`repro.sim.metrics`)."""
        return self.metrics.report(
            makespan=self.sim_time,
            extra={"kernels_run": self.stats.kernels_run,
                   "events_processed": self.events_processed,
                   "alias_queries": self.alias_queries_served(),
                   "reuse_hits": self.stats.reuse_hits,
                   "serial_cycles": self.stats.total_cycles,
                   "sim_seconds": self._wall_seconds})

    def _emit_counters(self, t: int) -> None:
        """Sample the Chrome counter tracks (per-VPU line occupancy, AT free
        slots, reuse-FIFO bytes) at cycle ``t`` — dispatches, completions and
        drains are the points where any of them can change."""
        if not self.tracer.enabled:
            return
        self.tracer.counter("at.free_slots", t, free=self.at.free_slots())
        for v in range(self.cache.n_vpus):
            self.tracer.counter(
                f"vpu{v}.lines", t,
                used=self.cache.vregs_per_vpu - self.cache.free_line_count(v))
        if self.reuse:
            for v in range(self.cache.n_vpus):
                self.tracer.counter(f"vpu{v}.reuse_bytes", t,
                                    bytes=self._reuse_bytes[v])

    # ----------------------------------------------------- operand reuse set
    def _reuse_lookup(self, v: int, region: StridedRegion) -> Optional[int]:
        """Cycle at which a containing clean copy on VPU ``v`` is fully
        landed, or None when the operand must stream."""
        if not self.reuse:
            return None
        # Index keys sort by (vpu, seq): the first containment hit for VPU v
        # is the oldest (FIFO-first) entry — the copy the pre-index deque
        # scan would have returned.
        for vv, seq in self._reuse_index.query(region):
            if vv != v:
                continue
            e = self._reuse_entries[v][seq]
            if contains_cached(e.region, region):
                return e.ready_at
        return None

    def _reuse_drop(self, v: int, seq: int) -> None:
        e = self._reuse_entries[v].pop(seq)
        self._reuse_index.remove((v, seq))
        self._reuse_bytes[v] -= e.region.nbytes

    def _reuse_note(self, v: int, region: StridedRegion, ready_at: int) -> None:
        """Record a freshly-streamed clean copy on VPU ``v``."""
        if not self.reuse:
            return
        for vv, seq in self._reuse_index.query(region):
            if vv == v and self._reuse_entries[v][seq].region == region:
                self._reuse_drop(v, seq)
        seq = next(self._reuse_seq)
        self._reuse_entries[v][seq] = ReuseEntry(region=region,
                                                 ready_at=ready_at)
        self._reuse_index.insert((v, seq), region)
        self._reuse_bytes[v] += region.nbytes
        while self._reuse_bytes[v] > self._reuse_cap:
            self._reuse_drop(v, next(iter(self._reuse_entries[v])))

    def _note_memory_write(self, region: StridedRegion) -> None:
        """Main memory changed under ``region`` (consolidation landing or a
        host store): every modeled copy overlapping it is stale. The index
        query pins down exactly the overlapped entries — nothing else is
        evicted, and no FIFO is scanned."""
        for vv, seq in self._reuse_index.query(region):
            self._reuse_drop(vv, seq)

    # ------------------------------------------------------------ scheduler
    def run_pending(self) -> None:
        """Drain every admitted kernel with the event-driven schedule.

        Re-entrant calls — a completion callback issuing new kernels from
        inside the event loop, or any issue under an *open* session (see
        :mod:`repro.core.session`) — only admit the queue into the pending
        set; the owning event loop (or the session's ``advance``/``drain``)
        processes the events. A top-level call in closed (batch) mode is the
        legacy behaviour: admit, run the timeline dry, settle."""
        if self._in_loop or self._session_open:
            self._admit_queue()
            return
        if not (self.queue or self._pending_map or self._timeline):
            return
        wall0 = time.perf_counter()
        self._admit_queue()
        self._wake.update(self._pending_map)
        t = self._run_events()
        self._settle(t)
        self._wall_seconds += time.perf_counter() - wall0

    def _relieve_at_pressure(self, need: int) -> None:
        """Address-Table pressure with the event loop live (re-entrant issue
        from a callback, or any issue under an open session): model a
        *frontend stall*. The decoder blocks mid-issue and the machine keeps
        executing — internal lifecycle events run until enough earlier
        kernels retire to free ``need`` slots — while posted arrivals stay
        queued (a stalled decoder cannot service them; they fire at the
        unblock time). Closed-batch calls fall through to the base eager
        drain, which this path never perturbs."""
        if need > 0 and self.at.free_slots() < need \
                and (self._in_loop or self._session_open):
            self._admit_queue()
            self._wake.update(self._pending_map)
            self._run_events(at_need=need)
        super()._relieve_at_pressure(need)

    def _admit_queue(self, at: Optional[int] = None) -> None:
        """Move queued kernels into the pending set and book their decodes
        starting at ``at`` (default: the timeline clock).

        Decode timeline: the eCPU ISR serialises preambles, but kernel k may
        dispatch right after its own decode — later decodes overlap with
        earlier kernels' allocation/compute. Each decode-completion event
        wakes exactly its own kernel."""
        if not self.queue:
            return
        pending = list(self.queue)
        self.queue.clear()
        for qk in pending:
            kid = qk.deps.kernel_id
            self._pending_map[kid] = qk
            for si, s in enumerate(qk.src_bindings):
                self._pending_src_count[s.phys_id] = \
                    self._pending_src_count.get(s.phys_id, 0) + 1
                self._war_index.insert((kid, si), s.region)
        t0 = self._timeline.now if at is None else at
        for qk in pending:
            kid = qk.deps.kernel_id
            iv = self.res_ecpu.acquire(t0, self.geometry.decode_cycles,
                                       label=f"decode k{kid}")
            self._ready_at[kid] = iv.end
            self.tracer.emit(f"{qk.spec.name} k{kid} decode", "preamble",
                             "ecpu", iv.start, iv.duration, kernel=kid)
            self.metrics.kernel_decoded(kid, iv.end, qk.spec.name)
            self.metrics.activity(f"{qk.spec.name} k{kid} decode", "preamble",
                                  "ecpu", iv.start, iv.end, kernel=kid)
            self._timeline.push(iv.end, "dispatch", kid)

    def _run_events(self, until: Optional[int] = None,
                    at_need: Optional[int] = None,
                    internal_only: bool = False) -> int:
        """Process timeline events in order until the timeline empties or
        the next event lies beyond ``until``; returns the clock. External
        events (posted arrivals) invoke their callback — which may issue new
        kernels re-entrantly — then admit whatever the callback queued.

        ``at_need`` is the frontend-stall mode (see
        :meth:`_relieve_at_pressure`): run only *internal* lifecycle events
        until that many Address-Table slots are free. External events are
        deferred back onto the timeline — the stalled decoder cannot service
        arrivals, so their callbacks fire at the unblock time (their posted
        sim time is preserved; only the service time moves, exactly a
        stalled issue queue's behaviour). ``internal_only`` (implied by
        ``at_need``) defers externals without an AT target — the settle uses
        it to run residual in-flight work dry."""
        internal_only = internal_only or at_need is not None
        eq = self._timeline
        t = eq.now
        was_in_loop, self._in_loop = self._in_loop, True
        deferred = []
        try:
            while True:
                if at_need is not None and self.at.free_slots() >= at_need:
                    break
                self._dispatch_sweep(t, self._inflight, eq)
                while internal_only and eq \
                        and eq.peek().kind == Timeline.EXTERNAL:
                    deferred.append(eq.pop())
                if not eq:
                    break
                if until is not None and eq.peek().time > until:
                    break
                ev = eq.pop()
                t = eq.advance_clock(ev.time)
                self.events_processed += 1
                # Lazy hard-fault check: fires at the first event at or
                # after ``hard_at`` (never via a posted event, so runs that
                # finish earlier keep their fault-free makespan).
                self._maybe_hard_fault(t, eq)
                if ev.kind == "dispatch":
                    # Decode finished: this kernel becomes examinable.
                    self._wake.add(ev.payload)
                elif ev.kind == "compute_done":
                    self._handle_compute_done(ev.payload, t, self._inflight,
                                              eq)
                elif ev.kind == "wb_done":
                    # A port that just finished a write-back immediately
                    # takes the next least-booked-port drain instead of
                    # leaving it for the final barrier flush. Drains evict
                    # residents, so capacity-blocked kernels get another
                    # look.
                    self._drain_idle_dma(t, self._inflight, eq)
                    self._emit_counters(t)
                    self._wake_capacity_blocked()
                elif ev.kind == Timeline.EXTERNAL:
                    ev.payload(t)
                    self._admit_queue()
        finally:
            self._in_loop = was_in_loop
            for ev in deferred:
                eq.push(ev.time, ev.kind, ev.payload)
        return t

    def _settle(self, t: int) -> None:
        """Close a batch drain: align the makespan with the latest booking,
        run capacity-starved leftovers through the serial fallback, and
        reset the wakeup bookkeeping.

        Capacity-starved leftovers fall back to the serial step so the
        failure mode (ResourceStall) is identical to CacheRuntime's. Their
        phase cycles (everything but the already-timelined decode) append
        serially to the makespan — nothing overlaps a starved schedule.
        Kernels admitted *during* the fallback (completion callbacks may
        issue new work) are not part of this settle: they stay pending, with
        their decode events on the timeline, for the next drain."""
        end = max([t, self.sim_time]
                  + [r.free_at for r in self._all_resources()])
        still = []
        fallback_before = self.stats.total_cycles
        snapshot = list(self._pending_map.values())
        ran: set[int] = set()
        was_in_loop, self._in_loop = self._in_loop, True   # nested issues admit
        try:
            for qk in snapshot:
                kid = qk.deps.kernel_id
                if kid not in self._pending_map:
                    # A retire callback's backpressure stall ran the event
                    # loop mid-pass and dispatched (and cleaned up) this
                    # kernel — nothing left to do here.
                    continue
                if self.tracker.ready(kid):
                    self.metrics.inc("kernels.fallback")
                    # Hide the kernel from re-entrant dispatch sweeps before
                    # running it, but keep its source counts until the pass
                    # ends: _needed_later must see the whole snapshot.
                    self._pending_map.pop(kid)
                    ran.add(kid)
                    self._run_one(qk)
                else:
                    still.append(qk)
        finally:
            self._in_loop = was_in_loop
        # A backpressure stall during the fallback may have dispatched
        # kernels event-driven; run their remaining lifecycle dry (externals
        # stay deferred) so nothing is left in flight across the settle
        # clock jump — the stall attribution could not account for that
        # gap. Closed-batch settles enter with an empty timeline and no
        # in-flight work, so this is a no-op there.
        if self._inflight or self._timeline:
            t2 = self._run_events(internal_only=True)
            end = max([end, t2] + [r.free_at for r in self._all_resources()])
        # Pending-state removal happens after the fallback pass (not per
        # kernel): _needed_later must see the whole snapshot's source counts
        # while fallback kernels retire, exactly as the batch scheduler did.
        for qk in snapshot:
            kid = qk.deps.kernel_id
            if kid in self._pending_map:
                self._remove_pending(kid)
            elif kid in ran:
                self._strip_pending_residue(kid, qk)
        # Kernels admitted *during* the fallback (retire callbacks issuing
        # new programs) get the same treatment as capacity-starved
        # leftovers: back to the queue for a fresh decode next drain. A
        # settle always ends with the pending set empty, so the serial
        # fallback cycles it appends to the makespan never open an
        # unattributed ready→dispatch gap in the stall accounting.
        for kid in list(self._pending_map):
            still.append(self._remove_pending(kid))
        end += self.stats.total_cycles - fallback_before
        self.sim_time = end
        self._timeline.advance_clock(end)
        self._wake.clear()
        self._dep_waiters.clear()
        self._war_waiters.clear()
        self._cap_blocked.clear()
        self.queue.extend(still)

    def _remove_pending(self, kid: int) -> QueuedKernel:
        """Drop one kernel from the pending bookkeeping (dispatched, run by
        the fallback, or re-queued as undispatchable)."""
        qk = self._pending_map.pop(kid)
        self._strip_pending_residue(kid, qk)
        return qk

    def _strip_pending_residue(self, kid: int, qk: QueuedKernel) -> None:
        """The non-map half of :meth:`_remove_pending`: release the decode
        booking, the source refcounts, and the WAR-index entries."""
        self._ready_at.pop(kid, None)
        for si, s in enumerate(qk.src_bindings):
            n = self._pending_src_count[s.phys_id] - 1
            if n:
                self._pending_src_count[s.phys_id] = n
            else:
                del self._pending_src_count[s.phys_id]
            self._war_index.remove((kid, si))

    def _dispatch_sweep(self, t: int, inflight: dict, eq: EventQueue) -> None:
        """Dispatch every kernel that can go at time ``t``.

        Kernels are examined in queue (ascending-id) order under the same
        pass discipline as the legacy full rescan: a pass walks ids upward
        (a heap, so mid-pass wakes ahead of the cursor join the same pass in
        order), kernels woken *behind* the cursor defer to the next pass,
        and passes repeat until one dispatches nothing. With ``wakeup`` the
        examined set is only the woken kernels — blocked kernels re-enter
        via their registered waker — which is schedule-equivalent because a
        kernel none of whose wake conditions fired would fail its checks
        with exactly the same answers as last time. With ``wakeup=False``
        every pass (re)examines the whole pending set, reproducing the
        legacy rescan-to-fixpoint engine."""
        while True:
            if not self.wakeup:
                self._wake.update(self._pending_map)
            if not self._wake:
                return
            progress = False
            cursor = -1
            deferred: set[int] = set()
            heap = sorted(self._wake)
            self._wake.clear()
            while heap:
                cand = heapq.heappop(heap)
                if cand <= cursor:
                    continue                   # duplicate wake this pass
                cursor = cand
                qk = self._pending_map.get(cand)
                if qk is None:
                    continue                   # already dispatched
                if self._try_dispatch(cand, qk, t, inflight, eq):
                    progress = True
                    if self._wake:             # wakes from this dispatch
                        for k in self._wake:
                            if k > cursor:
                                heapq.heappush(heap, k)
                            else:
                                deferred.add(k)
                        self._wake.clear()
            self._wake |= deferred
            if not progress:
                return

    def _try_dispatch(self, kid: int, qk: QueuedKernel, t: int,
                      inflight: dict, eq: EventQueue) -> bool:
        """Examine one pending kernel; dispatch it or register its waker."""
        if self._ready_at[kid] > t:
            return False         # its own decode event wakes it
        unmet = self.tracker.unmet_deps(kid)
        if unmet:
            self.metrics.kernel_blocked(kid, t, "raw_dep")
            if self.wakeup:
                for d in unmet:
                    self._dep_waiters.setdefault(d, set()).add(kid)
            return False
        blockers = self._war_blockers(qk, kid)
        if blockers:
            self.metrics.kernel_blocked(kid, t, "war_guard")
            if self.wakeup:
                for b in blockers:
                    self._war_waiters.setdefault(b, set()).add(kid)
            return False
        v = self._choose_vpu_pipelined(qk, t)
        if v is None:
            self.metrics.kernel_blocked(kid, t, "capacity")
            if self.wakeup:
                self._cap_blocked.add(kid)
            return False
        self._remove_pending(kid)
        self._dispatch(qk, v, t, inflight, eq)
        # This dispatch unblocks: later kernels WAR-gated on this reader, and
        # (because allocation can consolidate/evict residents on any VPU)
        # possibly every capacity-blocked kernel.
        waiters = self._war_waiters.pop(kid, None)
        if waiters:
            self._wake |= waiters
        self._wake_capacity_blocked()
        return True

    def _war_blockers(self, qk: QueuedKernel, kid: int) -> set[int]:
        """In-order WAR-aliasing guard: ``qk`` must not overwrite a memory
        region an earlier-queued, still-pending kernel reads (that kernel
        copies its sources in at dispatch; program order then guarantees it
        observes the pre-``qk`` data, exactly like the serial loop). Returns
        the blocking kernel ids (empty = free to go); the pending-source
        footprint index makes this O(hits), not O(pending × operands)."""
        return {k for k, _si in
                self._war_index.query(qk.dst_binding.region) if k < kid}

    def _wake_capacity_blocked(self) -> None:
        if self._cap_blocked:
            self._wake |= self._cap_blocked
            self._cap_blocked.clear()

    # -------------------------------------------------------- VPU selection
    def _free_lines(self, v: int) -> int:
        return self.cache.free_line_count(v)

    def _capacity_ok(self, qk: QueuedKernel, v: int) -> bool:
        need = 0
        seen: set[int] = set()
        for s in qk.src_bindings:
            if s.phys_id in seen:       # repeated operand (e.g. gemm(A, A))
                continue                # is claimed once by _allocation_step
            seen.add(s.phys_id)
            r = self.resident.get(s.phys_id)
            if r is not None and r.vpu == v:
                continue
            need += self.vpus[v].lines_needed(*s.shape, s.width)
        d = qk.dst_binding
        r = self.resident.get(d.phys_id)
        if not (r is not None and r.vpu == v
                and (r.rows, r.cols) == (d.rows, d.cols)):
            need += self.vpus[v].lines_needed(*d.shape, d.width)
        return self._free_lines(v) >= need

    def _choose_vpu_pipelined(self, qk: QueuedKernel, t: int) -> Optional[int]:
        """Same policy family as the serial scheduler — resident-operand
        affinity first — extended with earliest-free-datapath preference so
        independent kernels spread across VPUs. Returns None to wait.

        Offlined VPUs never attract work: affinity to a resident stranded on
        a fenced VPU falls through to the healthy candidates (the cross-VPU
        path in ``_allocate_source`` consolidates the resident through
        memory when the kernel lands elsewhere)."""
        for s in qk.src_bindings:
            r = self.resident.get(s.phys_id)
            if r is None or r.vpu in self.offline:
                continue
            return r.vpu if self._capacity_ok(qk, r.vpu) else None
        cands = [v for v in range(self.cache.n_vpus)
                 if v not in self.offline and self._capacity_ok(qk, v)]
        if not cands:
            return None
        return min(cands, key=lambda v: (max(self.res_dp[v].free_at, t),
                                         self.cache.dirty_line_count(v),
                                         -self._free_lines(v), v))

    # ------------------------------------------------------------ activities
    def _dispatch(self, qk: QueuedKernel, v: int, t: int, inflight: dict,
                  eq: EventQueue) -> None:
        kid = qk.deps.kernel_id
        vpu = self.vpus[v]
        kf = self.faults.kernel_faults(kid) if self.faults is not None \
            else None
        # Functional allocation happens NOW, in dependency order; the events
        # below only model when the hardware would finish each piece. (The
        # allocation's aliased-dirty flushes consolidate through
        # _consolidate_resident, which invalidates any reuse copies the
        # landing made stale — so the reuse lookups below see post-flush
        # memory state.)
        alloc = self._allocation_step(qk, vpu)
        lock_iv = self.res_lock.acquire(t, self.geometry.schedule_cycles,
                                        label=f"k{kid} claim")
        flows = (qk.spec.dataflow
                 if self.dataflow and qk.spec.dataflow else None)
        # Cross-instruction reuse: an operand whose region is contained in a
        # clean copy already modeled on this VPU skips its DMA-in train — the
        # skipped transfer cycles never enter the allocation phase (they are
        # tallied separately in PhaseStats.reused_dma_cycles).
        segs = alloc.dma_segments
        reuse_gates: list[int] = []
        skip_cycles = 0
        if self.reuse and flows is not None:
            kept = []
            for si, rows, cycles in segs:
                hit = self._reuse_lookup(v, qk.src_bindings[si].region)
                if hit is None:
                    kept.append((si, rows, cycles))
                    continue
                reuse_gates.append(hit)
                skip_cycles += cycles
                self.stats.reuse_hits += 1
                self.stats.reused_dma_cycles += cycles
                self.tracer.emit(f"{qk.spec.name} k{kid} reuse[op{si}]",
                                 "allocation", f"vpu{v}.dma",
                                 max(lock_iv.end, hit), 0, lane=f"op{si}",
                                 instant=True, kernel=kid, vpu=v, operand=si)
            segs = kept
        self.stats.allocation_cycles += (self.geometry.schedule_cycles
                                         + alloc.dma_cycles - skip_cycles)
        self.stats.writeback_cycles += alloc.wb_cycles
        self.tracer.emit(f"{qk.spec.name} k{kid} claim", "allocation",
                         "cache.lock", lock_iv.start, lock_iv.duration,
                         kernel=kid, vpu=v)
        self.metrics.activity(f"{qk.spec.name} k{kid} claim", "allocation",
                              "cache.lock", lock_iv.start, lock_iv.end,
                              kernel=kid, vpu=v)
        # Consolidation write-backs of older deferred results happen before
        # this kernel's operands stream in, each on the DMA port of the VPU
        # *holding* the resident (not necessarily the dispatch VPU); they are
        # *writeback*-phase cycles, booked separately so the trace's phase
        # totals agree with PhaseStats. The DMA-in below reads the bytes
        # these consolidations land, so it is gated on their completion.
        dma_start = lock_iv.end
        for wv, cyc in alloc.wb_segments:
            wb_iv = self.res_dma[wv].acquire(lock_iv.end, cyc,
                                             label=f"k{kid} consolidate")
            dma_start = max(dma_start, wb_iv.end)
            self.tracer.emit(f"{qk.spec.name} k{kid} consolidate", "writeback",
                             f"vpu{wv}.dma", wb_iv.start, wb_iv.duration,
                             kernel=kid, vpu=wv)
            self.metrics.activity(f"{qk.spec.name} k{kid} consolidate",
                                  "writeback", f"vpu{wv}.dma", wb_iv.start,
                                  wb_iv.end, kernel=kid, vpu=wv)
            self.metrics.inc("wb.consolidations")

        # ECC tier (fault model): the injection + recovery is functional —
        # bits really flip in the data array and the scrub really corrects
        # or re-fetches (see CacheRuntime._fault_scrub) — and the recovery
        # cycles book as a window on this VPU's DMA port ahead of the
        # operand tile trains (FIFO order pushes the trains behind it). The
        # window's end feeds the stall table so the delay bins as
        # ``fault_replay``, keeping per-kernel conservation exact.
        fault_end = 0
        if kf is not None and kf.ecc_bits:
            scrub_cycles = self._fault_scrub(qk, alloc, kf)
            if scrub_cycles:
                f_iv = self.res_dma[v].acquire(dma_start, scrub_cycles,
                                               label=f"k{kid} ecc-scrub")
                fault_end = f_iv.end
                self.stats.fault_cycles += scrub_cycles
                kind = "correct" if kf.ecc_bits == 1 else "refetch"
                self.tracer.emit(f"{qk.spec.name} k{kid} ecc-{kind}",
                                 "allocation", f"vpu{v}.dma", f_iv.start,
                                 f_iv.duration, kernel=kid, vpu=v)
                self.metrics.activity(f"{qk.spec.name} k{kid} ecc-{kind}",
                                      "allocation", f"vpu{v}.dma", f_iv.start,
                                      f_iv.end, kernel=kid, vpu=v)

        # Tile-train DMA-in (intra-instruction pipelining): each source
        # operand streams as its OWN train of (row-band × column-tile)
        # activities on the VPU's DMA port. With dataflow gating on, operands
        # that gate FULL on *both* axes (conv weights; GEMM's B when column
        # tiling is off) stream first so the streamable operands can feed the
        # datapath while still in flight. A row-FULL operand whose column
        # axis streams (GEMM's B under `tiling`) instead keeps its program
        # position: it transfers column-tile-major *after* the row-paced
        # operands, so output tile (*, 0) unblocks at B's first column tile
        # and compute overlaps the remaining tiles' DMA — the Neural-Cache
        # strip pipeline. Trains are keyed by physical binding, so a repeated
        # operand (gemm(A, A)) gates every occurrence on the one train that
        # was actually scheduled. Without a `tiling` config every operand has
        # a single column tile and the model reduces to the 1D row trains.
        band_limit = ((self.tiling[0] or self.row_chunk) if self.tiling
                      else self.row_chunk)
        col_limit = self.tiling[1] if self.tiling else 0
        if flows is not None:
            def fully_gated(flow) -> bool:
                return (flow.kind is FlowKind.FULL
                        and not (col_limit
                                 and flow.col_kind is not FlowKind.FULL))
            order = sorted(range(len(segs)),
                           key=lambda i: (not fully_gated(flows[segs[i][0]]),
                                          i))
            segs = [segs[i] for i in order]
        trains: dict[int, TileTrain] = {}
        streamed: list[tuple[StridedRegion, int]] = []
        eff_flows = list(flows) if flows is not None else None
        dma_ivs = []
        chunk_rows: list[int] = []
        # Trace rows + spans of the booked DMA tiles, for flow-arrow emission
        # (phys_id -> (block, band, tile) -> (row, start, end)); the parallel
        # flat list serves the legacy chunk-indexed gating model.
        tile_slices: dict[int, dict[tuple[int, int, int],
                                    tuple[str, int, int]]] = {}
        flat_slices: list[tuple[str, int, int]] = []
        ci = 0
        for si, rows, cycles in segs:
            flow = flows[si] if flows is not None else None
            binding = qk.src_bindings[si]
            blocks = 1
            if flow is not None and flow.blocks > 1:
                if rows % flow.blocks == 0:
                    blocks = flow.blocks
                else:
                    # Rows don't split into the declared blocks: stream as one
                    # train and gate FULL — a per-row window over a layout we
                    # can't decompose would be optimistic, not conservative.
                    eff_flows[si] = FULL
            flow_eff = eff_flows[si] if flows is not None else None
            band_parts = row_chunks(rows // blocks, band_limit)
            # Column tiles only pay off when the operand's column policy can
            # gate on partial columns; a column-FULL operand streams whole
            # rows (one tile) — splitting it buys no earlier compute start.
            if (flow_eff is not None and col_limit
                    and flow_eff.col_kind is not FlowKind.FULL):
                col_parts = row_chunks(binding.cols, col_limit)
            else:
                col_parts = [binding.cols]
            # Row-FULL / column-streamed operands (GEMM's B) transfer
            # column-tile-major so output tile (*, 0) unblocks as early as
            # possible; everything else goes band-major.
            col_major = (flow_eff is not None and len(col_parts) > 1
                         and flow_eff.kind is FlowKind.FULL)
            entries = tile_entries([band_parts] * blocks, col_parts,
                                   col_major)
            cyc_parts = split_proportional(
                cycles, [band_parts[bi] * col_parts[ti]
                         for _, bi, ti in entries])
            nb, nt = len(band_parts), len(col_parts)
            ends = [[[0] * nt for _ in range(nb)] for _ in range(blocks)]
            op_slices = tile_slices.setdefault(binding.phys_id, {})
            for (blk, bi, ti), cyc in zip(entries, cyc_parts):
                iv = self.res_dma[v].acquire(
                    dma_start, cyc, label=f"k{kid} dma-in[op{si}.{ci}]")
                dma_ivs.append(iv)
                if flows is None:       # legacy concatenated-gating weights
                    chunk_rows.append(band_parts[bi])
                ends[blk][bi][ti] = iv.end
                lane = f"op{si}" if nt == 1 else f"op{si}.c{ti}"
                self.tracer.emit(f"{qk.spec.name} k{kid} dma-in[op{si}.{ci}]",
                                 "allocation", f"vpu{v}.dma", iv.start,
                                 iv.duration, lane=lane, kernel=kid,
                                 vpu=v, chunk=ci, operand=si, band=bi,
                                 tile=ti)
                self.metrics.activity(
                    f"{qk.spec.name} k{kid} dma-in[op{si}.{ci}]",
                    "allocation", f"vpu{v}.dma", iv.start, iv.end,
                    kernel=kid, vpu=v)
                self.metrics.inc("dma.tiles")
                op_slices[(blk, bi, ti)] = (f"vpu{v}.dma/{lane}",
                                            iv.start, iv.end)
                flat_slices.append((f"vpu{v}.dma/{lane}", iv.start, iv.end))
                ci += 1
            cum_r = []
            acc = 0
            for r in band_parts:
                acc += r
                cum_r.append(acc)
            cum_c = []
            acc = 0
            for c in col_parts:
                acc += c
                cum_c.append(acc)
            trains[binding.phys_id] = TileTrain(
                [list(cum_r) for _ in range(blocks)], cum_c, ends)
            if self.reuse:
                streamed.append((binding.region,
                                 max(iv.end
                                     for iv in dma_ivs[-len(entries):])))

        compute_cycles = self._compute_step(qk, vpu, alloc.src_res,
                                            alloc.dst_res)
        self.stats.compute_cycles += compute_cycles
        # Replay tier (fault model), functional half: each corrupted attempt
        # flips a destination bit and re-executes from the still-resident,
        # still-clean sources — inline, while they are guaranteed valid (a
        # later kernel's consolidation sweep may evict them mid-flight).
        # The *timing* of each replay attempt books at compute_done.
        if kf is not None and kf.replays:
            for attempt in range(kf.replays):
                self._fault_corrupt_dst(qk, alloc, attempt)
                self._compute_step(qk, vpu, alloc.src_res, alloc.dst_res)
        # Matching compute pieces. Dataflow gating: the output-tile grid is
        # paced row-wise by the longest row-streaming train and column-wise
        # by the widest column-streaming train, and tile (i, j) waits for the
        # tile set every operand's policy demands (operands without a train
        # are already resident or reuse-skipped — residents impose no gate,
        # reuse copies gate at their modeled landing time). Legacy (dataflow
        # off): piece i is gated on chunk i of the concatenated stream. With
        # no DMA at all, compute is one piece.
        piece_spans: list[tuple[int, int, int]] = []   # (gate, start, end)
        if flows is not None and (dma_ivs or reuse_gates):
            constraints = [(trains[s.phys_id], eff_flows[si], s.phys_id)
                           for si, s in enumerate(qk.src_bindings)
                           if s.phys_id in trains]
            pacing = [tr for tr, fl, _ in constraints
                      if fl.kind is not FlowKind.FULL]
            n_pieces = max((tr.pace for tr in pacing), default=1)
            weights = next((tr.piece_weights() for tr in pacing
                            if tr.pace == n_pieces), [1] * n_pieces)
            col_pacing = [tr for tr, fl, _ in constraints
                          if fl.col_kind is not FlowKind.FULL
                          and tr.col_pace > 1]
            n_cols = max((tr.col_pace for tr in col_pacing), default=1)
            col_weights = next((tr.col_weights() for tr in col_pacing
                                if tr.col_pace == n_cols), [1] * n_cols)
            band_cycles = split_proportional(compute_cycles, weights)
            base_gate = max([lock_iv.end] + reuse_gates)
            dp_iv = None
            for pi, bc in enumerate(band_cycles):
                for pj, cyc in enumerate(split_proportional(bc, col_weights)):
                    ready = max([base_gate]
                                + [tr.gate(fl, pi, n_pieces, pj, n_cols)
                                   for tr, fl, _ in constraints])
                    tag = f"{pi},{pj}" if n_cols > 1 else f"{pi}"
                    dp_iv = self.res_dp[v].acquire(
                        ready, cyc, label=f"k{kid} {qk.spec.name}[{tag}]")
                    self.tracer.emit(f"{qk.spec.name} k{kid}[{tag}]",
                                     "compute", f"vpu{v}.datapath",
                                     dp_iv.start, dp_iv.duration, kernel=kid,
                                     vpu=v, chunk=pi * n_cols + pj, band=pi,
                                     tile=pj)
                    self.metrics.activity(f"{qk.spec.name} k{kid}[{tag}]",
                                          "compute", f"vpu{v}.datapath",
                                          dp_iv.start, dp_iv.end,
                                          kernel=kid, vpu=v)
                    piece_spans.append((ready, dp_iv.start, dp_iv.end))
                    if self.tracer.enabled and ready > base_gate:
                        self._emit_gate_flow(qk, kid, v, constraints,
                                             tile_slices, pi, n_pieces, pj,
                                             n_cols, tag, dp_iv, base_gate)
        elif dma_ivs:
            pieces = split_proportional(compute_cycles, chunk_rows)
            dp_iv = None
            for pi, (dma_iv, cyc) in enumerate(zip(dma_ivs, pieces)):
                dp_iv = self.res_dp[v].acquire(dma_iv.end, cyc,
                                               label=f"k{kid} {qk.spec.name}"
                                                     f"[{pi}]")
                self.tracer.emit(f"{qk.spec.name} k{kid}[{pi}]", "compute",
                                 f"vpu{v}.datapath", dp_iv.start,
                                 dp_iv.duration, kernel=kid, vpu=v, chunk=pi)
                self.metrics.activity(f"{qk.spec.name} k{kid}[{pi}]",
                                      "compute", f"vpu{v}.datapath",
                                      dp_iv.start, dp_iv.end,
                                      kernel=kid, vpu=v)
                piece_spans.append((dma_iv.end, dp_iv.start, dp_iv.end))
                if self.tracer.enabled:
                    row, s0, e0 = flat_slices[pi]
                    self.tracer.flow(f"{qk.spec.name} k{kid} gate[{pi}]",
                                     "compute", row, max(s0, e0 - 1),
                                     f"vpu{v}.datapath", dp_iv.start)
        else:
            dp_iv = self.res_dp[v].acquire(lock_iv.end, compute_cycles,
                                           label=f"k{kid} {qk.spec.name}")
            self.tracer.emit(f"{qk.spec.name} k{kid}", "compute",
                             f"vpu{v}.datapath", dp_iv.start, dp_iv.duration,
                             kernel=kid, vpu=v)
            self.metrics.activity(f"{qk.spec.name} k{kid}", "compute",
                                  f"vpu{v}.datapath", dp_iv.start, dp_iv.end,
                                  kernel=kid, vpu=v)
            piece_spans.append((lock_iv.end, dp_iv.start, dp_iv.end))

        self.metrics.kernel_dispatched(kid, t, v, lock_iv.end, dma_start,
                                       piece_spans, fault_end=fault_end)
        if self.reuse:
            for region, landed in streamed:
                self._reuse_note(v, region, landed)
        # attempt counts the replay bookings already modeled (0 = the first
        # compute_done is the initial execution); compute_cycles is carried
        # so each replay re-books the same datapath occupancy.
        inflight[kid] = (qk, v, alloc.src_res, alloc.dst_res,
                        kf, 0, compute_cycles)
        self._emit_counters(t)
        eq.push(dp_iv.end, "compute_done", kid)

    def _emit_gate_flow(self, qk, kid: int, v: int, constraints, tile_slices,
                        pi: int, n_pieces: int, pj: int, n_cols: int,
                        tag: str, dp_iv, base_gate: int) -> None:
        """Flow arrow from the DMA tile that binds compute piece ``(pi, pj)``
        to the piece's datapath slice. Observability only — ``gate_source``
        re-derives the argmax of the gate rectangle; timing is untouched."""
        best_gate, best_slice = base_gate, None
        for tr, fl, pid in constraints:
            g, src = tr.gate_source(fl, pi, n_pieces, pj, n_cols)
            if g > best_gate and src is not None:
                sl = tile_slices.get(pid, {}).get(src)
                if sl is not None:
                    best_gate, best_slice = g, sl
        if best_slice is not None:
            row, s0, e0 = best_slice
            self.tracer.flow(f"{qk.spec.name} k{kid} gate[{tag}]", "compute",
                             row, max(s0, e0 - 1),
                             f"vpu{v}.datapath", dp_iv.start)

    def _book_writebacks(self, segments: list, fallback: tuple[int, int],
                         t: int, label: str, eq: Optional[EventQueue],
                         **args) -> None:
        """Book write-back DMA activities per owning-VPU port. ``fallback``
        is ``(vpu, cycles)`` for the rare case cycles were accrued without
        segment attribution. ``eq=None`` when no event loop is running
        (barrier): completion then surfaces via the resources' free_at."""
        if not segments and fallback[1]:
            segments = [fallback]
        for wv, cyc in segments:
            iv = self.res_dma[wv].acquire(t, cyc, label=label)
            self.tracer.emit(label, "writeback", f"vpu{wv}.dma",
                             iv.start, iv.duration, vpu=wv, **args)
            self.metrics.activity(label, "writeback", f"vpu{wv}.dma",
                                  iv.start, iv.end,
                                  kernel=args.get("kernel"), vpu=wv)
            self.metrics.inc("wb.bookings")
            if eq is not None:
                eq.push(iv.end, "wb_done")

    def _retire_timed(self, qk, src_res, dst_res) -> tuple[int, list]:
        """Run the shared retire step, capturing (vpu, cycles) per
        consolidation so each lands on the right DMA port."""
        self._wb_segments = segs = []
        try:
            wb = self._retire_step(qk, src_res, dst_res)
        finally:
            self._wb_segments = None
        return wb, segs

    def _handle_compute_done(self, kid: int, t: int, inflight: dict,
                             eq: EventQueue) -> None:
        qk, v, src_res, dst_res, kf, attempt, compute_cycles = inflight[kid]
        # Replay tier, timing half: each attempt re-books the datapath after
        # its backoff and re-fires compute_done — timing only; the replayed
        # execution already ran inline at dispatch, so the functional result
        # is correct no matter how many attempts the timing models. A VPU
        # offlined mid-flight skips the bookings (its datapath is fenced;
        # the hard-fault path owns the rest of the story).
        if kf is not None and attempt < kf.replays and v not in self.offline:
            backoff = self.faults.backoff(attempt)
            dp_iv = self.res_dp[v].acquire(t + backoff, compute_cycles,
                                           label=f"k{kid} replay{attempt}")
            self.tracer.emit(f"{qk.spec.name} k{kid} replay[{attempt}]",
                             "compute", f"vpu{v}.datapath", dp_iv.start,
                             dp_iv.duration, kernel=kid, vpu=v)
            self.metrics.activity(f"{qk.spec.name} k{kid} replay[{attempt}]",
                                  "compute", f"vpu{v}.datapath", dp_iv.start,
                                  dp_iv.end, kernel=kid, vpu=v)
            self.metrics.inc("faults.injected")
            self.metrics.kernel_replayed(kid, t, dp_iv.start, dp_iv.end)
            self.stats.fault_cycles += dp_iv.end - t
            inflight[kid] = (qk, v, src_res, dst_res,
                             kf, attempt + 1, compute_cycles)
            eq.push(dp_iv.end, "compute_done", kid)
            return
        inflight.pop(kid)
        self.metrics.kernel_retired(kid, t)
        wb, segs = self._retire_timed(qk, src_res, dst_res)
        self.stats.writeback_cycles += wb
        self.stats.kernels_run += 1
        if wb:
            self._book_writebacks(segs, (v, wb), t,
                                  f"{qk.spec.name} k{kid} writeback", eq,
                                  kernel=kid)
        if v in self.offline:
            # The VPU died while this kernel was in flight: its (now retired)
            # destination must not stay deferred-resident on a fenced VPU.
            self._evacuate_vpu_timed(v, t, eq)
        elif kf is not None and kf.exhausted:
            # Retry exhaustion: the final attempt completed on scrubbed
            # state, but the datapath is deemed faulty — fence it now.
            self._offline_vpu(v, t, eq)
        self._drain_idle_dma(t, inflight, eq)
        self._emit_counters(t)
        # This completion satisfies dependency edges out of ``kid``, and the
        # retire/drain may have evicted residents (capacity changed).
        waiters = self._dep_waiters.pop(kid, None)
        if waiters:
            self._wake |= waiters
        self._wake_capacity_blocked()
        # Completion watchers last, with scheduler state consistent: a
        # watcher may re-entrantly issue the request's next kernels (the
        # continuous-batching step chain).
        self._notify_retired(kid, t)

    def _drain_idle_dma(self, t: int, inflight: dict, eq: EventQueue) -> None:
        """Opportunistically write back deferred results whose consumers are
        all done, using DMA ports that would otherwise sit idle.

        Eligible residents are served least-booked-port first — ascending
        DMA-port ``free_at`` on the event timelines, not resident scan order
        — so on wide configs the drains land on the ports with the most
        headroom; each port takes one drain per sweep, and the ``wb_done``
        event triggers the next sweep."""
        busy_phys: set[int] = set()
        for qk, *_ in inflight.values():
            busy_phys.update(s.phys_id for s in qk.src_bindings)
            busy_phys.add(qk.dst_binding.phys_id)
        eligible = []
        for phys_id, res in self.resident.items():
            if (phys_id in busy_phys or self._needed_later(phys_id)
                    or not res.dirty):
                continue
            port = self.res_dma[res.vpu]
            eligible.append((port.free_at, port.busy_cycles, phys_id))
        eligible.sort()
        for _, _, phys_id in eligible:
            res = self.resident.get(phys_id)
            # Re-check: an earlier drain's alias flush may have landed this
            # resident, and a port that took a drain this sweep is no longer
            # idle — its next drain waits for the wb_done sweep.
            if (res is None or not res.dirty
                    or not self.res_dma[res.vpu].idle_at(t)):
                continue
            b = self._binding_of(phys_id)
            v = res.vpu
            self._wb_segments = segs = []
            try:
                wb = (self._flush_older_aliases(b)
                      + self._writeback_resident(b, res))
            finally:
                self._wb_segments = None
            self.at.release(phys_id, RegionKind.DST)
            self.stats.writeback_cycles += wb
            self._book_writebacks(segs, (v, wb), t, f"drain phys{phys_id}",
                                  eq, phys=phys_id)

    # ---------------------------------------------------------- fault model
    def _offline_vpu(self, v: int, t: int, eq=None) -> None:
        """Hard-fault VPU ``v`` under the event timeline: fence its datapath
        (any further booking raises), evacuate its residents with timed
        write-backs, and mark it offline for every placement policy.
        Kernels already in flight on ``v`` run to completion — their
        functional work happened at dispatch — and their leftovers are
        evacuated at their retire. Raises :class:`FaultError` when the last
        healthy VPU dies."""
        if v in self.offline:
            return
        self.offline.add(v)
        self.res_dp[v].fence(t)
        self.metrics.inc("faults.offlined")
        self.tracer.emit(f"vpu{v} offline (hard fault)", "writeback",
                         f"vpu{v}.datapath", t, 0, instant=True, vpu=v)
        self._evacuate_vpu_timed(v, t, eq)
        if len(self.offline) >= self.cache.n_vpus:
            raise FaultError(
                f"hard fault offlined vpu{v}: no healthy VPU remains "
                f"({len(self.offline)}/{self.cache.n_vpus} offline)")
        # Survivors may now be the only capacity left — re-examine blocked
        # kernels so pending work redistributes immediately.
        self._wake_capacity_blocked()
        self._wake.update(self._pending_map)

    def _evacuate_vpu_timed(self, v: int, t: int, eq=None) -> None:
        """Timed counterpart of ``_evacuate_vpu``: consolidations book on
        the owning VPU's DMA port (the cache controller still drains a
        fenced VPU's data array — only the datapath is dead). Residents of
        in-flight kernels are skipped; the retire path re-runs the sweep."""
        busy_phys: set[int] = set()
        for qk, *_ in self._inflight.values():
            busy_phys.update(s.phys_id for s in qk.src_bindings)
            busy_phys.add(qk.dst_binding.phys_id)
        for phys_id in list(self.resident):
            res = self.resident.get(phys_id)
            if res is None or res.vpu != v or phys_id in busy_phys:
                continue
            if res.dirty:
                b = self._binding_of(phys_id)
                self._wb_segments = segs = []
                try:
                    wb = (self._flush_older_aliases(b)
                          + self._writeback_resident(b, res))
                finally:
                    self._wb_segments = None
                self.stats.writeback_cycles += wb
                self.at.release(phys_id, RegionKind.DST)
                self._book_writebacks(segs, (v, wb), t,
                                      f"evacuate phys{phys_id}", eq,
                                      phys=phys_id)
            else:
                self._evict_resident(phys_id)
                self.at.release(phys_id, RegionKind.DST)

    # -------------------------------------------------------------- pending
    def _needed_later(self, phys_id: int) -> bool:
        if self._pending_src_count.get(phys_id, 0) > 0:
            return True
        return super()._needed_later(phys_id)

    # -------------------------------------------------------------- barrier
    def _drain_deferred_residents(self, need_slots: Optional[int] = None) -> None:
        """Timed flush of deferred results (all for barrier, just enough AT
        slots for capacity-pressure relief): each consolidation books on the
        owning VPU's DMA port, so the flushes overlap across ports.

        Skips residents touched by in-flight kernels: mid-loop (AT pressure
        from a re-entrant issue) a dispatched kernel's functional state is
        already claimed, and evicting its destination would let the retire
        step re-insert a dead residency over released lines."""
        wall0 = time.perf_counter()
        t = self._timeline.now
        busy_phys: set[int] = set()
        for qk, *_ in self._inflight.values():
            busy_phys.update(s.phys_id for s in qk.src_bindings)
            busy_phys.add(qk.dst_binding.phys_id)
        for phys_id in list(self.resident):
            if need_slots is not None and self.at.free_slots() >= need_slots:
                break
            if phys_id in busy_phys:
                continue
            res = self.resident.get(phys_id)
            if res is None:              # invalidated by an earlier landing
                continue
            if res.dirty:
                b = self._binding_of(phys_id)
                v = res.vpu
                self._wb_segments = segs = []
                try:
                    wb = (self._flush_older_aliases(b)
                          + self._writeback_resident(b, res))
                finally:
                    self._wb_segments = None
                self.stats.writeback_cycles += wb
                self.at.release(phys_id, RegionKind.DST)
                self._book_writebacks(segs, (v, wb), t,
                                      f"flush phys{phys_id}", None,
                                      phys=phys_id)
            else:
                self._evict_resident(phys_id)
                self.at.release(phys_id, RegionKind.DST)
        if not (self._in_loop or self._session_open):
            # Batch mode: the flush extends the makespan and the next drain's
            # decodes start where it left off. Mid-session the clock belongs
            # to the event loop — flush bookings surface via free_at.
            self.sim_time = max([self.sim_time]
                                + [r.free_at for r in self._all_resources()])
            self._timeline.advance_clock(self.sim_time)
        self._wall_seconds += time.perf_counter() - wall0

    def barrier(self) -> None:
        """Drain the queue, then flush deferred results with timed DMA."""
        self.run_pending()
        if self.queue:
            raise RuntimeError("kernel queue not drained — dependency deadlock?")
        self._drain_deferred_residents()

    # -------------------------------------------------------------- sessions
    # The pipelined runtime's session clock IS the open timeline: issues book
    # decodes at the current clock, posted arrivals are timeline events, and
    # ``advance`` runs the event loop up to a bound with work left in flight.
    def session_now(self) -> int:
        return self._timeline.now

    def session_post(self, t: int, fn) -> None:
        self._timeline.post(t, fn)

    def session_advance(self, until: int) -> None:
        """Process every event due by ``until`` — dispatches, completions,
        posted arrivals — then move the clock there, leaving later events
        (and undispatched kernels) in flight."""
        wall0 = time.perf_counter()
        self._admit_queue()
        self._wake.update(self._pending_map)
        self._run_events(until)
        self._timeline.advance_clock(until)
        self._wall_seconds += time.perf_counter() - wall0

    def _deadlock_error(self) -> DeadlockError:
        """Assemble the structured diagnostics for a wedged drain: every
        stuck kernel with its last blocked reason and unmet dependency ids,
        plus each resource's ``free_at`` — enough to tell a dependency
        deadlock from a mis-modeled resource without re-running under a
        debugger."""
        pending: dict[int, dict] = {}
        stalls = getattr(self.metrics, "stalls", None)
        for qk in [*self._pending_map.values(), *self.queue]:
            kid = qk.deps.kernel_id
            rec = stalls.records.get(kid) if stalls is not None else None
            pending[kid] = {
                "kernel": qk.spec.name,
                "blocked_on": rec._reason if rec is not None else None,
                "unmet_deps": sorted(self.tracker.unmet_deps(kid)),
            }
        resources = {r.name: r.free_at for r in self._all_resources()}
        ids = ", ".join(f"k{kid}" for kid in sorted(pending))
        return DeadlockError(
            f"session drain made no progress with {len(pending)} kernel(s) "
            f"still pending ({ids}) — dependency deadlock; see "
            f"err.pending / err.resources for per-kernel blocked reasons "
            f"and resource horizons", pending, resources)

    def session_drain(self) -> None:
        """Run the timeline dry (arrivals included), settle, and flush —
        the open-session counterpart of :meth:`barrier`.

        Unlike a closed-batch barrier, one drain pass is not enough: the
        settle fallback fires retire callbacks, and those may issue fresh
        programs (a continuous-batching driver chaining its next step), so
        the pass repeats until a full pass makes no progress. A stuck
        remainder raises :class:`DeadlockError` with the pending kernels,
        their last blocked reasons, and resource horizons — instead of
        wedging silently or falling through to the generic barrier check."""
        was, self._session_open = self._session_open, False
        try:
            while self.queue or self._pending_map or self._timeline:
                before = (self.stats.kernels_run, self.events_processed,
                          self.stats.total_cycles)
                self.run_pending()
                after = (self.stats.kernels_run, self.events_processed,
                         self.stats.total_cycles)
                if after == before:
                    # A full pass moved nothing at all: if work remains it
                    # can never complete (event re-bookings would at least
                    # bump events_processed).
                    if self.queue or self._pending_map or self._inflight:
                        raise self._deadlock_error()
                    break
                if (after[0] == before[0]
                        and (self.queue or self._pending_map)
                        and not self._timeline and not self._inflight):
                    # Events ticked but no kernel retired, nothing is in
                    # flight, and the timeline is dry — the remaining
                    # kernels are re-examined each pass without ever
                    # becoming ready. Progress in the counters is an
                    # artifact of re-booked decode events, not real work.
                    raise self._deadlock_error()
            self.barrier()
        finally:
            self._session_open = was
