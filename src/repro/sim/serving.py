"""Continuous-batching serving scenario over re-entrant runtime sessions.

The closed-batch benchmarks answer "how fast does one tape run"; a serving
deployment asks a different question — what latency does a *request* see
when it arrives while other requests are mid-generation and has to share
the cache, the VPUs, and the kernel queue with them. This module drives
that scenario against either runtime through the
:class:`~repro.core.session.RuntimeSession` protocol, mirroring
``serving/engine.py``'s slot discipline:

  * requests arrive at sim times drawn from a Poisson process
    (:func:`poisson_arrivals`) or a bursty replay (:func:`bursty_arrivals`)
    and are posted onto the session timeline as external events;
  * an arrival is admitted into one of ``cfg.slots`` serving slots (or
    queues FIFO when all slots are busy) and issues its **prefill tape** —
    length proportional to the prompt, filling the request's resident KV
    buffers;
  * prefilled requests generate through **batched decode steps**: one
    program per global step concatenating every ready slot's decode ops
    (shapes per :func:`repro.lower.transformer.lower_decode_step`), with
    the KV cache and the ping-pong activation row held as *resident* cache
    state across steps under the real AT-capacity and flush rules — each
    step's K/V-append and activation read are genuine cross-program RAW
    dependencies on bytes the previous step left in the cache.

Everything is callback-driven off the session clock: prefill completion
records the request's first token (TTFT), step completion advances every
batched request one token and chains the next step, request completion
frees the slot and admits the head of the queue. The whole run is
deterministic for a fixed ``(config, arrivals)`` pair — arrival generators
take explicit seeds and the driver never consults wall-clock time.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.encoding import ElemWidth
from repro.core.program import KernelProgram, ProgramBuilder, ProgramError
from repro.core.session import RuntimeSession
from repro.lower._strip import DEFAULT_VLEN, DEFAULT_VREGS, emit_gemm
from repro.sim.metrics import RequestLog

__all__ = [
    "ServingConfig", "Request", "ServingDriver",
    "poisson_arrivals", "bursty_arrivals",
    "weights_program", "prefill_program", "decode_step_program",
]


# ------------------------------------------------------------ configuration
@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Scaled model shapes + slot discipline for a serving run.

    ``kv_max`` bounds a request's total context (prompt + generated); the
    per-request KV buffers are allocated at that capacity once and appended
    into column by column, so admission never reallocates."""

    d: int = 32               # model dim (scaled, per lower_decode_step)
    ff: int = 96              # MLP hidden dim
    kv_max: int = 48          # KV capacity per request (prompt + generated)
    slots: int = 4            # concurrent requests in the batch
    width: ElemWidth = ElemWidth.B
    alpha: float = 0.125      # leakyrelu slope (softmax stand-in)
    seed: int = 0
    vregs: int = DEFAULT_VREGS   # tiling knobs, passed to the strip-miner
    vlen: int = DEFAULT_VLEN


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: arrival sim time, prompt length, tokens to
    generate (the prefill's first token included)."""

    rid: int
    arrival: int
    prompt_len: int
    max_new: int


# -------------------------------------------------------- arrival processes
def poisson_arrivals(n: int, mean_gap: float, *,
                     prompt_range: tuple[int, int] = (4, 12),
                     new_range: tuple[int, int] = (2, 6),
                     seed: int = 0) -> list[Request]:
    """``n`` requests with exponentially distributed inter-arrival gaps
    (mean ``mean_gap`` cycles) — the open-loop Poisson offered load."""
    rng = np.random.default_rng(seed)
    out, t = [], 0
    for rid in range(n):
        t += int(round(rng.exponential(mean_gap)))
        out.append(Request(
            rid=rid, arrival=t,
            prompt_len=int(rng.integers(prompt_range[0], prompt_range[1] + 1)),
            max_new=int(rng.integers(new_range[0], new_range[1] + 1))))
    return out


def bursty_arrivals(n: int, burst: int, gap: int, *, spread: int = 32,
                    prompt_range: tuple[int, int] = (4, 12),
                    new_range: tuple[int, int] = (2, 6),
                    seed: int = 0) -> list[Request]:
    """Bursty replay: requests land in bursts of ``burst`` (jittered within
    ``spread`` cycles), bursts ``gap`` cycles apart — the tail-latency
    stressor a mean-rate Poisson sweep underestimates."""
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(n):
        base = (rid // burst) * gap
        out.append(Request(
            rid=rid, arrival=base + int(rng.integers(0, spread)),
            prompt_len=int(rng.integers(prompt_range[0], prompt_range[1] + 1)),
            max_new=int(rng.integers(new_range[0], new_range[1] + 1))))
    return sorted(out, key=lambda r: (r.arrival, r.rid))


# --------------------------------------------------------- program builders
def _declare_weights(b: ProgramBuilder, cfg: ServingConfig) -> None:
    """The shared model weights. Declared by every program that reads them,
    but placed exactly once — later programs reuse the prior addresses, so
    the declarations only carry shapes (and the reference oracle's images)."""
    b.buffer("wq", cfg.d, cfg.d, init="random", seed=cfg.seed + 1, lo=-3, hi=3)
    b.buffer("wo", cfg.d, cfg.d, init="random", seed=cfg.seed + 2, lo=-3, hi=3)
    b.buffer("w1", cfg.d, cfg.ff, init="random", seed=cfg.seed + 3,
             lo=-3, hi=3)
    b.buffer("w2", cfg.ff, cfg.d, init="random", seed=cfg.seed + 4,
             lo=-3, hi=3)


def _declare_request(b: ProgramBuilder, cfg: ServingConfig, rid: int) -> None:
    """One request's resident state: the KV cache at full ``kv_max``
    capacity plus the ping-pong activation row and per-step scratch."""
    p = f"r{rid}_"
    b.buffer(p + "x0", 1, cfg.d)
    b.buffer(p + "x1", 1, cfg.d)
    b.buffer(p + "kt", cfg.d, cfg.kv_max)
    b.buffer(p + "v", cfg.kv_max, cfg.d)
    b.buffer(p + "scores", 1, cfg.kv_max)
    b.buffer(p + "probs", 1, cfg.kv_max)
    b.buffer(p + "ctx", 1, cfg.d)
    b.buffer(p + "attn", 1, cfg.d)
    b.buffer(p + "h1", 1, cfg.ff)
    b.buffer(p + "act", 1, cfg.ff)
    b.buffer(p + "h2", 1, cfg.d)


def weights_program(cfg: ServingConfig) -> KernelProgram:
    """An ops-free tape that exists to place the shared weights once; its
    address map seeds the session-wide ``prior`` every later issue merges
    into."""
    b = ProgramBuilder("serving-weights", cfg.width)
    _declare_weights(b, cfg)
    return b.build()


def prefill_program(cfg: ServingConfig, rid: int,
                    prompt_len: int) -> KernelProgram:
    """Request ``rid``'s prefill tape — work proportional to the prompt.

    Each prompt position appends one K column and one V row (identity
    leakyrelu moves from the weight matrices — the integer library has no
    embedding lookup, so weight slices stand in for token embeddings), and
    the final position seeds the activation row ``x0`` the first decode
    step reads: a cross-program RAW carried through the resident cache."""
    if not 1 <= prompt_len <= cfg.kv_max:
        raise ProgramError(f"prefill r{rid}: prompt_len {prompt_len} outside "
                           f"[1, kv_max={cfg.kv_max}]")
    b = ProgramBuilder(f"prefill-r{rid}", cfg.width)
    _declare_weights(b, cfg)
    _declare_request(b, cfg, rid)
    p = f"r{rid}_"
    for s in range(prompt_len):
        b.op("leakyrelu", [b.view("wq", cfg.d, 1, col0=(rid + s) % cfg.d)],
             b.view(p + "kt", cfg.d, 1, col0=s), alpha=1.0,
             comment=f"_leakyrelu(m3, m0)  // r{rid} K append, pos {s}")
        b.op("leakyrelu", [b.view("wo", 1, cfg.d, row0=(rid + s) % cfg.d)],
             b.view(p + "v", 1, cfg.d, row0=s), alpha=1.0,
             comment=f"_leakyrelu(m3, m0)  // r{rid} V append, pos {s}")
    b.op("leakyrelu",
         [b.view("wo", 1, cfg.d, row0=(rid + prompt_len) % cfg.d)],
         b.full(p + "x0"), alpha=1.0,
         comment=f"_leakyrelu(m3, m0)  // r{rid} last-position activation")
    return b.build()


def decode_step_program(cfg: ServingConfig, states: Sequence["SlotState"],
                        step: int) -> KernelProgram:
    """One batched decode step: every ready slot's ops concatenated into a
    single tape, so slots compete for VPUs/queue/cache exactly as a
    continuous batch does. Per slot at KV length ``L`` (all appends and the
    activation read are RAW on bytes the *previous* program left resident):

      K/V append at column/row ``L`` → attention scores over ``L+1``
      positions → leakyrelu (softmax stand-in) → context gather → output
      projection → MLP (W1 → leakyrelu → W2) → next activation row into
      the other ping-pong buffer.
    """
    b = ProgramBuilder(f"decode-step-{step}", cfg.width)
    _declare_weights(b, cfg)
    kw = dict(vregs=cfg.vregs, vlen=cfg.vlen)
    for st in states:
        rid, L = st.rid, st.kv_len
        if L >= cfg.kv_max:
            raise ProgramError(f"decode r{rid}: KV length {L} at capacity "
                               f"{cfg.kv_max}")
        _declare_request(b, cfg, rid)
        p = f"r{rid}_"
        x_cur = b.full(p + ("x1" if st.parity else "x0"))
        x_nxt = b.full(p + ("x0" if st.parity else "x1"))
        b.op("leakyrelu", [b.view("wq", cfg.d, 1, col0=L % cfg.d)],
             b.view(p + "kt", cfg.d, 1, col0=L), alpha=1.0,
             comment=f"_leakyrelu(m3, m0)  // r{rid} K append @ {L}")
        b.op("leakyrelu", [x_cur], b.view(p + "v", 1, cfg.d, row0=L),
             alpha=1.0,
             comment=f"_leakyrelu(m3, m0)  // r{rid} V append @ {L}")
        emit_gemm(b, x_cur, b.view(p + "kt", cfg.d, L + 1),
                  b.view(p + "scores", 1, L + 1), alpha=0.5, **kw,
                  comment=f"_gemm(m3, m0, m1, m2)  // r{rid} scores[0:{L + 1}]")
        b.op("leakyrelu", [b.view(p + "scores", 1, L + 1)],
             b.view(p + "probs", 1, L + 1), alpha=cfg.alpha,
             comment=f"_leakyrelu(m3, m0)  // r{rid} probs (softmax stand-in)")
        emit_gemm(b, b.view(p + "probs", 1, L + 1),
                  b.view(p + "v", L + 1, cfg.d), b.full(p + "ctx"), **kw,
                  comment=f"_gemm(m3, m0, m1, m2)  // r{rid} ctx = p @ V")
        emit_gemm(b, b.full(p + "ctx"), b.full("wo"), b.full(p + "attn"),
                  **kw, comment=f"_gemm(m3, m0, m1, m2)  // r{rid} attn")
        emit_gemm(b, b.full(p + "attn"), b.full("w1"), b.full(p + "h1"),
                  **kw, comment=f"_gemm(m3, m0, m1, m2)  // r{rid} h1")
        b.op("leakyrelu", [b.full(p + "h1")], b.full(p + "act"),
             alpha=cfg.alpha,
             comment=f"_leakyrelu(m3, m0)  // r{rid} MLP activation")
        emit_gemm(b, b.full(p + "act"), b.full("w2"), b.full(p + "h2"),
                  **kw, comment=f"_gemm(m3, m0, m1, m2)  // r{rid} h2")
        b.op("leakyrelu", [b.full(p + "h2")], x_nxt, alpha=1.0,
             comment=f"_leakyrelu(m3, m0)  // r{rid} next activation "
                     f"(ping-pong)")
    return b.build()


# ------------------------------------------------------------------- driver
@dataclasses.dataclass
class SlotState:
    """One admitted request's generation state."""

    rid: int
    prompt_len: int
    max_new: int
    kv_len: int = 0           # KV positions filled (prompt after prefill)
    parity: int = 0           # which ping-pong buffer holds the activation
    tokens: int = 0           # tokens generated (1 at prefill completion)
    ready: bool = False       # prefill finished; eligible for decode steps


class ServingDriver:
    """Drives arrivals → admission → prefill → batched decode over one
    runtime session; collect results with :meth:`run`."""

    def __init__(self, rt_or_cop, cfg: Optional[ServingConfig] = None):
        self.cfg = cfg or ServingConfig()
        self.session = RuntimeSession(rt_or_cop, open_loop=True)
        self.rt = self.session.rt
        self.log = RequestLog(self.rt.metrics)
        self.active: dict[int, SlotState] = {}
        self.waiting: collections.deque[Request] = collections.deque()
        self.steps_issued = 0
        self._step_busy = False
        # Place the shared weights once; every later issue merges into this.
        h = self.session.issue(weights_program(self.cfg))
        self.addrs = h.addrs

    # -------------------------------------------------------------- driving
    def run(self, arrivals: Sequence[Request]) -> dict:
        """Post every arrival onto the timeline, drain to completion, and
        return the request-lifecycle summary (exact percentiles)."""
        for r in arrivals:
            self.session.post(r.arrival, lambda t, r=r: self._arrive(r, t))
        self.session.drain()
        if self.active or self.waiting:
            raise RuntimeError(
                f"drain returned with {len(self.active)} active / "
                f"{len(self.waiting)} queued requests — serving deadlock")
        return self.log.summary(self.session.now())

    # ------------------------------------------------------------ callbacks
    def _arrive(self, r: Request, t: int) -> None:
        # Log the nominal arrival time, not the service time: a frontend
        # stall can delay the callback past r.arrival, and that wait must
        # land in queue_wait/TTFT, not vanish from them.
        self.log.arrive(r.rid, r.prompt_len, r.max_new, r.arrival)
        # Admission control: a request whose context could outgrow kv_max
        # is rejected *here* — with a `serving.rejected` count — instead of
        # blowing up mid-tape in a decode step after cycles were spent on
        # its prefill. kv_len peaks at prompt_len + (max_new - 1).
        if (not 1 <= r.prompt_len <= self.cfg.kv_max
                or r.prompt_len + r.max_new > self.cfg.kv_max + 1):
            self.log.reject(r.rid, t)
            return
        if len(self.active) < self.cfg.slots:
            self._admit(r, t)
        else:
            self.waiting.append(r)

    def _admit(self, r: Request, t: int) -> None:
        self.log.admit(r.rid, t)
        st = SlotState(rid=r.rid, prompt_len=r.prompt_len, max_new=r.max_new,
                       kv_len=r.prompt_len)
        self.active[r.rid] = st
        h = self.session.issue(
            prefill_program(self.cfg, r.rid, r.prompt_len), addrs=self.addrs,
            on_done=lambda t, rid=r.rid: self._prefilled(rid, t))
        self.addrs = h.addrs

    def _prefilled(self, rid: int, t: int) -> None:
        st = self.active[rid]
        st.ready = True
        st.tokens = 1                      # the prefill yields token #1
        self.log.first_token(rid, t)
        if st.tokens >= st.max_new:
            self._finish(rid, t)
        else:
            self._maybe_step(t)

    def _maybe_step(self, t: int) -> None:
        if self._step_busy:
            return
        ready = sorted((st for st in self.active.values() if st.ready),
                       key=lambda st: st.rid)
        if not ready:
            return
        self._step_busy = True
        rids = tuple(st.rid for st in ready)
        prog = decode_step_program(self.cfg, ready, self.steps_issued)
        self.steps_issued += 1
        h = self.session.issue(
            prog, addrs=self.addrs,
            on_done=lambda t, rids=rids: self._step_done(rids, t))
        self.addrs = h.addrs

    def _step_done(self, rids: tuple[int, ...], t: int) -> None:
        self._step_busy = False
        for rid in rids:
            st = self.active[rid]
            st.kv_len += 1
            st.parity ^= 1
            st.tokens += 1
            self.log.token(rid)
            if st.tokens >= st.max_new:
                self._finish(rid, t)
        self._maybe_step(t)

    def _finish(self, rid: int, t: int) -> None:
        self.log.finish(rid, t)
        del self.active[rid]
        while self.waiting and len(self.active) < self.cfg.slots:
            self._admit(self.waiting.popleft(), t)
