"""Deterministic fault injection + recovery plans for the scheduler stack.

The ARCANE offload contract gives the controller a natural recovery point:
every kernel's operands are resident (single residency) when it executes, so
a detected error can be scrubbed or replayed *at the kernel boundary* without
unwinding partial state. This module models that story with three fault
classes mapped onto three recovery tiers:

1. **Transient cache-line bit flips** filtered by a SECDED ECC model.
   A single-bit flip in a freshly DMA-ed source line is corrected in place
   (the syndrome pinpoints the bit) for a configurable ``ecc_penalty``
   cycle charge. A double-bit flip is *detected* but uncorrectable —
   SECDED escalates it, and the controller re-fetches the source region
   from main memory (the clean architectural copy) with replay backoff.
2. **Detected DMA/compute corruption** triggers bounded **instruction
   replay**: the kernel's destination is recomputed from its (still
   resident, still clean) sources, with ``replay_backoff * (attempt+1)``
   cycles of backoff per attempt, up to ``max_replays`` attempts. The
   cycles land in the ``fault_replay`` stall bin so per-kernel
   ``busy + Σ stalls == latency`` conservation survives injection.
3. **Hard faults** (``hard_at``/``hard_vpu``, or replay-budget exhaustion)
   **offline the VPU**: the datapath is fenced, its residents are
   consolidated back to memory, and pending work re-dispatches across the
   surviving VPUs. Only when the *last* VPU dies does the run abort with
   :class:`FaultError`.

Determinism is load-bearing. A :class:`FaultPlan` draws one
:class:`KernelFaults` outcome per *kernel id* from
``np.random.default_rng([seed, kernel_id])`` — keyed by the id alone, never
by dispatch time or VPU choice — so the serial and pipelined schedulers see
the same faults for the same program, and a re-run reproduces the plan
bit-for-bit. Tests bypass the rates entirely with an explicit ``schedule``
of per-kernel entries.

The recovery tiers are *functionally exact* by construction: injection
really flips bits in the modeled SRAM array, and recovery really re-fetches
or recomputes, so a run whose faults are all recoverable flushes a memory
image bit-identical to the fault-free run — the invariant the differential
fuzzer locks in.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["FaultConfig", "FaultError", "FaultPlan", "KernelFaults",
           "as_fault_plan"]


class FaultError(RuntimeError):
    """An unrecoverable fault condition: the last healthy VPU went offline
    (degradation has nowhere left to degrade to)."""


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Knobs for the ``faults:`` YAML section (all rates per *kernel*).

    ``flip_rate``/``corrupt_rate`` drive the seeded random plan;
    ``schedule`` pins explicit per-kernel outcomes for tests (entries win
    over the random draw). ``hard_at``/``hard_vpu`` schedule one hard fault:
    at the first scheduler step at or after cycle ``hard_at``, VPU
    ``hard_vpu`` is fenced and drained. ``hard_at == 0`` disables it."""

    flip_rate: float = 0.0           # P(an ECC event hits a kernel's fetch)
    double_bit_fraction: float = 0.25  # P(uncorrectable | ECC event)
    corrupt_rate: float = 0.0        # P(a compute attempt is corrupted)
    max_replays: int = 3             # replay budget before the VPU is fenced
    ecc_penalty: int = 32            # cycles per ECC scrub (correct/detect)
    replay_backoff: int = 64         # backoff base: attempt i waits (i+1)*base
    hard_at: int = 0                 # cycle of the scheduled hard fault
    hard_vpu: int = 0                # victim VPU of the scheduled hard fault
    seed: int = 0                    # fault-plan RNG seed
    schedule: tuple = ()             # explicit per-kernel overrides (dicts)

    def __post_init__(self):
        for field in ("flip_rate", "double_bit_fraction", "corrupt_rate"):
            v = getattr(self, field)
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(f"faults.{field} must be in [0, 1], got {v}")
        for field in ("max_replays", "ecc_penalty", "replay_backoff",
                      "hard_at", "hard_vpu", "seed"):
            v = getattr(self, field)
            if int(v) < 0:
                raise ValueError(f"faults.{field} must be >= 0, got {v}")
        object.__setattr__(self, "schedule", tuple(self.schedule or ()))
        for ent in self.schedule:
            if not isinstance(ent, dict) or "kernel" not in ent:
                raise ValueError(f"faults.schedule entries need a 'kernel' "
                                 f"id, got {ent!r}")
            kind = ent.get("kind", "single")
            if kind not in ("single", "double", "corrupt", "hard"):
                raise ValueError(
                    f"faults.schedule kind must be one of "
                    f"single|double|corrupt|hard, got {kind!r}")

    @property
    def is_noop(self) -> bool:
        """True when no fault source is armed — the runtime skips the plan
        entirely, so a zero-rate config is bit- and cycle-identical to no
        ``faults:`` section at all."""
        return (self.flip_rate == 0.0 and self.corrupt_rate == 0.0
                and self.hard_at == 0 and not self.schedule)


@dataclasses.dataclass(frozen=True)
class KernelFaults:
    """The drawn fault outcome for one kernel.

    ``ecc_bits`` is the ECC tier: 0 = clean fetch, 1 = single-bit flip
    (corrected in place), 2 = double-bit flip (detected, re-fetched).
    ``replays`` is how many corrupted compute attempts precede the clean
    one; ``exhausted`` means the corruption outlasted the replay budget —
    the final attempt still completes on scrubbed state, but the VPU is
    fenced as faulty immediately after the kernel retires."""

    ecc_bits: int = 0
    replays: int = 0
    exhausted: bool = False

    @property
    def any(self) -> bool:
        return bool(self.ecc_bits or self.replays or self.exhausted)


def _from_schedule_entry(ent: dict, max_replays: int) -> KernelFaults:
    kind = ent.get("kind", "single")
    n = int(ent.get("replays", 1) or 1)
    if kind == "single":
        return KernelFaults(ecc_bits=1)
    if kind == "double":
        return KernelFaults(ecc_bits=2, replays=0)
    if kind == "corrupt":
        return KernelFaults(replays=min(n, max_replays),
                            exhausted=n > max_replays)
    # "hard": the corruption never clears — the whole budget burns, then
    # the VPU is fenced.
    return KernelFaults(replays=max_replays, exhausted=True)


class FaultPlan:
    """Memoized per-kernel fault outcomes + the recovery cost model.

    One plan per runtime. ``kernel_faults(kid)`` is a pure function of
    ``(seed, kid)`` (or the explicit schedule), so both schedulers — and a
    re-run under a different engine mode — draw identical faults."""

    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self._memo: dict[int, Optional[KernelFaults]] = {}
        # Later schedule entries win, mirroring YAML override layering.
        self._schedule: dict[int, dict] = {
            int(ent["kernel"]): ent for ent in cfg.schedule}

    def kernel_faults(self, kid: int) -> Optional[KernelFaults]:
        """The fault outcome for kernel ``kid`` (None = clean run)."""
        if kid in self._memo:
            return self._memo[kid]
        kf = self._draw(kid)
        if kf is not None and not kf.any:
            kf = None
        self._memo[kid] = kf
        return kf

    def _draw(self, kid: int) -> Optional[KernelFaults]:
        ent = self._schedule.get(kid)
        if ent is not None:
            return _from_schedule_entry(ent, self.cfg.max_replays)
        cfg = self.cfg
        if cfg.flip_rate == 0.0 and cfg.corrupt_rate == 0.0:
            return None
        rng = np.random.default_rng([cfg.seed, kid])
        ecc_bits = 0
        if rng.random() < cfg.flip_rate:
            ecc_bits = 2 if rng.random() < cfg.double_bit_fraction else 1
        failed = 0
        while failed <= cfg.max_replays and rng.random() < cfg.corrupt_rate:
            failed += 1
        return KernelFaults(ecc_bits=ecc_bits,
                            replays=min(failed, cfg.max_replays),
                            exhausted=failed > cfg.max_replays)

    def backoff(self, attempt: int) -> int:
        """Cycle cost of waiting out replay ``attempt`` (0-based): linear
        backoff, so retry storms get progressively more expensive."""
        return self.cfg.replay_backoff * (attempt + 1)

    def flip_position(self, kid: int, salt: int, n_bytes: int) -> tuple[int, int]:
        """Deterministic ``(byte, bit)`` flip target within ``n_bytes`` —
        keyed by ``(seed, kid, salt)`` so every injection site (ECC bits,
        each corrupt attempt) lands on its own reproducible position."""
        rng = np.random.default_rng([self.cfg.seed, kid, salt])
        return int(rng.integers(max(1, n_bytes))), int(rng.integers(8))


def as_fault_plan(faults) -> Optional[FaultPlan]:
    """Coerce the runtime's ``faults=`` argument into a plan (or None).

    Accepts None, a :class:`FaultPlan`, a :class:`FaultConfig`, or a plain
    dict of :class:`FaultConfig` fields. No-op configs collapse to None so
    the schedulers' hot paths stay branch-free when faults are off."""
    if faults is None:
        return None
    if isinstance(faults, FaultPlan):
        return None if faults.cfg.is_noop else faults
    if isinstance(faults, dict):
        faults = FaultConfig(**faults)
    if not isinstance(faults, FaultConfig):
        raise TypeError(f"faults must be a FaultConfig, FaultPlan, dict or "
                        f"None, got {type(faults).__name__}")
    return None if faults.is_noop else FaultPlan(faults)
