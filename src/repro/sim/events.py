"""Event-driven timing engine primitives for the pipelined C-RT model.

Two ingredients, both deliberately tiny and deterministic:

  * :class:`EventQueue` — a binary-heap priority queue of timestamped events.
    Ties on ``time`` break by monotonically-increasing insertion sequence, so
    replaying the same program yields the same event order, bit for bit.
  * :class:`Resource` — a single-server FIFO resource (the eCPU, one VPU
    datapath, one VPU DMA port, the cache lock). ``acquire`` books an activity
    on the resource's timeline: the activity starts when both the requester is
    ready *and* the resource is free, and the busy interval is recorded for
    trace export and utilisation reporting.

Times are modeled **cycles** (integers). There is no wall-clock anywhere in
this module — determinism is a hard requirement (the pipelined scheduler must
produce bit-identical numerics and reproducible traces run-to-run).
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
from typing import Any, Iterator, Optional

import numpy as np

from repro.core.dataflow import OperandFlow


@dataclasses.dataclass(frozen=True)
class Event:
    """One scheduled occurrence. Ordered by (time, seq) — never by payload."""

    time: int
    seq: int
    kind: str
    payload: Any = None

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)


class EventQueue:
    """Deterministic min-heap of :class:`Event`.

    ``push`` stamps each event with an insertion sequence number; ``pop``
    returns the earliest event, breaking time ties in insertion order. This
    makes the simulation a pure function of the submitted program.
    """

    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = itertools.count()

    def push(self, time: int, kind: str, payload: Any = None) -> Event:
        if time < 0:
            raise ValueError(f"event time must be non-negative, got {time}")
        ev = Event(time=int(time), seq=next(self._seq), kind=kind,
                   payload=payload)
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        if not self._heap:
            raise IndexError("pop from empty EventQueue")
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def drain(self) -> Iterator[Event]:
        while self._heap:
            yield self.pop()


class Timeline(EventQueue):
    """Open-ended event timeline: a persistent :class:`EventQueue` plus the
    current simulation clock.

    The closed-batch scheduler built a fresh queue per drain and ran it to
    empty; a :class:`Timeline` instead lives for the whole session, so events
    can be injected from *outside* the event loop — request arrivals posted at
    future sim times while the clock advances — and the loop can stop at an
    arbitrary ``until`` bound with work still in flight. ``now`` is the time
    of the last processed event (monotonically non-decreasing; the scheduler
    owns advancing it). External events carry a callback payload under the
    reserved kind ``"external"`` and are invoked by the scheduler's event
    loop when their time comes.
    """

    #: Event kind reserved for externally injected events (payload is a
    #: ``fn(t)`` callback invoked by the scheduler loop at the event's time).
    EXTERNAL = "external"

    def __init__(self) -> None:
        super().__init__()
        self.now = 0

    def advance_clock(self, t: int) -> int:
        """Move the clock forward to ``t`` (never backward); returns ``now``."""
        if t > self.now:
            self.now = int(t)
        return self.now

    def post(self, time: int, fn) -> Event:
        """Inject an external event (e.g. a request arrival) at sim time
        ``time``. Times in the past are clamped to ``now`` — the event then
        fires at the next loop step, which is as early as an arrival that
        already happened can be serviced."""
        if not callable(fn):
            raise TypeError(f"external event payload must be callable, got "
                            f"{type(fn).__name__}")
        return self.push(max(int(time), self.now), self.EXTERNAL, fn)


@dataclasses.dataclass
class Interval:
    start: int
    end: int
    label: str = ""

    @property
    def duration(self) -> int:
        return self.end - self.start


def row_chunks(rows: int, row_chunk: int) -> list[int]:
    """Split ``rows`` into chunks of at most ``row_chunk`` rows.

    ``row_chunk <= 0`` disables chunking (one chunk with every row) — the
    whole-transfer granularity the scheduler modeled before intra-instruction
    row pipelining.
    """
    if rows <= 0:
        return []
    if row_chunk <= 0:
        return [rows]
    return [min(row_chunk, rows - r) for r in range(0, rows, row_chunk)]


def split_proportional(total: int, weights: list[int]) -> list[int]:
    """Deterministically split integer ``total`` into ``len(weights)`` parts
    proportional to ``weights``; parts sum to ``total`` exactly (cumulative
    floor rounding, so replays are bit-identical)."""
    if not weights:
        return []
    s = sum(weights)
    if s <= 0:
        raise ValueError(f"weights must sum to a positive value, got {weights}")
    if len(weights) >= 32 and 0 <= total * s < 2 ** 62:
        # Vectorized path for long tile trains; int64 is exact here (the
        # largest intermediate is total * s, guarded above), so the parts are
        # bit-identical to the scalar loop below.
        w = np.asarray(weights, dtype=np.int64)
        if (w < 0).any():
            raise ValueError(f"negative weight {int(w.min())}")
        x = (total * np.cumsum(w)) // s
        return np.diff(x, prepend=0).tolist()
    out, acc, cum = [], 0, 0
    for w in weights:
        if w < 0:
            raise ValueError(f"negative weight {w}")
        cum += w
        x = total * cum // s
        out.append(x - acc)
        acc = x
    return out


def interleave_blocks(parts_per_block: list[list[int]]) -> list[tuple[int, int]]:
    """Round-robin interleave per-block chunk lists into one DMA order.

    Models the C-RT programming one 2D DMA descriptor per row-stacked block
    (e.g. the three channel planes of the conv-layer input) and streaming
    them alternately, so every block's early rows land early. Returns
    ``(block, rows)`` entries in transfer order.
    """
    out: list[tuple[int, int]] = []
    for j in range(max((len(p) for p in parts_per_block), default=0)):
        for b, parts in enumerate(parts_per_block):
            if j < len(parts):
                out.append((b, parts[j]))
    return out


def tile_entries(bands_per_block: list[list[int]], col_parts: list[int],
                 col_major: bool = False) -> list[tuple[int, int, int]]:
    """Transfer order of one operand's 2D tile train.

    Returns ``(block, band, tile)`` index triples: blocks round-robin at band
    granularity (every plane's early rows land early), and within a block's
    band the column tiles stream consecutively. ``col_major`` flips the
    nesting — all bands of column tile 0, then tile 1, … — the order a
    row-FULL / column-streamed operand (GEMM's B) wants, so its first column
    tile is complete as early as possible.
    """
    out: list[tuple[int, int, int]] = []
    n_bands = max((len(p) for p in bands_per_block), default=0)
    if col_major:
        for t in range(len(col_parts)):
            for j in range(n_bands):
                for b, parts in enumerate(bands_per_block):
                    if j < len(parts):
                        out.append((b, j, t))
    else:
        for j in range(n_bands):
            for b, parts in enumerate(bands_per_block):
                if j < len(parts):
                    for t in range(len(col_parts)):
                        out.append((b, j, t))
    return out


@dataclasses.dataclass
class TileTrain:
    """One operand's tile-indexed DMA activity train, per stacked block.

    ``cum_rows[b][i]`` is the cumulative row count of block ``b`` after its
    row band ``i``; ``cum_cols[t]`` the cumulative column count after column
    tile ``t`` (columns are shared across blocks — blocks stack rows);
    ``end_times[b][i][t]`` the modeled completion cycle of tile ``(i, t)`` of
    block ``b``. The gating question "when may compute piece ``(pi, pj)``
    start, given this operand's dataflow policy?" reduces to: per block, which
    band/tile rectangle first covers the rows × cols the policy requires —
    the answer is the prefix maximum of that rectangle's end times.
    """

    cum_rows: list[list[int]]
    cum_cols: list[int]
    end_times: list[list[list[int]]]

    def __post_init__(self):
        # Prefix max over the (band, tile) grid per block: pmax[b][i][t] is
        # the latest completion among tiles (<=i, <=t). Large grids build it
        # vectorized — two np.maximum.accumulate passes per block (exact
        # int64 arithmetic) — small grids keep the scalar loop, which beats
        # numpy's per-call overhead below ~64 cells. Either path yields the
        # same nested lists; gate queries bisect tiny cumulative lists, where
        # plain indexing beats numpy scalar access.
        self._pmax = []
        for grid in self.end_times:
            if len(grid) * len(grid[0]) >= 64:
                self._pmax.append(
                    np.maximum.accumulate(
                        np.maximum.accumulate(
                            np.asarray(grid, dtype=np.int64),
                            axis=0), axis=1).tolist())
                continue
            pm: list[list[int]] = []
            for i, row in enumerate(grid):
                cur = []
                run = 0
                for t, e in enumerate(row):
                    run = max(run, e)
                    cur.append(max(run, pm[i - 1][t]) if i else run)
                pm.append(cur)
            self._pmax.append(pm)

    @property
    def pace(self) -> int:
        """Band count of the longest block — the train's natural row-piece
        count when it paces the compute split."""
        return max(len(c) for c in self.cum_rows)

    @property
    def col_pace(self) -> int:
        """Column-tile count — the natural column-piece count."""
        return len(self.cum_cols)

    def piece_weights(self) -> list[int]:
        """Row weights of the pacing block's bands (compute-split weights)."""
        longest = max(self.cum_rows, key=len)
        return [c - p for c, p in zip(longest, [0] + longest[:-1])]

    def col_weights(self) -> list[int]:
        """Column weights of the tiles (compute column-split weights)."""
        return [c - p for c, p in
                zip(self.cum_cols, [0] + self.cum_cols[:-1])]

    def gate(self, flow: OperandFlow, piece: int, n_pieces: int,
             col_piece: int = 0, n_col_pieces: int = 1) -> int:
        """Cycle at which piece ``(piece, col_piece)`` of an
        ``n_pieces × n_col_pieces`` grid has every tile this operand's
        ``flow`` demands."""
        need_c = flow.cols_required(col_piece, n_col_pieces, self.cum_cols[-1])
        jc = bisect.bisect_left(self.cum_cols, need_c)
        t = 0
        for cum, pm in zip(self.cum_rows, self._pmax):
            need_r = flow.rows_required(piece, n_pieces, cum[-1])
            jr = bisect.bisect_left(cum, need_r)
            t = max(t, pm[jr][jc])
        return t

    def gate_source(self, flow: OperandFlow, piece: int, n_pieces: int,
                    col_piece: int = 0, n_col_pieces: int = 1
                    ) -> tuple[int, Optional[tuple[int, int, int]]]:
        """Like :meth:`gate`, but also name the binding tile.

        Returns ``(gate_cycle, (block, band, tile))`` — the last-landing tile
        inside the required rectangle (the tile whose completion the compute
        piece actually waits for; earliest-indexed on ties). Observability
        helper for flow-event emission: an O(rectangle) scan rather than an
        O(1) prefix-max lookup, so the scheduler's timing path never calls it.
        """
        need_c = flow.cols_required(col_piece, n_col_pieces, self.cum_cols[-1])
        jc = bisect.bisect_left(self.cum_cols, need_c)
        best_t = 0
        best_src: Optional[tuple[int, int, int]] = None
        for b, (cum, grid) in enumerate(zip(self.cum_rows, self.end_times)):
            need_r = flow.rows_required(piece, n_pieces, cum[-1])
            jr = bisect.bisect_left(cum, need_r)
            for i in range(jr + 1):
                row = grid[i]
                for t in range(jc + 1):
                    if row[t] > best_t or best_src is None:
                        best_t = row[t]
                        best_src = (b, i, t)
        return best_t, best_src


def ChunkTrain(cum_rows: list[list[int]],
               end_times: list[list[int]]) -> TileTrain:
    """Backward-compatible 1D constructor: a :class:`TileTrain` with a single
    column tile per band (the PR-3 row-chunked train)."""
    return TileTrain(cum_rows=cum_rows, cum_cols=[1],
                     end_times=[[[e] for e in ends] for ends in end_times])


class Resource:
    """Single-server FIFO resource with an occupancy timeline.

    ``free_at`` is the earliest cycle the next activity could start. Booking
    never reorders: activities occupy the resource in acquire order, which is
    exactly the in-order hardware queue each modeled unit has.
    """

    def __init__(self, name: str):
        self.name = name
        self.free_at = 0
        self.busy_cycles = 0
        self.fenced = False
        self.intervals: list[Interval] = []

    def fence(self, t: int) -> None:
        """Permanently fence the resource at ``t``: a hard fault offlined
        the modeled unit, so any further :meth:`acquire` raises. ``free_at``
        advances to the fence time so utilization reporting never sees
        phantom idle headroom on a dead unit."""
        self.fenced = True
        self.free_at = max(self.free_at, int(t))

    def acquire(self, at: int, duration: int, label: str = "") -> Interval:
        """Book ``duration`` cycles starting no earlier than ``at``.

        Returns the booked interval (start may be later than ``at`` if the
        resource is still busy). Zero-duration bookings are recorded too —
        they matter for trace completeness (e.g. a deferred write-back).
        """
        if self.fenced:
            raise RuntimeError(
                f"{self.name}: resource is fenced (hard fault offlined it); "
                f"the scheduler must not book new work here")
        if duration < 0:
            raise ValueError(f"{self.name}: negative duration {duration}")
        start = max(int(at), self.free_at)
        iv = Interval(start=start, end=start + int(duration), label=label)
        self.free_at = iv.end
        self.busy_cycles += iv.duration
        self.intervals.append(iv)
        return iv

    def idle_at(self, t: int) -> bool:
        return self.free_at <= t

    def utilization(self, horizon: int) -> float:
        return self.busy_cycles / horizon if horizon > 0 else 0.0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Resource({self.name!r}, free_at={self.free_at})"
