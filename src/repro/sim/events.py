"""Event-driven timing engine primitives for the pipelined C-RT model.

Two ingredients, both deliberately tiny and deterministic:

  * :class:`EventQueue` — a binary-heap priority queue of timestamped events.
    Ties on ``time`` break by monotonically-increasing insertion sequence, so
    replaying the same program yields the same event order, bit for bit.
  * :class:`Resource` — a single-server FIFO resource (the eCPU, one VPU
    datapath, one VPU DMA port, the cache lock). ``acquire`` books an activity
    on the resource's timeline: the activity starts when both the requester is
    ready *and* the resource is free, and the busy interval is recorded for
    trace export and utilisation reporting.

Times are modeled **cycles** (integers). There is no wall-clock anywhere in
this module — determinism is a hard requirement (the pipelined scheduler must
produce bit-identical numerics and reproducible traces run-to-run).
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
from typing import Any, Iterator, Optional

from repro.core.dataflow import OperandFlow


@dataclasses.dataclass(frozen=True)
class Event:
    """One scheduled occurrence. Ordered by (time, seq) — never by payload."""

    time: int
    seq: int
    kind: str
    payload: Any = None

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)


class EventQueue:
    """Deterministic min-heap of :class:`Event`.

    ``push`` stamps each event with an insertion sequence number; ``pop``
    returns the earliest event, breaking time ties in insertion order. This
    makes the simulation a pure function of the submitted program.
    """

    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = itertools.count()

    def push(self, time: int, kind: str, payload: Any = None) -> Event:
        if time < 0:
            raise ValueError(f"event time must be non-negative, got {time}")
        ev = Event(time=int(time), seq=next(self._seq), kind=kind,
                   payload=payload)
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        if not self._heap:
            raise IndexError("pop from empty EventQueue")
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def drain(self) -> Iterator[Event]:
        while self._heap:
            yield self.pop()


@dataclasses.dataclass
class Interval:
    start: int
    end: int
    label: str = ""

    @property
    def duration(self) -> int:
        return self.end - self.start


def row_chunks(rows: int, row_chunk: int) -> list[int]:
    """Split ``rows`` into chunks of at most ``row_chunk`` rows.

    ``row_chunk <= 0`` disables chunking (one chunk with every row) — the
    whole-transfer granularity the scheduler modeled before intra-instruction
    row pipelining.
    """
    if rows <= 0:
        return []
    if row_chunk <= 0:
        return [rows]
    return [min(row_chunk, rows - r) for r in range(0, rows, row_chunk)]


def split_proportional(total: int, weights: list[int]) -> list[int]:
    """Deterministically split integer ``total`` into ``len(weights)`` parts
    proportional to ``weights``; parts sum to ``total`` exactly (cumulative
    floor rounding, so replays are bit-identical)."""
    if not weights:
        return []
    s = sum(weights)
    if s <= 0:
        raise ValueError(f"weights must sum to a positive value, got {weights}")
    out, acc, cum = [], 0, 0
    for w in weights:
        if w < 0:
            raise ValueError(f"negative weight {w}")
        cum += w
        x = total * cum // s
        out.append(x - acc)
        acc = x
    return out


def interleave_blocks(parts_per_block: list[list[int]]) -> list[tuple[int, int]]:
    """Round-robin interleave per-block chunk lists into one DMA order.

    Models the C-RT programming one 2D DMA descriptor per row-stacked block
    (e.g. the three channel planes of the conv-layer input) and streaming
    them alternately, so every block's early rows land early. Returns
    ``(block, rows)`` entries in transfer order.
    """
    out: list[tuple[int, int]] = []
    for j in range(max((len(p) for p in parts_per_block), default=0)):
        for b, parts in enumerate(parts_per_block):
            if j < len(parts):
                out.append((b, parts[j]))
    return out


@dataclasses.dataclass
class ChunkTrain:
    """One operand's row-chunked DMA activity train, per stacked block.

    ``cum_rows[b][j]`` is the cumulative row count of block ``b`` after its
    chunk ``j``; ``end_times[b][j]`` is the modeled completion cycle of that
    chunk. The gating question "when may compute piece *i* start, given this
    operand's dataflow policy?" reduces to: for each block, which chunk first
    covers the rows the policy requires — the answer is the max of those
    chunks' end times.
    """

    cum_rows: list[list[int]]
    end_times: list[list[int]]

    @property
    def pace(self) -> int:
        """Chunk count of the longest block — the train's natural piece count
        when it paces the compute split."""
        return max(len(c) for c in self.cum_rows)

    def piece_weights(self) -> list[int]:
        """Row weights of the pacing block's chunks (compute-split weights)."""
        longest = max(self.cum_rows, key=len)
        return [c - p for c, p in zip(longest, [0] + longest[:-1])]

    def gate(self, flow: OperandFlow, piece: int, n_pieces: int) -> int:
        """Cycle at which piece ``piece`` (of ``n_pieces``) has every chunk
        this operand's ``flow`` demands."""
        t = 0
        for cum, ends in zip(self.cum_rows, self.end_times):
            need = flow.rows_required(piece, n_pieces, cum[-1])
            j = bisect.bisect_left(cum, need)
            t = max(t, ends[j])
        return t


class Resource:
    """Single-server FIFO resource with an occupancy timeline.

    ``free_at`` is the earliest cycle the next activity could start. Booking
    never reorders: activities occupy the resource in acquire order, which is
    exactly the in-order hardware queue each modeled unit has.
    """

    def __init__(self, name: str):
        self.name = name
        self.free_at = 0
        self.busy_cycles = 0
        self.intervals: list[Interval] = []

    def acquire(self, at: int, duration: int, label: str = "") -> Interval:
        """Book ``duration`` cycles starting no earlier than ``at``.

        Returns the booked interval (start may be later than ``at`` if the
        resource is still busy). Zero-duration bookings are recorded too —
        they matter for trace completeness (e.g. a deferred write-back).
        """
        if duration < 0:
            raise ValueError(f"{self.name}: negative duration {duration}")
        start = max(int(at), self.free_at)
        iv = Interval(start=start, end=start + int(duration), label=label)
        self.free_at = iv.end
        self.busy_cycles += iv.duration
        self.intervals.append(iv)
        return iv

    def idle_at(self, t: int) -> bool:
        return self.free_at <= t

    def utilization(self, horizon: int) -> float:
        return self.busy_cycles / horizon if horizon > 0 else 0.0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Resource({self.name!r}, free_at={self.free_at})"
