"""Unified metrics layer for the scheduler stack (observability tentpole).

Three cooperating pieces, all **purely observational** — collection never
touches scheduler state, books no resources, and draws no randomness, so a
metrics-on run produces a bit-identical schedule to a metrics-off run (the
differential fuzzer asserts exactly that):

  * :class:`MetricsRegistry` — a typed registry of named :class:`Counter` /
    :class:`Gauge` / :class:`Histogram` instruments, threaded through
    :class:`~repro.core.runtime.CacheRuntime` and
    :class:`~repro.sim.pipeline.PipelinedRuntime`.
  * :class:`StallTable` — per-kernel **stall attribution**: every cycle
    between a kernel becoming dispatchable (decode complete) and its retire
    (compute done) that the datapath is *not* computing the kernel is binned
    into exactly one wait cause (:data:`STALL_BINS`), with the conservation
    invariant ``busy + Σ stall_bins == retire - ready`` checked per kernel.
  * :class:`ActivityLog` — the completed event graph (every booked resource
    interval), from which :meth:`ActivityLog.critical_path` extracts the
    longest dependent chain: starting from the activity that ends at the
    makespan, repeatedly step to the activity whose completion *bound* the
    current one's start (booking start times always equal either a gate's
    completion or the resource's previous free_at — both activity ends), down
    to cycle 0. The chain is contiguous in time, so its per-resource /
    per-phase breakdown sums exactly to the makespan.

The per-kernel window is ``[ready, retired]`` where ``ready`` is the
decode-completion cycle (the kernel enters the dispatchable set) and
``retired`` the compute-done cycle; destination write-back happens after
retire (deferred or booked asynchronously) and is tracked by counters, not by
the conservation window.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Optional

#: Exclusive per-kernel wait causes (see StallTable.attribute_dispatch):
#:   raw_dep       — blocked pre-dispatch on an unmet dependency (RAW edge)
#:   war_guard     — blocked pre-dispatch by the in-order WAR-aliasing guard
#:   capacity      — blocked pre-dispatch: no VPU (or AT slot) has capacity
#:   cache_lock    — waiting for + holding the cache lock (allocator claim)
#:   drain         — consolidation write-backs of deferred results gating DMA
#:   dma_wait      — compute piece waiting for operand tiles (DMA port busy)
#:   datapath_busy — operand tiles landed but the datapath still runs another
#:                   kernel's piece
#:   fault_replay  — fault-recovery overhead: ECC scrub penalties on a
#:                   corrupted operand fetch plus bounded instruction-replay
#:                   attempts (backoff + requeue) after detected corruption
STALL_BINS = ("raw_dep", "war_guard", "capacity", "cache_lock", "drain",
              "dma_wait", "datapath_busy", "fault_replay")

#: Version stamp of the metrics-report dict layout (and of the shared BENCH
#: envelope in benchmarks/common.py, which embeds these reports).
METRICS_SCHEMA_VERSION = 1


class MetricsError(RuntimeError):
    """A metrics-layer invariant (e.g. stall-cycle conservation) failed."""


# ============================================================ typed registry
class Counter:
    """Monotonically-increasing integer instrument."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        self.value += n

    def to_dict(self) -> dict:
        return {"value": self.value}


class Gauge:
    """Instrument holding the latest sampled value."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n

    def dec(self, n=1) -> None:
        self.value -= n

    def to_dict(self) -> dict:
        return {"value": self.value}


class Histogram:
    """Power-of-two-bucket histogram of non-negative integer observations.

    Bucket ``k`` counts observations with ``bit_length() == k`` (i.e. value in
    ``[2^(k-1), 2^k)``; bucket 0 counts zeros) — fixed, deterministic bucket
    edges with no configuration, good enough for cycle-latency shapes.
    """

    __slots__ = ("name", "help", "count", "sum", "min", "max", "buckets")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.count = 0
        self.sum = 0
        self.min: Optional[int] = None
        self.max: Optional[int] = None
        self.buckets: dict[int, int] = {}

    def observe(self, v: int) -> None:
        v = int(v)
        if v < 0:
            raise ValueError(f"histogram {self.name}: negative observation {v}")
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        b = v.bit_length()
        self.buckets[b] = self.buckets.get(b, 0) + 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> int:
        """Upper edge of the bucket holding the ``q``-th percentile
        observation (conservative: the true value is ≤ the returned one,
        within the bucket's power-of-two resolution). ``q`` in [0, 100];
        0 with no observations."""
        if not 0 <= q <= 100:
            raise ValueError(f"histogram {self.name}: percentile {q} "
                             f"outside [0, 100]")
        if not self.count:
            return 0
        # Rank of the target observation (nearest-rank definition), walked
        # over the cumulative bucket counts in value order.
        rank = max(1, -(-self.count * q // 100))      # ceil without floats
        seen = 0
        for b in sorted(self.buckets):
            seen += self.buckets[b]
            if seen >= rank:
                # Bucket b holds values with bit_length() == b: [2^(b-1),
                # 2^b - 1]; bucket 0 holds zeros. Clamp to the observed max.
                upper = (1 << b) - 1
                return min(upper, self.max)
        return self.max                                # pragma: no cover

    @property
    def p50(self) -> int:
        return self.percentile(50)

    @property
    def p99(self) -> int:
        return self.percentile(99)

    def to_dict(self) -> dict:
        return {"count": self.count, "sum": self.sum, "min": self.min,
                "max": self.max, "mean": self.mean,
                "p50": self.p50, "p99": self.p99,
                "buckets": {f"<2^{k}" if k else "0": n
                            for k, n in sorted(self.buckets.items())}}


class MetricsRegistry:
    """Create-or-get registry of named instruments (one namespace)."""

    def __init__(self) -> None:
        self._metrics: dict[str, Any] = {}

    def _get(self, cls, name: str, help: str):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help)
        elif type(m) is not cls:
            raise MetricsError(
                f"metric {name!r} already registered as {type(m).__name__}, "
                f"requested {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def to_dict(self) -> dict:
        out: dict[str, dict] = {"counters": {}, "gauges": {}, "histograms": {}}
        section = {Counter: "counters", Gauge: "gauges",
                   Histogram: "histograms"}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out[section[type(m)]][name] = m.to_dict()
        return out


# ======================================================== stall attribution
@dataclasses.dataclass
class KernelStall:
    """One kernel's dispatch-to-retire cycle attribution."""

    kernel: int
    name: str
    ready: int                    # decode complete — dispatchable
    dispatched: int = -1
    retired: int = -1
    vpu: int = -1
    busy: int = 0                 # datapath cycles computing this kernel
    bins: dict[str, int] = dataclasses.field(
        default_factory=lambda: {b: 0 for b in STALL_BINS})
    fallback: bool = False        # retired via the serial fallback path —
                                  # no event-timeline window to conserve
    # transient attribution state (pre-dispatch blocking)
    _mark: int = dataclasses.field(default=-1, repr=False)
    _reason: Optional[str] = dataclasses.field(default=None, repr=False)

    @property
    def latency(self) -> int:
        return self.retired - self.ready

    @property
    def stall_cycles(self) -> int:
        return sum(self.bins.values())

    def conserved(self) -> bool:
        return self.fallback or \
            self.busy + self.stall_cycles == self.latency

    def to_dict(self) -> dict:
        return {"kernel": self.kernel, "name": self.name, "vpu": self.vpu,
                "ready": self.ready, "dispatched": self.dispatched,
                "retired": self.retired, "latency": self.latency,
                "busy": self.busy, "stalls": dict(self.bins),
                "fallback": self.fallback}


class StallTable:
    """Per-kernel stall attribution with the conservation invariant.

    The pipelined scheduler drives the table from its event loop:

      * :meth:`decoded` opens the window at decode completion;
      * :meth:`blocked` records each failed dispatch examination — the cycles
        from the previous examination to this one are charged to the reason
        the *previous* examination found (between examinations nothing about
        the kernel changed, so the old reason held the whole interval);
      * :meth:`dispatched` closes the pre-dispatch phase and attributes the
        post-dispatch window from the booked activity intervals;
      * :meth:`retired` closes the window and checks conservation.
    """

    def __init__(self) -> None:
        self.records: dict[int, KernelStall] = {}

    def decoded(self, kid: int, ready: int, name: str) -> None:
        self.records[kid] = KernelStall(kernel=kid, name=name, ready=ready,
                                        _mark=ready)

    def blocked(self, kid: int, t: int, reason: str) -> None:
        rec = self.records.get(kid)
        if rec is None:
            return
        if rec._reason is not None and t > rec._mark:
            rec.bins[rec._reason] += t - rec._mark
        rec._mark = t
        rec._reason = reason

    def dispatched(self, kid: int, t: int, vpu: int, lock_end: int,
                   dma_start: int,
                   pieces: Iterable[tuple[int, int, int]],
                   fault_end: int = 0) -> None:
        """Attribute the post-dispatch window.

        ``pieces`` is the kernel's compute pieces as ``(gate, start, end)``
        in datapath booking order (``gate`` = the cycle the piece's operand
        tiles were all landed). A cursor walks ``[t, last_end]``; every gap
        before a piece's start is split — cache-lock claim up to
        ``lock_end``, consolidation drain up to ``dma_start``, ECC scrub up
        to ``fault_end`` (the end of the fault-recovery window that delayed
        the operand fetch, 0 when the fetch was clean), operand-tile wait up
        to the piece's gate, and datapath contention for the rest — so
        ``busy + Σ bins`` covers the window with no double counting.
        """
        rec = self.records.get(kid)
        if rec is None:
            return
        if rec._reason is not None and t > rec._mark:
            rec.bins[rec._reason] += t - rec._mark
        rec._reason = None
        rec.dispatched = t
        rec.vpu = vpu
        cursor = t
        for gate, start, end in pieces:
            if start > cursor:
                if cursor < lock_end:
                    step = min(start, lock_end) - cursor
                    rec.bins["cache_lock"] += step
                    cursor += step
                if cursor < dma_start and cursor < start:
                    step = min(start, dma_start) - cursor
                    rec.bins["drain"] += step
                    cursor += step
                if cursor < fault_end and cursor < start:
                    step = min(start, fault_end) - cursor
                    rec.bins["fault_replay"] += step
                    cursor += step
                if cursor < gate and cursor < start:
                    step = min(start, gate) - cursor
                    rec.bins["dma_wait"] += step
                    cursor += step
                if cursor < start:
                    rec.bins["datapath_busy"] += start - cursor
                    cursor = start
            rec.busy += end - start
            cursor = max(cursor, end)
        rec._mark = cursor

    def replayed(self, kid: int, start: int, end: int) -> None:
        """Extend an open dispatch window with one replay attempt booked as
        ``[start, end)`` on the datapath: the gap from the record's cursor
        to ``start`` (replay backoff + port contention) charges to the
        ``fault_replay`` bin and the re-execution counts as busy, so the
        eventual :meth:`retired` check still conserves."""
        rec = self.records.get(kid)
        if rec is None:
            return
        if start > rec._mark:
            rec.bins["fault_replay"] += start - rec._mark
        rec.busy += end - start
        rec._mark = max(rec._mark, end)

    def retired(self, kid: int, t: int) -> KernelStall:
        rec = self.records[kid]
        rec.retired = t
        if not rec.conserved():
            raise MetricsError(
                f"stall-cycle conservation violated for kernel {kid} "
                f"({rec.name}): busy {rec.busy} + stalls {rec.stall_cycles} "
                f"!= latency {rec.latency} ({rec.to_dict()})")
        return rec

    def serial(self, kid: int, name: str, busy: int,
               bins: dict[str, int]) -> None:
        """Record (or supersede) a kernel retired by the *serial* scheduler
        step: the window is synthesized from the phase cycle totals
        (``latency = busy + Σ bins`` by construction). A pre-existing open
        record means the pipelined engine fell back to the serial step for
        this kernel — mark it, its event-timeline window never closed."""
        rec = self.records.get(kid)
        if rec is not None and rec.retired < 0:
            rec.fallback = True
            return
        rec = KernelStall(kernel=kid, name=name, ready=0, dispatched=0,
                          busy=busy)
        for b, v in bins.items():
            rec.bins[b] += v
        rec.retired = busy + rec.stall_cycles
        self.records[kid] = rec

    # ------------------------------------------------------------- reporting
    def conservation_ok(self) -> bool:
        return all(r.conserved() for r in self.records.values()
                   if r.retired >= 0)

    def by_kernel(self) -> dict[str, dict]:
        """Aggregate closed records per kernel *name*."""
        out: dict[str, dict] = {}
        for rec in self.records.values():
            if rec.retired < 0:
                continue
            agg = out.setdefault(rec.name, {
                "count": 0, "busy": 0, "latency": 0,
                "stalls": {b: 0 for b in STALL_BINS}, "fallbacks": 0})
            agg["count"] += 1
            agg["busy"] += rec.busy
            agg["latency"] += rec.latency
            agg["fallbacks"] += int(rec.fallback)
            for b, v in rec.bins.items():
                agg["stalls"][b] += v
        return out


# ========================================================== critical path
@dataclasses.dataclass(frozen=True)
class Activity:
    """One booked resource interval in the completed event graph."""

    aid: int
    name: str
    phase: str
    resource: str
    start: int
    end: int
    kernel: Optional[int] = None
    vpu: Optional[int] = None

    @property
    def duration(self) -> int:
        return self.end - self.start


@dataclasses.dataclass(frozen=True)
class CPSegment:
    """One merged span of the critical path (``resource is None`` = idle)."""

    start: int
    end: int
    resource: Optional[str]
    phase: Optional[str]
    kernel: Optional[int]
    name: str

    @property
    def cycles(self) -> int:
        return self.end - self.start

    def to_dict(self) -> dict:
        return {"start": self.start, "end": self.end, "cycles": self.cycles,
                "resource": self.resource, "phase": self.phase,
                "kernel": self.kernel, "name": self.name}


class ActivityLog:
    """Append-only log of booked activities + critical-path extraction."""

    def __init__(self) -> None:
        self.activities: list[Activity] = []

    def add(self, name: str, phase: str, resource: str, start: int, end: int,
            kernel: Optional[int] = None, vpu: Optional[int] = None) -> int:
        aid = len(self.activities)
        self.activities.append(Activity(
            aid=aid, name=name, phase=phase, resource=resource,
            start=int(start), end=int(end), kernel=kernel, vpu=vpu))
        return aid

    # ---------------------------------------------------------------- walk
    def critical_path(self, end_time: Optional[int] = None) -> list[CPSegment]:
        """Longest dependent chain ending at ``end_time`` (default: the last
        activity end), walked backward to cycle 0.

        Every booked start equals either a gate's completion cycle or the
        resource's previous ``free_at`` — both are activity end cycles — so
        at each step there is an activity ending exactly at the current
        activity's start; ties prefer the same kernel, then the same VPU,
        then the latest-logged activity. Where no activity ends at the
        boundary (a run restarted after pure idle time) an explicit idle
        segment bridges the gap, so the returned segments tile
        ``[0, end_time]`` exactly and their cycles sum to ``end_time``.
        """
        acts = self.activities
        if not acts:
            if end_time:
                return [CPSegment(0, end_time, None, None, None, "idle")]
            return []
        by_end: dict[int, list[Activity]] = {}
        for a in acts:
            by_end.setdefault(a.end, []).append(a)
        t = max(a.end for a in acts) if end_time is None else end_time
        path: list[Activity] = []
        gaps: list[tuple[int, int]] = []       # (start, end) idle spans
        visited: set[int] = set()
        cur: Optional[Activity] = None
        while t > 0:
            cands = [a for a in by_end.get(t, ()) if a.aid not in visited]
            if not cands:
                # Idle bridge: continue from the latest activity ending
                # strictly before t (there is one — acts is non-empty and
                # t > 0 past the earliest start implies some end < t, else
                # bridge to 0).
                prev_ends = [e for e in by_end if e < t]
                if not prev_ends:
                    gaps.append((0, t))
                    break
                e = max(prev_ends)
                gaps.append((e, t))
                t = e
                continue
            cur = self._pick(cands, cur)
            visited.add(cur.aid)
            path.append(cur)
            t = cur.start
        return self._segments(path, gaps)

    @staticmethod
    def _pick(cands: list[Activity], cur: Optional[Activity]) -> Activity:
        def key(a: Activity):
            same_kernel = (cur is not None and cur.kernel is not None
                           and a.kernel == cur.kernel)
            same_vpu = (cur is not None and cur.vpu is not None
                        and a.vpu == cur.vpu)
            # Prefer real work over zero-duration markers, then kinship.
            return (a.duration > 0, same_kernel, same_vpu, a.aid)
        return max(cands, key=key)

    @staticmethod
    def _segments(path: list[Activity],
                  gaps: list[tuple[int, int]]) -> list[CPSegment]:
        entries: list[CPSegment] = [
            CPSegment(a.start, a.end, a.resource, a.phase, a.kernel, a.name)
            for a in path] + [
            CPSegment(s, e, None, None, None, "idle") for s, e in gaps]
        entries.sort(key=lambda s: (s.start, s.end))
        merged: list[CPSegment] = []
        for seg in entries:
            if merged:
                last = merged[-1]
                if (last.resource, last.kernel, last.phase) == \
                        (seg.resource, seg.kernel, seg.phase) \
                        and seg.start <= last.end:
                    merged[-1] = CPSegment(
                        last.start, max(last.end, seg.end), last.resource,
                        last.phase, last.kernel,
                        last.name if last.cycles >= seg.cycles else seg.name)
                    continue
            merged.append(seg)
        return merged


def summarize_critical_path(segments: list[CPSegment],
                            makespan: int, top: int = 5) -> dict:
    """Roll a critical path up into the report dict (fractions of makespan)."""
    by_resource: dict[str, int] = {}
    by_phase: dict[str, int] = {}
    cp_cycles = idle = 0
    for seg in segments:
        if seg.resource is None:
            idle += seg.cycles
            continue
        cp_cycles += seg.cycles
        by_resource[seg.resource] = by_resource.get(seg.resource, 0) \
            + seg.cycles
        by_phase[seg.phase or "?"] = by_phase.get(seg.phase or "?", 0) \
            + seg.cycles
    total = cp_cycles + idle
    denom = max(makespan, 1)
    top_segs = sorted((s for s in segments if s.resource is not None),
                      key=lambda s: (-s.cycles, s.start))[:top]
    return {
        "makespan": makespan,
        "total": total,
        "cp_cycles": cp_cycles,
        "idle_cycles": idle,
        "covers_makespan": total == makespan,
        "by_resource": {r: {"cycles": c, "fraction": c / denom}
                        for r, c in sorted(by_resource.items(),
                                           key=lambda kv: -kv[1])},
        "by_phase": {p: {"cycles": c, "fraction": c / denom}
                     for p, c in sorted(by_phase.items(),
                                        key=lambda kv: -kv[1])},
        "segments": [s.to_dict() for s in segments],
        "top_segments": [s.to_dict() for s in top_segs],
    }


# ================================================================= facade
class SchedulerMetrics:
    """The metrics object threaded through the runtimes.

    ``enabled=False`` turns every hook into a cheap no-op (a single attribute
    check); enabled or not, the hooks never mutate scheduler state, so the
    schedule is bit-identical either way.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.registry = MetricsRegistry()
        self.stalls = StallTable()
        self.log = ActivityLog()

    # ------------------------------------------------------------- shortcuts
    def inc(self, name: str, n: int = 1) -> None:
        if self.enabled:
            self.registry.counter(name).inc(n)

    def set_gauge(self, name: str, v) -> None:
        if self.enabled:
            self.registry.gauge(name).set(v)

    def observe(self, name: str, v: int) -> None:
        if self.enabled:
            self.registry.histogram(name).observe(v)

    def activity(self, name: str, phase: str, resource: str, start: int,
                 end: int, kernel: Optional[int] = None,
                 vpu: Optional[int] = None) -> Optional[int]:
        if not self.enabled:
            return None
        return self.log.add(name, phase, resource, start, end,
                            kernel=kernel, vpu=vpu)

    # ----------------------------------------------------------- stall hooks
    def kernel_decoded(self, kid: int, ready: int, name: str) -> None:
        if self.enabled:
            self.stalls.decoded(kid, ready, name)

    def kernel_blocked(self, kid: int, t: int, reason: str) -> None:
        if self.enabled:
            self.stalls.blocked(kid, t, reason)

    def kernel_dispatched(self, kid: int, t: int, vpu: int, lock_end: int,
                          dma_start: int, pieces, fault_end: int = 0) -> None:
        if not self.enabled:
            return
        self.stalls.dispatched(kid, t, vpu, lock_end, dma_start, pieces,
                               fault_end=fault_end)
        self.inc("kernels.dispatched")
        rec = self.stalls.records.get(kid)
        if rec is not None:
            self.observe("kernel.dispatch_wait_cycles", t - rec.ready)

    def kernel_replayed(self, kid: int, t: int, start: int, end: int) -> None:
        """One instruction-replay attempt detected at ``t`` and re-executed
        over ``[start, end)``: feeds the stall table (conservation), the
        ``faults.replayed`` counter, and the replay-latency histogram."""
        if not self.enabled:
            return
        self.stalls.replayed(kid, start, end)
        self.inc("faults.replayed")
        self.observe("fault.replay_latency_cycles", end - t)

    def kernel_retired(self, kid: int, t: int) -> None:
        if not self.enabled:
            return
        rec = self.stalls.retired(kid, t)
        self.inc("kernels.retired")
        self.observe("kernel.latency_cycles", rec.latency)
        self.observe("kernel.busy_cycles", rec.busy)

    def kernel_serial(self, kid: int, name: str, busy: int,
                      bins: dict[str, int]) -> None:
        if not self.enabled:
            return
        self.stalls.serial(kid, name, busy, bins)
        self.inc("kernels.retired")

    # ------------------------------------------------------------- reporting
    def critical_path(self, makespan: Optional[int] = None) -> dict:
        segs = self.log.critical_path(end_time=makespan)
        return summarize_critical_path(segs, makespan if makespan is not None
                                       else (segs[-1].end if segs else 0))

    def report(self, makespan: Optional[int] = None,
               extra: Optional[dict] = None,
               with_critical_path: bool = True) -> dict:
        """The unified metrics report: typed instruments, per-kernel stall
        attribution (+ conservation verdict), and — when the activity log is
        populated (pipelined runs) — the critical-path breakdown."""
        doc = {
            "schema_version": METRICS_SCHEMA_VERSION,
            "enabled": self.enabled,
            **self.registry.to_dict(),
            "kernels": self.stalls.by_kernel(),
            "per_kernel": [r.to_dict()
                           for _, r in sorted(self.stalls.records.items())
                           if r.retired >= 0],
            "conservation_ok": self.stalls.conservation_ok(),
            "extra": dict(extra or {}),
        }
        if with_critical_path and self.log.activities:
            doc["critical_path"] = self.critical_path(makespan)
        else:
            doc["critical_path"] = None
        return doc


# ---------------------------------------------------------------------------
# Per-request serving lifecycle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RequestRecord:
    """One request's lifecycle timestamps (sim cycles) under a serving run.

    ``ttft`` counts from *arrival* (queue wait included) to the first
    generated token — the latency a client observes; ``tpot`` is the mean
    inter-token gap over the remaining ``tokens - 1`` decode steps."""

    rid: int
    prompt_len: int
    max_new: int
    arrived: int
    admitted: Optional[int] = None
    first_token: Optional[int] = None
    finished: Optional[int] = None
    rejected: Optional[int] = None     # admission-validation bounce time
    tokens: int = 0

    @property
    def done(self) -> bool:
        return self.finished is not None

    @property
    def queue_wait(self) -> Optional[int]:
        if self.admitted is None:
            return None
        return self.admitted - self.arrived

    @property
    def ttft(self) -> Optional[int]:
        if self.first_token is None:
            return None
        return self.first_token - self.arrived

    @property
    def tpot(self) -> Optional[float]:
        if self.finished is None or self.first_token is None:
            return None
        if self.tokens <= 1:
            return 0.0
        return (self.finished - self.first_token) / (self.tokens - 1)

    def to_dict(self) -> dict:
        return {"rid": self.rid, "prompt_len": self.prompt_len,
                "max_new": self.max_new, "arrived": self.arrived,
                "admitted": self.admitted, "first_token": self.first_token,
                "finished": self.finished, "rejected": self.rejected,
                "tokens": self.tokens,
                "queue_wait": self.queue_wait, "ttft": self.ttft,
                "tpot": self.tpot}


def _exact_percentile(vals: list, q: float) -> float:
    """Nearest-rank percentile over raw values (exact, unlike the
    power-of-two histogram buckets). ``q`` may be fractional — p99.9 ranks
    on 99.9, not a truncated 99."""
    if not vals:
        return 0.0
    s = sorted(vals)
    # ceil(len * q / 100) in integer arithmetic: q is scaled to 1e-4
    # percentile resolution first, so float noise (1000 * 99.9 / 100 ->
    # 999.0000000000001) can never bump the rank past the true one.
    qi = int(round(q * 10_000))
    rank = min(len(s), max(1, -(-len(s) * qi // 1_000_000)))
    return float(s[rank - 1])


class RequestLog:
    """Request lifecycle tracking for the serving scenario.

    Each transition feeds the shared metrics instruments —
    ``serving.ttft`` / ``serving.tpot`` / ``serving.queue_wait`` histograms
    and a ``serving.goodput_tokens_per_kcycle`` gauge — so a serving run's
    report carries them alongside the scheduler's stall attribution.
    ``summary()`` additionally computes *exact* percentiles from the raw
    records (the histograms quantize to power-of-two buckets)."""

    def __init__(self, metrics: "SchedulerMetrics"):
        self.metrics = metrics
        self.records: dict[int, RequestRecord] = {}

    # ----------------------------------------------------------- transitions
    def arrive(self, rid: int, prompt_len: int, max_new: int,
               t: int) -> RequestRecord:
        if rid in self.records:
            raise MetricsError(f"request {rid} already arrived")
        rec = RequestRecord(rid=rid, prompt_len=prompt_len, max_new=max_new,
                            arrived=int(t))
        self.records[rid] = rec
        self.metrics.inc("serving.requests.arrived")
        return rec

    def reject(self, rid: int, t: int) -> None:
        """Admission validation bounced the request (it can never fit the
        per-request KV budget): it arrived but is never admitted, so it
        stays out of every latency percentile."""
        rec = self.records[rid]
        rec.rejected = int(t)
        self.metrics.inc("serving.rejected")
        self.metrics.inc("serving.requests.rejected")

    def admit(self, rid: int, t: int) -> None:
        rec = self.records[rid]
        rec.admitted = int(t)
        self.metrics.inc("serving.requests.admitted")
        self.metrics.observe("serving.queue_wait", rec.queue_wait)

    def first_token(self, rid: int, t: int) -> None:
        rec = self.records[rid]
        rec.first_token = int(t)
        rec.tokens = max(rec.tokens, 1)
        self.metrics.observe("serving.ttft", rec.ttft)

    def token(self, rid: int, n: int = 1) -> None:
        self.records[rid].tokens += n

    def finish(self, rid: int, t: int) -> None:
        rec = self.records[rid]
        rec.finished = int(t)
        self.metrics.inc("serving.requests.finished")
        if rec.tokens > 1:
            self.metrics.observe("serving.tpot", int(round(rec.tpot)))
        done = [r for r in self.records.values() if r.done]
        toks = sum(r.tokens for r in done)
        if t > 0:
            self.metrics.set_gauge("serving.goodput_tokens_per_kcycle",
                                   round(1000.0 * toks / t, 3))

    # ------------------------------------------------------------- reporting
    def summary(self, now: Optional[int] = None) -> dict:
        """Exact lifecycle aggregates from the raw records."""
        done = [r for r in self.records.values() if r.done]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        tpots = [r.tpot for r in done if r.tpot is not None and r.tokens > 1]
        waits = [r.queue_wait for r in done if r.queue_wait is not None]
        toks = sum(r.tokens for r in done)
        end = now if now is not None else max(
            (r.finished for r in done), default=0)
        return {
            "requests": len(self.records),
            "finished": len(done),
            "rejected": sum(1 for r in self.records.values()
                            if r.rejected is not None),
            "tokens_generated": toks,
            "ttft_p50": _exact_percentile(ttfts, 50),
            "ttft_p99": _exact_percentile(ttfts, 99),
            "ttft_p999": _exact_percentile(ttfts, 99.9),
            "ttft_mean": (sum(ttfts) / len(ttfts)) if ttfts else 0.0,
            "tpot_p50": _exact_percentile(tpots, 50),
            "tpot_p99": _exact_percentile(tpots, 99),
            "queue_wait_p50": _exact_percentile(waits, 50),
            "queue_wait_p99": _exact_percentile(waits, 99),
            "goodput_tokens_per_kcycle":
                round(1000.0 * toks / end, 3) if end else 0.0,
            "per_request": [r.to_dict() for r in
                            sorted(self.records.values(),
                                   key=lambda r: r.rid)],
        }
