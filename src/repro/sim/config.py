"""YAML-driven simulator configuration (trace-based-model style).

A config file describes one ARCANE instance — VPU count and geometry, lane
counts, DMA widths, eCPU costs — in nested sections. Files compose through an
``extends`` key: a child names a base config (path relative to the child
file, or a builtin name like ``arcane-default``) and overrides only the
properties it changes; overrides deep-merge into the base. A mapping that
carries ``replace: true`` replaces the base mapping wholesale instead of
merging (same override mechanism the TBM ``--extend`` files use).

Example::

    # my-8vpu.yaml
    extends: arcane-default
    description: 8 wide VPUs
    cache: {n_vpus: 8}
    vpu: {lanes: 8, dma_bytes_per_cycle: 8}

``pyyaml`` is a dev-extra dependency; importing this module without it only
fails when a YAML file is actually loaded (dict-based configs always work).
"""
from __future__ import annotations

import copy
import dataclasses
import os
from typing import Any, Optional

from repro.core.cache import MainMemory
from repro.core.vpu import VPUGeometry

#: Directory holding the builtin configs shipped with the package.
BUILTIN_DIR = os.path.join(os.path.dirname(__file__), "configs")

_SECTIONS = {
    "cache": ("n_vpus", "vregs_per_vpu", "vlen_bytes", "queue_capacity"),
    "vpu": ("lanes", "dma_bytes_per_cycle"),
    "ecpu": ("decode_cycles", "schedule_cycles", "issue_cycles_per_vins"),
    "pipeline": ("row_chunk", "dataflow", "tiling", "reuse"),
    "memory": ("bytes",),
    "metrics": ("enabled",),
    "faults": ("flip_rate", "double_bit_fraction", "corrupt_rate",
               "max_replays", "ecc_penalty", "replay_backoff",
               "hard_at", "hard_vpu", "seed", "schedule"),
}


class ConfigError(ValueError):
    """Malformed, unknown-key, or cyclic simulator configuration."""


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Validated simulator configuration; see the builtin YAMLs for docs."""

    n_vpus: int = 4
    vregs_per_vpu: int = 32
    vlen_bytes: int = 1024
    queue_capacity: int = 16
    lanes: int = 4
    dma_bytes_per_cycle: int = 4
    decode_cycles: int = 350
    schedule_cycles: int = 120
    issue_cycles_per_vins: int = 4
    row_chunk: int = 8
    dataflow: bool = True
    tile_rows: int = 0
    tile_cols: int = 0
    reuse: bool = False
    metrics: bool = True
    memory_bytes: int = 16 << 20
    # ``faults:`` section — see repro.sim.faults.FaultConfig for semantics.
    # All-zero defaults collapse to a fault-free run (no plan is built).
    fault_flip_rate: float = 0.0
    fault_double_bit_fraction: float = 0.25
    fault_corrupt_rate: float = 0.0
    fault_max_replays: int = 3
    fault_ecc_penalty: int = 32
    fault_replay_backoff: int = 64
    fault_hard_at: int = 0
    fault_hard_vpu: int = 0
    fault_seed: int = 0
    fault_schedule: tuple = ()
    description: str = ""

    def __post_init__(self):
        for knob in ("dataflow", "reuse", "metrics"):
            raw = getattr(self, knob)
            if isinstance(raw, str):
                # YAML spells the knobs on/off; quoted strings normalise too.
                val = {"on": True, "true": True, "yes": True,
                       "off": False, "false": False, "no": False,
                       }.get(raw.lower())
                if val is None:
                    section = "metrics.enabled" if knob == "metrics" \
                        else f"pipeline.{knob}"
                    raise ConfigError(
                        f"{section} must be on/off, got {raw!r}")
                object.__setattr__(self, knob, val)
        for f in ("n_vpus", "vregs_per_vpu", "vlen_bytes", "queue_capacity",
                  "lanes", "dma_bytes_per_cycle", "memory_bytes"):
            if getattr(self, f) <= 0:
                raise ConfigError(f"{f} must be positive, got {getattr(self, f)}")
        if self.row_chunk < 0:
            raise ConfigError(
                f"row_chunk must be >= 0 (0 disables intra-instruction "
                f"pipelining), got {self.row_chunk}")
        for f in ("tile_rows", "tile_cols"):
            v = getattr(self, f)
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                raise ConfigError(
                    f"pipeline.tiling.{f[5:]} must be a non-negative integer "
                    f"(0 disables that axis), got {v!r}")
        if (self.tile_rows or self.tile_cols or self.reuse) \
                and not self.dataflow:
            raise ConfigError(
                "pipeline.tiling/reuse require pipeline.dataflow: on (the "
                "legacy concatenated-stream model has no per-operand trains)")
        if not isinstance(self.fault_schedule, (list, tuple)):
            raise ConfigError(
                f"faults.schedule must be a list of per-kernel entries, "
                f"got {self.fault_schedule!r}")
        object.__setattr__(self, "fault_schedule",
                           tuple(self.fault_schedule))
        try:
            # FaultConfig owns range/shape validation; build it eagerly so a
            # bad YAML fails at load time, not mid-run.
            self.fault_config()
        except (TypeError, ValueError) as e:
            raise ConfigError(str(e)) from e
        if self.fault_hard_at and not 0 <= self.fault_hard_vpu < self.n_vpus:
            raise ConfigError(
                f"faults.hard_vpu must name a VPU in [0, {self.n_vpus}), "
                f"got {self.fault_hard_vpu}")

    @property
    def tiling(self):
        """``(tile_rows, tile_cols)`` when 2D tiling is configured, None
        otherwise (the 1D ``row_chunk`` trains)."""
        if self.tile_rows or self.tile_cols:
            return (self.tile_rows, self.tile_cols)
        return None

    @property
    def llc_bytes(self) -> int:
        return self.n_vpus * self.vregs_per_vpu * self.vlen_bytes

    def geometry(self) -> VPUGeometry:
        return VPUGeometry(
            lanes=self.lanes,
            dma_bytes_per_cycle=self.dma_bytes_per_cycle,
            decode_cycles=self.decode_cycles,
            schedule_cycles=self.schedule_cycles,
            issue_cycles_per_vins=self.issue_cycles_per_vins,
            vlen_bytes=self.vlen_bytes,
        )

    def fault_config(self):
        """The ``faults:`` section as a :class:`repro.sim.faults.FaultConfig`,
        or None when every fault source is disarmed (the common case — the
        runtime then skips plan construction entirely)."""
        from repro.sim.faults import FaultConfig
        fc = FaultConfig(
            flip_rate=self.fault_flip_rate,
            double_bit_fraction=self.fault_double_bit_fraction,
            corrupt_rate=self.fault_corrupt_rate,
            max_replays=self.fault_max_replays,
            ecc_penalty=self.fault_ecc_penalty,
            replay_backoff=self.fault_replay_backoff,
            hard_at=self.fault_hard_at,
            hard_vpu=self.fault_hard_vpu,
            seed=self.fault_seed,
            schedule=self.fault_schedule,
        )
        return None if fc.is_noop else fc

    def make_runtime(self, scheduler: str = "serial", *, memory=None,
                     tracer=None):
        """Instantiate a runtime for this config.

        ``scheduler``: ``"serial"`` → :class:`repro.core.runtime.CacheRuntime`,
        ``"pipelined"`` → :class:`repro.sim.pipeline.PipelinedRuntime`.
        """
        from repro.core.runtime import CacheRuntime
        kwargs = dict(
            memory=memory or MainMemory(self.memory_bytes),
            n_vpus=self.n_vpus,
            vregs_per_vpu=self.vregs_per_vpu,
            vlen_bytes=self.vlen_bytes,
            queue_capacity=self.queue_capacity,
            geometry=self.geometry(),
            metrics=self.metrics,
            faults=self.fault_config(),
        )
        if scheduler == "serial":
            return CacheRuntime(**kwargs)
        if scheduler == "pipelined":
            from repro.sim.pipeline import PipelinedRuntime
            return PipelinedRuntime(tracer=tracer, row_chunk=self.row_chunk,
                                    dataflow=self.dataflow,
                                    tiling=self.tiling, reuse=self.reuse,
                                    **kwargs)
        raise ConfigError(
            f"unknown scheduler {scheduler!r} (expected 'serial'|'pipelined')")

    # ------------------------------------------------------------ from dicts
    @classmethod
    def from_dict(cls, raw: dict) -> "SimConfig":
        raw = dict(raw)
        raw.pop("extends", None)
        kwargs: dict[str, Any] = {"description": raw.pop("description", "")}
        for section, keys in _SECTIONS.items():
            sub = raw.pop(section, {}) or {}
            if not isinstance(sub, dict):
                raise ConfigError(f"section {section!r} must be a mapping")
            sub = dict(sub)
            sub.pop("replace", None)
            for k in list(sub):
                if k not in keys:
                    raise ConfigError(
                        f"unknown key {section}.{k} (expected one of {keys})")
            for k, v in sub.items():
                if (section, k) == ("pipeline", "tiling"):
                    kwargs.update(cls._parse_tiling(v))
                elif (section, k) == ("memory", "bytes"):
                    kwargs["memory_bytes"] = v
                elif (section, k) == ("metrics", "enabled"):
                    kwargs["metrics"] = v
                elif section == "faults":
                    kwargs[f"fault_{k}"] = v
                else:
                    kwargs[k] = v
        if raw:
            raise ConfigError(f"unknown top-level keys: {sorted(raw)}")
        return cls(**kwargs)

    @staticmethod
    def _parse_tiling(sub: Any) -> dict:
        """Validate the nested ``pipeline.tiling`` mapping ({rows, cols})."""
        if sub is None:
            return {}
        if not isinstance(sub, dict):
            raise ConfigError(
                f"pipeline.tiling must be a mapping with keys rows/cols "
                f"(rows per band / cols per tile; 0 disables an axis), "
                f"got {sub!r}")
        sub = {k: v for k, v in sub.items() if k != "replace"}
        for k in sub:
            if k not in ("rows", "cols"):
                raise ConfigError(
                    f"unknown key pipeline.tiling.{k} "
                    f"(expected one of ('rows', 'cols'))")
        return {"tile_rows": sub.get("rows", 0), "tile_cols": sub.get("cols", 0)}


# ------------------------------------------------------------------ merging
def deep_merge(base: dict, override: dict) -> dict:
    """Merge ``override`` into ``base`` (override wins), recursively for
    mappings. An override mapping with ``replace: true`` replaces the base
    mapping wholesale (the marker itself is dropped)."""
    out = dict(base)
    for k, v in override.items():
        if isinstance(v, dict):
            if v.get("replace"):
                v = {kk: vv for kk, vv in v.items() if kk != "replace"}
                out[k] = v
            elif isinstance(out.get(k), dict):
                out[k] = deep_merge(out[k], v)
            else:
                out[k] = dict(v)
        else:
            out[k] = v
    return out


# ---------------------------------------------------------------- overrides
def _check_override_paths(keys) -> None:
    """Reject override key sets where one dotted path prefixes another
    (``pipeline.tiling`` vs ``pipeline.tiling.rows``): the two writes race
    for the same subtree and the survivor would depend on application
    order — exactly the silent nondeterminism a sweep grid must not have."""
    paths = sorted(keys)
    for a, b in zip(paths, paths[1:]):
        if b.startswith(a + "."):
            raise ConfigError(
                f"conflicting override keys {a!r} and {b!r}: one is a "
                f"prefix of the other, so they write the same config subtree")


def merge_overrides(*maps: dict, sources: Optional[list] = None) -> dict:
    """Merge several flat override mappings (dotted keys → values) into one,
    raising :class:`ConfigError` on duplicate or prefix-conflicting keys.

    This is the sweep-grid combinator: each axis contributes one mapping per
    point, and two axes silently writing the same knob would make the grid
    labels lie about what each point runs. ``sources`` optionally names each
    mapping (axis names) for the error message."""
    out: dict[str, Any] = {}
    owner: dict[str, Any] = {}
    for i, m in enumerate(maps):
        name = sources[i] if sources else f"overrides[{i}]"
        for k, v in m.items():
            if not isinstance(k, str) or not k:
                raise ConfigError(
                    f"{name}: override keys must be non-empty dotted "
                    f"strings, got {k!r}")
            if k in out:
                raise ConfigError(
                    f"duplicate override key {k!r}: set by {owner[k]} "
                    f"and again by {name}")
            out[k] = v
            owner[k] = name
    _check_override_paths(out)
    return out


def apply_overrides(raw: dict, overrides: dict) -> dict:
    """Apply flat dotted-key overrides onto a raw config mapping (the YAML
    ``extends`` layer, *before* :meth:`SimConfig.from_dict` validation).

    ``{"cache.n_vpus": 8, "pipeline.tiling.rows": 4}`` descends/creates the
    nested sections and sets the leaves; a mapping value replaces the whole
    subtree. The input is not mutated. Unknown keys are deliberately left
    for :meth:`SimConfig.from_dict`, which names the valid ones."""
    _check_override_paths(overrides)
    out = copy.deepcopy(raw)
    for key, val in overrides.items():
        parts = key.split(".")
        node = out
        for p in parts[:-1]:
            child = node.get(p)
            if child is None:
                child = node[p] = {}
            elif not isinstance(child, dict):
                raise ConfigError(
                    f"override {key!r} descends through {p!r}, which holds "
                    f"the scalar {child!r}, not a section")
            node = child
        node[parts[-1]] = val
    return out


def config_from_overrides(base, overrides: Optional[dict] = None
                          ) -> "SimConfig":
    """Expand one sweep point: load ``base`` (builtin name, YAML path, or a
    raw mapping), apply dotted-key ``overrides`` on the raw layer, and
    validate the result into a :class:`SimConfig`."""
    if isinstance(base, dict):
        raw = base
    else:
        path = (base if str(base).endswith((".yaml", ".yml"))
                else builtin_config_path(str(base)))
        raw = load_raw(path)
    return SimConfig.from_dict(apply_overrides(raw, overrides or {}))


# ------------------------------------------------------------------ loading
def builtin_config_path(name: str) -> str:
    path = os.path.join(BUILTIN_DIR, name + ".yaml")
    if not os.path.exists(path):
        avail = sorted(f[:-5] for f in os.listdir(BUILTIN_DIR)
                       if f.endswith(".yaml"))
        raise ConfigError(f"no builtin config {name!r}; available: {avail}")
    return path


def _resolve(ref: str, relative_to: Optional[str]) -> str:
    """Resolve an ``extends`` reference: a path (relative to the referring
    file) or a builtin name."""
    if ref.endswith((".yaml", ".yml")):
        base_dir = os.path.dirname(relative_to) if relative_to else "."
        cand = ref if os.path.isabs(ref) else os.path.join(base_dir, ref)
        if os.path.exists(cand):
            return cand
        raise ConfigError(f"extends target not found: {cand}")
    return builtin_config_path(ref)


def load_raw(path: str, _chain: tuple = ()) -> dict:
    """Load one YAML file, following its ``extends`` chain (base first)."""
    try:
        import yaml
    except ImportError as e:     # pragma: no cover - dev extra present in CI
        raise ConfigError(
            "loading YAML configs requires pyyaml (pip install repro[dev])"
        ) from e
    path = os.path.abspath(path)
    if path in _chain:
        raise ConfigError(
            f"cyclic extends chain: {' -> '.join((*_chain, path))}")
    with open(path) as f:
        raw = yaml.safe_load(f) or {}
    if not isinstance(raw, dict):
        raise ConfigError(f"{path}: top level must be a mapping")
    parent = raw.pop("extends", None)
    if parent is None:
        return raw
    base = load_raw(_resolve(str(parent), path), (*_chain, path))
    return deep_merge(base, raw)


def load_config(path_or_name: str) -> SimConfig:
    """Load a :class:`SimConfig` from a YAML path or a builtin name."""
    path = (path_or_name if path_or_name.endswith((".yaml", ".yml"))
            else builtin_config_path(path_or_name))
    return SimConfig.from_dict(load_raw(path))
