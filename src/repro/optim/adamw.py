"""AdamW with f32 master weights, global-norm clipping and LR schedules.

Hand-rolled (no optax dependency): state = {master, m, v, step}. The master
copy lives in f32 even when live params are bf16 (mixed-precision training);
set ``moment_dtype``/``master_dtype`` to bf16 to halve optimizer memory for
HBM-limited configs (jamba-398B on a single pod — see EXPERIMENTS §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"
    master_dtype: str = "float32"


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    frac = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(cfg: AdamWConfig, params: PyTree) -> PyTree:
    mdt = jnp.dtype(cfg.moment_dtype)
    sdt = jnp.dtype(cfg.master_dtype)
    return {
        # copy=True: a same-dtype astype would alias the param buffer and
        # break donation (same buffer donated twice in the train step).
        "master": jax.tree.map(
            lambda p: jnp.array(p, dtype=sdt, copy=True), params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads: PyTree, state: PyTree,
                 params: PyTree) -> tuple[PyTree, PyTree, dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * g * g
        update = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        mf = master.astype(jnp.float32)
        mf = mf - lr * (update + cfg.weight_decay * mf)
        return (m_new.astype(m.dtype), v_new.astype(v.dtype),
                mf.astype(master.dtype))

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_ma = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, ma)
           for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(
        lambda ma, p: ma.astype(p.dtype), new_master, params)
    new_state = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
