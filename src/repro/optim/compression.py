"""Gradient compression: int8 quantized all-reduce with error feedback.

Used by the shard_map data-parallel driver (`distributed/collectives.py`) to
cut gradient all-reduce bytes 4× (f32→int8). Error feedback keeps the
compression unbiased over time: the quantization residual is added back into
the next step's gradient, so convergence tracks the uncompressed optimizer
(Seide et al. 2014; Karimireddy et al. 2019).

The all-reduce sums int32-accumulated int8 payloads, sharing one max-abs
scale per tensor (the scale is pmax-reduced first — one scalar, negligible).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def quantize(g: jax.Array, err: Optional[jax.Array] = None):
    """→ (int8 payload, f32 scale, new error residual)."""
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    residual = gf - q.astype(jnp.float32) * scale
    return q, scale, residual


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, axis_name: str,
                    err: Optional[jax.Array] = None):
    """Inside shard_map: all-reduce ``g`` over ``axis_name`` in int8.

    Returns (mean gradient f32, new error residual). Wire payload: int8
    tensor + one f32 scalar vs the uncompressed f32 tensor.
    """
    n = jax.lax.psum(1, axis_name)
    gf = g.astype(jnp.float32) + (err if err is not None else 0.0)
    # shared scale: max over participants so the int32 sum can't clip
    scale = jax.lax.pmax(jnp.max(jnp.abs(gf)) / 127.0 + 1e-30, axis_name)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    residual = gf - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale / n, residual


def tree_compressed_psum(grads: PyTree, axis_name: str,
                         err: Optional[PyTree] = None):
    flat, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(err) if err is not None else [None] * len(flat)
    pairs = [compressed_psum(g, axis_name, e) for g, e in zip(flat, flat_e)]
    mean = td.unflatten([p[0] for p in pairs])
    new_err = td.unflatten([p[1] for p in pairs])
    return mean, new_err
