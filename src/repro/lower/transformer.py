"""Lower transformer workloads onto the simulator as GEMM-dominated tapes.

Two scenarios, shapes taken from the ``repro.configs`` registry (scaled to
cache-feasible dimensions — the modeled LLC is hundreds of KiB, not GiB):

* **decode step** — one token through ``layers`` transformer blocks with a
  resident KV cache: QKV projection, scores against the cached keys,
  a leakyrelu nonlinearity standing in for softmax (the kernel library is
  the paper's Table I — integer NMC has no exp), attention-weighted value
  gather, output projection, and the two MLP projections; residual adds run
  through GeMM's β-accumulate path against a shared identity matrix, so the
  whole step is xmr/xmk instructions only.
* **MoE expert burst** — ``experts`` independent ``W1 → leakyrelu → W2``
  expert MLPs over a token block: back-to-back GEMM chains with no
  cross-expert dependencies, the regime where the pipelined scheduler's
  VPU-level parallelism shows.

Every GEMM is emitted through the shared strip-miner, so oversized weight
matrices become column strips re-reading the activation row — the
cross-instruction reuse pattern ``PipelinedRuntime(reuse=True)`` detects.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.encoding import ElemWidth
from repro.core.program import KernelProgram, ProgramBuilder, ProgramError
from repro.lower._strip import DEFAULT_VLEN, DEFAULT_VREGS, emit_gemm


@dataclasses.dataclass(frozen=True)
class DecodeSpec:
    """Scaled shapes of one decode step (see :func:`decode_step_from_config`
    for deriving these from a ``repro.configs`` architecture)."""

    name: str = "decode"
    d: int = 32               # model dim (scaled)
    ff: int = 96              # MLP hidden dim (scaled)
    kv: int = 32              # resident KV-cache length
    layers: int = 1
    vocab: int = 0            # >0: final logits projection (scaled vocab)
    width: ElemWidth = ElemWidth.B
    alpha: float = 0.125      # leakyrelu slope (softmax/silu stand-in)
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class MoESpec:
    """Scaled shapes of an MoE expert burst."""

    name: str = "moe"
    d: int = 32
    ff: int = 96
    tokens: int = 4           # token block routed to each expert
    experts: int = 2          # experts fired back to back (config: top_k)
    width: ElemWidth = ElemWidth.B
    alpha: float = 0.125
    seed: int = 0


def lower_decode_step(spec: DecodeSpec, *,
                      vregs_per_vpu: int = DEFAULT_VREGS,
                      vlen_bytes: int = DEFAULT_VLEN) -> KernelProgram:
    """One-token decode step as a validated, strip-mined tape."""
    if spec.d < 2 or spec.ff < 2 or spec.kv < 2 or spec.layers < 1:
        raise ProgramError(f"{spec.name}: degenerate decode shapes {spec}")
    b = ProgramBuilder(spec.name, spec.width)
    kw = dict(vregs=vregs_per_vpu, vlen=vlen_bytes)
    sfx = spec.width.suffix

    x = b.buffer("x0", 1, spec.d, init="random", seed=spec.seed, lo=-4, hi=4)
    ident = b.data("ident", np.eye(spec.d, dtype=np.int64))
    for l in range(spec.layers):
        wq = b.buffer(f"wq{l}", spec.d, spec.d, init="random",
                      seed=spec.seed + 10 * l + 1, lo=-3, hi=3)
        kt = b.buffer(f"kt{l}", spec.d, spec.kv, init="random",
                      seed=spec.seed + 10 * l + 2, lo=-3, hi=3)
        v = b.buffer(f"v{l}", spec.kv, spec.d, init="random",
                     seed=spec.seed + 10 * l + 3, lo=-3, hi=3)
        wo = b.buffer(f"wo{l}", spec.d, spec.d, init="random",
                      seed=spec.seed + 10 * l + 4, lo=-3, hi=3)
        w1 = b.buffer(f"w1_{l}", spec.d, spec.ff, init="random",
                      seed=spec.seed + 10 * l + 5, lo=-3, hi=3)
        w2 = b.buffer(f"w2_{l}", spec.ff, spec.d, init="random",
                      seed=spec.seed + 10 * l + 6, lo=-3, hi=3)

        q = b.buffer(f"q{l}", 1, spec.d)
        emit_gemm(b, b.full(x), b.full(wq), b.full(q), **kw,
                  comment=f"_gemm_{sfx}(m3, m0, m1, m2)  // q{l} = x @ Wq")
        scores = b.buffer(f"scores{l}", 1, spec.kv)
        emit_gemm(b, b.full(q), b.full(kt), b.full(scores), alpha=0.5, **kw,
                  comment=f"_gemm_{sfx}(m3, m0, m1, m2)  "
                          f"// scores{l} = 0.5 * q @ K^T (resident KV)")
        probs = b.buffer(f"probs{l}", 1, spec.kv)
        b.op("leakyrelu", [b.full(scores)], b.full(probs), alpha=spec.alpha,
             comment=f"_leakyrelu(m3, m0)  // probs{l} (softmax stand-in)")
        ctx = b.buffer(f"ctx{l}", 1, spec.d)
        emit_gemm(b, b.full(probs), b.full(v), b.full(ctx), **kw,
                  comment=f"_gemm_{sfx}(m3, m0, m1, m2)  // ctx{l} = p @ V")
        attn = b.buffer(f"attn{l}", 1, spec.d)
        emit_gemm(b, b.full(ctx), b.full(wo), b.full(attn), **kw,
                  comment=f"_gemm_{sfx}(m3, m0, m1, m2)  // attn{l} = ctx @ Wo")
        xa = b.buffer(f"xa{l}", 1, spec.d)
        emit_gemm(b, b.full(attn), b.full(ident), b.full(xa),
                  c=b.full(x), beta=1.0, **kw,
                  comment=f"_gemm_{sfx}(m3, m0, m1, m2)  "
                          f"// xa{l} = attn @ I + {x}  (residual via beta)")

        h1 = b.buffer(f"h1_{l}", 1, spec.ff)
        emit_gemm(b, b.full(xa), b.full(w1), b.full(h1), **kw,
                  comment=f"_gemm_{sfx}(m3, m0, m1, m2)  // h1_{l} = xa @ W1")
        act = b.buffer(f"act{l}", 1, spec.ff)
        b.op("leakyrelu", [b.full(h1)], b.full(act), alpha=spec.alpha,
             comment=f"_leakyrelu(m3, m0)  // act{l}")
        h2 = b.buffer(f"h2_{l}", 1, spec.d)
        emit_gemm(b, b.full(act), b.full(w2), b.full(h2), **kw,
                  comment=f"_gemm_{sfx}(m3, m0, m1, m2)  // h2_{l} = act @ W2")
        xn = b.buffer(f"x{l + 1}", 1, spec.d)
        emit_gemm(b, b.full(h2), b.full(ident), b.full(xn),
                  c=b.full(xa), beta=1.0, **kw,
                  comment=f"_gemm_{sfx}(m3, m0, m1, m2)  "
                          f"// x{l + 1} = h2 @ I + xa{l}  (residual via beta)")
        x = xn
    if spec.vocab > 0:
        wv = b.buffer("w_vocab", spec.d, spec.vocab, init="random",
                      seed=spec.seed + 7, lo=-3, hi=3)
        logits = b.buffer("logits", 1, spec.vocab)
        emit_gemm(b, b.full(x), b.full(wv), b.full(logits), **kw,
                  comment=f"_gemm_{sfx}(m3, m0, m1, m2)  "
                          f"// logits = {x} @ W_vocab")
    return b.build()


def lower_moe_burst(spec: MoESpec, *, vregs_per_vpu: int = DEFAULT_VREGS,
                    vlen_bytes: int = DEFAULT_VLEN) -> KernelProgram:
    """An MoE expert burst: ``experts`` independent expert MLPs over one
    routed token block, each a ``gemm → leakyrelu → gemm`` chain."""
    if spec.experts < 1 or spec.tokens < 1 or spec.d < 2 or spec.ff < 2:
        raise ProgramError(f"{spec.name}: degenerate MoE shapes {spec}")
    b = ProgramBuilder(spec.name, spec.width)
    kw = dict(vregs=vregs_per_vpu, vlen=vlen_bytes)
    sfx = spec.width.suffix
    x = b.buffer("tokens", spec.tokens, spec.d, init="random",
                 seed=spec.seed, lo=-4, hi=4)
    for e in range(spec.experts):
        w1 = b.buffer(f"e{e}_w1", spec.d, spec.ff, init="random",
                      seed=spec.seed + 10 * e + 1, lo=-3, hi=3)
        w2 = b.buffer(f"e{e}_w2", spec.ff, spec.d, init="random",
                      seed=spec.seed + 10 * e + 2, lo=-3, hi=3)
        h = b.buffer(f"e{e}_h", spec.tokens, spec.ff)
        emit_gemm(b, b.full(x), b.full(w1), b.full(h), **kw,
                  comment=f"_gemm_{sfx}(m3, m0, m1, m2)  "
                          f"// expert {e}: h = tokens @ W1")
        a = b.buffer(f"e{e}_act", spec.tokens, spec.ff)
        b.op("leakyrelu", [b.full(h)], b.full(a), alpha=spec.alpha,
             comment=f"_leakyrelu(m3, m0)  // expert {e} activation")
        y = b.buffer(f"e{e}_out", spec.tokens, spec.d)
        emit_gemm(b, b.full(a), b.full(w2), b.full(y), **kw,
                  comment=f"_gemm_{sfx}(m3, m0, m1, m2)  "
                          f"// expert {e}: out = act @ W2")
    return b.build()


# ------------------------------------------------------ configs/* frontend
def _scaled(dim: int, scale: int, floor: int = 8) -> int:
    """Scale a model dimension down to a cache-feasible multiple of 4."""
    return max(floor, (dim // scale) // 4 * 4)


def decode_step_from_config(arch: str, *, scale: int = 64, kv: int = 32,
                            layers: int = 1, vocab_scale: int = 1024,
                            width: ElemWidth = ElemWidth.B, seed: int = 0,
                            **lower_kw) -> tuple[KernelProgram, DecodeSpec]:
    """Lower a decode step with shapes from the ``repro.configs`` registry,
    divided by ``scale`` (the paper's machine is a microcontroller-class LLC;
    full LLM dims would need thousands of strips to no modeling benefit).
    Returns ``(program, spec)``; ``spec`` records the scaled shapes."""
    from repro.configs import get_config   # deferred: keeps repro.lower light
    cfg = get_config(arch)
    spec = DecodeSpec(
        name=f"decode-{arch}", d=_scaled(cfg.d_model, scale),
        ff=_scaled(cfg.d_ff, scale), kv=kv,
        layers=min(layers, cfg.n_layers),
        vocab=_scaled(cfg.vocab, vocab_scale, floor=16),
        width=width, seed=seed)
    return lower_decode_step(spec, **lower_kw), spec


def moe_burst_from_config(arch: str, *, scale: int = 64, tokens: int = 4,
                          experts: int = 0, width: ElemWidth = ElemWidth.B,
                          seed: int = 0, **lower_kw
                          ) -> tuple[KernelProgram, MoESpec]:
    """Lower an expert burst for an MoE architecture from the registry
    (``experts`` defaults to the config's ``top_k`` — the experts a token
    actually fires). Raises :class:`ProgramError` for non-MoE archs."""
    from repro.configs import get_config
    cfg = get_config(arch)
    if cfg.moe is None:
        raise ProgramError(f"{arch} has no MoE block to lower")
    spec = MoESpec(
        name=f"moe-{arch}", d=_scaled(cfg.d_model, scale),
        ff=_scaled(cfg.d_ff, scale), tokens=tokens,
        experts=experts or cfg.moe.top_k, width=width, seed=seed)
    return lower_moe_burst(spec, **lower_kw), spec
