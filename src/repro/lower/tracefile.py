"""Versioned JSONL trace files for KernelPrograms (TBM-style).

A trace is one JSON record per line: a header naming the format and IR
version, then one record per buffer and per op, in program order. The format
is line-diffable and authorable without Python — a scenario is a text file:

    {"record": "header", "format": "arcane-kernel-trace", "version": 1,
     "name": "demo", "width": "w"}
    {"record": "buffer", "name": "x", "rows": 8, "cols": 8,
     "init": "random", "seed": 3, "lo": -8, "hi": 8, "data": null}
    {"record": "op", "kernel": "leakyrelu", "srcs": [["x", 0, 0, 8, 8]],
     "dst": ["y", 0, 0, 8, 8], "params": {"alpha": 0.25}, "comment": "..."}

Views serialize as ``[buf, row0, col0, rows, cols]``. ``load(save(p)) == p``
holds structurally (the IR is plain ints/floats/strings/tuples). Loading
validates the assembled program against the kernel library, so a malformed
trace fails with the offending line or op, never mid-schedule.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.core.encoding import ElemWidth
from repro.core.isa import KernelLibrary
from repro.core.program import (Buffer, KernelOp, KernelProgram,
                                PROGRAM_VERSION, ProgramError, View)

TRACE_FORMAT = "arcane-kernel-trace"


class TraceFormatError(ProgramError):
    """The trace file/stream is not a well-formed versioned trace."""


# ------------------------------------------------------------------- save
def dumps(prog: KernelProgram) -> str:
    """Serialize a program to JSONL text (header + buffers + ops)."""
    lines = [json.dumps({"record": "header", "format": TRACE_FORMAT,
                         "version": PROGRAM_VERSION, "name": prog.name,
                         "width": prog.width.suffix})]
    for b in prog.buffers:
        lines.append(json.dumps({"record": "buffer",
                                 **dataclasses.asdict(b)}))
    for op in prog.ops:
        lines.append(json.dumps({"record": "op", "kernel": op.kernel,
                                 "srcs": [v.to_obj() for v in op.srcs],
                                 "dst": op.dst.to_obj(),
                                 "params": dict(op.params),
                                 "comment": op.comment}))
    return "\n".join(lines) + "\n"


def save_program(prog: KernelProgram, path: str) -> str:
    with open(path, "w") as f:
        f.write(dumps(prog))
    return path


# ------------------------------------------------------------------- load
def loads(text: str, library: Optional[KernelLibrary] = None
          ) -> KernelProgram:
    """Parse JSONL text into a validated :class:`KernelProgram`; raises
    :class:`TraceFormatError` naming the offending line."""
    header = None
    buffers: list[Buffer] = []
    ops: list[KernelOp] = []
    for ln, raw in enumerate(text.splitlines(), start=1):
        raw = raw.strip()
        if not raw:
            continue
        try:
            rec = json.loads(raw)
        except json.JSONDecodeError as e:
            raise TraceFormatError(f"line {ln}: invalid JSON: {e}") from e
        if not isinstance(rec, dict) or "record" not in rec:
            raise TraceFormatError(f"line {ln}: not a trace record")
        kind = rec["record"]
        if kind == "header":
            if header is not None:
                raise TraceFormatError(f"line {ln}: duplicate header")
            if rec.get("format") != TRACE_FORMAT:
                raise TraceFormatError(
                    f"line {ln}: format {rec.get('format')!r}, "
                    f"want {TRACE_FORMAT!r}")
            if rec.get("version") != PROGRAM_VERSION:
                raise TraceFormatError(
                    f"line {ln}: trace version {rec.get('version')!r} != "
                    f"supported {PROGRAM_VERSION}")
            try:
                header = {"name": str(rec.get("name", "")),
                          "width": ElemWidth.from_suffix(rec["width"])}
            except (KeyError, ValueError) as e:
                raise TraceFormatError(f"line {ln}: bad header: {e}") from e
            continue
        if header is None:
            raise TraceFormatError(f"line {ln}: {kind!r} record before the "
                                   f"header line")
        if kind == "buffer":
            try:
                data = rec.get("data")
                if data is not None:
                    data = tuple(tuple(int(x) for x in row) for row in data)
                buffers.append(Buffer(
                    name=str(rec["name"]), rows=int(rec["rows"]),
                    cols=int(rec["cols"]),
                    init=str(rec.get("init", "zeros")),
                    seed=int(rec.get("seed", 0)), lo=int(rec.get("lo", -8)),
                    hi=int(rec.get("hi", 8)), data=data))
            except (KeyError, TypeError, ValueError) as e:
                raise TraceFormatError(
                    f"line {ln}: bad buffer record: {e}") from e
        elif kind == "op":
            try:
                ops.append(KernelOp(
                    kernel=str(rec["kernel"]),
                    srcs=tuple(View.from_obj(v) for v in rec["srcs"]),
                    dst=View.from_obj(rec["dst"]),
                    params=dict(rec.get("params", {})),
                    comment=str(rec.get("comment", ""))))
            except (KeyError, TypeError, ValueError) as e:
                raise TraceFormatError(
                    f"line {ln}: bad op record: {e}") from e
        else:
            raise TraceFormatError(f"line {ln}: unknown record kind {kind!r}")
    if header is None:
        raise TraceFormatError("empty trace: no header record")
    prog = KernelProgram(name=header["name"], width=header["width"],
                         buffers=tuple(buffers), ops=tuple(ops))
    return prog.validate(library)


def load_program(path: str, library: Optional[KernelLibrary] = None
                 ) -> KernelProgram:
    with open(path) as f:
        return loads(f.read(), library)
