"""Lower the paper's CNN onto the simulator as a strip-mined KernelProgram.

The front layer is the paper's Listing-1 workload: a fused 3-channel conv
layer (``xmk4`` = conv + 2x2 maxpool + ReLU) over a channel-stacked
``(3H, W)`` image, issued as column strips sized to the VPU register file —
input strips are strided ``xmr`` bindings (stride = image width), exactly
the decomposition ``benchmarks/fig4_speedup.tiled_conv_layer`` used to
hand-roll. Deeper stages are unfused ``conv2d → leakyrelu → maxpool`` chains
on the single-channel feature map, each stage strip-mined independently; an
optional GEMM classifier head closes the network. Any depth, batch size, and
element width (the paper's "worst-case 32-bit workload" is ``ElemWidth.W``).

Buffer naming (per batch image ``i``): ``x{i}`` input, ``f0`` fused-layer
filter, ``l0_out{i}`` its output, then per extra stage ``d``:
``f{d}`` filter, ``l{d}_conv{i}`` / ``l{d}_act{i}`` / ``l{d}_pool{i}``;
``head`` weights and ``logits{i}`` when ``classes > 0``.
"""
from __future__ import annotations

import dataclasses

from repro.core.encoding import ElemWidth
from repro.core.program import (KernelProgram, ProgramBuilder, ProgramError,
                                View)
from repro.lower._strip import (DEFAULT_VLEN, DEFAULT_VREGS, col_strips,
                                emit_gemm, lines)


@dataclasses.dataclass(frozen=True)
class CNNSpec:
    """Shape of the lowered CNN (defaults: the paper's 32x32 Listing-1 run)."""

    name: str = "cnn"
    h: int = 32               # input spatial height (image is (3h, w))
    w: int = 32
    k: int = 3                # fused-layer filter size (filter is (3k, k))
    width: ElemWidth = ElemWidth.W
    depth: int = 0            # extra conv2d -> leakyrelu -> maxpool stages
    k2: int = 3               # filter size of the extra stages
    alpha: float = 0.25       # leakyrelu slope in the unfused stages
    classes: int = 0          # >0: GEMM classifier head over pooled features
    batch: int = 1
    seed: int = 0


def lower_cnn(spec: CNNSpec, *, vregs_per_vpu: int = DEFAULT_VREGS,
              vlen_bytes: int = DEFAULT_VLEN) -> KernelProgram:
    """Lower ``spec`` into a validated, strip-mined :class:`KernelProgram`."""
    eb = spec.width.nbytes
    sfx = spec.width.suffix
    b = ProgramBuilder(spec.name, spec.width)

    f0 = b.buffer("f0", 3 * spec.k, spec.k, init="random",
                  seed=spec.seed + 1, lo=-4, hi=4)
    head = None
    if spec.classes > 0:
        pass  # head shape depends on the final feature map; declared below

    for i in range(spec.batch):
        x = b.buffer(f"x{i}", 3 * spec.h, spec.w, init="random",
                     seed=spec.seed + 10 + i)
        cur = _fused_layer(b, spec, i, x, f0, vregs_per_vpu, vlen_bytes)
        for d in range(1, spec.depth + 1):
            cur = _unfused_stage(b, spec, i, d, cur, vregs_per_vpu,
                                 vlen_bytes, eb, sfx)
        if spec.classes > 0:
            feat = b.full(cur)
            if head is None:
                head = b.buffer("head", feat.cols, spec.classes,
                                init="random", seed=spec.seed + 2, lo=-3, hi=3)
            logits = b.buffer(f"logits{i}", feat.rows, spec.classes)
            emit_gemm(b, feat, b.full(head), b.full(logits),
                      alpha=1.0, beta=0.0,
                      vregs=vregs_per_vpu, vlen=vlen_bytes,
                      comment=f"_gemm_{sfx}(m3, m0, m1, m2)  "
                              f"// logits{i} = {cur} @ head")
    return b.build()


def _fused_layer(b: ProgramBuilder, spec: CNNSpec, i: int, x: str, f0: str,
                 vregs: int, vlen: int) -> str:
    """The Listing-1 fused conv layer, column-strip-mined to the register
    file (same budget arithmetic as the C-RT macro-kernel: 2 slack registers
    + the filter's lines are reserved, input strips span ``2*sw + k - 1``
    image columns per ``sw`` output columns)."""
    h, w, k, eb = spec.h, spec.w, spec.k, spec.width.nbytes
    cm, cn = h - k + 1, w - k + 1
    if cm < 2 or cn < 2:
        raise ProgramError(f"{spec.name}: {h}x{w} conv output smaller than "
                           f"the fused 2x2 pool window")
    om, on = cm // 2, cn // 2
    out = b.buffer(f"l0_out{i}", om, on)
    budget = vregs - 2 - lines(3 * k * k * eb, vlen)

    def fits(sw: int) -> bool:
        win = 2 * sw + k - 1
        return lines(3 * h * win * eb, vlen) + lines(om * sw * eb, vlen) \
            <= budget

    sfx = spec.width.suffix
    for c0, c1 in col_strips(on, fits):
        scols = c1 - c0
        win = 2 * scols + k - 1
        b.op("conv_layer",
             [View(buf=x, rows=3 * h, cols=win, col0=2 * c0), b.full(f0)],
             View(buf=out, rows=om, cols=scols, col0=c0),
             comment=f"_conv_layer_{sfx}(m3, m0, m1)  "
                     f"// {out}[:, {c0}:{c1}) from {x}[:, {2*c0}:{2*c0+win})")
    return out


def _unfused_stage(b: ProgramBuilder, spec: CNNSpec, i: int, d: int,
                   cur: str, vregs: int, vlen: int, eb: int, sfx: str) -> str:
    """One conv2d → leakyrelu → maxpool stage on the single-channel feature
    map, every step strip-mined over destination columns."""
    src = b.full(cur)
    cr, cc = src.rows, src.cols
    k2 = spec.k2
    if cr < k2 + 1 or cc < k2 + 1:
        raise ProgramError(f"{spec.name}: stage {d} input {cr}x{cc} too "
                           f"small for a {k2}x{k2} conv + 2x2 pool")
    fname = f"f{d}"
    if i == 0:
        b.buffer(fname, k2, k2, init="random", seed=spec.seed + 100 + d,
                 lo=-3, hi=3)

    # conv2d: out strip of sw cols reads an (sw + k2 - 1)-col input strip
    vr, vc = cr - k2 + 1, cc - k2 + 1
    conv = b.buffer(f"l{d}_conv{i}", vr, vc)
    cbudget = vregs - 2 - lines(k2 * k2 * eb, vlen)

    def conv_fits(sw: int) -> bool:
        return lines(cr * (sw + k2 - 1) * eb, vlen) \
            + lines(vr * sw * eb, vlen) <= cbudget

    for c0, c1 in col_strips(vc, conv_fits):
        scols = c1 - c0
        b.op("conv2d",
             [View(buf=cur, rows=cr, cols=scols + k2 - 1, col0=c0),
              b.full(fname)],
             View(buf=conv, rows=vr, cols=scols, col0=c0),
             comment=f"_conv2d(m3, m0, m1)  // {conv}[:, {c0}:{c1})")

    # leakyrelu: elementwise, same-shape strips
    act = b.buffer(f"l{d}_act{i}", vr, vc)

    def ew_fits(sw: int) -> bool:
        return 2 * lines(vr * sw * eb, vlen) <= vregs - 2

    for c0, c1 in col_strips(vc, ew_fits):
        scols = c1 - c0
        b.op("leakyrelu",
             [View(buf=conv, rows=vr, cols=scols, col0=c0)],
             View(buf=act, rows=vr, cols=scols, col0=c0),
             comment=f"_leakyrelu(m3, m0)  // {act}[:, {c0}:{c1})",
             alpha=spec.alpha)

    # maxpool 2x2 stride 2: out strip of sw cols reads 2*sw input cols
    pm, pn = (vr - 2) // 2 + 1, (vc - 2) // 2 + 1
    pool = b.buffer(f"l{d}_pool{i}", pm, pn)

    def pool_fits(sw: int) -> bool:
        return lines(vr * 2 * sw * eb, vlen) + lines(pm * sw * eb, vlen) \
            <= vregs - 2

    for c0, c1 in col_strips(pn, pool_fits):
        scols = c1 - c0
        b.op("maxpool",
             [View(buf=act, rows=vr, cols=2 * scols, col0=2 * c0)],
             View(buf=pool, rows=pm, cols=scols, col0=c0),
             comment=f"_maxpool(m3, m0, 2, 2)  // {pool}[:, {c0}:{c1})",
             stride=2, win_size=2)
    return pool
