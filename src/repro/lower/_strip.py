"""Strip-mining helpers shared by the lowering frontends.

The C-RT macro-kernel splits any operand larger than one VPU's vector
register file into column strips (strided ``xmr`` bindings over the same
buffer); these helpers compute the strip widths against the register-file
budget and emit strip-mined GEMMs through a :class:`ProgramBuilder`.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.encoding import ElemWidth
from repro.core.program import ProgramBuilder, View

#: The simulator's default VPU geometry (64 vregs x 1 KiB — see
#: ``benchmarks/fig4_speedup.arcane_cycles``); lowerings take overrides.
DEFAULT_VREGS = 64
DEFAULT_VLEN = 1024


def lines(nbytes: int, vlen_bytes: int) -> int:
    """Vector registers consumed by a packed operand of ``nbytes``."""
    return (nbytes + vlen_bytes - 1) // vlen_bytes


def col_strips(out_cols: int, fits: Callable[[int], bool]
               ) -> list[tuple[int, int]]:
    """Split ``out_cols`` destination columns into ``(c0, c1)`` strips: the
    widest power-of-two-halved strip whose operand set ``fits`` the register
    budget (1-column strips always ship — the runtime will reject a program
    that cannot fit even those, which is a genuine capacity error)."""
    sw = out_cols
    while sw > 1 and not fits(sw):
        sw = max(1, sw // 2)
    return [(c0, min(c0 + sw, out_cols)) for c0 in range(0, out_cols, sw)]


def emit_gemm(b: ProgramBuilder, a: View, w: View, dst: View, *,
              c: Optional[View] = None, alpha: float = 1.0, beta: float = 0.0,
              vregs: int = DEFAULT_VREGS, vlen: int = DEFAULT_VLEN,
              comment: str = "") -> None:
    """Emit ``dst = alpha * (a @ w) + beta * c`` as column strips of the
    destination (each strip re-reads the full ``a`` — the cross-instruction
    reuse regime the pipelined scheduler's ``reuse`` knob accelerates).

    ``c`` defaults to the destination strip itself (the Listing-1 idiom for
    β = 0, where the accumulator operand is numerically unused)."""
    eb = b.width.nbytes
    m, k = a.rows, a.cols
    n = w.cols
    assert w.rows == k and dst.shape == (m, n), (a.shape, w.shape, dst.shape)

    def fits(sw: int) -> bool:
        need = lines(m * k * eb, vlen) + lines(k * sw * eb, vlen) \
            + 2 * lines(m * sw * eb, vlen)      # accumulator + destination
        return need <= vregs - 2

    strips = col_strips(n, fits)
    for j, (c0, c1) in enumerate(strips):
        scols = c1 - c0
        dstrip = View(buf=dst.buf, rows=m, cols=scols,
                      row0=dst.row0, col0=dst.col0 + c0)
        wstrip = View(buf=w.buf, rows=k, cols=scols,
                      row0=w.row0, col0=w.col0 + c0)
        cstrip = dstrip if c is None else View(
            buf=c.buf, rows=m, cols=scols, row0=c.row0, col0=c.col0 + c0)
        note = comment or f"_gemm(m3, m0, m1, m2)  // {dst.buf}"
        if len(strips) > 1:
            note += f" cols [{c0}:{c1})"
        b.op("gemm", [a, wstrip, cstrip], dstrip, comment=note,
             alpha=alpha, beta=beta)
