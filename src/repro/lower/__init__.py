"""Model→tape lowering frontend: real workloads onto the ARCANE simulator.

This package closes the gap between the repo's model zoo and its simulator:
it lowers model-shaped workloads into :class:`repro.core.KernelProgram`
tapes — the validated xmr/xmk IR both C-RT schedulers execute through
``repro.core.run_program`` — with every operand strip-mined to the VPU
register-file budget, exactly like the C-RT macro-kernel does for operands
larger than the vector register capacity.

Three frontends:

* :mod:`repro.lower.cnn` — the paper's CNN workload (Listing 1): a fused
  3-channel ``xmk4`` conv layer, optional deeper ``conv2d → leakyrelu →
  maxpool`` stages, optional GEMM classifier head; any depth, batch, and
  element width.
* :mod:`repro.lower.transformer` — a transformer decode step (QKV / scores /
  attention / output / MLP projections as a GEMM-dominated tape with
  residual accumulation through GeMM's β path) and an MoE expert burst,
  with shapes taken from the ``repro.configs`` registry scaled down to
  cache-feasible dimensions.
* :mod:`repro.lower.tracefile` — versioned JSONL serialization (TBM-style),
  so scenarios can be authored, diffed, and replayed without Python.

Every lowered program carries Listing-1-style provenance comments on its ops
and checks numerically against ``repro.core.reference_images`` (the
sequential numpy oracle) — see ``tests/test_lower.py`` and
``benchmarks/bench_models.py``.
"""
from repro.lower.cnn import CNNSpec, lower_cnn
from repro.lower.tracefile import (TraceFormatError, dumps, load_program,
                                   loads, save_program)
from repro.lower.transformer import (DecodeSpec, MoESpec,
                                     decode_step_from_config,
                                     lower_decode_step, lower_moe_burst,
                                     moe_burst_from_config)

__all__ = [
    "CNNSpec", "lower_cnn", "DecodeSpec", "MoESpec", "lower_decode_step",
    "lower_moe_burst", "decode_step_from_config", "moe_burst_from_config",
    "TraceFormatError", "dumps", "loads", "load_program", "save_program",
]
