"""repro.dse — design-space exploration over the simulator stack.

The ARCANE trade the paper's Table II quantifies — incremental VPU lanes
buy near-linear throughput at sub-linear area growth — is a design-space
question, and this package is the harness that asks it at sweep scale:

  * :mod:`repro.dse.grid`      — declarative sweep grids (axes of dotted
    config overrides × scenarios) expanded into deterministic, diffable
    points on the YAML ``extends`` layer
  * :mod:`repro.dse.scenarios` — the model/serving scenario catalog shared
    with the benchmark drivers
  * :mod:`repro.dse.runner`    — per-point execution with golden-tape
    verification + stall summaries, fanned out over worker processes
  * :mod:`repro.dse.pareto`    — order-independent Pareto-front extraction
    (makespan / goodput vs. modeled area)

``benchmarks/bench_dse.py`` drives the whole pipeline and joins each row
with ``benchmarks/table2_area.py``'s modeled area estimates into
``BENCH_dse.json``.
"""
from repro.dse.grid import SweepGrid, SweepPoint
from repro.dse.pareto import annotate_fronts, dominates, pareto_front
from repro.dse.runner import run_point, run_points, stall_summary
from repro.dse.scenarios import (MODEL_SCENARIOS, SERVING_SCENARIOS,
                                 ServingScenario, scenario_kind,
                                 scenario_names)

__all__ = [
    "SweepGrid", "SweepPoint", "annotate_fronts", "dominates",
    "pareto_front", "run_point", "run_points", "stall_summary",
    "MODEL_SCENARIOS", "SERVING_SCENARIOS", "ServingScenario",
    "scenario_kind", "scenario_names",
]
