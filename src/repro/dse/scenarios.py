"""The sweep scenario catalog shared by the benchmark drivers.

One catalog, two kinds of entry:

* **Model scenarios** (``MODEL_SCENARIOS``): zero-config builders returning a
  lowered :class:`repro.core.KernelProgram` — the same closed-batch tapes
  ``benchmarks/bench_models.py`` times (that driver imports its ``SCENARIOS``
  from here). Builders take the cache geometry (``vregs_per_vpu`` /
  ``vlen_bytes``) so the strip-miner tiles for the register file each sweep
  point actually models — a program strip-mined for 64 registers is the
  wrong tape on a 32-register point.

* **Serving scenarios** (``SERVING_SCENARIOS``): the continuous-batching
  workload ``benchmarks/bench_serving.py`` sweeps — a seeded arrival process
  plus slot discipline, producing tokens-per-kilocycle goodput instead of a
  single makespan.

``repro.dse`` fans these out over configuration grids; the catalogs stay
here (importable, no ``benchmarks/`` path tricks) so worker processes can
rebuild any scenario from its name alone.
"""
from __future__ import annotations

import dataclasses

from repro.core.encoding import ElemWidth
from repro.core.program import KernelProgram
from repro.lower import (CNNSpec, decode_step_from_config, lower_cnn,
                         moe_burst_from_config)
from repro.lower._strip import DEFAULT_VLEN, DEFAULT_VREGS
from repro.sim.serving import (Request, ServingConfig, bursty_arrivals,
                               poisson_arrivals)

__all__ = [
    "MODEL_SCENARIOS", "SERVING_SCENARIOS", "ServingScenario",
    "scenario_kind", "scenario_names",
]


# --------------------------------------------------------- model scenarios
def scen_cnn_paper(*, vregs_per_vpu: int = DEFAULT_VREGS,
                   vlen_bytes: int = DEFAULT_VLEN) -> KernelProgram:
    """The paper's Listing-1 run: fused conv layer over a 32x32 RGB image,
    worst-case 32-bit elements."""
    return lower_cnn(CNNSpec(name="cnn-paper"),
                     vregs_per_vpu=vregs_per_vpu, vlen_bytes=vlen_bytes)


def scen_cnn_small(*, vregs_per_vpu: int = DEFAULT_VREGS,
                   vlen_bytes: int = DEFAULT_VLEN) -> KernelProgram:
    """Small-shape int8 fused conv layer (16x16): the cheap sweep anchor the
    CI design-space run fans out."""
    return lower_cnn(CNNSpec(name="cnn-small", h=16, w=16,
                             width=ElemWidth.B),
                     vregs_per_vpu=vregs_per_vpu, vlen_bytes=vlen_bytes)


def scen_cnn_deep_int8(*, vregs_per_vpu: int = DEFAULT_VREGS,
                       vlen_bytes: int = DEFAULT_VLEN) -> KernelProgram:
    """A deeper int8 CNN: fused front layer + two unfused
    conv2d->leakyrelu->maxpool stages + GEMM classifier head, batch of 2."""
    return lower_cnn(CNNSpec(name="cnn-deep-int8", h=24, w=24,
                             width=ElemWidth.B, depth=2, classes=8, batch=2),
                     vregs_per_vpu=vregs_per_vpu, vlen_bytes=vlen_bytes)


def _scen_decode(arch: str):
    def build(*, vregs_per_vpu: int = DEFAULT_VREGS,
              vlen_bytes: int = DEFAULT_VLEN) -> KernelProgram:
        prog, _spec = decode_step_from_config(
            arch, scale=64, kv=16, layers=1,
            vregs_per_vpu=vregs_per_vpu, vlen_bytes=vlen_bytes)
        return prog
    build.__doc__ = f"One-token decode step scaled from the {arch} config."
    return build


def scen_moe_granite(*, vregs_per_vpu: int = DEFAULT_VREGS,
                     vlen_bytes: int = DEFAULT_VLEN) -> KernelProgram:
    """Expert burst of granite's 8 active experts (top_k) over 4 tokens."""
    prog, _spec = moe_burst_from_config(
        "granite-moe-1b-a400m", scale=32,
        vregs_per_vpu=vregs_per_vpu, vlen_bytes=vlen_bytes)
    return prog


MODEL_SCENARIOS = {
    "cnn-paper": scen_cnn_paper,
    "cnn-small": scen_cnn_small,
    "cnn-deep-int8": scen_cnn_deep_int8,
    "decode-stablelm-3b": _scen_decode("stablelm-3b"),
    "decode-gemma2-9b": _scen_decode("gemma2-9b"),
    "moe-granite": scen_moe_granite,
}


# ------------------------------------------------------- serving scenarios
@dataclasses.dataclass(frozen=True)
class ServingScenario:
    """One continuous-batching workload: a seeded arrival process over the
    scaled serving model (see :mod:`repro.sim.serving`). Deterministic for a
    fixed spec — the sweep's goodput numbers are exactly reproducible."""

    name: str
    n_requests: int = 8
    mean_gap: int = 20_000
    arrivals: str = "poisson"          # "poisson" | "bursty"
    seed: int = 0
    kv_max: int = 24
    slots: int = 4
    prompt_range: tuple[int, int] = (3, 8)
    new_range: tuple[int, int] = (2, 5)

    def requests(self) -> list[Request]:
        if self.arrivals == "poisson":
            return poisson_arrivals(self.n_requests, self.mean_gap,
                                    prompt_range=self.prompt_range,
                                    new_range=self.new_range, seed=self.seed)
        if self.arrivals == "bursty":
            return bursty_arrivals(self.n_requests,
                                   max(2, self.n_requests // 3),
                                   self.mean_gap * 3,
                                   prompt_range=self.prompt_range,
                                   new_range=self.new_range, seed=self.seed)
        raise ValueError(f"{self.name}: unknown arrival process "
                         f"{self.arrivals!r} (expected poisson|bursty)")

    def serving_config(self, *, vregs_per_vpu: int = DEFAULT_VREGS,
                       vlen_bytes: int = DEFAULT_VLEN) -> ServingConfig:
        return ServingConfig(kv_max=self.kv_max, slots=self.slots,
                             vregs=vregs_per_vpu, vlen=vlen_bytes)


SERVING_SCENARIOS = {
    "serving-poisson": ServingScenario(name="serving-poisson"),
    "serving-bursty": ServingScenario(name="serving-bursty",
                                      arrivals="bursty"),
}


# ----------------------------------------------------------------- lookup
def scenario_names() -> list[str]:
    return sorted((*MODEL_SCENARIOS, *SERVING_SCENARIOS))


def scenario_kind(name: str) -> str:
    """``"model"`` or ``"serving"``; raises ``KeyError`` naming the
    available scenarios."""
    if name in MODEL_SCENARIOS:
        return "model"
    if name in SERVING_SCENARIOS:
        return "serving"
    raise KeyError(f"unknown scenario {name!r}; "
                   f"available: {scenario_names()}")
