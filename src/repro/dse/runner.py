"""Point execution + the parallel worker pool for design-space sweeps.

:func:`run_point` is the unit of work: one serializable point spec in, one
plain-JSON row out. Model points run the scenario's tape on **both**
schedulers with the numpy oracle as referee (golden-tape verification — a
sweep row is a verified execution, not just a timing) and report the
pipelined makespan; serving points run the continuous-batching driver and
report goodput. Every row carries the per-point stall-attribution summary
(the unified metrics layer), so when the Pareto join marks a point
dominated, the row itself says *where* its cycles went.

:func:`run_points` fans specs out over a ``ProcessPoolExecutor``
(simulator points are independent and CPU-bound — exactly the sweep shape
PR 5's simulator-throughput work paid for) and returns rows in spec order.
``in_process=True`` runs the same specs sequentially in the caller; the
tests assert the two paths produce bit-identical rows. Rows contain no
wall-clock fields — reruns of the same grid are diffable byte-for-byte.
"""
from __future__ import annotations

import os
from concurrent.futures import ProcessPoolExecutor
from typing import Optional, Sequence

import numpy as np

from repro.core import (ArcaneCoprocessor, issue_program, place_program,
                        reference_images)
from repro.core.program import ProgramRun
from repro.dse.scenarios import (MODEL_SCENARIOS, SERVING_SCENARIOS,
                                 scenario_kind)
from repro.sim.config import SimConfig, config_from_overrides
from repro.sim.serving import ServingDriver
from repro.sim.trace import Tracer

__all__ = ["run_point", "run_points", "stall_summary"]


# ---------------------------------------------------------------- summaries
def stall_summary(mrep: dict, top: int = 3) -> dict:
    """Collapse a metrics report's per-kernel stall attribution into one
    point-level summary: total busy/latency, the nonzero stall bins, and
    the ``top`` heaviest bins — the "why this point loses" digest carried
    on every sweep row."""
    if not mrep:
        return {"busy": 0, "latency": 0, "stalls": {}, "top": []}
    bins: dict[str, int] = {}
    busy = latency = 0
    for agg in mrep.get("kernels", {}).values():
        busy += agg["busy"]
        latency += agg["latency"]
        for b, c in agg["stalls"].items():
            if c:
                bins[b] = bins.get(b, 0) + c
    ranked = sorted(bins.items(), key=lambda kv: (-kv[1], kv[0]))
    return {"busy": busy, "latency": latency,
            "stalls": dict(sorted(bins.items())),
            "top": [list(kv) for kv in ranked[:top]]}


def _config_row(cfg: SimConfig) -> dict:
    """The knobs the area model and the front reader need, snapshotted."""
    return {"n_vpus": cfg.n_vpus, "lanes": cfg.lanes,
            "vregs_per_vpu": cfg.vregs_per_vpu,
            "vlen_bytes": cfg.vlen_bytes, "llc_bytes": cfg.llc_bytes,
            "dma_bytes_per_cycle": cfg.dma_bytes_per_cycle,
            "row_chunk": cfg.row_chunk,
            "tiling": list(cfg.tiling) if cfg.tiling else None,
            "reuse": cfg.reuse,
            "reuse_fifo_bytes": (cfg.vregs_per_vpu * cfg.vlen_bytes
                                 if cfg.reuse else 0)}


# ------------------------------------------------------------- point kinds
def _run_model_point(cfg: SimConfig, scenario: str) -> dict:
    prog = MODEL_SCENARIOS[scenario](vregs_per_vpu=cfg.vregs_per_vpu,
                                     vlen_bytes=cfg.vlen_bytes)
    ref = reference_images(prog)

    def execute(scheduler: str) -> ProgramRun:
        rt = cfg.make_runtime(scheduler, tracer=Tracer(enabled=False))
        cop = ArcaneCoprocessor(runtime=rt)
        addrs = place_program(cop, prog)
        issue_program(cop, prog, addrs)
        return ProgramRun(prog=prog, cop=cop, addrs=addrs)

    run_s = execute("serial")
    run_p = execute("pipelined")
    images = run_p.flushed_images()
    run_s.rt.cache.flush_all()
    np.testing.assert_array_equal(
        run_s.rt.memory.data, run_p.rt.memory.data,
        err_msg=f"{scenario}: serial and pipelined memory images diverged")
    for bname, arr in ref.items():
        np.testing.assert_array_equal(
            images[bname], arr,
            err_msg=f"{scenario}: buffer {bname} diverged from the oracle")

    serial = run_s.rt.stats.total_cycles
    makespan = run_p.rt.sim_time
    mrep = run_p.rt.metrics_report() if cfg.metrics else {}
    return {
        "kind": "model",
        "n_ops": prog.n_ops,
        "serial_cycles": serial,
        "makespan": makespan,
        "speedup": serial / makespan if makespan else float("inf"),
        "tokens_per_kcycle": None,
        "verified": True,          # the asserts above gate reaching this
        "conservation_ok": (mrep.get("conservation_ok", True)
                            if cfg.metrics else True),
        "stall_summary": stall_summary(mrep),
    }


def _run_serving_point(cfg: SimConfig, scenario: str) -> dict:
    scen = SERVING_SCENARIOS[scenario]
    rt = cfg.make_runtime("pipelined", tracer=Tracer(enabled=False))
    drv = ServingDriver(rt, scen.serving_config(
        vregs_per_vpu=cfg.vregs_per_vpu, vlen_bytes=cfg.vlen_bytes))
    s = drv.run(scen.requests())
    makespan = drv.session.now()
    mrep = rt.metrics_report() if cfg.metrics else {}
    conserved = (rt.metrics.stalls.conservation_ok() if cfg.metrics else True)
    return {
        "kind": "serving",
        "requests": s["requests"],
        "finished": s["finished"],
        "tokens": s["tokens_generated"],
        "steps": drv.steps_issued,
        "serial_cycles": None,
        "makespan": makespan,
        "tokens_per_kcycle": s["goodput_tokens_per_kcycle"],
        "ttft_p50": s["ttft_p50"],
        "ttft_p99": s["ttft_p99"],
        "queue_wait_p99": s["queue_wait_p99"],
        "verified": s["finished"] == s["requests"] and conserved,
        "conservation_ok": conserved,
        "stall_summary": stall_summary(mrep),
    }


# ----------------------------------------------------------------- workers
def run_point(spec: dict) -> dict:
    """Execute one point spec (``SweepPoint.to_spec`` shape) and return its
    row: identity (point id, labels, overrides), the config snapshot, and
    the verified metrics. Pure function of the spec — no wall-clock, no
    global state — so pool and in-process execution match bit-for-bit."""
    cfg = config_from_overrides(spec.get("base", "arcane-default"),
                                spec.get("overrides", {}))
    kind = scenario_kind(spec["scenario"])
    if kind == "model":
        row = _run_model_point(cfg, spec["scenario"])
    else:
        row = _run_serving_point(cfg, spec["scenario"])
    return {"point_id": spec["point_id"], "scenario": spec["scenario"],
            "labels": dict(spec.get("labels", {})),
            "overrides": dict(spec.get("overrides", {})),
            "config": _config_row(cfg), **row}


def run_points(specs: Sequence[dict], *, jobs: Optional[int] = None,
               in_process: bool = False) -> list[dict]:
    """Run every spec and return rows in spec order.

    ``in_process=True`` (or a single spec / ``jobs=1``) runs sequentially
    in the calling process; otherwise specs fan out over ``jobs`` worker
    processes (default: one per spec, capped at the CPU count)."""
    specs = list(specs)
    if in_process or jobs == 1 or len(specs) <= 1:
        return [run_point(s) for s in specs]
    workers = min(len(specs), jobs or os.cpu_count() or 1)
    with ProcessPoolExecutor(max_workers=workers) as ex:
        return list(ex.map(run_point, specs))
