"""Pareto-front extraction over sweep rows.

Objectives are ``(key, sense)`` pairs — ``("makespan", "min")``,
``("tokens_per_kcycle", "max")`` — evaluated on plain row mappings. A row
is *dominated* when some other row is at least as good on every objective
and strictly better on at least one; the front is the set of undominated
rows. The extraction is a pure filter (every row is compared against every
other), so the result is independent of input order — a property the tests
pin down, since a sweep's row order is an accident of worker scheduling
history even though this module always receives them in grid order.

Rows missing an objective value (``None``) are excluded from ranking: they
can neither dominate nor sit on the front (a serving row has no place in a
makespan front and vice versa).
"""
from __future__ import annotations

from typing import Optional, Sequence

__all__ = ["dominates", "pareto_front", "annotate_fronts"]

_SENSES = ("min", "max")


def _values(row: dict, objectives: Sequence[tuple]) -> Optional[tuple]:
    vals = []
    for key, sense in objectives:
        if sense not in _SENSES:
            raise ValueError(f"objective {key!r}: sense must be min|max, "
                             f"got {sense!r}")
        v = row.get(key)
        if v is None:
            return None
        vals.append(float(v) if sense == "min" else -float(v))
    return tuple(vals)


def dominates(a: dict, b: dict, objectives: Sequence[tuple]) -> bool:
    """True when ``a`` dominates ``b``: no worse on every objective and
    strictly better on at least one. Rows missing a value never dominate
    and are never dominated (they are outside the ranked set)."""
    va, vb = _values(a, objectives), _values(b, objectives)
    if va is None or vb is None:
        return False
    return all(x <= y for x, y in zip(va, vb)) and va != vb


def pareto_front(rows: Sequence[dict],
                 objectives: Sequence[tuple]) -> list[dict]:
    """The undominated subset of ``rows``, sorted by objective values (then
    ``point_id``) so the front reads monotonically along the trade-off
    curve regardless of input order. Duplicate-valued rows all survive —
    neither dominates the other."""
    ranked = [(r, _values(r, objectives)) for r in rows]
    ranked = [(r, v) for r, v in ranked if v is not None]
    front = [
        (r, v) for r, v in ranked
        if not any(all(x <= y for x, y in zip(w, v)) and w != v
                   for _q, w in ranked)
    ]
    front.sort(key=lambda rv: (rv[1], str(rv[0].get("point_id", ""))))
    return [r for r, _v in front]


def annotate_fronts(rows: Sequence[dict], objectives: Sequence[tuple],
                    *, id_key: str = "point_id") -> list[str]:
    """Mark every row in place: ``on_front`` (bool) and ``dominated_by``
    (IDs of the rows that dominate it, sorted) — the "why does this point
    lose" pointer next to its stall summary. Returns the front's IDs in
    trade-off order."""
    front_ids = [str(r.get(id_key)) for r in pareto_front(rows, objectives)]
    on_front = set(front_ids)
    for r in rows:
        if _values(r, objectives) is None:
            continue
        rid = str(r.get(id_key))
        r["on_front"] = rid in on_front
        r["dominated_by"] = sorted(
            str(q.get(id_key)) for q in rows if dominates(q, r, objectives))
    return front_ids
