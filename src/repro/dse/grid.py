"""Declarative sweep grids: axes of config overrides × scenarios → points.

A grid names a base config (builtin name, YAML path, or raw mapping), a set
of scenarios from the :mod:`repro.dse.scenarios` catalog, and ordered
**axes**. Each axis maps a human label to a flat mapping of dotted config
overrides (the :func:`repro.sim.config.apply_overrides` layer)::

    base: arcane-default
    scenarios: [cnn-small]
    axes:
      vpus:
        "2": {cache.n_vpus: 2}
        "4": {cache.n_vpus: 4}
      tile:
        flat: {pipeline.tiling.rows: 0, pipeline.tiling.cols: 0}
        4x16: {pipeline.tiling.rows: 4, pipeline.tiling.cols: 16}

:meth:`SweepGrid.expand` takes the cross product — every scenario × every
combination of one label per axis — merging the chosen override mappings
through :func:`repro.sim.config.merge_overrides`, so two axes writing the
same knob (or nested subtrees of one knob) raise :class:`ConfigError`
instead of silently racing. Point IDs are pure functions of the scenario
and the chosen labels in axis order (``cnn-small|vpus=2|tile=4x16``):
rerunning the same grid yields byte-identical IDs, which is what makes two
``BENCH_dse.json`` documents diffable.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional, Union

from repro.sim.config import (ConfigError, SimConfig, config_from_overrides,
                              merge_overrides)

__all__ = ["SweepGrid", "SweepPoint"]


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One expanded grid point: a scenario plus the merged overrides that
    turn the base config into this point's :class:`SimConfig`."""

    point_id: str
    scenario: str
    base: Union[str, dict]
    labels: tuple[tuple[str, str], ...]       # (axis, label), axis order
    overrides: tuple[tuple[str, Any], ...]    # merged dotted keys, sorted

    def overrides_dict(self) -> dict:
        return dict(self.overrides)

    def labels_dict(self) -> dict:
        return dict(self.labels)

    def config(self) -> SimConfig:
        return config_from_overrides(self.base, self.overrides_dict())

    def to_spec(self) -> dict:
        """Plain-data form handed to worker processes (and embedded in the
        BENCH rows — reruns can rebuild any point from its row alone)."""
        return {"point_id": self.point_id, "scenario": self.scenario,
                "base": self.base, "labels": self.labels_dict(),
                "overrides": self.overrides_dict()}

    @classmethod
    def from_spec(cls, spec: dict) -> "SweepPoint":
        return cls(point_id=spec["point_id"], scenario=spec["scenario"],
                   base=spec.get("base", "arcane-default"),
                   labels=tuple((k, str(v))
                                for k, v in spec.get("labels", {}).items()),
                   overrides=tuple(sorted(spec.get("overrides", {}).items())))


class SweepGrid:
    """A declarative design-space sweep: ``base`` × ``axes`` × ``scenarios``.

    ``axes`` is an ordered mapping ``{axis: {label: {dotted overrides}}}``;
    insertion order fixes both the cross-product nesting and the point-ID
    layout. Empty ``axes`` degenerates to one point per scenario (the base
    config itself)."""

    def __init__(self, base: Union[str, dict] = "arcane-default",
                 scenarios: tuple = ("cnn-small",),
                 axes: Optional[dict] = None):
        self.base = base
        self.scenarios = tuple(scenarios)
        self.axes: dict[str, dict[str, dict]] = {}
        if not self.scenarios:
            raise ConfigError("sweep grid needs at least one scenario")
        for axis, values in (axes or {}).items():
            if not isinstance(values, dict) or not values:
                raise ConfigError(
                    f"axis {axis!r} must be a non-empty mapping of "
                    f"label -> overrides, got {values!r}")
            labelled = {}
            for label, ov in values.items():
                if not isinstance(ov, dict):
                    raise ConfigError(
                        f"axis {axis!r} label {label!r}: overrides must be "
                        f"a mapping of dotted keys, got {ov!r}")
                labelled[str(label)] = dict(ov)
            self.axes[str(axis)] = labelled

    # -------------------------------------------------------------- specs
    @classmethod
    def from_dict(cls, raw: dict) -> "SweepGrid":
        raw = dict(raw)
        grid = cls(base=raw.pop("base", "arcane-default"),
                   scenarios=tuple(raw.pop("scenarios", ("cnn-small",))),
                   axes=raw.pop("axes", None))
        if raw:
            raise ConfigError(
                f"unknown grid keys: {sorted(raw)} "
                f"(expected base/scenarios/axes)")
        return grid

    @classmethod
    def from_yaml(cls, path: str) -> "SweepGrid":
        try:
            import yaml
        except ImportError as e:   # pragma: no cover - dev extra in CI
            raise ConfigError(
                "loading grid YAMLs requires pyyaml "
                "(pip install repro[dev])") from e
        with open(path) as f:
            raw = yaml.safe_load(f) or {}
        if not isinstance(raw, dict):
            raise ConfigError(f"{path}: grid top level must be a mapping")
        return cls.from_dict(raw)

    def to_dict(self) -> dict:
        return {"base": self.base, "scenarios": list(self.scenarios),
                "axes": {a: {l: dict(ov) for l, ov in vals.items()}
                         for a, vals in self.axes.items()}}

    # ---------------------------------------------------------- expansion
    def expand(self, validate: bool = True) -> list[SweepPoint]:
        """Cross-product the axes into concrete points (scenario-major,
        then axis insertion order — deterministic).

        ``validate=True`` additionally checks every point's scenario name
        against the catalog and builds its :class:`SimConfig` once, so a
        bad override fails at expansion with the point ID in hand, not
        minutes later inside a worker process."""
        axis_names = list(self.axes)
        choice_lists = [list(self.axes[a].items()) for a in axis_names]
        points: list[SweepPoint] = []
        for scenario in self.scenarios:
            for combo in itertools.product(*choice_lists):
                labels = tuple((a, label)
                               for a, (label, _ov) in zip(axis_names, combo))
                try:
                    merged = merge_overrides(
                        *(ov for _label, ov in combo), sources=axis_names)
                except ConfigError as e:
                    raise ConfigError(
                        f"grid point {self._point_id(scenario, labels)}: "
                        f"{e}") from e
                points.append(SweepPoint(
                    point_id=self._point_id(scenario, labels),
                    scenario=scenario, base=self.base, labels=labels,
                    overrides=tuple(sorted(merged.items()))))
        seen: dict[str, SweepPoint] = {}
        for p in points:
            if p.point_id in seen:
                raise ConfigError(f"duplicate point id {p.point_id!r} — "
                                  f"axis labels must be unique per axis")
            seen[p.point_id] = p
        if validate:
            from repro.dse.scenarios import scenario_kind
            for p in points:
                try:
                    scenario_kind(p.scenario)
                except KeyError as e:
                    raise ConfigError(f"{p.point_id}: {e.args[0]}") from e
                try:
                    p.config()
                except ConfigError as e:
                    raise ConfigError(f"{p.point_id}: {e}") from e
        return points

    @staticmethod
    def _point_id(scenario: str, labels: tuple) -> str:
        return "|".join([scenario, *(f"{a}={l}" for a, l in labels)])
