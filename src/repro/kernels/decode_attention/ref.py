"""Pure-jnp oracle for decode attention."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF


def decode_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
) -> jax.Array:
    """q: (B, Hkv, G, D); k, v: (B, Hkv, S, D); lengths: (B,) → (B, Hkv, G, D)."""
    b, hkv, g, d = q.shape
    s_len = k.shape[2]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    cols = jnp.arange(s_len)[None, None, None, :]
    ln = lengths.astype(jnp.int32)[:, None, None, None]
    mask = cols < ln
    if window is not None:
        mask = jnp.logical_and(mask, cols >= jnp.maximum(ln - window, 0))
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
