"""Jitted wrapper for decode attention: head grouping + backend selection."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("softcap", "scale", "window", "block_k", "backend", "interpret"),
)
def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    block_k: int = 512,
    backend: str = "pallas",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One-token GQA decode over a KV cache.

    q: (B, Hq, D); k, v: (B, Hkv, S, D); lengths: (B,) → (B, Hq, D).
    """
    b, hq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    if backend == "ref":
        out = decode_attention_ref(qg, k, v, lengths, softcap=softcap,
                                   scale=scale, window=window)
    else:
        out = decode_attention_pallas(qg, k, v, lengths, softcap=softcap,
                                      scale=scale, window=window,
                                      block_k=block_k, interpret=interpret)
    return out.reshape(b, hq, d)
