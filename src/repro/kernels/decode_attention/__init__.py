from repro.kernels.decode_attention.ops import *  # noqa: F401,F403
