"""Cache-resident decode attention Pallas kernel (single-token GQA decode).

The serving-side embodiment of ARCANE's near-memory idea: the KV cache is this
framework's "last-level cache", and decode attention is a complex instruction
executed *where the cache lives* — one fused sweep over cache pages with the
online-softmax state in VMEM. No gather, no concat, no head-broadcast
materialisation: the q-head group belonging to one KV head attends inside a
single program.

q: (B, Hkv, G, D)  — G = Hq / Hkv query heads per KV head,
k, v: (B, Hkv, S, D) — the cache, padded to the page multiple,
lengths: (B, 1) int32 — valid cache length per sequence (ragged batch).

Grid: (B, Hkv, pages); per-page blocks are skipped entirely once past the
sequence length (`pl.when`), so short sequences in a ragged batch cost only
their own pages — straggler mitigation at the kernel level.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, interpret_default, round_up


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, nkv: int, bk: int, scale: float,
                   softcap: Optional[float], window: Optional[int]):
    ik = pl.program_id(2)
    length = len_ref[0, 0]
    start = jnp.maximum(length - window, 0) if window is not None else 0

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(jnp.logical_and(ik * bk < length, (ik + 1) * bk > start))
    def _update():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = jnp.logical_and(cols < length, cols >= start)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nkv - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """q: (B, Hkv, G, D); k, v: (B, Hkv, S, D); lengths: (B,) → (B, Hkv, G, D)."""
    if interpret is None:
        interpret = interpret_default()
    b, hkv, g, d = q.shape
    _, _, s, _ = k.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bk = min(block_k, round_up(s, 8))
    sp = round_up(s, bk)
    if sp != s:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
    nkv = sp // bk
    lengths2d = lengths.reshape(b, 1).astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, nkv=nkv, bk=bk, scale=scale,
                               softcap=softcap, window=window)
    return pl.pallas_call(
        kernel,
        grid=(b, hkv, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, h, ik: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, ik: (bb, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, ik: (bb, h, ik, 0)),
            pl.BlockSpec((1, 1), lambda bb, h, ik: (bb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bb, h, ik: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, lengths2d)
