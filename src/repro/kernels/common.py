"""Shared helpers for the Pallas kernel suite.

All kernels target TPU (pl.pallas_call + explicit BlockSpec VMEM tiling) and
are validated on CPU in interpret mode. ``interpret_default()`` picks the mode
from the runtime backend so the same code path runs in both worlds; the
``REPRO_PALLAS_INTERPRET`` env var forces it either way.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

# TPU v5e hardware geometry the BlockSpecs are designed against.
MXU_DIM = 128          # systolic array is 128x128
VPU_LANES = 128        # vector unit lane count (8 sublanes x 128 lanes)
VMEM_BYTES = 128 * 2**20   # ~128 MiB of VMEM per core
HBM_BW = 819e9         # bytes/s
PEAK_BF16 = 197e12     # FLOP/s
ICI_BW = 50e9          # bytes/s/link


@functools.cache
def interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def pad_to(x: jax.Array, shape: tuple[int, ...], value=0) -> jax.Array:
    """Zero-pad trailing edges of ``x`` up to ``shape``."""
    pads = [(0, t - s) for s, t in zip(x.shape, shape)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads, constant_values=value)


def acc_dtype(dtype) -> jnp.dtype:
    """Accumulator type: int32 for integer datapaths, f32 otherwise (MXU)."""
    return jnp.int32 if jnp.issubdtype(jnp.dtype(dtype), jnp.integer) else jnp.float32


NEG_INF = float(-1e30)   # mask value that survives bf16 rounding
