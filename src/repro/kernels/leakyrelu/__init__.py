from repro.kernels.leakyrelu.ops import *  # noqa: F401,F403
