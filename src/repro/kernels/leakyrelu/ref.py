"""Pure-jnp oracle for xmk1 LeakyReLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def leakyrelu_ref(x: jax.Array, *, negative_slope: float = 0.01) -> jax.Array:
    neg = negative_slope * x.astype(jnp.float32)
    if jnp.issubdtype(x.dtype, jnp.integer):
        neg = jnp.round(neg)
    return jnp.where(x >= 0, x, neg.astype(x.dtype))
