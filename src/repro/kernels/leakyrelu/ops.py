"""Jitted wrapper for xmk1 LeakyReLU."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.leakyrelu.kernel import leakyrelu_pallas
from repro.kernels.leakyrelu.ref import leakyrelu_ref


@functools.partial(jax.jit, static_argnames=("negative_slope", "block",
                                             "backend", "interpret"))
def leakyrelu(
    x: jax.Array,
    *,
    negative_slope: float = 0.01,
    block: tuple[int, int] = (256, 256),
    backend: str = "pallas",
    interpret: Optional[bool] = None,
) -> jax.Array:
    if backend == "ref":
        return leakyrelu_ref(x, negative_slope=negative_slope)
    return leakyrelu_pallas(x, negative_slope=negative_slope, block=block,
                            interpret=interpret)
