"""xmk1 — LeakyReLU Pallas kernel (element-wise VPU micro-program)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default, pad_to, round_up


def _leakyrelu_kernel(x_ref, o_ref, *, negative_slope: float):
    x = x_ref[...]
    neg = negative_slope * x.astype(jnp.float32)
    if jnp.issubdtype(x.dtype, jnp.integer):
        neg = jnp.round(neg)
    o_ref[...] = jnp.where(x >= 0, x, neg.astype(x.dtype))


def leakyrelu_pallas(
    x: jax.Array,
    *,
    negative_slope: float = 0.01,
    block: tuple[int, int] = (256, 256),
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = interpret_default()
    m, n = x.shape
    bm = min(block[0], round_up(m, 8))
    bn = min(block[1], round_up(n, 128))
    mp, np_ = round_up(m, bm), round_up(n, bn)
    xp = pad_to(x, (mp, np_))
    out = pl.pallas_call(
        functools.partial(_leakyrelu_kernel, negative_slope=negative_slope),
        grid=(mp // bm, np_ // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xp)
    return out[:m, :n]
