"""Oracles for flash attention.

``attention_ref`` — naive O(S²)-memory reference (small-shape tests only).
``attention_chunked_ref`` — blocked online-softmax in pure jnp (lax.scan over
KV chunks). Numerically the flash algorithm itself; serves as (a) a second
oracle and (b) the production fallback on backends without Pallas (the CPU
dry-run lowers this one, keeping HLO buffers chunk-sized instead of S²).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF


def _mask(scores, q_offset, k_offset, kv_len, causal, window):
    sq, sk = scores.shape[-2], scores.shape[-1]
    rows = q_offset + jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 0)
    cols = k_offset + jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
    m = cols < kv_len
    if causal:
        m = jnp.logical_and(m, cols <= rows)
    if window is not None:
        m = jnp.logical_and(m, cols > rows - window)
    return jnp.where(m, scores, NEG_INF)


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: Optional[int] = None,
    softcap: Optional[float] = None, scale: Optional[float] = None,
    kv_len: Optional[int] = None,
) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    if kv_len is None:
        kv_len = skv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = _mask(s, 0, 0, kv_len, causal, window)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_chunked_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: Optional[int] = None,
    softcap: Optional[float] = None, scale: Optional[float] = None,
    kv_len: Optional[int] = None, chunk: int = 1024,
) -> jax.Array:
    """Blocked online-softmax attention in pure jnp; memory O(Sq · chunk)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    if kv_len is None:
        kv_len = skv
    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nkv = k.shape[2] // chunk
    kc = k.reshape(b, hkv, nkv, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nkv, chunk, d).transpose(2, 0, 1, 3, 4)

    # GQA without materialising the head repeat (a repeat across a
    # model-sharded head dim all-gathers the whole K/V — §Perf iteration 4):
    # q is viewed as (B, Hkv, group, Sq, D) and contracted against the
    # un-broadcast (B, Hkv, chunk, D).
    qf = (q.astype(jnp.float32) * scale).reshape(b, hkv, group, sq, d)

    def body(carry, xs):
        acc, m_prev, l_prev = carry
        idx, kb, vb = xs
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kb)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_off = idx * chunk
        rows = jax.lax.broadcasted_iota(jnp.int32, (sq, chunk), 0)
        cols = k_off + jax.lax.broadcasted_iota(jnp.int32, (sq, chunk), 1)
        msk = cols < kv_len
        if causal:
            msk = jnp.logical_and(msk, cols <= rows)
        if window is not None:
            msk = jnp.logical_and(msk, cols > rows - window)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = alpha * acc + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb)
        return (acc_new, m_new, l_new), None

    init = (
        jnp.zeros((b, hkv, group, sq, d), jnp.float32),
        jnp.full((b, hkv, group, sq, 1), NEG_INF, jnp.float32),
        jnp.zeros((b, hkv, group, sq, 1), jnp.float32),
    )
    (acc, _, l), _ = jax.lax.scan(
        body, init, (jnp.arange(nkv), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, hq, sq, d).astype(q.dtype)
