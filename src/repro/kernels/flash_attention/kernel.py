"""Flash attention Pallas kernel (prefill/training path).

The ARCANE principle applied to attention: score tiles, the online-softmax
state (m, l) and the output accumulator live in VMEM scratch for the entire
KV sweep — the S×S score matrix is never materialised in HBM. Supports:

  * causal masking (decoder self-attention),
  * sliding-window ("local") attention — gemma2's alternating local layers,
  * logit soft-capping — gemma2,
  * GQA: fewer KV heads than Q heads (the KV block index maps h → h // group),
  * KV-length masking for padded caches / cross-attention.

Grid: (batch, q_heads, q_blocks, kv_blocks), kv innermost so the scratch
carries across the sweep. Blocks that cannot contribute under the causal /
window structure are skipped with ``pl.when`` (no MACs, the dominant saving
for long sequences and small windows).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, interpret_default, round_up


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  nkv: int, bq: int, bk: int, scale: float,
                  causal: bool, window: Optional[int],
                  softcap: Optional[float], kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    k_start = ik * bk

    # --- structural block skip ------------------------------------------
    needed = k_start < kv_len                       # not entirely padding
    if causal:
        needed = jnp.logical_and(needed, k_start <= q_start + bq - 1)
    if window is not None:
        # col must be > row - window for some (row, col) in the tile
        needed = jnp.logical_and(needed, k_start + bk - 1 > q_start - window)

    @pl.when(needed)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)             # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols < kv_len
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        if window is not None:
            mask = jnp.logical_and(mask, cols > rows - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                           # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nkv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)               # fully-masked rows
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    kv_len: Optional[int] = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) → (B, Hq, Sq, D)."""
    if interpret is None:
        interpret = interpret_default()
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    if kv_len is None:
        kv_len = skv

    bq = min(block_q, round_up(sq, 8))
    bk = min(block_k, round_up(skv, 8))
    sq_p, skv_p = round_up(sq, bq), round_up(skv, bk)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    nq, nkv = sq_p // bq, skv_p // bk

    kernel = functools.partial(
        _flash_kernel, nkv=nkv, bq=bq, bk=bk, scale=scale, causal=causal,
        window=window, softcap=softcap, kv_len=kv_len)

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, iq, ik: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, iq, ik, g=group: (bb, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, iq, ik, g=group: (bb, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, h, iq, ik: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]
