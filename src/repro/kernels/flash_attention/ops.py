"""Jitted wrapper for flash attention with backend selection."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import (attention_chunked_ref,
                                               attention_ref)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "kv_len",
                     "block_q", "block_k", "backend", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    kv_len: Optional[int] = None,
    block_q: int = 256,
    block_k: int = 256,
    backend: str = "pallas",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) → (B, Hq, Sq, D)."""
    if backend == "ref":
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, kv_len=kv_len)
    if backend == "chunked":
        return attention_chunked_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_len=kv_len, chunk=block_k)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        kv_len=kv_len, block_q=block_q, block_k=block_k, interpret=interpret)
