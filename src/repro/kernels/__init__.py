"""Pallas TPU kernel suite — the xmnmc micro-programs + attention kernels.

Each kernel package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper), ref.py (pure-jnp oracle). Validated in interpret mode on CPU.
"""
from repro.kernels.gemm.ops import gemm
from repro.kernels.convlayer.ops import conv_layer
from repro.kernels.maxpool.ops import maxpool
from repro.kernels.leakyrelu.ops import leakyrelu
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.decode_attention.ops import decode_attention

__all__ = ["gemm", "conv_layer", "maxpool", "leakyrelu", "flash_attention",
           "decode_attention"]
