"""Jitted wrapper for xmk2 MaxPool."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.maxpool.kernel import maxpool_pallas
from repro.kernels.maxpool.ref import maxpool_ref


@functools.partial(jax.jit, static_argnames=("win", "stride", "block_rows",
                                             "backend", "interpret"))
def maxpool(
    x: jax.Array,
    *,
    win: int = 2,
    stride: Optional[int] = None,
    block_rows: int = 64,
    backend: str = "pallas",
    interpret: Optional[bool] = None,
) -> jax.Array:
    if backend == "ref":
        return maxpool_ref(x, win=win, stride=stride)
    return maxpool_pallas(x, win=win, stride=stride, block_rows=block_rows,
                          interpret=interpret)
