"""Pure-jnp oracle for xmk2 MaxPool."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def maxpool_ref(x: jax.Array, *, win: int = 2, stride: Optional[int] = None) -> jax.Array:
    stride = stride or win
    h, w = x.shape
    out_h = (h - win) // stride + 1
    out_w = (w - win) // stride + 1
    acc = None
    for di in range(win):
        for dj in range(win):
            sl = jax.lax.slice(
                x, (di, dj),
                (di + (out_h - 1) * stride + 1, dj + (out_w - 1) * stride + 1),
                (stride, stride))
            acc = sl if acc is None else jnp.maximum(acc, sl)
    return acc
