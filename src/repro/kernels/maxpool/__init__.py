from repro.kernels.maxpool.ops import *  # noqa: F401,F403
