"""xmk2 — MaxPool Pallas kernel (window, stride configurable)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import interpret_default


def _maxpool_kernel(x_ref, o_ref, *, win: int, stride: int, out_w: int):
    x = x_ref[...]
    bh = o_ref.shape[0]
    acc = None
    for di in range(win):
        for dj in range(win):
            sl = jax.lax.slice(
                x, (di, dj),
                (di + (bh - 1) * stride + 1, dj + (out_w - 1) * stride + 1),
                (stride, stride))
            acc = sl if acc is None else jnp.maximum(acc, sl)
    o_ref[...] = acc.astype(o_ref.dtype)


def maxpool_pallas(
    x: jax.Array,
    *,
    win: int = 2,
    stride: Optional[int] = None,
    block_rows: int = 64,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Max pooling over (H, W) with square window; x: (H, W)."""
    if interpret is None:
        interpret = interpret_default()
    stride = stride or win
    h, w = x.shape
    out_h = (h - win) // stride + 1
    out_w = (w - win) // stride + 1
    bh = min(block_rows, out_h)
    n_bands = -(-out_h // bh)
    in_band = (bh - 1) * stride + win
    needed_h = ((n_bands - 1) * bh + bh - 1) * stride + win
    if needed_h > h:
        pad = jnp.full((needed_h - h, w), jnp.iinfo(x.dtype).min
                       if jnp.issubdtype(x.dtype, jnp.integer)
                       else -jnp.inf, x.dtype)
        x = jnp.concatenate([x, pad], axis=0)

    out = pl.pallas_call(
        functools.partial(_maxpool_kernel, win=win, stride=stride, out_w=out_w),
        grid=(n_bands,),
        in_specs=[pl.BlockSpec((pl.Element(in_band), pl.Element(w)),
                               lambda r: (r * bh * stride, 0))],
        out_specs=pl.BlockSpec((bh, out_w), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((n_bands * bh, out_w), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
    return out[:out_h]
