from repro.kernels.gemm.ops import *  # noqa: F401,F403
