"""Pure-jnp oracle for the xmk0 GeMM kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import acc_dtype


def gemm_ref(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    out_dtype=None,
) -> jax.Array:
    acc = acc_dtype(jnp.result_type(a.dtype, b.dtype))
    if out_dtype is None:
        out_dtype = acc if acc == jnp.int32 else a.dtype
    out = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=acc
    )
    scaled = alpha != 1.0 or c is not None
    if alpha != 1.0:
        out = alpha * out.astype(jnp.float32)
    if c is not None:
        out = out.astype(jnp.float32) + beta * c.astype(jnp.float32)
    if jnp.issubdtype(jnp.dtype(out_dtype), jnp.integer) and scaled:
        out = jnp.round(out)
    return out.astype(out_dtype)
