"""xmk0 — GeMM Pallas kernel: D = alpha * (A @ B) + beta * C.

TPU mapping of ARCANE's flagship complex instruction. The VMEM residency
discipline the paper implements with cache-line vector registers appears here
as the accumulator scratch: each (bm, bn) output tile lives in VMEM across the
whole K-reduction (grid's innermost axis), so partial products never round-trip
to HBM, and the optional beta*C epilogue is fused into the final flush — one
instruction, one residency, exactly the xmk0 contract.

Block shapes default to MXU-aligned (128, 128, 128); int8 inputs accumulate in
int32 (the MXU's native int path), floats in f32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import acc_dtype, interpret_default


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int, alpha, beta,
                 has_c: bool, c_ref=None):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(k == nk - 1)
    def _flush():
        out = acc_ref[...]
        if alpha != 1.0:
            out = (alpha * out.astype(jnp.float32))
        if has_c:
            out = out.astype(jnp.float32) + beta * c_ref[...].astype(jnp.float32)
        if jnp.issubdtype(o_ref.dtype, jnp.integer):
            out = jnp.round(out.astype(jnp.float32)) if (alpha != 1.0 or has_c) else out
        o_ref[...] = out.astype(o_ref.dtype)


def gemm_pallas(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Raw tiled kernel; dims must already be multiples of the block shape."""
    if interpret is None:
        interpret = interpret_default()
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"gemm_pallas requires padded dims, got {(m, k, n)} with blocks "
        f"{(block_m, block_k, block_n)}")
    acc = acc_dtype(jnp.result_type(a.dtype, b.dtype))
    if out_dtype is None:
        out_dtype = acc if acc == jnp.int32 else a.dtype
    nk = k // block_k
    has_c = c is not None

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
    ]
    operands = [a, b]
    if has_c:
        in_specs.append(pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)))
        operands.append(c)

    def kernel(*refs):
        if has_c:
            a_ref, b_ref, c_ref, o_ref, acc_ref = refs
        else:
            a_ref, b_ref, o_ref, acc_ref = refs
            c_ref = None
        _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, nk=nk, alpha=alpha,
                     beta=beta, has_c=has_c, c_ref=c_ref)

    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), acc)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
