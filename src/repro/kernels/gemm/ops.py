"""Jitted public wrapper for xmk0 GeMM: padding, backend selection, batching."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import pad_to, round_up
from repro.kernels.gemm.kernel import gemm_pallas
from repro.kernels.gemm.ref import gemm_ref


@functools.partial(
    jax.jit,
    static_argnames=("alpha", "beta", "block_m", "block_n", "block_k",
                     "out_dtype", "backend", "interpret"),
)
def gemm(
    a: jax.Array,
    b: jax.Array,
    c: Optional[jax.Array] = None,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
    backend: str = "pallas",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """D = alpha * (A @ B) + beta * C, shapes (m, k) x (k, n) [+ (m, n)]."""
    if backend == "ref":
        return gemm_ref(a, b, c, alpha=alpha, beta=beta, out_dtype=out_dtype)
    m, k = a.shape
    _, n = b.shape
    bm = min(block_m, round_up(m, 8))
    bn = min(block_n, round_up(n, 128))
    bk = min(block_k, round_up(k, 128))
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    ap = pad_to(a, (mp, kp))
    bp = pad_to(b, (kp, np_))
    cp = pad_to(c, (mp, np_)) if c is not None else None
    out = gemm_pallas(ap, bp, cp, alpha=alpha, beta=beta, block_m=bm,
                      block_n=bn, block_k=bk, out_dtype=out_dtype,
                      interpret=interpret)
    return out[:m, :n]
