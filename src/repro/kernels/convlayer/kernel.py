"""xmk4 — fused 3-channel Conv-Layer Pallas kernel (conv → maxpool2×2 → ReLU).

The paper's showcase complex instruction: an entire CNN layer executed as ONE
offloaded instruction on cache-resident data. TPU adaptation (DESIGN.md §2):
the whole fusion runs inside a single ``pallas_call`` so the convolution
accumulator and the pooling intermediate never leave VMEM — the exact analogue
of never leaving the ARCANE LLC.

Layout: input (C, H, W), filters (F, C, KH, KW), output (F, H', W') with
H' = (H-KH+1)//2 (valid conv, 2×2/2 maxpool). The convolution is computed as
KH·KW shifted element-wise multiply-accumulates — a direct transcription of
the NM-Carus vector micro-program (per-row vector MACs), which on TPU maps to
full-width VPU lanes rather than an im2col GEMM; for the small filters the
instruction targets (3–7), shifted MACs beat im2col because no operand
duplication is materialised.

Grid: one program per output row-band per filter. The input band slice is
re-fetched per filter (cheap: it stays HBM→VMEM streamed), the accumulator is
a VMEM scratch of one band.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import acc_dtype, interpret_default


def _convlayer_kernel(x_ref, f_ref, o_ref, acc_ref, *, kh: int, kw: int,
                      negative_slope: float, out_h: int, out_w: int):
    """One (filter, row-band) program: conv rows [2*r0, 2*r0+2*bh+kh-1)."""
    conv_h = 2 * o_ref.shape[1]           # conv rows pooled into this band
    conv_w = 2 * out_w
    x = x_ref[...]                        # (C, band_in_h, W)
    f = f_ref[...]                        # (1, C, kh, kw) — this filter
    acc_ref[...] = jnp.zeros_like(acc_ref)
    for di in range(kh):
        for dj in range(kw):
            # (C, conv_h, conv_w) shifted window, MAC over channels.
            window = jax.lax.dynamic_slice(
                x, (0, di, dj), (x.shape[0], conv_h, conv_w))
            coef = f[0, :, di, dj][:, None, None].astype(acc_ref.dtype)
            acc_ref[...] += jnp.sum(window.astype(acc_ref.dtype) * coef, axis=0)
    acc = acc_ref[...]
    pooled = acc.reshape(o_ref.shape[1], 2, out_w, 2).max(axis=(1, 3))
    zero = jnp.zeros((), pooled.dtype)
    slope = jnp.asarray(negative_slope, jnp.float32)
    act = jnp.where(pooled >= zero, pooled,
                    (slope * pooled.astype(jnp.float32)).astype(pooled.dtype)
                    if not jnp.issubdtype(pooled.dtype, jnp.integer)
                    else jnp.round(slope * pooled.astype(jnp.float32)).astype(pooled.dtype))
    o_ref[0, ...] = act.astype(o_ref.dtype)


def conv_layer_pallas(
    x: jax.Array,
    f: jax.Array,
    *,
    negative_slope: float = 0.0,
    block_rows: int = 32,
    out_dtype=None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused conv(valid) + maxpool(2×2/2) + LeakyReLU.

    x: (C, H, W); f: (F, C, KH, KW) → (F, (H-KH+1)//2, (W-KW+1)//2).
    """
    if interpret is None:
        interpret = interpret_default()
    cch, h, w = x.shape
    nf, cf, kh, kw = f.shape
    assert cch == cf, (x.shape, f.shape)
    conv_h, conv_w = h - kh + 1, w - kw + 1
    out_h, out_w = conv_h // 2, conv_w // 2
    assert out_h > 0 and out_w > 0, "input smaller than pool window"
    acc = acc_dtype(x.dtype)
    if out_dtype is None:
        out_dtype = x.dtype

    bh = min(block_rows, out_h)
    # pad out_h to band multiple; input rows needed per band: 2*bh + kh - 1
    n_bands = -(-out_h // bh)
    padded_out_h = n_bands * bh
    in_band = 2 * bh + kh - 1
    # pad x rows so the last band's slice stays in range
    needed_h = 2 * padded_out_h + kh - 1
    if needed_h > h:
        x = jnp.pad(x, ((0, 0), (0, needed_h - h), (0, 0)))

    kernel = functools.partial(
        _convlayer_kernel, kh=kh, kw=kw, negative_slope=negative_slope,
        out_h=out_h, out_w=out_w)

    out = pl.pallas_call(
        kernel,
        grid=(nf, n_bands),
        in_specs=[
            # Overlapping input band (element indexing): all channels, rows
            # [2*r*bh, 2*r*bh + in_band), all cols. pl.Element lets the band
            # stride (2*bh) differ from the band height (2*bh + kh - 1).
            pl.BlockSpec(
                (pl.Element(cch), pl.Element(in_band), pl.Element(w)),
                lambda fi, r: (0, r * 2 * bh, 0),
            ),
            # one filter
            pl.BlockSpec((1, cch, kh, kw), lambda fi, r: (fi, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bh, out_w), lambda fi, r: (fi, r, 0)),
        out_shape=jax.ShapeDtypeStruct((nf, padded_out_h, out_w), out_dtype),
        scratch_shapes=[pltpu.VMEM((bh * 2, out_w * 2), acc)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, f)
    return out[:, :out_h, :]
