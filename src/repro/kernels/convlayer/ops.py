"""Jitted wrapper for the xmk4 fused conv layer."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.convlayer.kernel import conv_layer_pallas
from repro.kernels.convlayer.ref import conv_layer_ref


@functools.partial(
    jax.jit,
    static_argnames=("negative_slope", "block_rows", "out_dtype", "backend",
                     "interpret"),
)
def conv_layer(
    x: jax.Array,
    f: jax.Array,
    *,
    negative_slope: float = 0.0,
    block_rows: int = 32,
    out_dtype=None,
    backend: str = "pallas",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused conv(valid)+maxpool(2×2/2)+LeakyReLU — the xmk4 instruction.

    x: (C, H, W); f: (F, C, KH, KW) → (F, (H-KH+1)//2, (W-KW+1)//2).
    """
    if backend == "ref":
        return conv_layer_ref(x, f, negative_slope=negative_slope,
                              out_dtype=out_dtype)
    return conv_layer_pallas(x, f, negative_slope=negative_slope,
                             block_rows=block_rows, out_dtype=out_dtype,
                             interpret=interpret)
