"""Pure-jnp oracle for the xmk4 fused conv layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import acc_dtype


def conv_layer_ref(
    x: jax.Array,
    f: jax.Array,
    *,
    negative_slope: float = 0.0,
    out_dtype=None,
) -> jax.Array:
    """conv(valid) → maxpool 2×2/2 → LeakyReLU; x (C,H,W), f (F,C,KH,KW)."""
    cch, h, w = x.shape
    nf, cf, kh, kw = f.shape
    assert cch == cf
    acc = acc_dtype(x.dtype)
    if out_dtype is None:
        out_dtype = x.dtype
    conv_h, conv_w = h - kh + 1, w - kw + 1
    out = jnp.zeros((nf, conv_h, conv_w), acc)
    xl = x.astype(acc)
    fl = f.astype(acc)
    for di in range(kh):
        for dj in range(kw):
            window = xl[:, di : di + conv_h, dj : dj + conv_w]
            # (F, C, 1, 1) * (1, C, H', W') summed over C
            out = out + jnp.einsum("chw,fc->fhw", window, fl[:, :, di, dj])
    ph, pw = conv_h // 2, conv_w // 2
    pooled = out[:, : ph * 2, : pw * 2].reshape(nf, ph, 2, pw, 2).max(axis=(2, 4))
    neg = negative_slope * pooled.astype(jnp.float32)
    if jnp.issubdtype(jnp.dtype(out_dtype), jnp.integer):
        # Two's-complement truncation on register write-back (wrap, not
        # saturate) — go through int32 so the narrowing cast wraps like the
        # kernel's integer accumulator path does.
        neg = jnp.round(neg)
        act = jnp.where(pooled >= 0, pooled, neg.astype(acc))
        return act.astype(jnp.int32).astype(out_dtype)
    act = jnp.where(pooled >= 0, pooled.astype(jnp.float32), neg)
    return act.astype(out_dtype)
