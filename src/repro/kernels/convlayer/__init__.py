from repro.kernels.convlayer.ops import *  # noqa: F401,F403
