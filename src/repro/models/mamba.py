"""Mamba (S6 selective-state-space) block — Jamba's sequence mixer.

The SSM recurrence is the purest instance of the paper's principle in the LM
stack: the state (d_inner × d_state per channel) is a *resident* operand that
every token updates in place — compute lives where the state lives, nothing
is re-fetched. Training uses a chunked scan: `lax.scan` over chunks (state
materialised only at chunk boundaries, chunk body rematerialised in the
backward pass) with an associative scan inside the chunk.

Decode carries (conv_state, ssm_state) per layer — O(1) per token, which is
why Jamba is a `long_500k` architecture.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.engine import ArcaneEngine
from repro.models.layers import dense, dense_init, truncated_normal_init


def _dt_rank(cfg: ModelConfig) -> int:
    return cfg.mamba.dt_rank or -(-cfg.d_model // 16)


def mamba_init(key, cfg: ModelConfig) -> dict:
    mb = cfg.mamba
    d = cfg.d_model
    di = mb.expand * d
    dtr = _dt_rank(cfg)
    dt = cfg.pdtype
    keys = jax.random.split(key, 6)
    # S4D-real initialisation for A; dt bias init for softplus ∈ [1e-3, 0.1]
    a = jnp.broadcast_to(jnp.arange(1, mb.d_state + 1, dtype=jnp.float32),
                         (di, mb.d_state))
    dt_init = jnp.exp(jax.random.uniform(keys[4], (di,), jnp.float32)
                      * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "in_proj": dense_init(keys[0], d, 2 * di, dt),
        "conv_w": truncated_normal_init(keys[1], (mb.d_conv, di), dt, 0.5),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(keys[2], di, dtr + 2 * mb.d_state, dt),
        "dt_proj": dense_init(keys[3], dtr, di, dt,
                              scale=dtr ** -0.5, bias=False),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(keys[5], di, d, dt),
    }


def _ssm_inputs(engine, params, cfg, xz):
    """Common path: split, conv, and the selective (dt, B, C) projections."""
    mb = cfg.mamba
    di = mb.expand * cfg.d_model
    dtr = _dt_rank(cfg)
    x, z = jnp.split(xz, 2, axis=-1)
    return x, z, dtr, di


def _selective_terms(engine, params, cfg, x_conv):
    """x_conv: (B, L, di) → decay a, input contribution b, readout C, skip."""
    mb = cfg.mamba
    dtr = _dt_rank(cfg)
    proj = dense(engine, params["x_proj"], x_conv)
    dt_lat, bmat, cmat = jnp.split(
        proj, [dtr, dtr + mb.d_state], axis=-1)
    dt = jax.nn.softplus(
        dense(engine, params["dt_proj"], dt_lat).astype(jnp.float32)
        + params["dt_bias"])                                   # (B,L,di)
    a_cont = -jnp.exp(params["A_log"])                          # (di, ds)
    decay = jnp.exp(dt[..., None] * a_cont)                     # (B,L,di,ds)
    contrib = (dt * x_conv.astype(jnp.float32))[..., None] \
        * bmat.astype(jnp.float32)[..., None, :]                # (B,L,di,ds)
    return decay, contrib, cmat.astype(jnp.float32)


def _causal_conv(params, x, conv_state=None):
    """Depthwise causal conv along L. x: (B, L, di)."""
    w = params["conv_w"].astype(jnp.float32)                    # (K, di)
    kk = w.shape[0]
    xf = x.astype(jnp.float32)
    if conv_state is not None:
        xf = jnp.concatenate([conv_state, xf], axis=1)
    else:
        xf = jnp.pad(xf, ((0, 0), (kk - 1, 0), (0, 0)))
    out = sum(w[i] * xf[:, i : i + x.shape[1]] for i in range(kk))
    return (out + params["conv_b"].astype(jnp.float32)), xf[:, -(kk - 1):]


def mamba_forward(engine: ArcaneEngine, params: dict, cfg: ModelConfig,
                  x: jax.Array, h0=None) -> jax.Array:
    """Training/prefill forward; x: (B, S, d)."""
    mb = cfg.mamba
    b, s, _ = x.shape
    xz = dense(engine, params["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    x_conv, _ = _causal_conv(params, xi)
    x_conv = jax.nn.silu(x_conv).astype(x.dtype)
    decay, contrib, cmat = _selective_terms(engine, params, cfg, x_conv)

    chunk = min(mb.chunk, s)
    assert s % chunk == 0, (s, chunk)
    nchunks = s // chunk

    def chunk_body(h, xs):
        dec_c, con_c, cm_c = xs                                # (B,L,di,ds)...
        # associative scan within the chunk: (a, b) ∘ (a', b') = (aa', a'b+b')
        def combine(l, r):
            return l[0] * r[0], l[1] * r[0] + r[1]
        a_acc, b_acc = jax.lax.associative_scan(
            combine, (dec_c, con_c), axis=1)
        hs = a_acc * h[:, None] + b_acc                        # (B,L,di,ds)
        y = jnp.einsum("blds,bls->bld", hs, cm_c)
        return hs[:, -1], y

    decay = decay.reshape(b, nchunks, chunk, *decay.shape[2:]).swapaxes(0, 1)
    contrib = contrib.reshape(b, nchunks, chunk, *contrib.shape[2:]).swapaxes(0, 1)
    cmr = cmat.reshape(b, nchunks, chunk, -1).swapaxes(0, 1)
    init = h0 if h0 is not None else jnp.zeros(
        (b, decay.shape[3], mb.d_state), jnp.float32)
    h_last, ys = jax.lax.scan(jax.checkpoint(chunk_body), init,
                              (decay, contrib, cmr))
    y = ys.swapaxes(0, 1).reshape(b, s, -1)
    y = y + params["D"] * x_conv.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return dense(engine, params["out_proj"], y), h_last


def mamba_decode(engine: ArcaneEngine, params: dict, cfg: ModelConfig,
                 x: jax.Array, conv_state: jax.Array, ssm_state: jax.Array):
    """One-token step. x: (B, d); conv_state: (B, K-1, di);
    ssm_state: (B, di, ds)."""
    mb = cfg.mamba
    b, _ = x.shape
    xz = dense(engine, params["in_proj"], x[:, None, :])
    xi, z = jnp.split(xz, 2, axis=-1)
    x_conv, conv_state = _causal_conv(params, xi, conv_state)
    x_conv = jax.nn.silu(x_conv).astype(x.dtype)                # (B,1,di)
    decay, contrib, cmat = _selective_terms(engine, params, cfg, x_conv)
    h = decay[:, 0] * ssm_state + contrib[:, 0]                 # (B,di,ds)
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0])
    y = y + params["D"] * x_conv[:, 0].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z[:, 0])
    return dense(engine, params["out_proj"], y), conv_state, h
