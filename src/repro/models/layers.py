"""Foundational layers: norms, embeddings, rotary embeddings, dense dispatch.

All matrix multiplies flow through the ArcaneEngine (xmk0 dispatch) so the
paper's execution discipline is uniform across every architecture.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.engine import ArcaneEngine


def truncated_normal_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                jnp.float32)).astype(dtype)


# ----------------------------------------------------------------- norms
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale) parameterisation: zeros-init == identity
    return (normed * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def layernorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    normed = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_init, rmsnorm
    if kind == "layernorm":
        return layernorm_init, layernorm
    raise ValueError(kind)


# ----------------------------------------------------------------- dense
def dense_init(key, d_in: int, d_out: int, dtype, *, bias: bool = False,
               scale: Optional[float] = None) -> dict:
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    p = {"w": truncated_normal_init(key, (d_in, d_out), dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(engine: ArcaneEngine, params: dict, x: jax.Array) -> jax.Array:
    """xmk0 dispatch: out = x @ W (+ b, fused as the beta*C epilogue)."""
    b = params.get("b")
    if b is None:
        return engine.gemm(x, params["w"])
    c = jnp.broadcast_to(b, (*x.shape[:-1], b.shape[-1]))
    return engine.gemm(x, params["w"], c, alpha=1.0, beta=1.0)


# ------------------------------------------------------------- embeddings
def embedding_init(key, vocab: int, d: int, dtype) -> dict:
    return {"table": truncated_normal_init(key, (vocab, d), dtype, 0.02)}


def embed(params: dict, tokens: jax.Array, *, scale: bool = False) -> jax.Array:
    out = jnp.take(params["table"], tokens, axis=0)
    if scale:
        out = out * math.sqrt(out.shape[-1])
    return out


def unembed(engine: ArcaneEngine, params: dict, x: jax.Array,
            *, softcap: Optional[float] = None) -> jax.Array:
    logits = engine.gemm(x, params["table"].T, out_dtype=jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


# ------------------------------------------------------------------- rope
def rope_frequencies(head_dim: int, *, theta: float = 10000.0,
                     fraction: float = 1.0) -> jax.Array:
    rot_dim = int(head_dim * fraction) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                            / rot_dim))


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float = 10000.0,
               fraction: float = 1.0) -> jax.Array:
    """x: (B, H, S, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta=theta, fraction=fraction)
    rot = 2 * freqs.shape[0]
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # B,1,S,rot/2
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x_rot.shape)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass.astype(out.dtype)], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(max_len: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal position embeddings: (max_len, d)."""
    return sinusoidal_at(jnp.arange(max_len), d)


def sinusoidal_at(positions: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embedding rows for arbitrary positions: (*pos.shape, d)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / (half - 1))
    args = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]
