"""Model assembly: decoder-only LM (all families) and encoder-decoder (whisper).

Depth is executed as ``lax.scan`` over *periods* of the repeating layer
pattern (per-position parameter stacks with a leading ``n_periods`` axis), so
HLO size is independent of layer count — essential for the 62/64/72-layer
assigned configs — and the activation-checkpoint policy applies per period.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.engine import ArcaneEngine, default_engine
from repro.models import blocks as blk
from repro.models.layers import (embed, embedding_init, make_norm,
                                 sinusoidal_positions, unembed)

PyTree = Any


def _stack_init(key, n: int, init_fn):
    """Initialise ``n`` copies of a block and stack leaves on axis 0."""
    keys = jax.random.split(key, n)
    trees = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _index_tree(tree: PyTree, i):
    return jax.tree.map(lambda x: x[i], tree)


class LM:
    """Decoder-only (optionally enc-dec / vision-prefixed) language model."""

    def __init__(self, cfg: ModelConfig, engine: Optional[ArcaneEngine] = None,
                 *, remat: bool = True, unroll: bool = False):
        self.cfg = cfg
        self.engine = engine or default_engine()
        self.remat = remat
        # unroll=True replaces the period scan with a Python loop — used by
        # the dry-run's depth-extrapolation compiles (cost_analysis counts a
        # while-loop body once regardless of trip count).
        self.unroll = unroll

    def _scan(self, fn, carry, xs):
        if not self.unroll:
            return jax.lax.scan(fn, carry, xs)
        n = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(n):
            carry, y = fn(carry, jax.tree.map(lambda x, i=i: x[i], xs))
            ys.append(y)
        if all(y is None for y in ys):
            return carry, None
        return carry, jax.tree.map(lambda *zs: jnp.stack(zs), *ys)

    # ------------------------------------------------------------- params
    def init_params(self, key) -> PyTree:
        cfg = self.cfg
        ninit, _ = make_norm(cfg.norm)
        keys = jax.random.split(key, 8)
        params: dict[str, Any] = {
            "embed": embedding_init(keys[0], cfg.vocab, cfg.d_model,
                                    cfg.pdtype),
            "final_norm": ninit(cfg.d_model, cfg.pdtype),
        }
        cross = cfg.enc_dec
        params["blocks"] = tuple(
            _stack_init(
                jax.random.fold_in(keys[1], i), cfg.n_periods,
                functools.partial(blk.block_init, cfg=cfg, spec=spec,
                                  cross=cross))
            for i, spec in enumerate(cfg.pattern)
        )
        if cfg.enc_dec:
            from repro.configs.base import LayerSpec
            enc_spec = LayerSpec(kind="attn")
            params["enc_blocks"] = (
                _stack_init(keys[2], cfg.n_enc_layers,
                            functools.partial(blk.block_init, cfg=cfg,
                                              spec=enc_spec)),
            )
            params["enc_final_norm"] = ninit(cfg.d_model, cfg.pdtype)
        if not cfg.tie_embeddings:
            params["unembed"] = embedding_init(keys[3], cfg.vocab,
                                               cfg.d_model, cfg.pdtype)
        return params

    def param_shapes(self) -> PyTree:
        return jax.eval_shape(
            lambda k: self.init_params(k), jax.random.key(0))

    # ------------------------------------------------------------ forward
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"], scale=cfg.embed_scale)
        if cfg.vision_prefix:
            x = jnp.concatenate(
                [batch["vision_embeds"].astype(x.dtype), x], axis=1)
        if cfg.enc_dec:
            # whisper decoder uses absolute positions (rope_fraction = 0)
            pos = sinusoidal_positions(x.shape[1], cfg.d_model)
            x = x + pos[None].astype(x.dtype)
        return x.astype(cfg.cdtype)

    def _encoder(self, params, batch):
        cfg = self.cfg
        from repro.configs.base import LayerSpec
        x = batch["audio_embeds"].astype(cfg.cdtype)
        s = x.shape[1]
        pos_tab = sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
        x = x + pos_tab[None]
        positions = jnp.arange(s)
        enc_spec = LayerSpec(kind="attn")

        def period_fn(carry, bp):
            h = carry
            h, _ = blk.block_forward(self.engine, bp, cfg, enc_spec,
                                     h, positions, causal=False)
            return h, None

        fn = jax.checkpoint(period_fn) if self.remat else period_fn
        x, _ = self._scan(fn, x, params["enc_blocks"][0])
        _, napply = make_norm(cfg.norm)
        return napply(params["enc_final_norm"], x)

    def forward(self, params, batch) -> tuple[jax.Array, jax.Array]:
        """→ (logits (B, S, V) f32, moe_aux)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s)
        enc_out = self._encoder(params, batch) if cfg.enc_dec else None

        def period_fn(carry, bps):
            h, aux = carry
            for i, spec in enumerate(cfg.pattern):
                h, a = blk.block_forward(self.engine, bps[i], cfg, spec, h,
                                         positions, enc_out=enc_out)
                aux = aux + a
            return (h, aux), None

        fn = jax.checkpoint(period_fn) if self.remat else period_fn
        (x, aux), _ = self._scan(fn, (x, jnp.float32(0.0)), params["blocks"])
        _, napply = make_norm(cfg.norm)
        x = napply(params["final_norm"], x)
        table = params["unembed" if "unembed" in params else "embed"]
        logits = unembed(self.engine, table, x, softcap=cfg.final_softcap)
        if cfg.vision_prefix:
            logits = logits[:, cfg.vision_prefix:]
        return logits, aux

    def loss(self, params, batch) -> tuple[jax.Array, dict]:
        logits, aux = self.forward(params, batch)
        tokens = batch["tokens"]
        targets = tokens[:, 1:]
        lg = logits[:, :-1]
        mask = batch.get("loss_mask")
        mask = mask[:, 1:] if mask is not None else jnp.ones_like(
            targets, jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mask
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = nll.sum() / denom
        total = ce + aux
        return total, {"ce": ce, "aux": aux,
                       "tokens": denom}

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, max_len: int, *, dtype=None,
                   enc_len: int = 0) -> tuple:
        cfg = self.cfg
        dtype = dtype or cfg.cdtype

        def one(spec):
            def mk(i):
                return blk.init_block_cache(cfg, spec, batch, max_len, dtype,
                                            cross_len=enc_len)
            return jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[mk(i) for i in range(cfg.n_periods)])

        return tuple(one(spec) for spec in cfg.pattern)

    def cache_shapes(self, batch: int, max_len: int, *, dtype=None,
                     enc_len: int = 0):
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len, dtype=dtype,
                                    enc_len=enc_len))

    def prefill(self, params, batch, cache) -> tuple[jax.Array, tuple]:
        """Process the full prompt; returns (last-position logits, cache)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s)
        enc_out = self._encoder(params, batch) if cfg.enc_dec else None

        def period_fn(h, xs):
            bps, caches = xs
            new_caches = []
            for i, spec in enumerate(cfg.pattern):
                h, c = blk.block_prefill(self.engine, bps[i], cfg, spec, h,
                                         positions, caches[i],
                                         enc_out=enc_out)
                new_caches.append(c)
            return h, tuple(new_caches)

        x, cache = self._scan(period_fn, x, (params["blocks"], cache))
        _, napply = make_norm(cfg.norm)
        x = napply(params["final_norm"], x[:, -1:])
        table = params["unembed" if "unembed" in params else "embed"]
        logits = unembed(self.engine, table, x, softcap=cfg.final_softcap)
        return logits[:, 0], cache

    def decode_step(self, params, tokens: jax.Array, position: jax.Array,
                    cache: tuple, *, enc_len: int = 0):
        """tokens: (B,) int32; position: (B,) → (logits (B, V), cache)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens, scale=cfg.embed_scale)
        if cfg.enc_dec:
            from repro.models.layers import sinusoidal_at
            x = x + sinusoidal_at(position, cfg.d_model).astype(x.dtype)
        x = x.astype(cfg.cdtype)

        def period_fn(h, xs):
            bps, caches = xs
            new_caches = []
            for i, spec in enumerate(cfg.pattern):
                h, c = blk.block_decode(self.engine, bps[i], cfg, spec, h,
                                        position, caches[i],
                                        enc_len=enc_len or None)
                new_caches.append(c)
            return h, tuple(new_caches)

        x, cache = self._scan(period_fn, x, (params["blocks"], cache))
        _, napply = make_norm(cfg.norm)
        x = napply(params["final_norm"], x)
        table = params["unembed" if "unembed" in params else "embed"]
        logits = unembed(self.engine, table, x, softcap=cfg.final_softcap)
        return logits, cache
