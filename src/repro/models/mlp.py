"""Gated (SwiGLU) and classic 2-layer MLPs — all GeMMs via xmk0 dispatch."""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.core.engine import ArcaneEngine
from repro.distributed.sharding import constrain
from repro.models.layers import activation, dense, dense_init


def mlp_init(key, cfg: ModelConfig, *, d_ff: int = 0) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = cfg.pdtype
    if cfg.act == "gelu" and cfg.enc_dec:
        # whisper-style classic 2-layer MLP
        k1, k2 = jax.random.split(key)
        return {"up": dense_init(k1, d, ff, dt, bias=True),
                "down": dense_init(k2, ff, d, dt, bias=True)}
    kg, ku, kd = jax.random.split(key, 3)
    return {"gate": dense_init(kg, d, ff, dt),
            "up": dense_init(ku, d, ff, dt),
            "down": dense_init(kd, ff, d, dt)}


def mlp(engine: ArcaneEngine, params: dict, cfg: ModelConfig,
        x: jax.Array) -> jax.Array:
    act = activation(cfg.act)
    if "gate" not in params:
        h = act(dense(engine, params["up"], x))
        h = constrain(h, "batch", None, "model")
        return dense(engine, params["down"], h)
    g = act(dense(engine, params["gate"], x))
    u = dense(engine, params["up"], x)
    h = constrain(g * u, "batch", None, "model")
    return dense(engine, params["down"], h)
