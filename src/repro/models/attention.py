"""GQA attention (train forward, prefill-with-cache, single-token decode).

Projections run through the ArcaneEngine xmk0 dispatch; score/AV compute goes
through the flash-attention "complex instruction" (prefill) or the
cache-resident decode kernel (serving) — the near-memory principle applied to
the KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.engine import ArcaneEngine
from repro.distributed.sharding import constrain
from repro.models.layers import apply_rope, dense, dense_init


def attention_init(key, cfg: ModelConfig, *, cross: bool = False) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = cfg.pdtype
    return {
        "q": dense_init(kq, d, cfg.n_heads * hd, dt, bias=cfg.qkv_bias),
        "k": dense_init(kk, d, cfg.n_kv_heads * hd, dt, bias=cfg.qkv_bias),
        "v": dense_init(kv, d, cfg.n_kv_heads * hd, dt, bias=cfg.qkv_bias),
        "o": dense_init(ko, cfg.n_heads * hd, d, dt),
    }


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    b, s, _ = x.shape
    out = x.reshape(b, s, n, -1).transpose(0, 2, 1, 3)   # (B, H, S, D)
    return constrain(out, "batch", "model", None, None)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def attention_forward(
    engine: ArcaneEngine,
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    window: Optional[int] = None,
    kv_override: Optional[tuple[jax.Array, jax.Array]] = None,
    causal: bool = True,
) -> jax.Array:
    """Training/prefill forward. x: (B, S, d). kv_override: cross-attention."""
    q = _split_heads(dense(engine, params["q"], x), cfg.n_heads)
    if kv_override is None:
        k = _split_heads(dense(engine, params["k"], x), cfg.n_kv_heads)
        v = _split_heads(dense(engine, params["v"], x), cfg.n_kv_heads)
        q = apply_rope(q, positions, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
        k = apply_rope(k, positions, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
    else:
        k, v = kv_override
    out = engine.attention(q, k, v, causal=causal, window=window,
                           softcap=cfg.attn_softcap)
    return dense(engine, params["o"], _merge_heads(out))


def attention_prefill(
    engine: ArcaneEngine,
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    *,
    window: Optional[int] = None,
    ring: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill: forward + write K/V into the cache at [0, S).

    Ring mode (window-sized cache for local layers, §Perf iteration 5): only
    the last ``window`` rows are kept, placed at slot ``pos % window`` — a
    static permutation because S and window are static.
    """
    b, s, _ = x.shape
    q = _split_heads(dense(engine, params["q"], x), cfg.n_heads)
    k = _split_heads(dense(engine, params["k"], x), cfg.n_kv_heads)
    v = _split_heads(dense(engine, params["v"], x), cfg.n_kv_heads)
    q = apply_rope(q, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    k = apply_rope(k, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    out = engine.attention(q, k, v, causal=True, window=window,
                           softcap=cfg.attn_softcap)
    if ring:
        w = cache_k.shape[2]
        keep = min(w, s)
        pos_tail = jnp.arange(s - keep, s)
        slots = pos_tail % w                      # static permutation
        cache_k = cache_k.at[:, :, slots, :].set(
            k[:, :, s - keep:, :].astype(cache_k.dtype))
        cache_v = cache_v.at[:, :, slots, :].set(
            v[:, :, s - keep:, :].astype(cache_v.dtype))
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, 0, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, 0, 0, 0))
    return dense(engine, params["o"], _merge_heads(out)), cache_k, cache_v


def attention_decode(
    engine: ArcaneEngine,
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    position: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    *,
    window: Optional[int] = None,
    ring: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, d); position: (B,) current index.

    The new K/V row is written into the cache, then the decode kernel sweeps
    the cache in place. Sliding-window layers bound the sweep length via the
    kv length argument (cache is ring-buffered by the serving layer).
    """
    b, d = x.shape
    hd = cfg.resolved_head_dim
    q = dense(engine, params["q"], x[:, None, :])           # (B,1,Hq*hd)
    k = dense(engine, params["k"], x[:, None, :])
    v = dense(engine, params["v"], x[:, None, :])
    q = _split_heads(q, cfg.n_heads)                         # (B,Hq,1,hd)
    k = _split_heads(k, cfg.n_kv_heads)
    q = apply_rope(q, position[:, None], theta=cfg.rope_theta,
                   fraction=cfg.rope_fraction)
    k = apply_rope(k, position[:, None], theta=cfg.rope_theta,
                   fraction=cfg.rope_fraction)
    v = _split_heads(v, cfg.n_kv_heads)

    # scatter the new row at per-sequence positions (ring: pos % window —
    # the ring holds exactly the window, so no extra masking is needed and
    # the softmax is order-independent)
    w = cache_k.shape[2]
    slot = position % w if ring else position

    def put(cache, new):
        # cache: (B, Hkv, S, hd); new: (B, Hkv, 1, hd)
        return jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (0, p, 0))
        )(cache, new.astype(cache.dtype), slot)

    cache_k = put(cache_k, k)
    cache_v = put(cache_v, v)
    lengths = jnp.minimum(position + 1, w) if ring else position + 1
    out = engine.decode_attention(q[:, :, 0, :], cache_k, cache_v, lengths,
                                  softcap=cfg.attn_softcap,
                                  window=None if ring else window)  # (B,Hq,hd)
    out = dense(engine, params["o"], out.reshape(b, cfg.n_heads * hd))
    return out, cache_k, cache_v
