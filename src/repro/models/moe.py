"""Top-k routed Mixture-of-Experts — grouped, capacity-based dispatch (GShard).

TPU/pjit-native formulation (§Perf iterations 1–3, EXPERIMENTS.md):

  * tokens are processed in **groups** (G groups of S_g tokens; groups align
    with the data-parallel sharding), and every scatter/gather of the
    dispatch is **group-local** — under SPMD these partition cleanly with no
    cross-device index traffic (the naive flat scatter all-gathered a
    u32[T·k, d] index tensor and all-reduced the full dispatched buffer every
    layer: measured 1.4 TiB/device/step on granite train_4k);
  * the only cross-device exchange is the (G ↔ E) transpose of the dispatched
    buffer — the canonical MoE all-to-all (data axis ↔ model/expert axis);
  * position-in-expert uses sort-based ranking (stable argsort), O(n log n):
    the one-hot cumsum it replaces lowered to a quadratic prefix-sum
    (~100× HLO-flop inflation, §Perf iteration 1);
  * expert FFN runs as grouped GeMMs ``(E, G·C, d) @ (E, d, ff)`` — xmk0 per
    expert; experts shard over the model axis (the paper's multi-VPU
    dispatch).

Tokens beyond an expert's per-group capacity are dropped (capacity_factor);
the Switch/GShard load-balancing auxiliary loss is returned for training.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.engine import ArcaneEngine
from repro.distributed.sharding import constrain
from repro.models.layers import activation, dense_init, truncated_normal_init

# Target tokens per dispatch group; groups align with data shards.
GROUP_TOKENS = 8192


def moe_init(key, cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    e = cfg.moe.n_experts
    dt = cfg.pdtype
    kr, kg, ku, kd = jax.random.split(key, 4)
    scale = 1.0 / (d ** 0.5)
    return {
        "router": dense_init(kr, d, e, jnp.float32),
        "gate": truncated_normal_init(kg, (e, d, ff), dt, scale),
        "up": truncated_normal_init(ku, (e, d, ff), dt, scale),
        "down": truncated_normal_init(kd, (e, ff, d), dt, 1.0 / (ff ** 0.5)),
    }


def _group_dispatch(xt, expert_ids, gate_vals, e: int, cap: int):
    """Group-local dispatch. xt: (S_g, d); ids/gates: (S_g, k).

    Returns (dispatched (E·cap, d), flat_idx (S_g·k,), keep, slot_gate).
    """
    k = expert_ids.shape[-1]
    s_g = xt.shape[0]
    slot_expert = expert_ids.reshape(-1)
    slot_gate = gate_vals.reshape(-1)
    n_slots = s_g * k
    order = jnp.argsort(slot_expert, stable=True)
    sorted_e = jnp.take(slot_expert, order)
    group_start = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos_sorted = jnp.arange(n_slots) - jnp.take(group_start, sorted_e)
    slot_pos = jnp.zeros((n_slots,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    keep = slot_pos < cap
    flat_idx = jnp.where(keep, slot_expert * cap + slot_pos, e * cap)
    token_of_slot = jnp.repeat(jnp.arange(s_g), k)
    dispatched = jnp.zeros((e * cap + 1, xt.shape[1]), xt.dtype).at[
        flat_idx].set(jnp.take(xt, token_of_slot, axis=0), mode="drop")
    return dispatched[: e * cap], flat_idx, keep, slot_gate


def _group_combine(y, flat_idx, keep, slot_gate, k: int):
    """Inverse of _group_dispatch. y: (E·cap, d) → (S_g, d)."""
    e_cap = y.shape[0]
    gathered = jnp.where(
        keep[:, None], jnp.take(y, flat_idx.clip(0, e_cap - 1), axis=0), 0.0)
    weighted = gathered * slot_gate[:, None].astype(gathered.dtype)
    s_g = flat_idx.shape[0] // k
    return jnp.sum(weighted.reshape(s_g, k, -1), axis=1)


def moe(engine: ArcaneEngine, params: dict, cfg: ModelConfig,
        x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (out, aux_loss)."""
    b, s, d = x.shape
    mcfg = cfg.moe
    e, k = mcfg.n_experts, mcfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    # ---- router (f32 for numerical stability of the softmax) -------------
    logits = jnp.dot(xt.astype(jnp.float32), params["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)            # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- load-balancing aux loss (Switch/GShard) --------------------------
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=1),
        axis=0)
    aux = e * jnp.sum(me * ce) * mcfg.router_aux_coef

    # ---- grouped dispatch --------------------------------------------------
    g = max(1, t // GROUP_TOKENS)
    while t % g:           # g must divide T; shrink to the nearest divisor
        g -= 1
    s_g = t // g
    cap = int(mcfg.capacity_factor * s_g * k / e) + 1
    xg = xt.reshape(g, s_g, d)
    idsg = expert_ids.reshape(g, s_g, k)
    gatesg = gate_vals.reshape(g, s_g, k)
    dispatched, flat_idx, keep, slot_gate = jax.vmap(
        lambda xx, ii, gg: _group_dispatch(xx, ii, gg, e, cap))(
            xg, idsg, gatesg)                       # (G, E·cap, d), ...
    # (G, E, cap, d) → (E, G·cap, d): the MoE all-to-all (data ↔ experts)
    xe = dispatched.reshape(g, e, cap, d).swapaxes(0, 1).reshape(e, g * cap, d)
    xe = constrain(xe, "model", "batch", None)

    # ---- grouped expert SwiGLU (xmk0 per expert) ---------------------------
    act = activation(cfg.act)
    gg_ = act(jnp.einsum("ecd,edf->ecf", xe, params["gate"],
                         preferred_element_type=jnp.float32))
    uu = jnp.einsum("ecd,edf->ecf", xe, params["up"],
                    preferred_element_type=jnp.float32)
    y = jnp.einsum("ecf,efd->ecd", (gg_ * uu).astype(xe.dtype),
                   params["down"],
                   preferred_element_type=jnp.float32).astype(xt.dtype)
    y = constrain(y, "model", "batch", None)

    # ---- combine (inverse all-to-all + group-local gather) -----------------
    yg = y.reshape(e, g, cap, d).swapaxes(0, 1).reshape(g, e * cap, d)
    out = jax.vmap(lambda yy, fi, kp, sg: _group_combine(yy, fi, kp, sg, k))(
        yg, flat_idx, keep, slot_gate)              # (G, S_g, d)
    return out.reshape(b, s, d).astype(x.dtype), aux.astype(jnp.float32)
