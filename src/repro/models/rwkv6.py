"""RWKV-6 ("Finch") block — attention-free time mixing with data-dependent decay.

Per head (size N): state S ∈ R^{N×N} evolves as

    S_t[j, :] = w_t[j] · S_{t-1}[j, :] + k_t[j] · v_t[:]
    y_t[:]    = Σ_j r_t[j] · (S_{t-1}[j, :] + u[j] · k_t[j] · v_t[:])

with the v6 signature feature: the decay w_t is *data-dependent* through a
low-rank MLP (w0 + tanh(x_w A) B). Token-shift mixing uses static lerp
coefficients (the full ddlerp of the reference implementation is a second
low-rank mix; simplification noted in DESIGN.md — the state-space semantics
and decay data-dependence are preserved).

Like Mamba, the resident-state update is the near-memory pattern: O(1) state
per token, no KV cache — the reason rwkv6 runs the 500k-decode shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.engine import ArcaneEngine
from repro.models.layers import dense, dense_init, truncated_normal_init


def rwkv_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    r = cfg.rwkv
    n_heads = d // r.head_size
    dt = cfg.pdtype
    keys = jax.random.split(key, 10)
    return {
        # time-mix lerp coefficients for r, k, v, g, w
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),
        "r": dense_init(keys[0], d, d, dt),
        "k": dense_init(keys[1], d, d, dt),
        "v": dense_init(keys[2], d, d, dt),
        "g": dense_init(keys[3], d, d, dt),
        "o": dense_init(keys[4], d, d, dt),
        # data-dependent decay lora: w = w0 + tanh(x_w @ A) @ B
        "w0": -6.0 * jnp.ones((d,), jnp.float32),
        "wA": truncated_normal_init(keys[5], (d, r.decay_lora), dt, 0.02),
        "wB": truncated_normal_init(keys[6], (r.decay_lora, d), dt, 0.02),
        "u": truncated_normal_init(keys[7], (d,), jnp.float32, 0.5),
        "ln_scale": jnp.ones((n_heads, r.head_size), jnp.float32),
        # channel mixing
        "cm_mu": 0.5 * jnp.ones((2, d), jnp.float32),
        "cm_k": dense_init(keys[8], d, cfg.d_ff, dt),
        "cm_v": dense_init(keys[9], cfg.d_ff, d, dt),
        "cm_r": dense_init(jax.random.fold_in(key, 11), d, d, dt),
    }


def _shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """Token shift: x_{t-1} (zeros / carried `last` for t = 0)."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _mix(x, prev, mu):
    return x + (prev - x) * mu.astype(x.dtype)


def _wkv_terms(engine, params, cfg, x, prev):
    """Projections for the wkv scan. x, prev: (B, L, d)."""
    r = cfg.rwkv
    n = r.head_size
    b, s, d = x.shape
    h = d // n
    mu = params["mu"]
    xr = _mix(x, prev, mu[0]); xk = _mix(x, prev, mu[1])
    xv = _mix(x, prev, mu[2]); xg = _mix(x, prev, mu[3])
    xw = _mix(x, prev, mu[4])
    rr = dense(engine, params["r"], xr).reshape(b, s, h, n)
    kk = dense(engine, params["k"], xk).reshape(b, s, h, n)
    vv = dense(engine, params["v"], xv).reshape(b, s, h, n)
    gg = jax.nn.silu(dense(engine, params["g"], xg))
    w_lat = jnp.tanh(engine.gemm(xw, params["wA"]))
    w = params["w0"] + engine.gemm(w_lat, params["wB"]).astype(jnp.float32)
    decay = jnp.exp(-jnp.exp(w)).reshape(b, s, h, n)            # (0,1)
    return rr.astype(jnp.float32), kk.astype(jnp.float32), \
        vv.astype(jnp.float32), gg, decay


def _groupnorm(params, y):
    """Per-head layer norm of the wkv output. y: (B, L, H, N)."""
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    return (y - mu) * jax.lax.rsqrt(var + 64e-5) * params["ln_scale"]


def rwkv_time_mix(engine: ArcaneEngine, params: dict, cfg: ModelConfig,
                  x: jax.Array, state=None, last_x=None):
    """x: (B, S, d) → (out, final_state, final_x). Chunked scan over time."""
    r = cfg.rwkv
    n = r.head_size
    b, s, d = x.shape
    h = d // n
    prev = _shift(x, last_x)
    rr, kk, vv, gg, decay = _wkv_terms(engine, params, cfg, x, prev)
    u = params["u"].reshape(h, n)

    chunk = min(r.chunk, s)
    assert s % chunk == 0
    nchunks = s // chunk

    def chunk_body(S, xs):
        rc, kc, vc, wc = xs                                     # (B,L,H,N)

        def step(Sh, ts):
            rt, kt, vt, wt = ts                                  # (B,H,N)
            kv = kt[..., :, None] * vt[..., None, :]             # (B,H,N,N)
            yt = jnp.einsum("bhj,bhjn->bhn", rt, Sh + u[..., None] * kv)
            Sh = wt[..., None] * Sh + kv
            return Sh, yt

        S, ys = jax.lax.scan(step, S,
                             (rc.swapaxes(0, 1), kc.swapaxes(0, 1),
                              vc.swapaxes(0, 1), wc.swapaxes(0, 1)))
        return S, ys.swapaxes(0, 1)                              # (B,L,H,N)

    def to_chunks(t):
        return t.reshape(b, nchunks, chunk, h, n).swapaxes(0, 1)

    init = state if state is not None else jnp.zeros((b, h, n, n), jnp.float32)
    S_last, ys = jax.lax.scan(
        jax.checkpoint(chunk_body), init,
        (to_chunks(rr), to_chunks(kk), to_chunks(vv), to_chunks(decay)))
    y = ys.swapaxes(0, 1).reshape(b, s, h, n)
    y = _groupnorm(params, y).reshape(b, s, d).astype(x.dtype) * gg
    return dense(engine, params["o"], y), S_last, x[:, -1]


def rwkv_channel_mix(engine: ArcaneEngine, params: dict, cfg: ModelConfig,
                     x: jax.Array, last_x=None):
    prev = _shift(x, last_x)
    mu = params["cm_mu"]
    xk = _mix(x, prev, mu[0])
    xr = _mix(x, prev, mu[1])
    k = jnp.square(jax.nn.relu(dense(engine, params["cm_k"], xk)))
    kv = dense(engine, params["cm_v"], k)
    return jax.nn.sigmoid(dense(engine, params["cm_r"], xr)) * kv, x[:, -1]


def rwkv_time_mix_decode(engine: ArcaneEngine, params: dict, cfg: ModelConfig,
                         x: jax.Array, state: jax.Array, last_x: jax.Array):
    """One-token time mix. x: (B, d); state: (B, H, N, N); last_x: (B, d)."""
    r = cfg.rwkv
    n = r.head_size
    b, d = x.shape
    h = d // n
    rr, kk, vv, gg, decay = _wkv_terms(engine, params, cfg, x[:, None, :],
                                       last_x[:, None, :])
    u = params["u"].reshape(h, n)
    rt, kt, vt, wt = rr[:, 0], kk[:, 0], vv[:, 0], decay[:, 0]
    kv = kt[..., :, None] * vt[..., None, :]
    yt = jnp.einsum("bhj,bhjn->bhn", rt, state + u[..., None] * kv)
    state = wt[..., None] * state + kv
    y = _groupnorm(params, yt[:, None]).reshape(b, 1, d).astype(x.dtype) * gg
    return dense(engine, params["o"], y)[:, 0], state, x
