"""Layer blocks: norm/residual wiring around the sequence mixers + FFN/MoE.

A block is one position in the config's repeating layer pattern. Three entry
points per block — forward (train), prefill (cache write), decode (one token,
cache read/update) — each dispatching on LayerSpec.kind. The per-kind cache
pytrees are defined here so the serving layer and the launcher agree on
shapes.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.core.engine import ArcaneEngine
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import mla as mla_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import make_norm
from repro.models.mlp import mlp, mlp_init
from repro.models.moe import moe, moe_init


def _norm(cfg):
    return make_norm(cfg.norm)


def block_init(key, cfg: ModelConfig, spec: LayerSpec, *,
               cross: bool = False) -> dict:
    ninit, _ = _norm(cfg)
    d = cfg.d_model
    dt = cfg.pdtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": ninit(d, dt)}
    if spec.kind in ("attn", "attn_local"):
        p["attn"] = attn.attention_init(k1, cfg)
    elif spec.kind == "mla":
        p["attn"] = mla_mod.mla_init(k1, cfg)
    elif spec.kind == "mamba":
        p["mixer"] = mam.mamba_init(k1, cfg)
    elif spec.kind == "rwkv":
        p["mixer"] = rwkv_mod.rwkv_init(k1, cfg)
        p["ln2"] = ninit(d, dt)
        return p  # rwkv carries its own channel-mix FFN
    else:
        raise ValueError(spec.kind)
    if cross:
        p["cross_ln"] = ninit(d, dt)
        p["cross"] = attn.attention_init(k4, cfg)
    p["ln2"] = ninit(d, dt)
    p["ffn"] = moe_init(k2, cfg) if spec.moe else mlp_init(k3, cfg)
    return p


def _ffn_apply(engine, params, cfg, spec, x):
    if spec.moe:
        return moe(engine, params["ffn"], cfg, x)
    return mlp(engine, params["ffn"], cfg, x), jnp.float32(0.0)


def block_forward(engine: ArcaneEngine, params: dict, cfg: ModelConfig,
                  spec: LayerSpec, x: jax.Array, positions: jax.Array, *,
                  causal: bool = True,
                  enc_out: Optional[jax.Array] = None) -> tuple[jax.Array, jax.Array]:
    """Returns (x, moe_aux_loss)."""
    _, napply = _norm(cfg)
    x = constrain(x, "batch", None, None)
    h = napply(params["ln1"], x)
    if spec.kind in ("attn", "attn_local"):
        window = cfg.local_window if spec.kind == "attn_local" else None
        h = attn.attention_forward(engine, params["attn"], cfg, h, positions,
                                   window=window, causal=causal)
    elif spec.kind == "mla":
        h = mla_mod.mla_forward(engine, params["attn"], cfg, h, positions)
    elif spec.kind == "mamba":
        h, _ = mam.mamba_forward(engine, params["mixer"], cfg, h)
    elif spec.kind == "rwkv":
        h, _, _ = rwkv_mod.rwkv_time_mix(engine, params["mixer"], cfg, h)
        x = x + h
        h2 = napply(params["ln2"], x)
        cm, _ = rwkv_mod.rwkv_channel_mix(engine, params["mixer"], cfg, h2)
        return x + cm, jnp.float32(0.0)
    x = x + h
    if enc_out is not None and "cross" in params:
        hc = napply(params["cross_ln"], x)
        kx = attn._split_heads(
            attn.dense(engine, params["cross"]["k"], enc_out), cfg.n_kv_heads)
        vx = attn._split_heads(
            attn.dense(engine, params["cross"]["v"], enc_out), cfg.n_kv_heads)
        x = x + attn.attention_forward(
            engine, params["cross"], cfg, hc, positions, causal=False,
            kv_override=(kx, vx))
    h = napply(params["ln2"], x)
    h, aux = _ffn_apply(engine, params, cfg, spec, h)
    return x + h, aux


# ---------------------------------------------------------------- caches
def init_block_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype, *, cross_len: int = 0) -> dict:
    hd = cfg.resolved_head_dim
    if spec.kind in ("attn", "attn_local"):
        s_len = max_len
        if (spec.kind == "attn_local" and cfg.ring_local_cache
                and cfg.local_window and cfg.local_window < max_len):
            s_len = cfg.local_window          # ring buffer (§Perf iter. 5)
        c = {"k": jnp.zeros((batch, cfg.n_kv_heads, s_len, hd), dtype),
             "v": jnp.zeros((batch, cfg.n_kv_heads, s_len, hd), dtype)}
        if cross_len:
            c["xk"] = jnp.zeros((batch, cfg.n_kv_heads, cross_len, hd), dtype)
            c["xv"] = jnp.zeros((batch, cfg.n_kv_heads, cross_len, hd), dtype)
        return c
    if spec.kind == "mla":
        m = cfg.mla
        return {"c": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
                "kr": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)}
    if spec.kind == "mamba":
        di = cfg.mamba.expand * cfg.d_model
        return {"conv": jnp.zeros((batch, cfg.mamba.d_conv - 1, di),
                                  jnp.float32),
                "ssm": jnp.zeros((batch, di, cfg.mamba.d_state), jnp.float32)}
    if spec.kind == "rwkv":
        n = cfg.rwkv.head_size
        h = cfg.d_model // n
        return {"S": jnp.zeros((batch, h, n, n), jnp.float32),
                "tm_x": jnp.zeros((batch, cfg.d_model), dtype),
                "cm_x": jnp.zeros((batch, cfg.d_model), dtype)}
    raise ValueError(spec.kind)


def block_prefill(engine, params, cfg, spec, x, positions, cache, *,
                  enc_out=None):
    """Prefill from position 0; returns (x, cache)."""
    _, napply = _norm(cfg)
    h = napply(params["ln1"], x)
    if spec.kind in ("attn", "attn_local"):
        window = cfg.local_window if spec.kind == "attn_local" else None
        ring = (window is not None and cfg.ring_local_cache
                and cache["k"].shape[2] == window)
        h, cache["k"], cache["v"] = attn.attention_prefill(
            engine, params["attn"], cfg, h, positions, cache["k"], cache["v"],
            window=window, ring=ring)
    elif spec.kind == "mla":
        h, cache["c"], cache["kr"] = mla_mod.mla_prefill(
            engine, params["attn"], cfg, h, positions, cache["c"], cache["kr"])
    elif spec.kind == "mamba":
        # prefill == forward, carrying the final state into the cache
        b, s, _ = h.shape
        xz = None
        h, last = mam.mamba_forward(engine, params["mixer"], cfg, h)
        cache["ssm"] = last
        # conv state: last K-1 pre-conv activations — recompute cheaply
        # (the in_proj of the last K-1 tokens)
        from repro.models.layers import dense as _dense
        tail = napply(params["ln1"], x[:, -(cfg.mamba.d_conv - 1):])
        xz_tail = _dense(engine, params["mixer"]["in_proj"], tail)
        xi_tail = jnp.split(xz_tail, 2, axis=-1)[0]
        cache["conv"] = xi_tail.astype(jnp.float32)
    elif spec.kind == "rwkv":
        h, cache["S"], cache["tm_x"] = rwkv_mod.rwkv_time_mix(
            engine, params["mixer"], cfg, h)
        x = x + h
        h2 = napply(params["ln2"], x)
        cm, cache["cm_x"] = rwkv_mod.rwkv_channel_mix(
            engine, params["mixer"], cfg, h2)
        return x + cm, cache
    x = x + h
    if enc_out is not None and "cross" in params:
        hc = napply(params["cross_ln"], x)
        # compute & cache the cross K/V once
        kx = attn._split_heads(
            attn.dense(engine, params["cross"]["k"], enc_out), cfg.n_kv_heads)
        vx = attn._split_heads(
            attn.dense(engine, params["cross"]["v"], enc_out), cfg.n_kv_heads)
        cache["xk"], cache["xv"] = kx.astype(cache["xk"].dtype), \
            vx.astype(cache["xv"].dtype)
        x = x + attn.attention_forward(
            engine, params["cross"], cfg, hc, positions, causal=False,
            kv_override=(kx, vx))
    h = napply(params["ln2"], x)
    h, _ = _ffn_apply(engine, params, cfg, spec, h)
    return x + h, cache


def block_decode(engine, params, cfg, spec, x, position, cache, *,
                 enc_len: Optional[int] = None):
    """One-token step. x: (B, d); returns (x, cache)."""
    _, napply = _norm(cfg)
    h = napply(params["ln1"], x)
    if spec.kind in ("attn", "attn_local"):
        window = cfg.local_window if spec.kind == "attn_local" else None
        ring = (window is not None and cfg.ring_local_cache
                and cache["k"].shape[2] == window)
        h, cache["k"], cache["v"] = attn.attention_decode(
            engine, params["attn"], cfg, h, position, cache["k"], cache["v"],
            window=window, ring=ring)
    elif spec.kind == "mla":
        h, cache["c"], cache["kr"] = mla_mod.mla_decode(
            engine, params["attn"], cfg, h, position, cache["c"], cache["kr"])
    elif spec.kind == "mamba":
        h, cache["conv"], cache["ssm"] = mam.mamba_decode(
            engine, params["mixer"], cfg, h, cache["conv"], cache["ssm"])
    elif spec.kind == "rwkv":
        h, cache["S"], cache["tm_x"] = rwkv_mod.rwkv_time_mix_decode(
            engine, params["mixer"], cfg, h, cache["S"], cache["tm_x"])
        x = x + h
        h2 = napply(params["ln2"], x)
        cm, cache["cm_x"] = rwkv_mod.rwkv_channel_mix(
            engine, params["mixer"], cfg, h2[:, None, :],
            cache["cm_x"])
        return x + cm[:, 0], cache
    x = x + h
    if "cross" in params and "xk" in cache:
        hc = napply(params["cross_ln"], x)
        b = x.shape[0]
        q = attn.dense(engine, params["cross"]["q"], hc[:, None, :])
        q = attn._split_heads(q, cfg.n_heads)[:, :, 0, :]       # (B,Hq,hd)
        lengths = jnp.full((b,), enc_len, jnp.int32)
        o = engine.decode_attention(q, cache["xk"], cache["xv"], lengths,
                                    softcap=cfg.attn_softcap)
        o = attn.dense(engine, params["cross"]["o"],
                       o.reshape(b, cfg.n_heads * cfg.resolved_head_dim))
        x = x + o
    h = napply(params["ln2"], x)
    h, _ = _ffn_apply(engine, params, cfg, spec, h[:, None, :])
    return x + h[:, 0], cache
