"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-style MLA).

Train/prefill: latent KV is expanded to per-head K/V and the standard flash
kernel runs. Decode: the **latent cache** is the near-memory operand — we use
the absorbed-matmul identity

    score_h = q_nope_hᵀ W_uk_h c + q_rope_hᵀ k_rope
            = [W_uk_hᵀ q_nope_h ; q_rope_h] · [c ; k_rope]

so single-token decode is a cache-resident sweep over the *compressed* latent
stream (kv_lora_rank + rope_dim per token instead of 2·H·head_dim) — ARCANE's
"compute where the cache lives" with an 18× smaller cache for MiniCPM3's
geometry. The value path absorbs W_uv the same way.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.engine import ArcaneEngine
from repro.models.layers import (apply_rope, dense, dense_init, rmsnorm,
                                 rmsnorm_init, truncated_normal_init)


def mla_init(key, cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dt = cfg.pdtype
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    keys = jax.random.split(key, 8)
    return {
        "q_down": dense_init(keys[0], d, m.q_lora_rank, dt),
        "q_norm": rmsnorm_init(m.q_lora_rank, dt),
        "q_up": dense_init(keys[1], m.q_lora_rank, h * qk_head, dt),
        "kv_down": dense_init(keys[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dt),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dt),
        "k_up": truncated_normal_init(
            keys[3], (h, m.kv_lora_rank, m.qk_nope_head_dim), dt,
            1.0 / math.sqrt(m.kv_lora_rank)),
        "v_up": truncated_normal_init(
            keys[4], (h, m.kv_lora_rank, m.v_head_dim), dt,
            1.0 / math.sqrt(m.kv_lora_rank)),
        "o": dense_init(keys[5], h * m.v_head_dim, d, dt),
    }


def _project_qkv(engine, params, cfg, x, positions):
    """Shared q/latent computation. Returns q_nope, q_rope, c_kv, k_rope."""
    m = cfg.mla
    h = cfg.n_heads
    b = x.shape[0]
    s = x.shape[1]
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_lat = rmsnorm(params["q_norm"], dense(engine, params["q_down"], x))
    q = dense(engine, params["q_up"], q_lat).reshape(b, s, h, qk_head)
    q = q.transpose(0, 2, 1, 3)                                   # (B,H,S,qk)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    kv = dense(engine, params["kv_down"], x)                      # (B,S,r+rope)
    c_kv = rmsnorm(params["kv_norm"], kv[..., : m.kv_lora_rank])
    k_rope = kv[..., m.kv_lora_rank:][:, None]                    # (B,1,S,rope)
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)
    k_rope = apply_rope(k_rope, positions, theta=cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(engine: ArcaneEngine, params: dict, cfg: ModelConfig,
                x: jax.Array, positions: jax.Array) -> jax.Array:
    """Training forward: expand latents to per-head K/V, flash attention."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_nope, q_rope, c_kv, k_rope = _project_qkv(engine, params, cfg, x, positions)
    k_nope = jnp.einsum("bsr,hrd->bhsd", c_kv, params["k_up"])
    v = jnp.einsum("bsr,hrd->bhsd", c_kv, params["v_up"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, h, s, m.qk_rope_head_dim))],
        axis=-1)
    scale = 1.0 / math.sqrt(qk_head)
    # v head dim may differ from qk head dim — pad for the shared kernel.
    if m.v_head_dim < qk_head:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_head - m.v_head_dim)))
    out = engine.attention(q, k, v, causal=True, scale=scale)
    out = out[..., : m.v_head_dim]
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim)
    return dense(engine, params["o"], out)


def mla_prefill(engine, params, cfg, x, positions, cache_c, cache_kr):
    """Prefill: run forward and stash the *latent* stream into the cache."""
    m = cfg.mla
    q_nope, q_rope, c_kv, k_rope = _project_qkv(engine, params, cfg, x, positions)
    out = mla_forward(engine, params, cfg, x, positions)
    cache_c = jax.lax.dynamic_update_slice(
        cache_c, c_kv.astype(cache_c.dtype), (0, 0, 0))
    cache_kr = jax.lax.dynamic_update_slice(
        cache_kr, k_rope[:, 0].astype(cache_kr.dtype), (0, 0, 0))
    return out, cache_c, cache_kr


def mla_decode(engine: ArcaneEngine, params: dict, cfg: ModelConfig,
               x: jax.Array, position: jax.Array,
               cache_c: jax.Array, cache_kr: jax.Array):
    """Absorbed single-token decode over the latent cache.

    x: (B, d); cache_c: (B, S, r); cache_kr: (B, S, rope).
    """
    m = cfg.mla
    b, _ = x.shape
    h = cfg.n_heads
    r = m.kv_lora_rank
    rope = m.qk_rope_head_dim
    qk_head = m.qk_nope_head_dim + rope
    q_nope, q_rope, c_new, kr_new = _project_qkv(
        engine, params, cfg, x[:, None, :], position[:, None])
    # write the new latent row
    cache_c = jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0))
    )(cache_c, c_new.astype(cache_c.dtype), position)
    cache_kr = jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0))
    )(cache_kr, kr_new[:, 0].astype(cache_kr.dtype), position)

    # absorb W_uk into q: q_eff = W_ukᵀ q_nope  → (B, H, r)
    q_eff = jnp.einsum("bhd,hrd->bhr", q_nope[:, :, 0, :], params["k_up"])
    q_full = jnp.concatenate([q_eff, q_rope[:, :, 0, :]], axis=-1)  # (B,H,r+rope)
    keys = jnp.concatenate([cache_c, cache_kr], axis=-1)[:, None]   # (B,1,S,r+rope)
    vals = jnp.pad(cache_c, ((0, 0), (0, 0), (0, rope)))[:, None]   # pad to r+rope
    lengths = position + 1
    scale = 1.0 / math.sqrt(qk_head)
    out = engine.decode_attention(q_full, keys.astype(q_full.dtype),
                                  vals.astype(q_full.dtype), lengths,
                                  scale=scale)                      # (B,H,r+rope)
    out_lat = out[..., :r]                                          # (B,H,r)
    out_v = jnp.einsum("bhr,hrd->bhd", out_lat, params["v_up"])
    out_v = out_v.reshape(b, h * m.v_head_dim)
    return dense(engine, params["o"], out_v), cache_c, cache_kr
