"""Serving engine: continuous-batching decode over the cache-resident kernels.

A fixed pool of ``max_slots`` sequence slots shares one batched KV cache
(ARCANE's LLC role). Requests are admitted into free slots at any step
(per-slot prefill, inserted into the batch cache with dynamic_update_slice);
every step decodes one token for all live slots. Ragged lengths are free:
the decode kernel skips cache pages past each slot's length, so a just-
admitted short sequence does not pay for its neighbours (the kernel-level
straggler mitigation described in the decode kernel docstring).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import LM

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


def _insert_slot(batched: PyTree, one: PyTree, slot: int) -> PyTree:
    """Write a batch-1 cache pytree into slot ``slot`` of the batched cache.

    Cache leaves are (n_periods, B, ...); the singleton cache has B = 1.
    """
    def put(c, n):
        return jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (0, slot) + (0,) * (c.ndim - 2))
    return jax.tree.map(put, batched, one)


class ServeSession:
    def __init__(self, model: LM, params: PyTree, *, max_slots: int = 4,
                 max_len: int = 512, eos_id: Optional[int] = None,
                 seed: int = 0):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = model.init_cache(max_slots, max_len)
        self.positions = np.zeros((max_slots,), np.int32)
        self.slots: list[Optional[Request]] = [None] * max_slots
        self.last_tokens = np.zeros((max_slots,), np.int32)
        self._uid = 0
        self._key = jax.random.key(seed)
        self._prefill1 = jax.jit(
            lambda p, b, c: model.prefill(p, b, c))
        self._decode = jax.jit(
            lambda p, t, po, c: model.decode_step(p, t, po, c))
        self.pending: list[Request] = []
        self.finished: list[Request] = []

    # ------------------------------------------------------------------ API
    def submit(self, prompt, **kw) -> Request:
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32), **kw)
        self._uid += 1
        self.pending.append(req)
        return req

    def _admit(self) -> None:
        for slot in range(self.max_slots):
            if self.slots[slot] is not None or not self.pending:
                continue
            req = self.pending.pop(0)
            s = len(req.prompt)
            assert s + req.max_new_tokens <= self.max_len, "prompt too long"
            one_cache = self.model.init_cache(1, self.max_len)
            logits, one_cache = self._prefill1(
                self.params, {"tokens": jnp.asarray(req.prompt[None])},
                one_cache)
            self.cache = _insert_slot(self.cache, one_cache, slot)
            tok = self._sample(logits, req.temperature)
            req.out_tokens.append(int(tok[0]))
            self.slots[slot] = req
            self.positions[slot] = s
            self.last_tokens[slot] = int(tok[0])

    def _sample(self, logits: jax.Array, temperature: float) -> np.ndarray:
        if temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, -1), np.int32)
        self._key, sub = jax.random.split(self._key)
        return np.asarray(
            jax.random.categorical(sub, logits / temperature, -1), np.int32)

    def step(self) -> int:
        """Admit pending requests, decode one token for all live slots.
        Returns number of live slots."""
        self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return 0
        tokens = jnp.asarray(self.last_tokens)
        positions = jnp.asarray(self.positions)
        logits, self.cache = self._decode(self.params, tokens, positions,
                                          self.cache)
        lg = np.asarray(logits, np.float32)
        for slot in live:
            req = self.slots[slot]
            tok = self._sample(jnp.asarray(lg[slot : slot + 1]),
                               req.temperature)[0]
            req.out_tokens.append(int(tok))
            self.positions[slot] += 1
            self.last_tokens[slot] = int(tok)
            hit_eos = self.eos_id is not None and int(tok) == self.eos_id
            full = len(req.out_tokens) >= req.max_new_tokens or \
                self.positions[slot] + 1 >= self.max_len
            if hit_eos or full:
                req.done = True
                self.finished.append(req)
                self.slots[slot] = None
        return len(live)

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if not self.pending and all(s is None for s in self.slots):
                break
            self.step()
        return self.finished
