"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

``input_specs`` returns the exact argument pytrees the lowered step function
takes — weak-type-correct, shardable, zero allocation. Modality frontends are
stubs per the assignment: whisper gets precomputed frame embeddings, internvl
gets precomputed patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig, get_config
from repro.configs.base import ModelConfig
from repro.models.transformer import LM
from repro.optim.adamw import AdamWConfig, adamw_init

PyTree = Any

# Archs whose size requires ZeRO-3/FSDP param sharding on the 256-chip pod.
FSDP_ARCHS = {"llama4-scout-17b-a16e", "gemma2-9b", "qwen2.5-32b",
              "jamba-1.5-large-398b"}
# Archs where optimizer moments drop to bf16 to fit HBM (noted in EXPERIMENTS).
BF16_MOMENT_ARCHS = {"jamba-1.5-large-398b", "llama4-scout-17b-a16e"}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def opt_config_for(arch: str) -> AdamWConfig:
    if arch in BF16_MOMENT_ARCHS:
        return AdamWConfig(moment_dtype="bfloat16", master_dtype="float32")
    return AdamWConfig()


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        # the vision prefix counts toward the context length: text tokens
        # fill the remainder so prefill exactly fits the seq_len cache
        s_tok = s - cfg.vision_prefix
        batch = {"tokens": sds((b, s_tok), jnp.int32)}
        if cfg.vision_prefix:
            batch["vision_embeds"] = sds((b, cfg.vision_prefix, cfg.d_model),
                                         cfg.cdtype)
        if cfg.enc_dec:
            batch["audio_embeds"] = sds((b, s, cfg.d_model), cfg.cdtype)
        return batch
    # decode shapes: one new token against a seq_len cache
    return {"tokens": sds((b,), jnp.int32),
            "position": sds((b,), jnp.int32)}


def state_specs(model: LM, arch: str) -> tuple[PyTree, PyTree]:
    """(params, opt_state) as ShapeDtypeStructs via eval_shape."""
    params = model.param_shapes()
    opt_cfg = opt_config_for(arch)
    opt = jax.eval_shape(lambda p: adamw_init(opt_cfg, p), params)
    return params, opt


def cache_specs(model: LM, cfg: ModelConfig, shape: ShapeConfig) -> PyTree:
    enc_len = shape.seq_len if cfg.enc_dec else 0
    return model.cache_shapes(shape.global_batch, shape.seq_len,
                              dtype=cfg.cdtype, enc_len=enc_len)


def input_specs(arch: str, shape: ShapeConfig, model: LM) -> dict:
    """Everything the step function consumes, as ShapeDtypeStructs."""
    cfg = model.cfg
    params, opt = state_specs(model, arch)
    out = {"params": params}
    if shape.kind == "train":
        out["opt_state"] = opt
        out["batch"] = batch_specs(cfg, shape)
    elif shape.kind == "prefill":
        out["batch"] = batch_specs(cfg, shape)
        out["cache"] = cache_specs(model, cfg, shape)
    else:  # decode / long_decode
        out["batch"] = batch_specs(cfg, shape)
        out["cache"] = cache_specs(model, cfg, shape)
    return out
