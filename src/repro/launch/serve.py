"""Serving launcher: batched continuous decoding on the host mesh.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --smoke \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.engine import ArcaneEngine
from repro.models.transformer import LM
from repro.serving.engine import ServeSession


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--backend", default="ref")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg, ArcaneEngine(backend=args.backend))
    params = model.init_params(jax.random.key(0))
    sess = ServeSession(model, params, max_slots=args.slots,
                        max_len=args.max_len)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        sess.submit(rng.integers(0, cfg.vocab, plen),
                    max_new_tokens=args.max_new)
    t0 = time.perf_counter()
    done = sess.run_to_completion()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens / dt:.1f} tok/s)")
    return {"requests": len(done), "tokens": tokens, "seconds": dt}


if __name__ == "__main__":
    run()
