"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices stand in for 2 TPU v5e pods. For each cell the step function is
lowered with ShapeDtypeStruct inputs (no allocation), compiled, and the
memory/cost analysis + the collective-byte census (parsed from the compiled
HLO) are recorded for EXPERIMENTS §Dry-run and §Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
        --shape train_4k --mesh both --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""
# The VERY FIRST lines — before ANY other import — jax locks the device
# count on first init.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config, grid  # noqa: E402
from repro.core.engine import ArcaneEngine  # noqa: E402
from repro.distributed.sharding import (batch_pspecs, cache_pspecs,  # noqa: E402
                                        param_pspecs, to_shardings,
                                        zero_pspecs)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (FSDP_ARCHS, cache_specs, input_specs,  # noqa: E402
                                opt_config_for, state_specs)
from repro.models.transformer import LM  # noqa: E402
from repro.train.step import make_serve_steps, make_train_step  # noqa: E402

_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|s8|u32|u8|pred|f64|s64|u64|s16|u16)"
                       r"\[([0-9,]*)\]")

_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
          "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
          "pred": 1}

# ``%name = <shape> all-reduce(...)`` — also match async -start forms,
# skip -done (would double count).
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op in the compiled HLO.

    The output shape bytes approximate what crosses the wire per device for
    AG/AR/RS/A2A/CP, up to the ring-algorithm factor (folded into the
    roofline link constant). NOTE: ops inside while-loop (scan) bodies appear
    once — the dry-run corrects by depth extrapolation (see lower_cell).
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(2).lower()
        out[op] = out.get(op, 0) + _shape_bytes(m.group(1))
    return out


def extrapolate(full: dict, p1: dict, p2: dict, n_periods: int) -> dict:
    """Correct scan-body single-count: X(L) = X(1) + (L-1)·(X(2)-X(1)).

    Exact for quantities linear in depth (flops, bytes, collective bytes,
    optimizer update work); `full` supplies everything else (peak memory).
    """
    def lin(a, b):
        return a + (n_periods - 1) * (b - a)

    coll = {}
    for k in set(p1["collective_bytes"]) | set(p2["collective_bytes"]):
        coll[k] = int(lin(p1["collective_bytes"].get(k, 0),
                          p2["collective_bytes"].get(k, 0)))
    return {
        "flops": float(lin(p1["flops"], p2["flops"])),
        "bytes_accessed": float(lin(p1["bytes_accessed"],
                                    p2["bytes_accessed"])),
        "collective_bytes": coll,
    }


def lower_cell(arch: str, shape_name: str, mesh, *, backend: str = "ref",
               n_periods: int | None = None, constrain_acts: bool = False,
               cfg_overrides: dict | None = None):
    """Lower+compile one cell; returns the result record.

    ``n_periods`` overrides the depth (in pattern periods) — used by the
    depth-extrapolation that corrects cost_analysis's once-per-scan counting.
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    if n_periods is not None:
        repl = {"n_layers": n_periods * cfg.period}
        if cfg.enc_dec:
            repl["n_enc_layers"] = n_periods
        cfg = _dc.replace(cfg, **repl)
    shape = SHAPES[shape_name]
    engine = ArcaneEngine(backend=backend)
    model = LM(cfg, engine, unroll=n_periods is not None)
    from repro.distributed.sharding import set_activation_mesh
    set_activation_mesh(mesh if constrain_acts else None)
    fsdp = arch in FSDP_ARCHS
    specs = input_specs(arch, shape, model)
    t0 = time.time()

    with mesh:
        p_sh = to_shardings(param_pspecs(specs["params"], mesh, fsdp=fsdp),
                            mesh)
        b_sh = to_shardings(batch_pspecs(specs["batch"], mesh), mesh)
        if shape.kind == "train":
            opt_cfg = opt_config_for(arch)
            o_sh = to_shardings(zero_pspecs(specs["opt_state"], mesh), mesh)
            g_sh = to_shardings(zero_pspecs(specs["params"], mesh), mesh) \
                if constrain_acts else None
            step = make_train_step(model, opt_cfg, grad_shardings=g_sh)
            fn = jax.jit(step,
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(specs["params"], specs["opt_state"],
                               specs["batch"])
        elif shape.kind == "prefill":
            prefill_step, _ = make_serve_steps(
                model, enc_len=shape.seq_len if cfg.enc_dec else 0)
            c_sh = to_shardings(cache_pspecs(specs["cache"], mesh), mesh)
            fn = jax.jit(prefill_step,
                         in_shardings=(p_sh, b_sh, c_sh),
                         out_shardings=(None, c_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(specs["params"], specs["batch"],
                               specs["cache"])
        else:
            _, decode_step = make_serve_steps(
                model, enc_len=shape.seq_len if cfg.enc_dec else 0)
            c_sh = to_shardings(cache_pspecs(specs["cache"], mesh), mesh)
            fn = jax.jit(decode_step,
                         in_shardings=(p_sh, b_sh["tokens"], b_sh["position"],
                                       c_sh),
                         out_shardings=(None, c_sh),
                         donate_argnums=(3,))
            lowered = fn.lower(specs["params"], specs["batch"]["tokens"],
                               specs["batch"]["position"], specs["cache"])
        compiled = lowered.compile()
    set_activation_mesh(None)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo)
    n_dev = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": int(n_dev),
        "seconds_to_compile": round(time.time() - t0, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(mem.peak_memory_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        },
        "model": {
            "params": get_config(arch).param_count(),
            "active_params": get_config(arch).active_param_count(),
        },
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch x shape) cell")
    ap.add_argument("--backend", default="ref",
                    help="engine backend for lowering (ref|pallas)")
    ap.add_argument("--no-roofline", action="store_true",
                    help="skip the 1/2-period extrapolation compiles")
    ap.add_argument("--constrain-acts", action="store_true",
                    help="apply activation sharding constraints (§Perf)")
    ap.add_argument("--ring-local-cache", action="store_true",
                    help="window-sized ring KV cache for local layers")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCHS:
            for sh in grid(arch):
                cells.append((arch, sh.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape_name in cells:
        for mesh_name, mesh in meshes:
            tag = f"{arch}__{shape_name}__{mesh_name}"
            out_path = os.path.join(args.out, tag + ".json")
            if os.path.exists(out_path):
                print(f"[skip] {tag}")
                continue
            try:
                ov = ({"ring_local_cache": True}
                      if args.ring_local_cache else None)
                rec = lower_cell(arch, shape_name, mesh,
                                 backend=args.backend,
                                 constrain_acts=args.constrain_acts,
                                 cfg_overrides=ov)
                if mesh_name == "single" and not args.no_roofline:
                    # depth extrapolation: correct once-per-scan counting
                    cfgK = get_config(arch)
                    p1 = lower_cell(arch, shape_name, mesh,
                                    backend=args.backend, n_periods=1,
                                    constrain_acts=args.constrain_acts,
                                    cfg_overrides=ov)
                    p2 = lower_cell(arch, shape_name, mesh,
                                    backend=args.backend, n_periods=2,
                                    constrain_acts=args.constrain_acts,
                                    cfg_overrides=ov)
                    rec["corrected"] = extrapolate(rec, p1, p2,
                                                   cfgK.n_periods)
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                peak = rec["memory"]["peak_bytes"] / 2**30
                arg = rec["memory"]["argument_bytes"] / 2**30
                cf = rec.get("corrected", rec)
                print(f"[ok]   {tag}: compile={rec['seconds_to_compile']}s "
                      f"flops={cf['flops']:.3e} peak/dev={peak:.2f}GiB "
                      f"args/dev={arg:.2f}GiB "
                      f"coll/dev={sum(cf['collective_bytes'].values())/2**20:.1f}MiB")
            except Exception as e:
                failures += 1
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
