"""Training launcher: mesh setup, sharded state, fault-tolerant loop.

Fault-tolerance machinery (single-host shapes of the multi-pod mechanisms):

  * **checkpoint/restart** — CheckpointManager (atomic, async, elastic);
    resume is automatic from <ckpt_dir>/LATEST, and the data pipeline
    regenerates the exact stream from the step counter alone.
  * **preemption handling** — SIGTERM/SIGINT trigger a synchronous save at
    the next step boundary before exit (the TPU preemption-notice pattern).
  * **step watchdog** — a straggler/hang detector: if a step exceeds
    ``watchdog_factor`` × the trailing median, the event is logged with the
    step number (at pod scale this feeds the reschedule/elastic controller;
    here it is surfaced in metrics and the log).

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --smoke --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import signal
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.core.engine import ArcaneEngine
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.distributed.sharding import (batch_pspecs, param_pspecs,
                                        to_shardings, zero_pspecs)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.transformer import LM
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.step import make_train_step


class Preemption:
    def __init__(self):
        self.flag = False
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, self._handler)
            except ValueError:
                pass  # not main thread

    def _handler(self, signum, frame):
        self.flag = True


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--watchdog-factor", type=float, default=5.0)
    ap.add_argument("--backend", default="ref")
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    engine = ArcaneEngine(backend=args.backend)
    model = LM(cfg, engine)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(args.model_axis))

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          total_steps=args.steps)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    source = SyntheticLM(data_cfg)

    with mesh:
        params = model.init_params(jax.random.key(0))
        opt_state = adamw_init(opt_cfg, params)
        p_sh = to_shardings(param_pspecs(params, mesh), mesh)
        o_sh = to_shardings(zero_pspecs(opt_state, mesh), mesh)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        step_fn = jax.jit(
            make_train_step(model, opt_cfg, microbatches=args.microbatches),
            in_shardings=(p_sh, o_sh, None),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1))

        ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        start_step = 0
        if ckpt is not None and ckpt.latest_step() is not None:
            start_step = ckpt.latest_step()
            state, extra = ckpt.restore(
                start_step, {"params": params, "opt": opt_state},
                shardings={"params": p_sh, "opt": o_sh})
            params, opt_state = state["params"], state["opt"]
            print(f"[resume] from step {start_step}")

        preempt = Preemption()
        durations: list[float] = []
        stragglers = 0
        history = []
        it = Prefetcher(source, start_step=start_step)
        for step in range(start_step, args.steps):
            batch_np = next(it)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            durations.append(dt)
            if len(durations) > 8:
                med = statistics.median(durations[-32:])
                if dt > args.watchdog_factor * med:
                    stragglers += 1
                    print(f"[watchdog] step {step}: {dt:.2f}s vs median "
                          f"{med:.2f}s — straggler/hang suspected")
            history.append(loss)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} {dt:.2f}s")
            should_save = ckpt is not None and (
                (step + 1) % args.ckpt_every == 0 or preempt.flag
                or step == args.steps - 1)
            if should_save:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          extra={"loss": loss}, blocking=preempt.flag)
            if preempt.flag:
                print(f"[preempt] checkpoint at step {step + 1}, exiting")
                break
        it.close()
        if ckpt is not None:
            ckpt.wait()
    return {"history": history, "stragglers": stragglers,
            "final_loss": history[-1] if history else None}


if __name__ == "__main__":
    run()
