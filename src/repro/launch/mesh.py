"""Production mesh factory (TPU v5e pods; host-device placeholders on CPU).

``make_production_mesh`` is a function — importing this module never touches
jax device state. The dry-run sets XLA_FLAGS for 512 host devices *before*
importing anything (see dryrun.py); everything else sees the real topology.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh(
        (n // model_axis, model_axis), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
