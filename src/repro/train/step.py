"""Train/serve step factories.

``make_train_step`` builds the jittable update: value_and_grad over the model
loss (remat is inside the model's period scan), optional microbatch gradient
accumulation (lax.scan, f32 accumulators — the reduce-scatter of each
microbatch's gradients overlaps the next microbatch's compute under the XLA
scheduler), then the AdamW update. ``make_serve_steps`` builds prefill and
single-token decode steps for the serving shapes.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import LM
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

PyTree = Any


def make_train_step(model: LM, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, grad_shardings: PyTree = None):
    """grad_shardings: optional NamedSharding tree (the ZeRO layout). When
    given, gradients are constrained to it right after the backward pass, so
    XLA lowers the data-parallel gradient all-reduce into reduce-scatter (to
    the optimizer shard) + param all-gather — half the gradient wire bytes
    (§Perf iteration 6)."""
    loss_fn = model.loss

    def _constrain_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(
            lambda g, sh: jax.lax.with_sharding_constraint(g, sh),
            grads, grad_shardings)

    def train_step(params: PyTree, opt_state: PyTree, batch: PyTree):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = _constrain_grads(grads)
        else:
            def micro(carry, mb):
                grads_acc, loss_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                grads_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), grads_acc, g)
                return (grads_acc, loss_acc + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (zero, jnp.float32(0.0)),
                                            mbs)
            grads = _constrain_grads(
                jax.tree.map(lambda g: g / microbatches, grads))
            loss = loss / microbatches
            metrics = {}
        new_params, new_opt, om = adamw_update(opt_cfg, grads, opt_state,
                                               params)
        out_metrics = {"loss": loss, **{k: v for k, v in metrics.items()
                                        if jnp.ndim(v) == 0}, **om}
        return new_params, new_opt, out_metrics

    return train_step


def make_serve_steps(model: LM, *, enc_len: int = 0):
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    def decode_step(params, tokens, position, cache):
        return model.decode_step(params, tokens, position, cache,
                                 enc_len=enc_len)

    return prefill_step, decode_step


def init_train_state(model: LM, opt_cfg: AdamWConfig, key) -> tuple:
    params = model.init_params(key)
    return params, adamw_init(opt_cfg, params)
