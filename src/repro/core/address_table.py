"""Address Table (AT) — kernel operand state tracking (paper §III-A3).

Each entry holds the *exact 2D footprint* of a kernel operand (a
:class:`~repro.core.regions.StridedRegion`), a validity flag and a status
flag, plus whether the region is a kernel *source* or *destination*. The
Kernel Decoder registers regions when an operation is queued; the cache
controller consults the AT on critical accesses and stalls only the requests
that would corrupt an in-flight kernel:

- host STORE into a live *source* region  → WAR hazard → stall until the
  operand has been allocated (copied) into VPU lines;
- host LOAD  from a live *destination*    → RAW hazard → stall until kernel
  write-back completes;
- host STORE into a live *destination*    → WAW hazard → stall likewise.

Because entries carry the strided footprint rather than its bounding byte
interval, a host access that lands in the *gap* between two strided rows of
an operand (e.g. the untouched columns beside a conv strip) does not stall —
the check is exact, not conservative.

Entries are reference-counted per physical binding so that renamed matrices
(same logical register, different physical tags) track independently. Live
entries are mirrored into an :class:`~repro.core.alias_index.AliasIndex`
keyed by slot, so the host-access checks and registration bookkeeping cost
O(hits) rather than a scan of the whole (statically sized) table.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Iterator, Optional

from repro.core.alias_index import AliasIndex
from repro.core.isa import KernelError
from repro.core.regions import StridedRegion


class RegionKind(enum.Enum):
    SRC = "src"
    DST = "dst"


class RegionStatus(enum.Enum):
    BUSY = "busy"          # operand still needed by a pending/running kernel
    ALLOCATED = "alloc"    # source copied into VPU lines → host stores OK again
    FREE = "free"


@dataclasses.dataclass
class ATEntry:
    region: StridedRegion
    kind: RegionKind
    status: RegionStatus = RegionStatus.BUSY
    valid: bool = True
    phys_id: int = -1             # owning physical matrix binding
    refcount: int = 1             # pending kernels still referencing the region

    @property
    def start(self) -> int:
        return self.region.start

    @property
    def end(self) -> int:         # one past last byte of the bounding interval
        return self.region.end

    def overlaps(self, start: int, end: int) -> bool:
        """Exact strided-footprint check against flat interval [start, end)."""
        return self.valid and self.region.overlaps_interval(start, end)


class AddressTable:
    """Statically sized AT (static allocation philosophy, §IV-B)."""

    def __init__(self, capacity: int = 64):
        self.capacity = capacity
        self._entries: list[Optional[ATEntry]] = [None] * capacity
        # Live-entry lookup structures: (phys_id, kind) -> slot for the O(1)
        # up-ref/release/mark paths, a min-heap of reusable slots (the lowest
        # free slot wins, matching the original first-free scan), and the
        # footprint index answering the host-access hazard checks by slot.
        self._by_key: dict[tuple[int, RegionKind], int] = {}
        self._free_heap: list[int] = list(range(capacity))
        self._alias_index = AliasIndex()

    def __iter__(self) -> Iterator[ATEntry]:
        return (e for e in self._entries if e is not None and e.valid)

    def free_slots(self) -> int:
        """Slots available for new registrations (empty or invalidated)."""
        return self.capacity - len(self._by_key)

    def slots_needed(self, regions: list[tuple[int, "RegionKind"]]) -> int:
        """Fresh slots a batch of registrations would consume: repeated
        operands and regions already registered live just up-ref the
        existing ``(phys_id, kind)`` entry."""
        return len(set(regions) - self._by_key.keys())

    def _free_slot(self) -> int:
        while self._free_heap:
            i = heapq.heappop(self._free_heap)
            e = self._entries[i]
            if e is None or not e.valid:
                return i
        # Preamble-level rejection (bridge answers 'kill'), not a crash: the
        # runtime drains deferred write-backs on capacity pressure before
        # registering, so reaching here means the table is truly over
        # capacity for the live working set.
        raise KernelError(
            f"Address Table full ({self.capacity} entries live) — raise "
            f"queue_capacity in the config or barrier() to drain deferred "
            f"write-backs")

    def register(self, region: StridedRegion, kind: RegionKind,
                 phys_id: int) -> ATEntry:
        """Register (or up-ref) an operand region for a queued kernel."""
        slot = self._by_key.get((phys_id, kind))
        if slot is not None:
            e = self._entries[slot]
            e.refcount += 1
            e.status = RegionStatus.BUSY
            return e
        entry = ATEntry(region=region, kind=kind, phys_id=phys_id)
        slot = self._free_slot()
        self._entries[slot] = entry
        self._by_key[(phys_id, kind)] = slot
        self._alias_index.insert(slot, region)
        return entry

    def mark_allocated(self, phys_id: int) -> None:
        """Source operand copied into VPU lines — WAR window closed."""
        slot = self._by_key.get((phys_id, RegionKind.SRC))
        if slot is not None:
            self._entries[slot].status = RegionStatus.ALLOCATED

    def release(self, phys_id: int, kind: RegionKind) -> None:
        """Kernel finished with the region: down-ref; free at zero (permissions
        restored for the host, §IV-B3)."""
        slot = self._by_key.get((phys_id, kind))
        if slot is None:
            return
        e = self._entries[slot]
        e.refcount -= 1
        if e.refcount <= 0:
            e.valid = False
            e.status = RegionStatus.FREE
            del self._by_key[(phys_id, kind)]
            self._alias_index.remove(slot)
            heapq.heappush(self._free_heap, slot)

    # ---------------------------------------------------------------- checks
    def blocks_store(self, start: int, end: int) -> Optional[ATEntry]:
        """Would a host store into [start, end) corrupt an in-flight kernel?"""
        for slot in self._alias_index.query_interval(start, end):
            e = self._entries[slot]
            if e.kind == RegionKind.SRC and e.status == RegionStatus.BUSY:
                return e  # WAR: operand not yet copied into the VPU
            if e.kind == RegionKind.DST:
                return e  # WAW: result would be overwritten by the kernel
        return None

    def blocks_load(self, start: int, end: int) -> Optional[ATEntry]:
        """Would a host load from [start, end) observe a stale result?"""
        for slot in self._alias_index.query_interval(start, end):
            e = self._entries[slot]
            if e.kind == RegionKind.DST:
                return e  # RAW: kernel result not written back yet
        return None

    def live_count(self) -> int:
        return len(self._by_key)
