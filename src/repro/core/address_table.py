"""Address Table (AT) — kernel operand state tracking (paper §III-A3).

Each entry holds the *exact 2D footprint* of a kernel operand (a
:class:`~repro.core.regions.StridedRegion`), a validity flag and a status
flag, plus whether the region is a kernel *source* or *destination*. The
Kernel Decoder registers regions when an operation is queued; the cache
controller consults the AT on critical accesses and stalls only the requests
that would corrupt an in-flight kernel:

- host STORE into a live *source* region  → WAR hazard → stall until the
  operand has been allocated (copied) into VPU lines;
- host LOAD  from a live *destination*    → RAW hazard → stall until kernel
  write-back completes;
- host STORE into a live *destination*    → WAW hazard → stall likewise.

Because entries carry the strided footprint rather than its bounding byte
interval, a host access that lands in the *gap* between two strided rows of
an operand (e.g. the untouched columns beside a conv strip) does not stall —
the check is exact, not conservative.

Entries are reference-counted per physical binding so that renamed matrices
(same logical register, different physical tags) track independently.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterator, Optional

from repro.core.isa import KernelError
from repro.core.regions import StridedRegion


class RegionKind(enum.Enum):
    SRC = "src"
    DST = "dst"


class RegionStatus(enum.Enum):
    BUSY = "busy"          # operand still needed by a pending/running kernel
    ALLOCATED = "alloc"    # source copied into VPU lines → host stores OK again
    FREE = "free"


@dataclasses.dataclass
class ATEntry:
    region: StridedRegion
    kind: RegionKind
    status: RegionStatus = RegionStatus.BUSY
    valid: bool = True
    phys_id: int = -1             # owning physical matrix binding
    refcount: int = 1             # pending kernels still referencing the region

    @property
    def start(self) -> int:
        return self.region.start

    @property
    def end(self) -> int:         # one past last byte of the bounding interval
        return self.region.end

    def overlaps(self, start: int, end: int) -> bool:
        """Exact strided-footprint check against flat interval [start, end)."""
        return self.valid and self.region.overlaps_interval(start, end)


class AddressTable:
    """Statically sized AT (static allocation philosophy, §IV-B)."""

    def __init__(self, capacity: int = 64):
        self.capacity = capacity
        self._entries: list[Optional[ATEntry]] = [None] * capacity

    def __iter__(self) -> Iterator[ATEntry]:
        return (e for e in self._entries if e is not None and e.valid)

    def free_slots(self) -> int:
        """Slots available for new registrations (empty or invalidated)."""
        return sum(1 for e in self._entries if e is None or not e.valid)

    def slots_needed(self, regions: list[tuple[int, "RegionKind"]]) -> int:
        """Fresh slots a batch of registrations would consume: repeated
        operands and regions already registered live just up-ref the
        existing ``(phys_id, kind)`` entry."""
        have = {(e.phys_id, e.kind) for e in self}
        return len(set(regions) - have)

    def _free_slot(self) -> int:
        for i, e in enumerate(self._entries):
            if e is None or not e.valid:
                return i
        # Preamble-level rejection (bridge answers 'kill'), not a crash: the
        # runtime drains deferred write-backs on capacity pressure before
        # registering, so reaching here means the table is truly over
        # capacity for the live working set.
        raise KernelError(
            f"Address Table full ({self.capacity} entries live) — raise "
            f"queue_capacity in the config or barrier() to drain deferred "
            f"write-backs")

    def register(self, region: StridedRegion, kind: RegionKind,
                 phys_id: int) -> ATEntry:
        """Register (or up-ref) an operand region for a queued kernel."""
        for e in self:
            if e.phys_id == phys_id and e.kind == kind:
                e.refcount += 1
                e.status = RegionStatus.BUSY
                return e
        entry = ATEntry(region=region, kind=kind, phys_id=phys_id)
        self._entries[self._free_slot()] = entry
        return entry

    def mark_allocated(self, phys_id: int) -> None:
        """Source operand copied into VPU lines — WAR window closed."""
        for e in self:
            if e.phys_id == phys_id and e.kind == RegionKind.SRC:
                e.status = RegionStatus.ALLOCATED

    def release(self, phys_id: int, kind: RegionKind) -> None:
        """Kernel finished with the region: down-ref; free at zero (permissions
        restored for the host, §IV-B3)."""
        for e in self:
            if e.phys_id == phys_id and e.kind == kind:
                e.refcount -= 1
                if e.refcount <= 0:
                    e.valid = False
                    e.status = RegionStatus.FREE
                return

    # ---------------------------------------------------------------- checks
    def blocks_store(self, start: int, end: int) -> Optional[ATEntry]:
        """Would a host store into [start, end) corrupt an in-flight kernel?"""
        for e in self:
            if not e.overlaps(start, end):
                continue
            if e.kind == RegionKind.SRC and e.status == RegionStatus.BUSY:
                return e  # WAR: operand not yet copied into the VPU
            if e.kind == RegionKind.DST:
                return e  # WAW: result would be overwritten by the kernel
        return None

    def blocks_load(self, start: int, end: int) -> Optional[ATEntry]:
        """Would a host load from [start, end) observe a stale result?"""
        for e in self:
            if e.overlaps(start, end) and e.kind == RegionKind.DST:
                return e  # RAW: kernel result not written back yet
        return None

    def live_count(self) -> int:
        return sum(1 for _ in self)
