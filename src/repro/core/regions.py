"""Exact 2D strided-region algebra for aliasing decisions.

A kernel operand's main-memory footprint is a *strided band*: ``rows`` row
segments of ``row_bytes`` bytes whose starts form the arithmetic progression
``addr + i * stride_bytes``. Hazard tracking needs one question answered
exactly: can two such footprints share a byte?

Interval intersection of the bounding ranges is necessary but far from
sufficient — two column strips of the same row-major array interleave in the
flat address space without ever touching the same byte, and treating them as
aliases serializes every strip of a strip-mined conv/GEMM through false
WAW/WAR edges. The previous refinement handled only the equal-stride,
non-wrapping case; this module decides the general problem exactly:

Two row segments ``[x, x + ra)`` and ``[y, y + rb)`` intersect iff
``-(rb - 1) <= y - x <= ra - 1``. With ``x = a.addr + i * sa`` and
``y = b.addr + j * sb`` the footprints alias iff some

    t(i, j) = (b.addr - a.addr) + j * sb - i * sa,   0 <= i < a.rows,
                                                     0 <= j < b.rows

falls in the window ``[-(rb - 1), ra - 1]``. Unbounded, ``t`` ranges over a
single residue class mod ``gcd(sa, sb)`` — a cheap necessary condition — and
the bounded decision reduces to, per row of the shorter operand, one integer
interval division. Everything is O(min(rows, rows)) worst case with O(1)
fast paths for the common equal-stride and single-row shapes; no footprint
is ever enumerated byte by byte.
"""
from __future__ import annotations

import dataclasses

import math


@dataclasses.dataclass(frozen=True)
class StridedRegion:
    """One 2D strided byte footprint: ``rows`` segments of ``row_bytes``
    starting at ``addr + i * stride_bytes``.

    ``stride_bytes`` may be smaller than ``row_bytes`` (self-overlapping
    rows) — the algebra does not assume non-wrapping bands.
    """

    addr: int
    rows: int
    row_bytes: int
    stride_bytes: int
    #: One past the last byte touched — precomputed because every index
    #: insert and overlap test reads it (derived, hence excluded from
    #: repr/eq).
    end: int = dataclasses.field(init=False, repr=False, compare=False)

    def __post_init__(self):
        if self.rows <= 0:
            raise ValueError(f"rows must be positive, got {self.rows}")
        if self.row_bytes <= 0:
            raise ValueError(f"row_bytes must be positive, got {self.row_bytes}")
        if self.rows > 1 and self.stride_bytes <= 0:
            raise ValueError(
                f"stride_bytes must be positive for multi-row regions, "
                f"got {self.stride_bytes}")
        object.__setattr__(
            self, "end",
            self.addr + (self.rows - 1) * max(self.stride_bytes, 0)
            + self.row_bytes)
        # Plain-int identity tuple for the memoized pairwise decisions below
        # (tuple-of-ints hashing is C-speed; the generated dataclass
        # __hash__/__eq__ dominated the hot confirmation loops).
        object.__setattr__(self, "_key", (self.addr, self.rows,
                                          self.row_bytes, self.stride_bytes))

    # ------------------------------------------------------------- geometry
    @property
    def start(self) -> int:
        return self.addr

    @property
    def nbytes(self) -> int:
        """Bytes of payload moved (rows may self-overlap in memory)."""
        return self.rows * self.row_bytes

    def row_interval(self, i: int) -> tuple[int, int]:
        """``[start, end)`` of row ``i``."""
        if not 0 <= i < self.rows:
            raise IndexError(f"row {i} out of range [0, {self.rows})")
        s = self.addr + i * self.stride_bytes
        return s, s + self.row_bytes

    # -------------------------------------------------------------- algebra
    def overlaps_interval(self, start: int, end: int) -> bool:
        """Exact test against a flat byte interval ``[start, end)``."""
        if end <= start:
            return False
        return self.overlaps(StridedRegion(addr=start, rows=1,
                                           row_bytes=end - start,
                                           stride_bytes=end - start))

    def overlaps(self, other: "StridedRegion") -> bool:
        """True iff the two footprints share at least one byte. Exact."""
        # Bounding-interval reject (also the exact answer when both are
        # single rows, since then footprint == bounding interval).
        if self.start >= other.end or other.start >= self.end:
            return False
        if self.rows == 1 and other.rows == 1:
            return True

        c = other.addr - self.addr
        sa, sb = self.stride_bytes, other.stride_bytes
        lo, hi = -(other.row_bytes - 1), self.row_bytes - 1

        # Single-row operands degenerate to a 1D progression-vs-interval test.
        if self.rows == 1:
            return _progression_hits(sb, other.rows, lo - c, hi - c)
        if other.rows == 1:
            return _progression_hits(sa, self.rows, c - hi, c - lo)

        # Equal strides: t = c + (j - i) * s with j - i in
        # [-(rows_a - 1), rows_b - 1] — one O(1) division.
        if sa == sb:
            k_lo, k_hi = -(self.rows - 1), other.rows - 1
            j_lo = max(k_lo, _ceil_div(lo - c, sa))
            return j_lo <= k_hi and j_lo * sa <= hi - c

        # Residue fast-reject: every t is ≡ c (mod gcd); if no member of
        # that class lands in the window, the bounded sets can't either.
        g = math.gcd(sa, sb)
        if g > 1 and lo + ((c - lo) % g) > hi:
            return False

        # Exact bounded decision: sweep the shorter operand's rows, answer
        # each row with one interval division on the other progression.
        if self.rows <= other.rows:
            for i in range(self.rows):
                base = i * sa - c
                if _progression_hits(sb, other.rows, base + lo, base + hi):
                    return True
        else:
            for j in range(other.rows):
                base = j * sb + c
                if _progression_hits(sa, self.rows, base - hi, base - lo):
                    return True
        return False

    def contains(self, other: "StridedRegion") -> bool:
        """True iff every byte of ``other`` is also a byte of ``self``. Exact.

        This is the cross-instruction reuse question the pipelined scheduler
        asks: a fresh operand binding may skip its DMA-in train when a copy of
        a *containing* region is already modeled resident and clean. Two
        regimes cover the general case exactly:

        * ``self`` with ``stride_bytes <= row_bytes`` (or a single row) tiles
          memory contiguously — its footprint is the flat interval
          ``[start, end)``, so bounding-interval inclusion is the answer.
        * ``self`` with inter-row gaps: no contained byte run can span two of
          ``self``'s rows (the gap would intrude), so every row of ``other``
          must land inside a single row of ``self`` — one divmod per row of
          ``other``, O(1) when the strides match (the column-tile case).
        """
        if other.start < self.start or other.end > self.end:
            return False
        if self.rows == 1 or self.stride_bytes <= self.row_bytes:
            return True          # contiguous footprint == bounding interval
        sa = self.stride_bytes
        if other.rows > 1 and other.stride_bytes == sa:
            # Equal strides: row j of other sits at the same intra-row offset
            # of self's row i0+j for every j — one check plus a row-count bound.
            i0, off = divmod(other.addr - self.addr, sa)
            return (off + other.row_bytes <= self.row_bytes
                    and i0 + other.rows <= self.rows)
        for j in range(other.rows):
            i, off = divmod(other.addr + j * other.stride_bytes - self.addr, sa)
            if i >= self.rows or off + other.row_bytes > self.row_bytes:
                return False
        return True

#: Bound on each level of a pairwise memo; when a level fills it is cleared
#: wholesale — the steady-state working set of a sweep is far smaller.
_PAIR_CACHE_LIMIT = 1 << 14

#: Top-level memo dicts, registered so ``clear_pair_memos`` can reach them.
_PAIR_MEMO_TABLES: list = []


def _pair_memo(decide, doc: str):
    """Build a memoized pairwise region decision.

    Two-level dicts keyed by the regions' plain int tuples (two
    allocation-free probes instead of hashing a composite key). Sound
    because regions are frozen and ``decide`` is pure. Steady-state
    strip-mined programs ask the same pairwise questions every iteration —
    hazard admission, WAR gating, reuse invalidation all revisit the same
    handful of strip footprints — so the hot-path callers (the alias
    index's exact confirmations) go through these bounded memos."""
    memo: dict = {}
    _PAIR_MEMO_TABLES.append(memo)

    def cached(a: StridedRegion, b: StridedRegion) -> bool:
        d = memo.get(a._key)
        if d is None:
            if len(memo) >= _PAIR_CACHE_LIMIT:
                memo.clear()
            d = memo[a._key] = {}
        v = d.get(b._key)
        if v is None:
            if len(d) >= _PAIR_CACHE_LIMIT:
                d.clear()
            v = d[b._key] = decide(a, b)
        return v

    cached.__doc__ = doc
    return cached


overlaps_cached = _pair_memo(
    StridedRegion.overlaps,
    "Memoized :meth:`StridedRegion.overlaps` (see ``_pair_memo``).")
contains_cached = _pair_memo(
    StridedRegion.contains,
    "Memoized :meth:`StridedRegion.contains` (see ``_pair_memo``).")


def clear_pair_memos() -> None:
    """Drop all memoized pairwise answers (results are unaffected — the
    memos are pure). Benchmarks call this between timed runs so no run
    inherits another's warm cache."""
    for t in _PAIR_MEMO_TABLES:
        t.clear()


def _ceil_div(a: int, b: int) -> int:
    return -((-a) // b)


def _progression_hits(step: int, count: int, lo: int, hi: int) -> bool:
    """Does ``{k * step : 0 <= k < count}`` intersect ``[lo, hi]``?"""
    if hi < lo:
        return False
    k_lo = max(0, _ceil_div(lo, step))
    return k_lo < count and k_lo * step <= hi


def footprints_overlap(a_addr: int, a_rows: int, a_row_bytes: int,
                       a_stride: int, b_addr: int, b_rows: int,
                       b_row_bytes: int, b_stride: int) -> bool:
    """Functional form of :meth:`StridedRegion.overlaps`."""
    return StridedRegion(a_addr, a_rows, a_row_bytes, a_stride).overlaps(
        StridedRegion(b_addr, b_rows, b_row_bytes, b_stride))
