"""Bit-exact xmnmc instruction encoding (RISC-V Custom-2 space, major opcode 0x5b).

The paper (§IV-A) places the extension in the 25-bit Custom-2 encoding space with
major opcode ``0x5b``. Each instruction carries three source registers whose
*contents* are split into 16-bit (hi, lo) pairs — four halves hold logical matrix
register indices and two hold scalar parameters (α, β) — see Table I. The kernel
selector is a 5-bit ``func5`` field (``xmkN``, N ∈ [0, 30]); ``xmr`` (matrix
reserve) takes the remaining code point (31). The element width suffix
``.w/.h/.b`` (32/16/8-bit) is encoded in ``funct3``.

Instruction word layout (R4-type, as used by the RISC-V "custom" major opcodes)::

    31    27 26  25 24   20 19   15 14    12 11   7 6      0
    [func5 ] [fmt ] [ rs2  ] [ rs1  ] [funct3] [ rd ] [opcode]
      kernel   0b10    reg      reg     width    reg    0x5b

``fmt`` = 0b10 marks the xmnmc sub-space (leaves 0b00/01/11 free for future
software-defined extensions). ``rs3`` is implicit: the bridge samples the three
operand registers named by the ABI (a0/a1/a2 by convention), so only rs1/rs2 hold
architectural register numbers here and rd receives the decode outcome.

This module is the framework's dispatch IR: the production engine and the
cache-runtime simulator both decode exactly these 32-bit words.
"""
from __future__ import annotations

import dataclasses
import enum

OPCODE_CUSTOM2 = 0x5B
FMT_XMNMC = 0b10

XMR_FUNC5 = 31          # xmr takes the code point outside xmkN, N in [0, 30]
NUM_XMK = 31            # xmk0 .. xmk30
NUM_MATRIX_REGS = 32    # logical matrix registers m0..m31 (16-bit field, ABI cap)


class ElemWidth(enum.IntEnum):
    """Element width suffix — funct3 encoding."""

    W = 0  # 32-bit
    H = 1  # 16-bit
    B = 2  # 8-bit

    @property
    def nbytes(self) -> int:
        # Tuple lookup by enum value — this sits in per-row hot loops, where
        # building a dict (and hashing enum members) per call showed up.
        return (4, 2, 1)[int(self)]

    @property
    def suffix(self) -> str:
        return ("w", "h", "b")[int(self)]

    @classmethod
    def from_suffix(cls, s: str) -> "ElemWidth":
        return {"w": cls.W, "h": cls.H, "b": cls.B}[s]


def _check_range(name: str, value: int, lo: int, hi: int) -> None:
    if not lo <= value <= hi:
        raise ValueError(f"{name}={value} out of range [{lo}, {hi}]")


@dataclasses.dataclass(frozen=True)
class InstrWord:
    """Decoded fields of one 32-bit xmnmc instruction word."""

    func5: int
    width: ElemWidth
    rs1: int = 10  # a0
    rs2: int = 11  # a1
    rd: int = 10   # a0 (decode outcome)

    def encode(self) -> int:
        _check_range("func5", self.func5, 0, 31)
        _check_range("rs1", self.rs1, 0, 31)
        _check_range("rs2", self.rs2, 0, 31)
        _check_range("rd", self.rd, 0, 31)
        return (
            (self.func5 << 27)
            | (FMT_XMNMC << 25)
            | (self.rs2 << 20)
            | (self.rs1 << 15)
            | (int(self.width) << 12)
            | (self.rd << 7)
            | OPCODE_CUSTOM2
        )

    @classmethod
    def decode(cls, word: int) -> "InstrWord":
        _check_range("word", word, 0, 0xFFFFFFFF)
        opcode = word & 0x7F
        if opcode != OPCODE_CUSTOM2:
            raise IllegalInstruction(f"opcode {opcode:#x} is not Custom-2 (0x5b)")
        fmt = (word >> 25) & 0b11
        if fmt != FMT_XMNMC:
            raise IllegalInstruction(f"fmt {fmt:#b} is not the xmnmc sub-space")
        funct3 = (word >> 12) & 0b111
        if funct3 > 2:
            raise IllegalInstruction(f"funct3 {funct3} is not a valid width suffix")
        return cls(
            func5=(word >> 27) & 0x1F,
            width=ElemWidth(funct3),
            rs1=(word >> 15) & 0x1F,
            rs2=(word >> 20) & 0x1F,
            rd=(word >> 7) & 0x1F,
        )

    @property
    def is_xmr(self) -> bool:
        return self.func5 == XMR_FUNC5

    @property
    def mnemonic(self) -> str:
        base = "xmr" if self.is_xmr else f"xmk{self.func5}"
        return f"{base}.{self.width.suffix}"


class IllegalInstruction(ValueError):
    """Raised by the decoder on a malformed word — the bridge replies 'reject'."""


def _pack16(hi: int, lo: int) -> int:
    _check_range("hi", hi, 0, 0xFFFF)
    _check_range("lo", lo, 0, 0xFFFF)
    return ((hi & 0xFFFF) << 16) | (lo & 0xFFFF)


def _unpack16(reg: int) -> tuple[int, int]:
    return (reg >> 16) & 0xFFFF, reg & 0xFFFF


@dataclasses.dataclass(frozen=True)
class Operands:
    """The three 32-bit source-register values sampled by the bridge.

    Table I layout (hi/lo halves of rs1, rs2, rs3). Which half means what is
    kernel-defined; accessors below follow the built-in kernels' conventions.
    """

    rs1: int
    rs2: int
    rs3: int

    # -- generic halves ----------------------------------------------------
    @property
    def hi1(self) -> int: return _unpack16(self.rs1)[0]
    @property
    def lo1(self) -> int: return _unpack16(self.rs1)[1]
    @property
    def hi2(self) -> int: return _unpack16(self.rs2)[0]
    @property
    def lo2(self) -> int: return _unpack16(self.rs2)[1]
    @property
    def hi3(self) -> int: return _unpack16(self.rs3)[0]
    @property
    def lo3(self) -> int: return _unpack16(self.rs3)[1]

    # -- Table I row: xmr --------------------------------------------------
    # hi(rs1)=hi(&A) lo(rs1)=lo(&A) hi(rs2)=stride lo(rs2)=md hi(rs3)=cols lo(rs3)=rows
    @classmethod
    def for_xmr(cls, addr: int, stride: int, md: int, cols: int, rows: int) -> "Operands":
        _check_range("addr", addr, 0, 0xFFFFFFFF)
        return cls(rs1=addr, rs2=_pack16(stride, md), rs3=_pack16(cols, rows))

    @property
    def xmr_addr(self) -> int: return self.rs1
    @property
    def xmr_stride(self) -> int: return self.hi2
    @property
    def xmr_md(self) -> int: return self.lo2
    @property
    def xmr_cols(self) -> int: return self.hi3
    @property
    def xmr_rows(self) -> int: return self.lo3

    # -- Table I row: xmk (GeMM-style full form) ---------------------------
    # hi(rs1)=alpha lo(rs1)=beta hi(rs2)=ms3 lo(rs2)=md hi(rs3)=ms1 lo(rs3)=ms2
    @classmethod
    def for_xmk(
        cls,
        md: int,
        ms1: int = 0,
        ms2: int = 0,
        ms3: int = 0,
        alpha: int = 0,
        beta: int = 0,
    ) -> "Operands":
        return cls(
            rs1=_pack16(alpha, beta),
            rs2=_pack16(ms3, md),
            rs3=_pack16(ms1, ms2),
        )

    @property
    def alpha(self) -> int: return self.hi1
    @property
    def beta(self) -> int: return self.lo1
    @property
    def ms3(self) -> int: return self.hi2
    @property
    def md(self) -> int: return self.lo2
    @property
    def ms1(self) -> int: return self.hi3
    @property
    def ms2(self) -> int: return self.lo3


@dataclasses.dataclass(frozen=True)
class Offload:
    """One offloaded instruction as it crosses the CV-X-IF: word + operand regs."""

    word: int
    operands: Operands

    @property
    def instr(self) -> InstrWord:
        return InstrWord.decode(self.word)


def encode_xmr(width: ElemWidth, addr: int, stride: int, md: int, cols: int, rows: int) -> Offload:
    _check_range("md", md, 0, NUM_MATRIX_REGS - 1)
    word = InstrWord(func5=XMR_FUNC5, width=width).encode()
    return Offload(word=word, operands=Operands.for_xmr(addr, stride, md, cols, rows))


def encode_xmk(
    n: int,
    width: ElemWidth,
    md: int,
    ms1: int = 0,
    ms2: int = 0,
    ms3: int = 0,
    alpha: int = 0,
    beta: int = 0,
) -> Offload:
    _check_range("xmk index", n, 0, NUM_XMK - 1)
    for name, m in (("md", md), ("ms1", ms1), ("ms2", ms2), ("ms3", ms3)):
        _check_range(name, m, 0, NUM_MATRIX_REGS - 1)
    word = InstrWord(func5=n, width=width).encode()
    return Offload(
        word=word,
        operands=Operands.for_xmk(md=md, ms1=ms1, ms2=ms2, ms3=ms3, alpha=alpha, beta=beta),
    )
