"""ARCANE core — the paper's contribution.

Simulator stack (paper-faithful): encoding → bridge → runtime (C-RT) →
cache/VPUs. Production stack: engine (trace-time decode + renaming) →
repro.kernels Pallas micro-programs.
"""
from repro.core.encoding import (ElemWidth, InstrWord, Offload, Operands,
                                 encode_xmk, encode_xmr, IllegalInstruction,
                                 OPCODE_CUSTOM2, XMR_FUNC5, NUM_XMK,
                                 NUM_MATRIX_REGS)
from repro.core.isa import (KernelCost, KernelDef, KernelError, KernelLibrary,
                            KernelSpec, default_library, fx_encode)
from repro.core.matrix import MatrixBinding, MatrixMap, np_dtype
from repro.core.regions import StridedRegion, footprints_overlap
from repro.core.cache import (ArcaneCache, CacheLocked, LineBusy, MainMemory,
                              ResourceStall)
from repro.core.address_table import AddressTable, RegionKind, RegionStatus
from repro.core.hazards import DependencyTracker, KernelDeps
from repro.core.runtime import CacheRuntime, PhaseStats
from repro.core.vpu import VPU, VPUGeometry, ResidentMatrix
from repro.core.bridge import ArcaneCoprocessor, Bridge, XifResult
from repro.core.program import (Buffer, KernelOp, KernelProgram,
                                ProgramBuilder, ProgramError, ProgramRun,
                                View, PROGRAM_VERSION, issue_program,
                                place_program, reference_images, run_program)
from repro.core.session import IssueHandle, RuntimeSession

__all__ = [
    "ElemWidth", "InstrWord", "Offload", "Operands", "encode_xmk", "encode_xmr",
    "IllegalInstruction", "OPCODE_CUSTOM2", "XMR_FUNC5", "NUM_XMK",
    "NUM_MATRIX_REGS", "KernelCost", "KernelDef", "KernelError",
    "KernelLibrary", "KernelSpec", "default_library", "fx_encode",
    "MatrixBinding", "MatrixMap", "np_dtype", "StridedRegion",
    "footprints_overlap", "ArcaneCache", "CacheLocked",
    "LineBusy", "MainMemory", "ResourceStall", "AddressTable", "RegionKind",
    "RegionStatus", "DependencyTracker", "KernelDeps", "CacheRuntime",
    "PhaseStats", "VPU", "VPUGeometry", "ResidentMatrix", "ArcaneCoprocessor",
    "Bridge", "XifResult", "Buffer", "KernelOp", "KernelProgram",
    "ProgramBuilder", "ProgramError", "ProgramRun", "View", "PROGRAM_VERSION",
    "issue_program", "place_program", "reference_images", "run_program",
    "IssueHandle", "RuntimeSession",
]
