"""KernelProgram — the first-class kernel-program IR (the xmnmc "tape").

Every program the simulator executes is a sequence of exactly two instruction
types (paper §IV): ``xmr`` matrix reservations and ``xmkN`` matrix kernels.
Until now each consumer hand-rolled that sequence — the examples drove the
coprocessor imperatively, the differential fuzzer kept a private replay loop,
and every benchmark driver built tapes a third way. This module makes the
program itself a value:

  * :class:`Buffer`    — a named main-memory image (placed data, seeded
    random contents, or a zero-initialised destination);
  * :class:`View`      — a strided sub-rectangle of a buffer (one ``xmr``
    reservation: ``stride`` = the buffer's row pitch);
  * :class:`KernelOp`  — one ``xmkN`` with its operand views, α/β or
    stride/window parameters, and a free-form provenance comment (the
    Listing-1 intrinsic call the op lowers);
  * :class:`KernelProgram` — the validated, serializable whole.

A program is *data*: plain frozen dataclasses over ints/strings/tuples, so
``==`` is structural, and :mod:`repro.lower.tracefile` round-trips it through
versioned JSONL without loss. Validation runs each kernel's registered
preamble (shape/param checking and destination-shape inference) before any
runtime sees the tape, so a malformed program fails at build time with the
op index, not mid-schedule.

Both runtimes consume programs through one entry point,
:func:`run_program` — the differential harness's ``_replay`` logic promoted
out of tests: place every buffer, bind each op's sources to m0..m2 and its
destination to m3, issue the kernel, barrier. :func:`reference_images` is the
matching functional oracle: it executes the same ops sequentially with the
library's numpy bodies on plain arrays — no cache, no scheduler — giving the
golden flushed-memory image every scheduler variant must reproduce.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.encoding import ElemWidth
from repro.core.isa import (KernelError, KernelLibrary, default_library,
                            fx_encode)
from repro.core.matrix import np_dtype

#: Bumped when the IR's serialized shape changes (tracefile headers carry it).
PROGRAM_VERSION = 1

#: Register assignment used by :func:`issue_program`: op sources bind to
#: m0..m2 in order, the destination reservation to m3 (the Listing-1 layout).
DST_REG = 3

BUFFER_INITS = ("zeros", "random", "data")

#: Per-kernel parameter schema: name -> default. ``maxpool`` travels its two
#: ints in the operand halves (Table I); every other builtin takes Q8.8 α/β.
PARAM_SPECS: dict[str, dict] = {
    "gemm": {"alpha": 1.0, "beta": 0.0},
    "leakyrelu": {"alpha": 0.0},
    "maxpool": {"stride": 2, "win_size": 2},
    "conv2d": {},
    "conv_layer": {},
}
#: Fallback schema for user-registered kernels (α/β scalars, like gemm).
DEFAULT_PARAM_SPEC = {"alpha": 0.0, "beta": 0.0}


class ProgramError(ValueError):
    """The program is malformed (validation failed before any execution)."""


# --------------------------------------------------------------------- IR
@dataclasses.dataclass(frozen=True)
class Buffer:
    """A named main-memory image.

    ``init`` selects how the bytes come to exist:
      * ``"data"``   — explicit contents (nested tuples of ints; host-stored);
      * ``"random"`` — seeded ``rng.integers(lo, hi, (rows, cols))``
        (host-stored, reproducible without shipping the bytes);
      * ``"zeros"``  — a destination: allocated, never written by the host.
    """

    name: str
    rows: int
    cols: int
    init: str = "zeros"
    seed: int = 0
    lo: int = -8
    hi: int = 8
    data: Optional[tuple] = None

    def materialize(self, width: ElemWidth) -> Optional[np.ndarray]:
        """The host-visible initial contents (None for a zeros buffer)."""
        dt = np_dtype(width)
        if self.init == "zeros":
            return None
        if self.init == "random":
            rng = np.random.default_rng(self.seed)
            return rng.integers(self.lo, self.hi, (self.rows, self.cols)) \
                .astype(dt)
        return np.asarray(self.data, dtype=np.int64) \
            .astype(dt, casting="unsafe")

    def nbytes(self, width: ElemWidth) -> int:
        return self.rows * self.cols * width.nbytes


@dataclasses.dataclass(frozen=True)
class View:
    """A strided sub-rectangle of a buffer — one ``xmr`` reservation.

    The reservation's stride is the buffer's row pitch (``buffer.cols``
    elements), so any view narrower than its buffer is a strided binding.
    """

    buf: str
    rows: int
    cols: int
    row0: int = 0
    col0: int = 0

    @property
    def shape(self) -> tuple[int, int]:
        return (self.rows, self.cols)

    def to_obj(self) -> list:
        return [self.buf, self.row0, self.col0, self.rows, self.cols]

    @classmethod
    def from_obj(cls, obj) -> "View":
        buf, row0, col0, rows, cols = obj
        return cls(buf=str(buf), row0=int(row0), col0=int(col0),
                   rows=int(rows), cols=int(cols))


ViewLike = Union[View, tuple, list]


def as_view(v: ViewLike) -> View:
    if isinstance(v, View):
        return v
    return View.from_obj(list(v))


@dataclasses.dataclass(frozen=True)
class KernelOp:
    """One ``xmkN``: kernel name, operand views, parameters, provenance."""

    kernel: str
    srcs: tuple[View, ...]
    dst: View
    # Canonicalized parameter dict (see PARAM_SPECS); missing keys mean the
    # kernel's default. Floats are Q8.8-range scalars, ints travel raw.
    params: dict = dataclasses.field(default_factory=dict)
    # Free-form provenance: the Listing-1 intrinsic call (or lowering site)
    # this op came from. Carried through serialization, ignored by execution.
    comment: str = ""


@dataclasses.dataclass(frozen=True)
class KernelProgram:
    """A validated, serializable xmnmc tape plus its named memory images."""

    name: str
    width: ElemWidth
    buffers: tuple[Buffer, ...]
    ops: tuple[KernelOp, ...]

    # ------------------------------------------------------------ helpers
    def buffer(self, name: str) -> Buffer:
        for b in self.buffers:
            if b.name == name:
                return b
        raise ProgramError(f"no buffer named {name!r}")

    @property
    def n_ops(self) -> int:
        return len(self.ops)

    # --------------------------------------------------------- validation
    def validate(self, library: Optional[KernelLibrary] = None
                 ) -> "KernelProgram":
        """Structural + semantic validation; returns self or raises
        :class:`ProgramError` naming the offending buffer/op."""
        lib = library or default_library()
        by_func5 = {name: f5 for f5, name in lib.names().items()}
        dims: dict[str, tuple[int, int]] = {}
        for b in self.buffers:
            if not b.name:
                raise ProgramError("buffer with empty name")
            if b.name in dims:
                raise ProgramError(f"duplicate buffer name {b.name!r}")
            if b.rows <= 0 or b.cols <= 0:
                raise ProgramError(f"buffer {b.name!r}: non-positive shape "
                                   f"{(b.rows, b.cols)}")
            if b.init not in BUFFER_INITS:
                raise ProgramError(f"buffer {b.name!r}: unknown init "
                                   f"{b.init!r} (want one of {BUFFER_INITS})")
            if b.init == "data":
                arr = np.asarray(b.data, dtype=np.int64) \
                    if b.data is not None else None
                if arr is None or arr.shape != (b.rows, b.cols):
                    got = None if arr is None else arr.shape
                    raise ProgramError(f"buffer {b.name!r}: data shape {got} "
                                       f"!= {(b.rows, b.cols)}")
            dims[b.name] = (b.rows, b.cols)

        def check_view(where: str, v: View) -> None:
            if v.buf not in dims:
                raise ProgramError(f"{where}: unknown buffer {v.buf!r}")
            br, bc = dims[v.buf]
            if v.rows <= 0 or v.cols <= 0 or v.row0 < 0 or v.col0 < 0 \
                    or v.row0 + v.rows > br or v.col0 + v.cols > bc:
                raise ProgramError(
                    f"{where}: view {v.rows}x{v.cols}@({v.row0},{v.col0}) "
                    f"outside buffer {v.buf!r} ({br}x{bc})")

        for i, op in enumerate(self.ops):
            where = f"op {i} ({op.kernel})"
            if op.kernel not in by_func5:
                raise ProgramError(f"{where}: kernel not in library "
                                   f"{sorted(by_func5)}")
            kdef = lib.lookup(by_func5[op.kernel])
            if len(op.srcs) != kdef.n_sources:
                raise ProgramError(f"{where}: {len(op.srcs)} sources, kernel "
                                   f"takes {kdef.n_sources}")
            for v in op.srcs:
                check_view(where, v)
            check_view(where, op.dst)
            spec = PARAM_SPECS.get(op.kernel, DEFAULT_PARAM_SPEC)
            unknown = set(op.params) - set(spec)
            if unknown:
                raise ProgramError(f"{where}: unknown params {sorted(unknown)}"
                                   f" (schema: {sorted(spec)})")
            try:
                rt_params = runtime_params(op.kernel, op.params)
                dst_shape, _ = kdef.preamble(
                    [v.shape for v in op.srcs], rt_params, self.width)
            except KernelError as e:
                raise ProgramError(f"{where}: preamble rejected: {e}") from e
            if tuple(dst_shape) != op.dst.shape:
                raise ProgramError(f"{where}: destination view {op.dst.shape}"
                                   f" != preamble-inferred {tuple(dst_shape)}")
        return self

    # ------------------------------------------------------ serialization
    def to_obj(self) -> dict:
        """A JSON-ready plain-dict form (see repro.lower.tracefile)."""
        return {
            "name": self.name,
            "width": self.width.suffix,
            "buffers": [dataclasses.asdict(b) for b in self.buffers],
            "ops": [{"kernel": op.kernel,
                     "srcs": [v.to_obj() for v in op.srcs],
                     "dst": op.dst.to_obj(),
                     "params": dict(op.params),
                     "comment": op.comment} for op in self.ops],
        }

    @classmethod
    def from_obj(cls, obj: dict) -> "KernelProgram":
        def buf(d: dict) -> Buffer:
            data = d.get("data")
            if data is not None:
                data = tuple(tuple(int(x) for x in row) for row in data)
            return Buffer(name=str(d["name"]), rows=int(d["rows"]),
                          cols=int(d["cols"]), init=str(d.get("init", "zeros")),
                          seed=int(d.get("seed", 0)), lo=int(d.get("lo", -8)),
                          hi=int(d.get("hi", 8)), data=data)

        def op(d: dict) -> KernelOp:
            return KernelOp(kernel=str(d["kernel"]),
                            srcs=tuple(View.from_obj(v) for v in d["srcs"]),
                            dst=View.from_obj(d["dst"]),
                            params=dict(d.get("params", {})),
                            comment=str(d.get("comment", "")))

        try:
            return cls(name=str(obj.get("name", "")),
                       width=ElemWidth.from_suffix(obj["width"]),
                       buffers=tuple(buf(b) for b in obj["buffers"]),
                       ops=tuple(op(o) for o in obj["ops"]))
        except (KeyError, TypeError, ValueError) as e:
            raise ProgramError(f"malformed program object: {e}") from e


# ------------------------------------------------------------- parameters
def runtime_params(kernel: str, params: dict) -> dict:
    """Encode IR params into the operand-half form the decoder/bodies see:
    maxpool's stride/win travel raw in the halves (Table I); everything else
    carries Q8.8-encoded α/β (range-checked here — out-of-range scalars are
    a validation error, exactly as the decoder would kill the offload)."""
    spec = PARAM_SPECS.get(kernel, DEFAULT_PARAM_SPEC)
    merged = {**spec, **params}
    if kernel == "maxpool":
        return {"stride": int(merged["stride"]),
                "win_size": int(merged["win_size"])}
    out = {}
    if "alpha" in merged:
        out["alpha"] = fx_encode(float(merged["alpha"]))
    if "beta" in merged:
        out["beta"] = fx_encode(float(merged["beta"]))
    return out


def _operand_halves(kernel: str, params: dict) -> tuple[int, int]:
    """(alpha, beta) 16-bit operand halves for the xmk encoding."""
    rp = runtime_params(kernel, params)
    if kernel == "maxpool":
        return rp["stride"], rp["win_size"]
    return rp.get("alpha", 0), rp.get("beta", 0)


# ---------------------------------------------------------------- builder
class ProgramBuilder:
    """Mutable convenience layer over the frozen IR.

    Lowerings and generators call :meth:`buffer`/:meth:`data`/:meth:`op` and
    finish with :meth:`build`, which freezes and validates. Views may be
    passed as ``View`` or ``(buf, row0, col0, rows, cols)`` tuples.
    """

    def __init__(self, name: str, width: ElemWidth,
                 library: Optional[KernelLibrary] = None):
        self.name = name
        self.width = width
        self.library = library
        self._buffers: list[Buffer] = []
        self._names: set[str] = set()
        self._ops: list[KernelOp] = []

    def _add(self, b: Buffer) -> str:
        if b.name in self._names:
            raise ProgramError(f"duplicate buffer name {b.name!r}")
        self._names.add(b.name)
        self._buffers.append(b)
        return b.name

    def buffer(self, name: str, rows: int, cols: int, *, init: str = "zeros",
               seed: int = 0, lo: int = -8, hi: int = 8) -> str:
        """Declare a zeros or seeded-random buffer; returns its name."""
        return self._add(Buffer(name=name, rows=rows, cols=cols, init=init,
                                seed=seed, lo=lo, hi=hi))

    def data(self, name: str, array) -> str:
        """Declare a buffer with explicit contents; returns its name."""
        arr = np.asarray(array)
        if arr.ndim != 2:
            raise ProgramError(f"buffer {name!r}: data must be 2D, "
                               f"got shape {arr.shape}")
        rows = tuple(tuple(int(x) for x in row) for row in arr)
        return self._add(Buffer(name=name, rows=arr.shape[0],
                                cols=arr.shape[1], init="data", data=rows))

    def view(self, buf: str, rows: int, cols: int, row0: int = 0,
             col0: int = 0) -> View:
        return View(buf=buf, rows=rows, cols=cols, row0=row0, col0=col0)

    def full(self, buf: str) -> View:
        """A whole-buffer view (dense reservation)."""
        for b in self._buffers:
            if b.name == buf:
                return View(buf=buf, rows=b.rows, cols=b.cols)
        raise ProgramError(f"no buffer named {buf!r}")

    def op(self, kernel: str, srcs: Sequence[ViewLike], dst: ViewLike,
           comment: str = "", **params) -> KernelOp:
        op = KernelOp(kernel=kernel,
                      srcs=tuple(as_view(v) for v in srcs),
                      dst=as_view(dst), params=dict(params), comment=comment)
        self._ops.append(op)
        return op

    def build(self) -> KernelProgram:
        prog = KernelProgram(name=self.name, width=self.width,
                             buffers=tuple(self._buffers),
                             ops=tuple(self._ops))
        return prog.validate(self.library)


# -------------------------------------------------------------- execution
@dataclasses.dataclass
class ProgramRun:
    """Handle to a completed :func:`run_program`: the coprocessor plus the
    buffer placement, with typed readback helpers."""

    prog: KernelProgram
    cop: "object"                       # ArcaneCoprocessor
    addrs: dict[str, int]

    @property
    def rt(self):
        return self.cop.rt

    def gather(self, name: str) -> np.ndarray:
        """Hazard-checked host load of one buffer (through the cache)."""
        b = self.prog.buffer(name)
        return self.cop.gather(self.addrs[name], b.rows, b.cols,
                               self.prog.width)

    def flushed_images(self) -> dict[str, np.ndarray]:
        """Flush the LLC, then read every buffer straight from main memory —
        the image the bit-identity and golden-oracle checks compare."""
        self.rt.cache.flush_all()
        dt = np_dtype(self.prog.width)
        out = {}
        for b in self.prog.buffers:
            a = self.addrs[b.name]
            raw = self.rt.memory.data[a:a + b.nbytes(self.prog.width)]
            out[b.name] = raw.copy().view(dt).reshape(b.rows, b.cols)
        return out


def _as_cop(rt_or_cop):
    from repro.core.bridge import ArcaneCoprocessor
    if isinstance(rt_or_cop, ArcaneCoprocessor):
        return rt_or_cop
    return ArcaneCoprocessor(runtime=rt_or_cop)


def place_program(rt_or_cop, prog: KernelProgram,
                  prior: Optional[dict[str, int]] = None) -> dict[str, int]:
    """Place every buffer of ``prog`` into simulated main memory (host-store
    for data/random images, bare allocation for zeros destinations); returns
    the name→address map. Split out of :func:`run_program` so throughput
    benchmarks can keep placement outside the timed region.

    ``prior`` maps already-placed buffer names to their addresses (shared
    weights, a request's KV buffers carried across step programs): those are
    reused as-is — neither re-allocated nor re-initialised, so state written
    by earlier programs survives — and the returned map merges both."""
    cop = _as_cop(rt_or_cop)
    addrs: dict[str, int] = dict(prior) if prior else {}
    for b in prog.buffers:
        if b.name in addrs:
            continue
        arr = b.materialize(prog.width)
        if arr is None:
            addrs[b.name] = cop.malloc(b.nbytes(prog.width))
        else:
            addrs[b.name] = cop.place(arr, prog.width)
    return addrs


def issue_program(rt_or_cop, prog: KernelProgram, addrs: dict[str, int],
                  barrier: bool = True) -> None:
    """Issue ``prog``'s instruction stream: per op, one ``xmr`` per source
    (m0..m2), one for the destination (m3), then the ``xmkN`` — the
    differential harness's replay loop, now the only one in the tree."""
    cop = _as_cop(rt_or_cop)
    width = prog.width
    eb = width.nbytes
    dims = {b.name: (b.rows, b.cols) for b in prog.buffers}
    lib = cop.rt.library
    by_func5 = {name: f5 for f5, name in lib.names().items()}

    def bind(reg: int, v: View) -> None:
        bc = dims[v.buf][1]
        addr = addrs[v.buf] + (v.row0 * bc + v.col0) * eb
        cop._xmr(width, reg, addr, bc, v.rows, v.cols)

    for op in prog.ops:
        for reg, v in enumerate(op.srcs):
            bind(reg, v)
        bind(DST_REG, op.dst)
        alpha, beta = _operand_halves(op.kernel, op.params)
        ms = [0, 0, 0]
        ms[:len(op.srcs)] = range(len(op.srcs))
        cop.xmk(by_func5[op.kernel], width, DST_REG, ms1=ms[0], ms2=ms[1],
                ms3=ms[2], alpha=alpha, beta=beta)
    if barrier:
        cop.barrier()


def run_program(rt_or_cop, prog: KernelProgram, *,
                validate: bool = True, barrier: bool = True) -> ProgramRun:
    """The single entry point both runtimes consume programs through — now a
    thin wrapper over a *closed* :class:`~repro.core.session.RuntimeSession`:
    issue everything at t0, drain. A closed session keeps the legacy batch
    discipline (queue backpressure drains eagerly), so this is bit-identical
    to the pre-session path; the differential fuzzer pins that down.
    ``rt_or_cop`` is a :class:`~repro.core.runtime.CacheRuntime`, a
    :class:`~repro.sim.PipelinedRuntime`, or an already-wrapped
    :class:`~repro.core.bridge.ArcaneCoprocessor`."""
    # Function-level import: session imports this module's helpers.
    from repro.core.session import RuntimeSession
    cop = _as_cop(rt_or_cop)
    sess = RuntimeSession(cop, open_loop=False, validate=validate)
    h = sess.issue(prog)
    if barrier:
        sess.drain()
    return ProgramRun(prog=prog, cop=cop, addrs=h.addrs)


# ----------------------------------------------------------------- oracle
def reference_images(prog: KernelProgram,
                     library: Optional[KernelLibrary] = None
                     ) -> dict[str, np.ndarray]:
    """Execute ``prog`` sequentially on plain numpy arrays — no cache, no
    scheduler, no DMA — using the same registered kernel bodies the VPUs run.
    Returns the expected final contents of every buffer: the golden image a
    flushed run of either scheduler must match bit for bit."""
    lib = library or default_library()
    by_func5 = {name: f5 for f5, name in lib.names().items()}
    dt = np_dtype(prog.width)
    imgs: dict[str, np.ndarray] = {}
    for b in prog.buffers:
        arr = b.materialize(prog.width)
        imgs[b.name] = (np.zeros((b.rows, b.cols), dtype=dt)
                        if arr is None else arr.copy())
    for op in prog.ops:
        kdef = lib.lookup(by_func5[op.kernel])
        srcs = [imgs[v.buf][v.row0:v.row0 + v.rows,
                            v.col0:v.col0 + v.cols].copy()
                for v in op.srcs]
        out = kdef.body(srcs, runtime_params(op.kernel, op.params),
                        prog.width)
        d = op.dst
        imgs[d.buf][d.row0:d.row0 + d.rows, d.col0:d.col0 + d.cols] = \
            np.asarray(out).astype(dt, casting="unsafe")
    return imgs
