"""C-RT — the Cache Runtime executed by the eCPU (paper §IV-B).

Three cooperating modules around a statically-allocated kernel queue
(producer–consumer, single-threaded preemptive in hardware; cooperative here):

  * **Kernel Decoder** — runs in the "interrupt handler" when the bridge
    latches an offload: O(1) kernel-library lookup by func5, preamble
    (validation + destination shape inference), hazard check with
    logical-matrix renaming, AT registration, queue push.
  * **Kernel Scheduler** — pops ready kernels (dependency DAG), selects the
    VPU with the fewest dirty cache lines, drives the Matrix Allocator, runs
    the kernel, and decides whether to defer the destination write-back
    (kept resident if a queued kernel will read it).
  * **Matrix Allocator** — acquires the cache lock, claims vector registers,
    programs 2D DMA transfers (memory→VPU with kernel-chosen layout;
    VPU→memory consolidation on write-back), releases lock and AT regions.

Phase cycle/time accounting (preamble / allocation / compute / writeback)
feeds the Fig. 3 reproduction benchmark directly.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from collections import deque
from typing import Callable, Optional

from repro.core.address_table import AddressTable, RegionKind
from repro.core.alias_index import AliasIndex
from repro.core.cache import ArcaneCache, MainMemory
from repro.core.dataflow import resolve as resolve_dataflow
from repro.core.encoding import ElemWidth, Offload, NUM_MATRIX_REGS
from repro.core.hazards import DependencyTracker, KernelDeps
from repro.core.isa import KernelError, KernelLibrary, KernelSpec, default_library
from repro.core.matrix import MatrixBinding, MatrixMap
from repro.core.regions import StridedRegion
from repro.core.vpu import VPU, VPUGeometry, ResidentMatrix


@dataclasses.dataclass
class PhaseStats:
    """Modeled cycles and wall-clock per C-RT phase (Fig. 3 axes)."""

    preamble_cycles: int = 0
    allocation_cycles: int = 0
    compute_cycles: int = 0
    writeback_cycles: int = 0
    preamble_s: float = 0.0
    allocation_s: float = 0.0
    compute_s: float = 0.0
    writeback_s: float = 0.0
    kernels_run: int = 0
    # Cross-instruction operand reuse (pipelined scheduler only): DMA-in
    # trains skipped because a containing region was already modeled resident
    # and clean in the dispatch VPU's data array, and the transfer cycles
    # those skips avoided (excluded from allocation_cycles/total_cycles).
    reuse_hits: int = 0
    reused_dma_cycles: int = 0
    # Fault-recovery overhead (ECC scrubs, replay backoff + re-execution):
    # part of total_cycles — recovery really costs modeled time — but kept
    # out of the four phase buckets so Fig. 3 shares stay comparable.
    fault_cycles: int = 0

    @property
    def total_cycles(self) -> int:
        return (self.preamble_cycles + self.allocation_cycles
                + self.compute_cycles + self.writeback_cycles
                + self.fault_cycles)

    def shares(self) -> dict[str, float]:
        t = max(self.total_cycles, 1)
        return {
            "preamble": self.preamble_cycles / t,
            "allocation": self.allocation_cycles / t,
            "compute": self.compute_cycles / t,
            "writeback": self.writeback_cycles / t,
        }

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, type(getattr(self, f.name))())


@dataclasses.dataclass
class QueuedKernel:
    deps: KernelDeps
    spec: KernelSpec
    src_bindings: tuple[MatrixBinding, ...]
    dst_binding: MatrixBinding


@dataclasses.dataclass
class Allocation:
    """Result of the Matrix Allocator step for one kernel.

    ``dma_segments`` records each memory→VPU source transfer as
    ``(src_idx, rows, dma_cycles)`` — the pipelined scheduler chunks these
    into per-operand row-granular activity trains (``src_idx`` identifies
    which operand's dataflow policy gates the chunks; operands already
    resident, including repeated ones, produce no segment); the serial
    scheduler only uses the totals.
    """

    src_res: list[ResidentMatrix]
    dst_res: ResidentMatrix
    dma_cycles: int
    wb_cycles: int
    dma_segments: list[tuple[int, int, int]]  # (src_idx, rows, cycles) per DMA-in
    wb_segments: list[tuple[int, int]]       # (vpu, cycles) per consolidation


class CacheRuntime:
    """The C-RT instance owning one ARCANE LLC."""

    def __init__(
        self,
        memory: Optional[MainMemory] = None,
        *,
        n_vpus: int = 4,
        vregs_per_vpu: int = 32,
        vlen_bytes: int = 1024,
        lanes: int = 4,
        queue_capacity: int = 16,
        library: Optional[KernelLibrary] = None,
        num_matrix_regs: int = NUM_MATRIX_REGS,
        geometry: Optional[VPUGeometry] = None,
        metrics: bool = True,
        faults=None,
    ):
        # Function-level import: repro.sim.metrics is dependency-free, but a
        # module-level import would trigger repro.sim.__init__ → pipeline →
        # this module while it is still initialising.
        from repro.sim.metrics import SchedulerMetrics
        from repro.sim.faults import as_fault_plan
        self.memory = memory or MainMemory(16 << 20)
        self.cache = ArcaneCache(self.memory, n_vpus=n_vpus,
                                 vregs_per_vpu=vregs_per_vpu,
                                 vlen_bytes=vlen_bytes)
        self.geometry = geometry or VPUGeometry(lanes=lanes,
                                                vlen_bytes=vlen_bytes)
        self.library = library or default_library()
        self.vpus = [VPU(i, self.cache, self.geometry, self.library)
                     for i in range(n_vpus)]
        self.matrix_map = MatrixMap(num_matrix_regs)
        self.at = AddressTable(capacity=4 * queue_capacity)
        self.tracker = DependencyTracker()
        self.queue_capacity = queue_capacity
        self.queue: deque[QueuedKernel] = deque()
        self.resident: dict[int, ResidentMatrix] = {}   # phys_id -> residency
        # Footprints of resident matrices, keyed by phys_id, plus a claim
        # sequence number per residency: the dirty-alias flush sweeps query
        # the index for overlap candidates (O(hits), not O(residents)) and
        # replay them in claim order — the same order the plain dict scan
        # used, so flush-ordering behaviour is unchanged.
        self._resident_index = AliasIndex()
        self._resident_seq: dict[int, int] = {}
        self._claim_counter = itertools.count()
        self.stats = PhaseStats()
        # Unified metrics layer (purely observational — never consulted by
        # any scheduling decision, so metrics on/off cannot change schedules).
        self.metrics = SchedulerMetrics(enabled=metrics)
        # Fault-injection plan (None = faults off, the default). The plan is
        # keyed by kernel id only, so both schedulers draw identical faults
        # for the same program. ``offline`` holds hard-faulted VPU indices:
        # they accept no new work, and their residents are evacuated.
        self.faults = as_fault_plan(faults)
        self.offline: set[int] = set()
        if self.faults is not None and self.faults.cfg.hard_at and \
                not 0 <= self.faults.cfg.hard_vpu < n_vpus:
            raise ValueError(
                f"faults.hard_vpu {self.faults.cfg.hard_vpu} out of range "
                f"for {n_vpus} VPUs")
        # When set (by a scheduler wanting per-port timing), every
        # consolidation DMA appends (vpu, cycles) here — the transfer runs on
        # the port of the VPU *holding* the resident, not the dispatch VPU.
        self._wb_segments: Optional[list[tuple[int, int]]] = None
        # ---- re-entrant session protocol (see repro.core.session) ----
        # The serial clock is modeled-cycles-so-far plus injected idle (the
        # gaps between a drain finishing and the next posted arrival).
        self._session_idle = 0
        self._session_posts: list[tuple[int, int, Callable]] = []
        self._post_seq = itertools.count()
        # Re-entrancy guards: completion callbacks may issue new kernels from
        # *inside* a drain. ``_running`` stops the serial fixpoint loop from
        # nesting; ``_in_loop`` is the pipelined scheduler's event-loop flag
        # (defined here so shared helpers can consult it either way).
        self._running = False
        self._in_loop = False
        self._session_open = False
        # Issue capture + completion watchers: a session wraps issue_program
        # with a capture hook so it learns the kernel ids a program decoded
        # into, and registers per-kernel callbacks fired exactly once when
        # the kernel retires (serial _run_one or pipelined compute_done).
        self._issue_capture: Optional[Callable[[int], None]] = None
        self._retire_watchers: dict[int, list[Callable[[int], None]]] = {}

    # ================================================================ decoder
    def decode(self, off: Offload) -> None:
        """Kernel Decoder: software-decode one offloaded instruction."""
        t0 = time.perf_counter()
        instr = off.instr
        ops = off.operands
        if instr.is_xmr:
            # xmr: pure metadata — bind (rename) the logical register.
            self.matrix_map.reserve(
                logical=ops.xmr_md,
                addr=ops.xmr_addr,
                rows=ops.xmr_rows,
                cols=ops.xmr_cols,
                stride=self._xmr_stride(ops),
                width=instr.width,
            )
            self.stats.preamble_cycles += self.geometry.decode_cycles // 4
            self.stats.preamble_s += time.perf_counter() - t0
            return

        if len(self.queue) >= self.queue_capacity:
            # Static queue full: drain before accepting (backpressure).
            self.run_pending()

        kdef = self.library.lookup(instr.func5)
        srcs = [self.matrix_map.lookup(m)
                for m in (ops.ms1, ops.ms2, ops.ms3)[: kdef.n_sources]]
        params = {"alpha": ops.alpha, "beta": ops.beta}
        if instr.func5 == 2:  # maxpool packs stride/win in rs1 (Table I)
            params = {"stride": ops.hi1, "win_size": ops.lo1}
        dst_shape, cost = kdef.preamble([s.shape for s in srcs], params, instr.width)

        dst_prev = self.matrix_map.lookup(ops.md)
        # Destination keeps its reservation's memory footprint but gets shape
        # from the preamble (effective dims allocation, §IV-B3).
        if dst_shape[0] * dst_shape[1] * instr.width.nbytes > \
           dst_prev.rows * dst_prev.cols * dst_prev.elem_bytes:
            raise KernelError(
                f"{kdef.name}: result {dst_shape} exceeds m{ops.md} reservation")
        dst = self.matrix_map.reserve(
            logical=ops.md, addr=dst_prev.addr, rows=dst_shape[0],
            cols=dst_shape[1], stride=max(dst_prev.stride, dst_shape[1]),
            width=instr.width,
        )

        spec = KernelSpec(func5=instr.func5, name=kdef.name, width=instr.width,
                          src_shapes=tuple(s.shape for s in srcs),
                          dst_shape=dst_shape, params=params, cost=cost,
                          dataflow=resolve_dataflow(
                              kdef.dataflow, tuple(s.shape for s in srcs),
                              params, instr.width))
        # Capacity pressure: make room in the Address Table *before* admitting
        # (a failed registration mid-admission would leak tracker state).
        # Repeated operands and regions already registered only up-ref, so
        # count the genuinely fresh slots. The drain first retires the queue,
        # then lands deferred write-backs — each release frees an AT entry —
        # and only a table that stays full after that raises.
        at_regions = ([(s.phys_id, RegionKind.SRC) for s in srcs]
                      + [(dst.phys_id, RegionKind.DST)])
        if self.at.free_slots() < len(at_regions):
            # Only compute the exact fresh-slot count (set algebra) when the
            # free count could actually be short of the worst case.
            self._relieve_at_pressure(self.at.slots_needed(at_regions))
        deps = self.tracker.admit(srcs, dst)
        for s in srcs:
            self.at.register(s.region, RegionKind.SRC, s.phys_id)
        self.at.register(dst.region, RegionKind.DST, dst.phys_id)
        self.queue.append(QueuedKernel(deps=deps, spec=spec,
                                       src_bindings=tuple(srcs), dst_binding=dst))
        self.stats.preamble_cycles += self.geometry.decode_cycles
        self.stats.preamble_s += time.perf_counter() - t0
        self.metrics.inc("kernels.decoded")
        if self._issue_capture is not None:
            # Capture at decode time (not after issue_program returns):
            # queue backpressure can retire early kernels of a long program
            # mid-issue, and their completion watchers must already exist.
            self._issue_capture(deps.kernel_id)

    @staticmethod
    def _xmr_stride(ops) -> int:
        # Table I: A.stride is in elements; 0 means dense (stride = cols).
        # A nonzero stride below cols would make rows overlap in memory —
        # reject it instead of silently clamping to dense (the clamp changed
        # which bytes the program addressed without telling anyone).
        if ops.xmr_stride == 0:
            return ops.xmr_cols
        if ops.xmr_stride < ops.xmr_cols:
            raise KernelError(
                f"xmr: stride {ops.xmr_stride} < cols {ops.xmr_cols} "
                f"(Table I: stride is in elements; 0 means dense)")
        return ops.xmr_stride

    # ============================================================== scheduler
    def _select_vpu(self, needed_lines: int) -> int:
        """Fewest-dirty-lines policy (§IV-B2) among VPUs with capacity.

        Offlined (hard-faulted) VPUs are never candidates — graceful
        degradation redistributes work across the survivors."""
        best, best_key = -1, None
        for v in range(self.cache.n_vpus):
            if v in self.offline:
                continue
            free = self.cache.free_line_count(v)
            if free < needed_lines:
                continue
            key = (self.cache.dirty_line_count(v), -free)
            if best_key is None or key < best_key:
                best, best_key = v, key
        if best < 0:
            raise RuntimeError("no VPU has capacity for the kernel operands")
        return best

    def run_pending(self) -> None:
        """Drain the kernel queue respecting the dependency DAG.

        Re-entrant calls (a completion watcher issuing new kernels from
        inside ``_run_one``) return immediately: the outer fixpoint loop
        re-checks the queue every pass, so nested work is picked up without
        recursing."""
        if self._running:
            return
        self._running = True
        try:
            progress = True
            while self.queue and progress:
                progress = False
                for _ in range(len(self.queue)):
                    qk = self.queue.popleft()
                    if self.tracker.ready(qk.deps.kernel_id):
                        self._run_one(qk)
                        progress = True
                    else:
                        self.queue.append(qk)
        finally:
            self._running = False

    def _run_one(self, qk: QueuedKernel) -> None:
        t0 = time.perf_counter()
        kid = qk.deps.kernel_id
        # A scheduled hard fault due at (or before) the current clock fires
        # before this kernel is placed, so placement sees the survivor set.
        self._maybe_hard_fault(self.session_now())
        kf = self.faults.kernel_faults(kid) if self.faults is not None \
            else None
        vpu = self.vpus[self._choose_vpu(qk)]

        # -------------------------------------------------- allocation phase
        alloc = self._allocation_step(qk, vpu)
        self.stats.allocation_cycles += (self.geometry.schedule_cycles
                                         + alloc.dma_cycles)
        self.stats.writeback_cycles += alloc.wb_cycles
        self.stats.allocation_s += time.perf_counter() - t0

        # ------------------------------------------- ECC tier (fault model)
        fault_cycles = 0
        if kf is not None and kf.ecc_bits:
            fault_cycles += self._fault_scrub(qk, alloc, kf)

        # ----------------------------------------------------- compute phase
        t1 = time.perf_counter()
        cycles = self._compute_step(qk, vpu, alloc.src_res, alloc.dst_res)
        self.stats.compute_cycles += cycles
        self.stats.compute_s += time.perf_counter() - t1

        # ---------------------------------------- replay tier (fault model)
        if kf is not None and kf.replays:
            for attempt in range(kf.replays):
                self._fault_corrupt_dst(qk, alloc, attempt)
                rc = self._compute_step(qk, vpu, alloc.src_res, alloc.dst_res)
                fault_cycles += self.faults.backoff(attempt) + rc
                self.metrics.inc("faults.injected")
                self.metrics.inc("faults.replayed")
                self.metrics.observe("fault.replay_latency_cycles",
                                     self.faults.backoff(attempt) + rc)
        self.stats.fault_cycles += fault_cycles

        # --------------------------------------------------- writeback phase
        t2 = time.perf_counter()
        retire_wb = self._retire_step(qk, alloc.src_res, alloc.dst_res)
        self.stats.writeback_cycles += retire_wb
        self.stats.writeback_s += time.perf_counter() - t2
        self.stats.kernels_run += 1
        # Serial stall synthesis: phases run back-to-back, so the window is
        # exactly the phase totals (conserved by construction).
        self.metrics.kernel_serial(
            kid, qk.spec.name, busy=cycles,
            bins={"cache_lock": self.geometry.schedule_cycles,
                  "dma_wait": alloc.dma_cycles,
                  "drain": alloc.wb_cycles + retire_wb,
                  "fault_replay": fault_cycles})
        self._notify_retired(kid, self.session_now())
        # Retry exhaustion: the kernel completed on scrubbed state, but the
        # datapath is deemed faulty — fence it after the retire.
        if kf is not None and kf.exhausted:
            self._offline_vpu(vpu.index, self.session_now())

    # ------------------------------------------------- shared scheduler steps
    # The serial scheduler above and repro.sim.pipeline.PipelinedRuntime both
    # drive exactly these four steps; only *when* each step runs differs, so
    # the numerical results are identical by construction.
    def _choose_vpu(self, qk: QueuedKernel) -> int:
        """VPU selection: resident-operand affinity, else fewest-dirty-lines.

        Affinity never points at an offlined VPU: its surviving residents
        (if any) are consolidated through memory by the cross-VPU path in
        ``_allocate_source`` when a healthy VPU picks the kernel up."""
        for s in qk.src_bindings:
            r = self.resident.get(s.phys_id)
            if r is not None and r.vpu not in self.offline:
                return r.vpu
        return self._select_vpu(self._lines_for(qk))

    def _lines_for(self, qk: QueuedKernel) -> int:
        return sum(
            self.vpus[0].lines_needed(*s.shape, s.width) for s in qk.src_bindings
        ) + self.vpus[0].lines_needed(*qk.dst_binding.shape, qk.dst_binding.width)

    def _allocation_step(self, qk: QueuedKernel, vpu: VPU) -> Allocation:
        """Matrix Allocator: lock, claim vregs, 2D-DMA the operands in.

        Returns an :class:`Allocation`; the caller attributes the cycles
        (allocation vs writeback phase) and may re-chunk ``dma_segments``
        into row-granular timing activities.
        """
        if not self.cache.acquire_lock():
            raise RuntimeError("cache lock already held")
        dma_cycles = wb_cycles = 0
        segments: list[tuple[int, int, int]] = []
        self._wb_segments = wb_segments = []
        try:
            src_res = []
            for si, s in enumerate(qk.src_bindings):
                res, dma_c, wb_c = self._allocate_source(vpu, s)
                src_res.append(res)
                dma_cycles += dma_c
                wb_cycles += wb_c
                if dma_c:
                    segments.append((si, s.rows, dma_c))
                self.at.mark_allocated(s.phys_id)
            dst_res = self._allocate_destination(vpu, qk.dst_binding)
        finally:
            self.cache.release_lock()
            self._wb_segments = None
        return Allocation(src_res=src_res, dst_res=dst_res,
                          dma_cycles=dma_cycles, wb_cycles=wb_cycles,
                          dma_segments=segments, wb_segments=wb_segments)

    def _compute_step(self, qk: QueuedKernel, vpu: VPU,
                      src_res: list[ResidentMatrix],
                      dst_res: ResidentMatrix) -> int:
        return vpu.execute(qk.spec, src_res, dst_res)

    def _retire_step(self, qk: QueuedKernel, src_res: list[ResidentMatrix],
                     dst_res: ResidentMatrix) -> int:
        """Complete the kernel: release sources, defer or write back the
        destination. Returns destination write-back DMA cycles (0 if deferred).
        """
        dst = qk.dst_binding
        self.tracker.complete(qk.deps.kernel_id)
        for s, r in zip(qk.src_bindings, src_res):
            self.at.release(s.phys_id, RegionKind.SRC)
            if not r.dirty and not self._needed_later(s.phys_id):
                self._evict_resident(s.phys_id)
        if self._needed_later(dst.phys_id):
            # Deferred write-back: destination stays resident for the consumer.
            self.resident[dst.phys_id] = dst_res
            return 0
        wb_cycles = (self._flush_older_aliases(dst)
                     + self._writeback_resident(dst, dst_res))
        self.at.release(dst.phys_id, RegionKind.DST)
        return wb_cycles

    def _needed_later(self, phys_id: int) -> bool:
        return any(phys_id in qk.deps.sources for qk in self.queue)

    # ============================================================ fault model
    # Injection and recovery are *functionally exact*: injection really flips
    # bits in the modeled SRAM array and recovery really re-fetches or
    # recomputes, always inline at dispatch time — while the kernel's
    # operands are guaranteed resident and valid — so a run whose faults are
    # all recoverable flushes a memory image bit-identical to the fault-free
    # run. The pipelined scheduler reuses these helpers for the functional
    # side and layers its own event-timeline cost model on top.
    def _maybe_hard_fault(self, t: int, eq=None) -> None:
        """Fire the scheduled hard fault once the clock reaches ``hard_at``.

        Checked lazily at scheduler steps (never via a posted event) so a
        run that finishes before ``hard_at`` keeps its fault-free makespan.
        """
        f = self.faults
        if f is None or not f.cfg.hard_at:
            return
        v = f.cfg.hard_vpu
        if v in self.offline or t < f.cfg.hard_at:
            return
        self._offline_vpu(v, t, eq)

    def _offline_vpu(self, v: int, t: int, eq=None) -> None:
        """Hard-fault VPU ``v``: evacuate its residents (dirty ones land in
        admission order, clean ones drop) and remove it from every placement
        policy. Raises :class:`FaultError` when no healthy VPU remains."""
        if v in self.offline:
            return
        self.offline.add(v)
        self.metrics.inc("faults.offlined")
        self._evacuate_vpu(v)
        if len(self.offline) >= self.cache.n_vpus:
            from repro.sim.faults import FaultError
            raise FaultError(
                f"hard fault offlined vpu{v}: no healthy VPU remains "
                f"({len(self.offline)}/{self.cache.n_vpus} offline)")

    def _evacuate_vpu(self, v: int) -> None:
        """Consolidate every resident on ``v`` back to memory (the cache
        controller can still drain a fenced VPU's data array). Mirrors
        ``_drain_deferred_residents``: pending readers re-fetch the landed
        bytes from a healthy VPU afterwards."""
        for phys_id in list(self.resident):
            res = self.resident.get(phys_id)
            if res is None or res.vpu != v:
                continue
            if res.dirty:
                b = self._binding_of(phys_id)
                self.stats.writeback_cycles += (
                    self._flush_older_aliases(b)
                    + self._writeback_resident(b, res))
                self.at.release(phys_id, RegionKind.DST)
            else:
                self._evict_resident(phys_id)
                self.at.release(phys_id, RegionKind.DST)

    def _fault_scrub(self, qk: QueuedKernel, alloc: Allocation,
                     kf) -> int:
        """ECC tier: flip bit(s) in the first freshly-fetched source line,
        then recover — correct in place (single-bit, SECDED syndrome) or
        replay the transfer from memory's clean architectural copy
        (double-bit). Returns the recovery cycle charge (0 when the kernel
        fetched nothing, i.e. every operand was already resident)."""
        if not alloc.dma_segments:
            return 0
        kid = qk.deps.kernel_id
        si = alloc.dma_segments[0][0]
        res = alloc.src_res[si]
        b = qk.src_bindings[si]
        line = int(res.line_idxs[0])
        span = min(b.row_bytes, self.cache.vlen_bytes)
        byte, bit = self.faults.flip_position(kid, 0, span)
        self.metrics.inc("faults.injected")
        self.cache.data[line, byte] ^= 1 << bit
        if kf.ecc_bits == 1:
            # The syndrome pinpoints the bit: correct in place.
            self.cache.data[line, byte] ^= 1 << bit
            self.metrics.inc("faults.corrected")
            return self.faults.cfg.ecc_penalty
        # Double-bit: detected but uncorrectable — make the line genuinely
        # bad with a second flip, then re-fetch the whole source region.
        byte2, bit2 = self.faults.flip_position(kid, 1, span)
        self.cache.data[line, byte2] ^= 1 << bit2
        nbytes = self.cache.dma_in_2d(res.vpu, res.line_idxs, b.addr, b.rows,
                                      b.row_bytes, b.stride_bytes)
        self.metrics.inc("faults.replayed")
        return (self.faults.cfg.ecc_penalty + self.faults.backoff(0)
                + self.geometry.dma_cycles(nbytes, b.rows))

    def _fault_corrupt_dst(self, qk: QueuedKernel, alloc: Allocation,
                           attempt: int) -> None:
        """Replay tier injection: flip one bit in the destination's first
        line — the detected compute corruption the replay overwrites when
        the kernel re-executes from its still-clean sources."""
        kid = qk.deps.kernel_id
        b = qk.dst_binding
        span = min(b.row_bytes, self.cache.vlen_bytes)
        byte, bit = self.faults.flip_position(kid, 16 + attempt, span)
        self.cache.data[int(alloc.dst_res.line_idxs[0]), byte] ^= 1 << bit

    # ============================================================== allocator
    def _claim(self, vpu: VPU, b: MatrixBinding) -> ResidentMatrix:
        n = vpu.lines_needed(b.rows, b.cols, b.width)
        idxs = self.cache.claim_vregs(vpu.index, n)
        res = ResidentMatrix(phys_id=b.phys_id, vpu=vpu.index, line_idxs=idxs,
                             rows=b.rows, cols=b.cols, width=b.width)
        self.resident[b.phys_id] = res
        self._resident_index.insert(b.phys_id, b.region)
        self._resident_seq[b.phys_id] = next(self._claim_counter)
        # Residency pins the tracker's binding + write-order stamp: deferred
        # results need both after their writer completes (bounded-state prune).
        self.tracker.pin(b.phys_id)
        return res

    def _allocate_source(
        self, vpu: VPU, b: MatrixBinding
    ) -> tuple[ResidentMatrix, int, int]:
        """Materialise a source on ``vpu``; returns (res, dma_cycles, wb_cycles)."""
        wb_cycles = 0
        res = self.resident.get(b.phys_id)
        if res is not None:
            # A deferred result from a *newer* aliasing writer supersedes
            # this copy's bytes: land it first (the landing invalidates the
            # stale copy, and we fall through to a fresh fetch).
            wb_cycles += self._land_newer_aliases(b)
            res = self.resident.get(b.phys_id)
        if res is not None:
            if res.vpu != vpu.index:
                # Deferred result lives on another VPU: consolidate through
                # memory, then load here (cross-VPU move). The consolidation
                # is the deferred write-back landing, so the DST region it
                # guarded is released here (host RAW window closes).
                was_dirty = res.dirty
                wb_cycles += (self._flush_older_aliases(b)
                              + self._writeback_resident(b, res))
                if was_dirty:
                    self.at.release(b.phys_id, RegionKind.DST)
                res = None
            else:
                return res, 0, wb_cycles
        # The DMA below reads main memory: any *dirty* deferred resident
        # whose footprint overlaps this source must land first, or the read
        # observes pre-kernel bytes (the reader's RAW edge only orders it
        # after the writer *completed* — not after its deferred write-back).
        wb_cycles += self._flush_aliased_dirty(b)
        res = self._claim(vpu, b)
        nbytes = self.cache.dma_in_2d(
            vpu.index, res.line_idxs, b.addr, b.rows, b.row_bytes, b.stride_bytes)
        return res, self.geometry.dma_cycles(nbytes, b.rows), wb_cycles

    def _allocate_destination(self, vpu: VPU, b: MatrixBinding) -> ResidentMatrix:
        res = self.resident.get(b.phys_id)
        if res is not None and res.vpu == vpu.index and \
           (res.rows, res.cols) == (b.rows, b.cols):
            return res
        if res is not None:
            self._evict_resident(b.phys_id)
        # Destinations are allocated with effective dims; no memory fetch is
        # needed (the kernel overwrites every element — fetch-on-write applies
        # only to the write-back path’s partial lines, handled by dma_out_2d).
        return self._claim(vpu, b)

    def _consolidate_resident(self, b: MatrixBinding,
                              res: ResidentMatrix) -> int:
        """Write a dirty resident's data to memory *without* evicting it
        (the residency stays for future readers); returns DMA cycles.

        Landing invalidates stale copies: any *other* clean resident whose
        footprint overlaps the bytes just written holds pre-landing data —
        it is evicted so the next reader re-fetches the fresh union."""
        if not res.dirty:
            return 0
        nbytes = self.cache.dma_out_2d(
            res.vpu, res.line_idxs, b.addr, b.rows, b.row_bytes, b.stride_bytes)
        res.dirty = False
        for pid in self._resident_index.query(b.region):
            r = self.resident.get(pid)
            if r is None or r.dirty or pid == b.phys_id:
                continue
            self._evict_resident(pid)
        cycles = self.geometry.dma_cycles(nbytes, b.rows)
        if self._wb_segments is not None:
            self._wb_segments.append((res.vpu, cycles))
        self._note_memory_write(b.region)
        return cycles

    def _note_memory_write(self, region) -> None:
        """Hook: ``region``'s bytes in main memory just changed (consolidation
        landing). The pipelined scheduler invalidates modeled reuse copies
        here; the serial scheduler models no reuse."""

    def _writeback_resident(self, b: MatrixBinding, res: ResidentMatrix) -> int:
        """Consolidate a resident matrix back to memory; returns DMA cycles."""
        cycles = self._consolidate_resident(b, res)
        self._evict_resident(b.phys_id)
        return cycles

    def _aliased_dirty(self, b: MatrixBinding,
                       newer_than: Optional[int] = None
                       ) -> list[tuple[int, int, MatrixBinding]]:
        """Dirty residents (≠ ``b``) overlapping ``b``, as sorted
        ``(writer_id, phys_id, binding)`` — admission (writer) order."""
        out = []
        for phys_id in self._resident_index.query(b.region):
            res = self.resident[phys_id]
            if phys_id == b.phys_id or not res.dirty:
                continue
            w = self.tracker.writer_of(phys_id)
            w = w if w is not None else -1
            if newer_than is not None and w <= newer_than:
                continue
            out.append((w, phys_id, self._binding_of(phys_id)))
        return sorted(out)

    def _land_aliased(self, items) -> int:
        """Land the given dirty residents in admission order, each preceded
        by its own older overlapping aliases (write-order discipline).
        Residents stay in place, clean, for their own pending readers; DST
        regions are released (the data is in memory now). Returns DMA
        cycles."""
        cycles = 0
        for _, phys_id, other in items:
            res = self.resident.get(phys_id)
            if res is None or not res.dirty:
                continue                         # landed by an earlier flush
            cycles += (self._flush_older_aliases(other)
                       + self._consolidate_resident(other, res))
            self.at.release(phys_id, RegionKind.DST)
        return cycles

    def _flush_aliased_dirty(self, b: MatrixBinding) -> int:
        """Land every dirty resident overlapping ``b`` before ``b``'s bytes
        are *read* from memory, so the read observes all deferred results."""
        return self._land_aliased(self._aliased_dirty(b))

    def _land_newer_aliases(self, b: MatrixBinding) -> int:
        """``b`` has a resident copy; deferred results from writers admitted
        *after* ``b``'s supersede its bytes — land them (the landing evicts
        the now-stale copy) so the reader re-fetches the fresh union."""
        my_w = self.tracker.writer_of(b.phys_id)
        return self._land_aliased(
            self._aliased_dirty(b, newer_than=my_w if my_w is not None else -1))

    def _flush_older_aliases(self, b: MatrixBinding) -> int:
        """Enforce admission-order memory write-backs: before ``b``'s data
        lands in memory, consolidate every dirty resident written by an
        *earlier-admitted* kernel whose footprint overlaps ``b`` — a deferred
        older result flushed later would clobber the newer bytes (and with a
        partial overlap, discarding it would lose the non-overlapped bytes).
        The flushed resident stays in place, clean, for its pending readers;
        its DST region is released (host RAW window closes with the data in
        memory). Returns DMA cycles."""
        my_writer = self.tracker.writer_of(b.phys_id)
        if my_writer is None:
            return 0
        cycles = 0
        # Snapshot the overlap candidates up-front (consolidations below
        # mutate the index) and replay them in residency claim order — the
        # iteration order of the pre-index dict scan.
        hits = [pid for pid in self._resident_index.query(b.region)
                if pid != b.phys_id]
        hits.sort(key=self._resident_seq.__getitem__)
        for phys_id in hits:
            res = self.resident.get(phys_id)
            if res is None or not res.dirty:
                continue
            w = self.tracker.writer_of(phys_id)
            if w is None or w >= my_writer:
                continue
            cycles += self._consolidate_resident(self._binding_of(phys_id),
                                                 res)
            self.at.release(phys_id, RegionKind.DST)
        return cycles

    def _evict_resident(self, phys_id: int) -> None:
        res = self.resident.pop(phys_id, None)
        if res is not None:
            self._resident_index.discard(phys_id)
            self._resident_seq.pop(phys_id, None)
            self.cache.release_vregs(res.line_idxs)
            self.tracker.unpin(phys_id)

    # ================================================================= barrier
    def _drain_deferred_residents(self, need_slots: Optional[int] = None) -> None:
        """Write back deferred dirty results and drop clean residents,
        releasing their AT destination regions — all of them (``barrier``),
        or just enough to free ``need_slots`` AT slots (capacity-pressure
        relief: residency affinity of the rest survives). Pending readers of
        a drained resident re-fetch from memory afterwards — the
        consolidation lands the bytes first, so draining under a non-empty
        queue is a pure timing cost, not a correctness hazard."""
        for phys_id in list(self.resident):
            if need_slots is not None and self.at.free_slots() >= need_slots:
                return
            res = self.resident.get(phys_id)
            if res is None:              # invalidated by an earlier landing
                continue
            if res.dirty:
                b = self._binding_of(phys_id)
                self.stats.writeback_cycles += (
                    self._flush_older_aliases(b)
                    + self._writeback_resident(b, res))
                self.at.release(phys_id, RegionKind.DST)
            else:
                # Clean residents (including ones consolidated early by
                # _flush_older_aliases) just drop; release the DST region so
                # host loads don't stall on a stale registration.
                self._evict_resident(phys_id)
                self.at.release(phys_id, RegionKind.DST)

    def _relieve_at_pressure(self, need: int) -> None:
        """Ensure ``need`` free Address Table slots before a registration.

        Static tables fill up when deferred write-backs pin DST entries
        (capacity pressure, §IV-B static allocation): first drain the kernel
        queue (retires release SRC entries), then force the deferred
        write-backs to land (each release frees its DST entry). A table that
        is still full afterwards is genuinely over capacity — raise a clear
        :class:`KernelError` instead of corrupting a half-registered kernel.
        """
        if need <= 0 or self.at.free_slots() >= need:
            return
        self.run_pending()
        # ``self._running``: a completion watcher is decoding new kernels
        # from inside a drain (the run_pending above was a guarded no-op, so
        # the queue may be non-empty) — the kernel that fired the watcher has
        # fully retired, so draining deferred residents is sound (see
        # _drain_deferred_residents: readers re-fetch landed bytes).
        if self.at.free_slots() < need and (not self.queue or self._running):
            self._drain_deferred_residents(need_slots=need)
        if self.at.free_slots() < need:
            raise KernelError(
                f"Address Table full ({self.at.capacity} entries, "
                f"{self.at.free_slots()} free, {need} needed) even after a "
                f"deferred write-back drain — raise queue_capacity (the AT "
                f"holds 4 entries per queue slot) in the config")

    def barrier(self) -> None:
        """Drain all queued kernels and write back all deferred results."""
        self.run_pending()
        if self.queue:
            raise RuntimeError("kernel queue not drained — dependency deadlock?")
        self._drain_deferred_residents()

    # ============================================================== sessions
    # The re-entrant session protocol (repro.core.session.RuntimeSession is
    # the user-facing wrapper). The serial runtime has no event timeline, so
    # its clock is "modeled cycles so far plus injected idle": issuing at a
    # future time first drains queued work (work-conserving — the hardware
    # would not sit on runnable kernels), then pads the clock with idle.
    def session_now(self) -> int:
        """Current sim time of this runtime's session clock."""
        return self._session_idle + self.stats.total_cycles

    def session_post(self, t: int, fn: Callable[[int], None]) -> None:
        """Inject an external event (e.g. a request arrival): ``fn(now)`` is
        called when the session clock reaches ``t`` (clamped to now) during
        a later :meth:`session_advance`/:meth:`session_drain`."""
        if not callable(fn):
            raise TypeError(f"session_post payload must be callable, got "
                            f"{type(fn).__name__}")
        heapq.heappush(self._session_posts,
                       (max(int(t), self.session_now()),
                        next(self._post_seq), fn))

    def _session_pad(self, t: int) -> None:
        """Advance the clock to ``t``: run queued work first (its cycles are
        busy time, not idle), then pad the remainder with idle."""
        self.run_pending()
        now = self.session_now()
        if t > now:
            self._session_idle += t - now

    def _service_posts(self, until: Optional[int]) -> None:
        while self._session_posts and (until is None
                                       or self._session_posts[0][0] <= until):
            t, _, fn = heapq.heappop(self._session_posts)
            self._session_pad(t)
            fn(self.session_now())
            self.run_pending()

    def session_advance(self, until: int) -> None:
        """Service every posted event due by ``until`` (in time order, each
        followed by a drain of the work it issued), then pad to ``until``."""
        self._service_posts(until)
        self._session_pad(until)

    def session_drain(self) -> None:
        """Run the session to completion: service all remaining posts (and
        any they chain), then barrier."""
        self._service_posts(None)
        self.barrier()

    def _notify_retired(self, kid: int, t: int) -> None:
        """Fire the completion watchers registered for kernel ``kid`` —
        exactly once per kernel, at its retire point on either scheduler."""
        for cb in self._retire_watchers.pop(kid, ()):
            cb(t)

    def alias_queries_served(self) -> int:
        """AliasIndex queries answered across the scheduler stack (profiling:
        the ``--profile`` benchmark flag and PipelineReport surface this)."""
        return (self.at._alias_index.queries
                + self.tracker._alias_index.queries
                + self._resident_index.queries)

    def metrics_report(self) -> dict:
        """The unified metrics report (see :mod:`repro.sim.metrics`). The
        serial scheduler books no event timeline, so the report carries the
        typed instruments and per-kernel stall synthesis but no critical
        path."""
        return self.metrics.report(
            makespan=self.stats.total_cycles,
            extra={"kernels_run": self.stats.kernels_run,
                   "alias_queries": self.alias_queries_served()})

    def _binding_of(self, phys_id: int) -> MatrixBinding:
        for b in self.matrix_map.live_bindings():
            if b.phys_id == phys_id:
                return b
        # Renamed away by a later xmr: the tracker retains the binding the
        # kernel was admitted with, so a deferred result whose logical
        # register was rebound can still be written back to its own region.
        b = self.tracker.binding(phys_id)
        if b is not None:
            return b
        raise KeyError(f"physical binding {phys_id} not live")

    # ============================================================== host path
    def host_load(self, addr: int, n: int):
        """Host CPU load with AT hazard check (RAW on kernel destinations)."""
        if self.at.blocks_load(addr, addr + n):
            self.barrier()          # stall-until-writeback, then serve
        return self.cache.host_read(addr, n)

    def host_store(self, addr: int, buf) -> None:
        """Host CPU store with AT hazard check (WAR on sources, WAW on dsts)."""
        if self.at.blocks_store(addr, addr + len(buf)):
            self.barrier()
        self.cache.host_write(addr, buf)
        if len(buf):
            self._note_memory_write(StridedRegion(
                addr=addr, rows=1, row_bytes=len(buf), stride_bytes=len(buf)))
