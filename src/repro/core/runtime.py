"""C-RT — the Cache Runtime executed by the eCPU (paper §IV-B).

Three cooperating modules around a statically-allocated kernel queue
(producer–consumer, single-threaded preemptive in hardware; cooperative here):

  * **Kernel Decoder** — runs in the "interrupt handler" when the bridge
    latches an offload: O(1) kernel-library lookup by func5, preamble
    (validation + destination shape inference), hazard check with
    logical-matrix renaming, AT registration, queue push.
  * **Kernel Scheduler** — pops ready kernels (dependency DAG), selects the
    VPU with the fewest dirty cache lines, drives the Matrix Allocator, runs
    the kernel, and decides whether to defer the destination write-back
    (kept resident if a queued kernel will read it).
  * **Matrix Allocator** — acquires the cache lock, claims vector registers,
    programs 2D DMA transfers (memory→VPU with kernel-chosen layout;
    VPU→memory consolidation on write-back), releases lock and AT regions.

Phase cycle/time accounting (preamble / allocation / compute / writeback)
feeds the Fig. 3 reproduction benchmark directly.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

from repro.core.address_table import AddressTable, RegionKind
from repro.core.cache import ArcaneCache, MainMemory
from repro.core.encoding import ElemWidth, Offload, NUM_MATRIX_REGS
from repro.core.hazards import DependencyTracker, KernelDeps
from repro.core.isa import KernelError, KernelLibrary, KernelSpec, default_library
from repro.core.matrix import MatrixBinding, MatrixMap
from repro.core.vpu import VPU, VPUGeometry, ResidentMatrix


@dataclasses.dataclass
class PhaseStats:
    """Modeled cycles and wall-clock per C-RT phase (Fig. 3 axes)."""

    preamble_cycles: int = 0
    allocation_cycles: int = 0
    compute_cycles: int = 0
    writeback_cycles: int = 0
    preamble_s: float = 0.0
    allocation_s: float = 0.0
    compute_s: float = 0.0
    writeback_s: float = 0.0
    kernels_run: int = 0

    @property
    def total_cycles(self) -> int:
        return (self.preamble_cycles + self.allocation_cycles
                + self.compute_cycles + self.writeback_cycles)

    def shares(self) -> dict[str, float]:
        t = max(self.total_cycles, 1)
        return {
            "preamble": self.preamble_cycles / t,
            "allocation": self.allocation_cycles / t,
            "compute": self.compute_cycles / t,
            "writeback": self.writeback_cycles / t,
        }

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, type(getattr(self, f.name))())


@dataclasses.dataclass
class QueuedKernel:
    deps: KernelDeps
    spec: KernelSpec
    src_bindings: tuple[MatrixBinding, ...]
    dst_binding: MatrixBinding


class CacheRuntime:
    """The C-RT instance owning one ARCANE LLC."""

    def __init__(
        self,
        memory: Optional[MainMemory] = None,
        *,
        n_vpus: int = 4,
        vregs_per_vpu: int = 32,
        vlen_bytes: int = 1024,
        lanes: int = 4,
        queue_capacity: int = 16,
        library: Optional[KernelLibrary] = None,
        num_matrix_regs: int = NUM_MATRIX_REGS,
        geometry: Optional[VPUGeometry] = None,
    ):
        self.memory = memory or MainMemory(16 << 20)
        self.cache = ArcaneCache(self.memory, n_vpus=n_vpus,
                                 vregs_per_vpu=vregs_per_vpu,
                                 vlen_bytes=vlen_bytes)
        self.geometry = geometry or VPUGeometry(lanes=lanes)
        self.library = library or default_library()
        self.vpus = [VPU(i, self.cache, self.geometry, self.library)
                     for i in range(n_vpus)]
        self.matrix_map = MatrixMap(num_matrix_regs)
        self.at = AddressTable(capacity=4 * queue_capacity)
        self.tracker = DependencyTracker()
        self.queue_capacity = queue_capacity
        self.queue: deque[QueuedKernel] = deque()
        self.resident: dict[int, ResidentMatrix] = {}   # phys_id -> residency
        self.stats = PhaseStats()

    # ================================================================ decoder
    def decode(self, off: Offload) -> None:
        """Kernel Decoder: software-decode one offloaded instruction."""
        t0 = time.perf_counter()
        instr = off.instr
        ops = off.operands
        if instr.is_xmr:
            # xmr: pure metadata — bind (rename) the logical register.
            self.matrix_map.reserve(
                logical=ops.xmr_md,
                addr=ops.xmr_addr,
                rows=ops.xmr_rows,
                cols=ops.xmr_cols,
                stride=self._xmr_stride(ops),
                width=instr.width,
            )
            self.stats.preamble_cycles += self.geometry.decode_cycles // 4
            self.stats.preamble_s += time.perf_counter() - t0
            return

        if len(self.queue) >= self.queue_capacity:
            # Static queue full: drain before accepting (backpressure).
            self.run_pending()

        kdef = self.library.lookup(instr.func5)
        srcs = [self.matrix_map.lookup(m)
                for m in (ops.ms1, ops.ms2, ops.ms3)[: kdef.n_sources]]
        params = {"alpha": ops.alpha, "beta": ops.beta}
        if instr.func5 == 2:  # maxpool packs stride/win in rs1 (Table I)
            params = {"stride": ops.hi1, "win_size": ops.lo1}
        dst_shape, cost = kdef.preamble([s.shape for s in srcs], params, instr.width)

        dst_prev = self.matrix_map.lookup(ops.md)
        # Destination keeps its reservation's memory footprint but gets shape
        # from the preamble (effective dims allocation, §IV-B3).
        if dst_shape[0] * dst_shape[1] * instr.width.nbytes > \
           dst_prev.rows * dst_prev.cols * dst_prev.elem_bytes:
            raise KernelError(
                f"{kdef.name}: result {dst_shape} exceeds m{ops.md} reservation")
        dst = self.matrix_map.reserve(
            logical=ops.md, addr=dst_prev.addr, rows=dst_shape[0],
            cols=dst_shape[1], stride=max(dst_prev.stride, dst_shape[1]),
            width=instr.width,
        )

        spec = KernelSpec(func5=instr.func5, name=kdef.name, width=instr.width,
                          src_shapes=tuple(s.shape for s in srcs),
                          dst_shape=dst_shape, params=params, cost=cost)
        deps = self.tracker.admit(srcs, dst)
        for s in srcs:
            self.at.register(s.start, s.end, RegionKind.SRC, s.phys_id)
        self.at.register(dst.start, dst.end, RegionKind.DST, dst.phys_id)
        self.queue.append(QueuedKernel(deps=deps, spec=spec,
                                       src_bindings=tuple(srcs), dst_binding=dst))
        self.stats.preamble_cycles += self.geometry.decode_cycles
        self.stats.preamble_s += time.perf_counter() - t0

    @staticmethod
    def _xmr_stride(ops) -> int:
        # Table I: A.stride is in elements; 0 means dense (stride = cols).
        return ops.xmr_stride if ops.xmr_stride >= ops.xmr_cols else ops.xmr_cols

    # ============================================================== scheduler
    def _select_vpu(self, needed_lines: int) -> int:
        """Fewest-dirty-lines policy (§IV-B2) among VPUs with capacity."""
        best, best_key = -1, None
        for v in range(self.cache.n_vpus):
            free = sum(1 for i in self.cache.vpu_lines(v)
                       if not self.cache.lines[i].busy_computing)
            if free < needed_lines:
                continue
            key = (self.cache.dirty_line_count(v), -free)
            if best_key is None or key < best_key:
                best, best_key = v, key
        if best < 0:
            raise RuntimeError("no VPU has capacity for the kernel operands")
        return best

    def run_pending(self) -> None:
        """Drain the kernel queue respecting the dependency DAG."""
        progress = True
        while self.queue and progress:
            progress = False
            for _ in range(len(self.queue)):
                qk = self.queue.popleft()
                if self.tracker.ready(qk.deps.kernel_id):
                    self._run_one(qk)
                    progress = True
                else:
                    self.queue.append(qk)

    def _run_one(self, qk: QueuedKernel) -> None:
        t0 = time.perf_counter()
        vpu = self.vpus[self._choose_vpu(qk)]

        # -------------------------------------------------- allocation phase
        src_res, dst_res, dma_cycles, wb_cycles = self._allocation_step(qk, vpu)
        self.stats.allocation_cycles += self.geometry.schedule_cycles + dma_cycles
        self.stats.writeback_cycles += wb_cycles
        self.stats.allocation_s += time.perf_counter() - t0

        # ----------------------------------------------------- compute phase
        t1 = time.perf_counter()
        cycles = self._compute_step(qk, vpu, src_res, dst_res)
        self.stats.compute_cycles += cycles
        self.stats.compute_s += time.perf_counter() - t1

        # --------------------------------------------------- writeback phase
        t2 = time.perf_counter()
        self.stats.writeback_cycles += self._retire_step(qk, src_res, dst_res)
        self.stats.writeback_s += time.perf_counter() - t2
        self.stats.kernels_run += 1

    # ------------------------------------------------- shared scheduler steps
    # The serial scheduler above and repro.sim.pipeline.PipelinedRuntime both
    # drive exactly these four steps; only *when* each step runs differs, so
    # the numerical results are identical by construction.
    def _choose_vpu(self, qk: QueuedKernel) -> int:
        """VPU selection: resident-operand affinity, else fewest-dirty-lines."""
        for s in qk.src_bindings:
            r = self.resident.get(s.phys_id)
            if r is not None:
                return r.vpu
        return self._select_vpu(self._lines_for(qk))

    def _lines_for(self, qk: QueuedKernel) -> int:
        return sum(
            self.vpus[0].lines_needed(*s.shape, s.width) for s in qk.src_bindings
        ) + self.vpus[0].lines_needed(*qk.dst_binding.shape, qk.dst_binding.width)

    def _allocation_step(
        self, qk: QueuedKernel, vpu: VPU
    ) -> tuple[list[ResidentMatrix], ResidentMatrix, int, int]:
        """Matrix Allocator: lock, claim vregs, 2D-DMA the operands in.

        Returns ``(src_res, dst_res, dma_cycles, consolidation_wb_cycles)``;
        the caller attributes the cycles (allocation vs writeback phase).
        """
        if not self.cache.acquire_lock():
            raise RuntimeError("cache lock already held")
        dma_cycles = wb_cycles = 0
        try:
            src_res = []
            for s in qk.src_bindings:
                res, dma_c, wb_c = self._allocate_source(vpu, s)
                src_res.append(res)
                dma_cycles += dma_c
                wb_cycles += wb_c
                self.at.mark_allocated(s.phys_id)
            dst_res = self._allocate_destination(vpu, qk.dst_binding)
        finally:
            self.cache.release_lock()
        return src_res, dst_res, dma_cycles, wb_cycles

    def _compute_step(self, qk: QueuedKernel, vpu: VPU,
                      src_res: list[ResidentMatrix],
                      dst_res: ResidentMatrix) -> int:
        return vpu.execute(qk.spec, src_res, dst_res)

    def _retire_step(self, qk: QueuedKernel, src_res: list[ResidentMatrix],
                     dst_res: ResidentMatrix) -> int:
        """Complete the kernel: release sources, defer or write back the
        destination. Returns destination write-back DMA cycles (0 if deferred).
        """
        dst = qk.dst_binding
        self.tracker.complete(qk.deps.kernel_id)
        for s, r in zip(qk.src_bindings, src_res):
            self.at.release(s.phys_id, RegionKind.SRC)
            if not r.dirty and not self._needed_later(s.phys_id):
                self._evict_resident(s.phys_id)
        if self._needed_later(dst.phys_id):
            # Deferred write-back: destination stays resident for the consumer.
            self.resident[dst.phys_id] = dst_res
            return 0
        wb_cycles = (self._flush_older_aliases(dst)
                     + self._writeback_resident(dst, dst_res))
        self.at.release(dst.phys_id, RegionKind.DST)
        return wb_cycles

    def _needed_later(self, phys_id: int) -> bool:
        return any(phys_id in qk.deps.sources for qk in self.queue)

    # ============================================================== allocator
    def _claim(self, vpu: VPU, b: MatrixBinding) -> ResidentMatrix:
        n = vpu.lines_needed(b.rows, b.cols, b.width)
        idxs = self.cache.claim_vregs(vpu.index, n)
        res = ResidentMatrix(phys_id=b.phys_id, vpu=vpu.index, line_idxs=idxs,
                             rows=b.rows, cols=b.cols, width=b.width)
        self.resident[b.phys_id] = res
        return res

    def _allocate_source(
        self, vpu: VPU, b: MatrixBinding
    ) -> tuple[ResidentMatrix, int, int]:
        """Materialise a source on ``vpu``; returns (res, dma_cycles, wb_cycles)."""
        wb_cycles = 0
        res = self.resident.get(b.phys_id)
        if res is not None:
            if res.vpu != vpu.index:
                # Deferred result lives on another VPU: consolidate through
                # memory, then load here (cross-VPU move). The consolidation
                # is the deferred write-back landing, so the DST region it
                # guarded is released here (host RAW window closes).
                was_dirty = res.dirty
                wb_cycles = (self._flush_older_aliases(b)
                             + self._writeback_resident(b, res))
                if was_dirty:
                    self.at.release(b.phys_id, RegionKind.DST)
                res = None
            else:
                return res, 0, wb_cycles
        res = self._claim(vpu, b)
        nbytes = self.cache.dma_in_2d(
            vpu.index, res.line_idxs, b.addr, b.rows, b.row_bytes, b.stride_bytes)
        return res, self.geometry.dma_cycles(nbytes, b.rows), wb_cycles

    def _allocate_destination(self, vpu: VPU, b: MatrixBinding) -> ResidentMatrix:
        res = self.resident.get(b.phys_id)
        if res is not None and res.vpu == vpu.index and \
           (res.rows, res.cols) == (b.rows, b.cols):
            return res
        if res is not None:
            self._evict_resident(b.phys_id)
        # Destinations are allocated with effective dims; no memory fetch is
        # needed (the kernel overwrites every element — fetch-on-write applies
        # only to the write-back path’s partial lines, handled by dma_out_2d).
        return self._claim(vpu, b)

    def _consolidate_resident(self, b: MatrixBinding,
                              res: ResidentMatrix) -> int:
        """Write a dirty resident's data to memory *without* evicting it
        (the residency stays for future readers); returns DMA cycles."""
        if not res.dirty:
            return 0
        nbytes = self.cache.dma_out_2d(
            res.vpu, res.line_idxs, b.addr, b.rows, b.row_bytes, b.stride_bytes)
        res.dirty = False
        return self.geometry.dma_cycles(nbytes, b.rows)

    def _writeback_resident(self, b: MatrixBinding, res: ResidentMatrix) -> int:
        """Consolidate a resident matrix back to memory; returns DMA cycles."""
        cycles = self._consolidate_resident(b, res)
        self._evict_resident(b.phys_id)
        return cycles

    def _flush_older_aliases(self, b: MatrixBinding) -> int:
        """Enforce admission-order memory write-backs: before ``b``'s data
        lands in memory, consolidate every dirty resident written by an
        *earlier-admitted* kernel whose footprint overlaps ``b`` — a deferred
        older result flushed later would clobber the newer bytes (and with a
        partial overlap, discarding it would lose the non-overlapped bytes).
        The flushed resident stays in place, clean, for its pending readers;
        its DST region is released (host RAW window closes with the data in
        memory). Returns DMA cycles."""
        my_writer = self.tracker.writer_of(b.phys_id)
        if my_writer is None:
            return 0
        cycles = 0
        for phys_id in list(self.resident):
            res = self.resident[phys_id]
            if phys_id == b.phys_id or not res.dirty:
                continue
            w = self.tracker.writer_of(phys_id)
            if w is None or w >= my_writer:
                continue
            other = self._binding_of(phys_id)
            if not other.overlaps(b):
                continue
            cycles += self._consolidate_resident(other, res)
            self.at.release(phys_id, RegionKind.DST)
        return cycles

    def _evict_resident(self, phys_id: int) -> None:
        res = self.resident.pop(phys_id, None)
        if res is not None:
            self.cache.release_vregs(res.line_idxs)

    # ================================================================= barrier
    def barrier(self) -> None:
        """Drain all queued kernels and write back all deferred results."""
        self.run_pending()
        if self.queue:
            raise RuntimeError("kernel queue not drained — dependency deadlock?")
        for phys_id in list(self.resident):
            res = self.resident[phys_id]
            if res.dirty:
                b = self._binding_of(phys_id)
                self.stats.writeback_cycles += (
                    self._flush_older_aliases(b)
                    + self._writeback_resident(b, res))
                self.at.release(phys_id, RegionKind.DST)
            else:
                # Clean residents (including ones consolidated early by
                # _flush_older_aliases) just drop; release the DST region so
                # host loads don't stall on a stale registration.
                self._evict_resident(phys_id)
                self.at.release(phys_id, RegionKind.DST)

    def _binding_of(self, phys_id: int) -> MatrixBinding:
        for b in self.matrix_map.live_bindings():
            if b.phys_id == phys_id:
                return b
        # Renamed away by a later xmr: the tracker retains the binding the
        # kernel was admitted with, so a deferred result whose logical
        # register was rebound can still be written back to its own region.
        b = self.tracker.binding(phys_id)
        if b is not None:
            return b
        raise KeyError(f"physical binding {phys_id} not live")

    # ============================================================== host path
    def host_load(self, addr: int, n: int):
        """Host CPU load with AT hazard check (RAW on kernel destinations)."""
        if self.at.blocks_load(addr, addr + n):
            self.barrier()          # stall-until-writeback, then serve
        return self.cache.host_read(addr, n)

    def host_store(self, addr: int, buf) -> None:
        """Host CPU store with AT hazard check (WAR on sources, WAW on dsts)."""
        if self.at.blocks_store(addr, addr + len(buf)):
            self.barrier()
        self.cache.host_write(addr, buf)
