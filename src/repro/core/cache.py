"""Functional model of the ARCANE LLC (paper §III-A).

Fully-associative cache whose data array doubles as the VPUs' vector register
files: ``n_lines = n_vpus * vregs_per_vpu`` and the line length equals the
maximum vector length (1 KiB in the paper's synthesized configs). Hits resolve
in one cycle; misses/write-backs go through a DMA to main memory; replacement is
a counter-based approximate LRU; the write policy is write-back +
fetch-on-write. A lock register arbitrates host-CPU vs eCPU access; lines
claimed by an in-flight kernel are marked *busy-computing* and are neither
evictable nor host-accessible.

This is the paper-faithful simulator used by the CNN example, the Fig.3/Fig.4
benchmarks and the property tests. The production LM path keeps the same
discipline at the VMEM level through Pallas BlockSpecs (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


class CacheLocked(Exception):
    """Host access attempted while the eCPU holds the cache lock (stall)."""


class LineBusy(Exception):
    """Access or eviction attempted on a busy-computing line (stall)."""


class ResourceStall(Exception):
    """No allocatable line available (all candidates busy-computing)."""


class MainMemory:
    """Flat byte-addressable main (off-chip) memory."""

    def __init__(self, size: int):
        self.size = size
        self.data = np.zeros(size, dtype=np.uint8)

    def read(self, addr: int, n: int) -> np.ndarray:
        if addr < 0 or addr + n > self.size:
            raise IndexError(f"memory read [{addr}, {addr + n}) out of bounds")
        return self.data[addr : addr + n].copy()

    def write(self, addr: int, buf: np.ndarray) -> None:
        buf = np.ascontiguousarray(buf, dtype=np.uint8).reshape(-1)
        if addr < 0 or addr + buf.size > self.size:
            raise IndexError(f"memory write [{addr}, {addr + buf.size}) out of bounds")
        self.data[addr : addr + buf.size] = buf

    # Typed convenience accessors used by examples/tests.
    def write_array(self, addr: int, arr: np.ndarray) -> None:
        self.write(addr, np.ascontiguousarray(arr).view(np.uint8))

    def read_array(self, addr: int, shape: tuple[int, ...], dtype) -> np.ndarray:
        n = int(np.prod(shape)) * np.dtype(dtype).itemsize
        return self.read(addr, n).view(dtype).reshape(shape).copy()


@dataclasses.dataclass
class CacheLineState:
    valid: bool = False
    dirty: bool = False
    tag: int = -1              # line-aligned base address of the cached block
    lru: int = 0               # counter-based approximate LRU timestamp
    busy_computing: bool = False
    is_src: bool = False       # CT fast-path flags (§III-A3): line holds a kernel
    is_dst: bool = False       # source / destination operand


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    writebacks: int = 0
    fills: int = 0
    host_stalls: int = 0

    def reset(self) -> None:
        self.hits = self.misses = self.writebacks = self.fills = self.host_stalls = 0


class ArcaneCache:
    """The LLC: cache controller + data array shared with the VPUs."""

    def __init__(
        self,
        memory: MainMemory,
        n_vpus: int = 4,
        vregs_per_vpu: int = 32,
        vlen_bytes: int = 1024,
    ):
        self.memory = memory
        self.n_vpus = n_vpus
        self.vregs_per_vpu = vregs_per_vpu
        self.vlen_bytes = vlen_bytes
        self.n_lines = n_vpus * vregs_per_vpu
        self.lines = [CacheLineState() for _ in range(self.n_lines)]
        # The data array: one row per line; VPU v's vector register r is row
        # v * vregs_per_vpu + r — the memory *is* the register file.
        self.data = np.zeros((self.n_lines, vlen_bytes), dtype=np.uint8)
        # tag -> line for O(1) lookup; at most one valid line per tag (fills
        # only happen on misses, so duplicates cannot arise).
        self._tag_to_line: dict[int, int] = {}
        # Per-VPU busy/dirty line counters: scheduler policy inputs
        # (fewest-dirty-lines, capacity checks) read these every dispatch —
        # maintained incrementally instead of rescanning the line slice.
        self._busy_per_vpu = [0] * n_vpus
        self._dirty_per_vpu = [0] * n_vpus
        self._lru_counter = 0
        self.locked_by_ecpu = False
        self.stats = CacheStats()

    # ------------------------------------------------------------------ util
    def line_of_vreg(self, vpu: int, vreg: int) -> int:
        if not (0 <= vpu < self.n_vpus and 0 <= vreg < self.vregs_per_vpu):
            raise IndexError("vpu/vreg out of range")
        return vpu * self.vregs_per_vpu + vreg

    def vpu_lines(self, vpu: int) -> range:
        return range(vpu * self.vregs_per_vpu, (vpu + 1) * self.vregs_per_vpu)

    def _align(self, addr: int) -> int:
        return addr - (addr % self.vlen_bytes)

    def _touch(self, idx: int) -> None:
        self._lru_counter += 1
        self.lines[idx].lru = self._lru_counter

    def lookup(self, addr: int) -> Optional[int]:
        return self._tag_to_line.get(self._align(addr))

    def _invalidate_tag(self, idx: int) -> None:
        ln = self.lines[idx]
        if ln.valid and self._tag_to_line.get(ln.tag) == idx:
            del self._tag_to_line[ln.tag]

    def dirty_line_count(self, vpu: int) -> int:
        """Scheduler policy input: prefer the VPU with fewest dirty lines."""
        return self._dirty_per_vpu[vpu]

    def free_line_count(self, vpu: int) -> int:
        """Lines of ``vpu`` not claimed by an in-flight kernel."""
        return self.vregs_per_vpu - self._busy_per_vpu[vpu]

    def _set_dirty(self, idx: int, val: bool) -> None:
        ln = self.lines[idx]
        if ln.dirty != val:
            ln.dirty = val
            self._dirty_per_vpu[idx // self.vregs_per_vpu] += 1 if val else -1

    # ------------------------------------------------------------------ lock
    def acquire_lock(self) -> bool:
        """eCPU lock request; not granted during ongoing host ops (modeled as
        always-grantable here because host ops are atomic in the simulator)."""
        if self.locked_by_ecpu:
            return False
        self.locked_by_ecpu = True
        return True

    def release_lock(self) -> None:
        self.locked_by_ecpu = False

    # ------------------------------------------------------------- fill/evict
    def _set_busy(self, idx: int, val: bool) -> None:
        ln = self.lines[idx]
        if ln.busy_computing != val:
            ln.busy_computing = val
            self._busy_per_vpu[idx // self.vregs_per_vpu] += 1 if val else -1

    def _writeback(self, idx: int) -> None:
        ln = self.lines[idx]
        if ln.valid and ln.dirty:
            end = min(ln.tag + self.vlen_bytes, self.memory.size)
            self.memory.write(ln.tag, self.data[idx, : end - ln.tag])
            self.stats.writebacks += 1
        self._set_dirty(idx, False)

    def _victim(self) -> int:
        best, best_lru = -1, None
        for i, ln in enumerate(self.lines):
            if ln.busy_computing:
                continue
            if not ln.valid:
                return i
            if best_lru is None or ln.lru < best_lru:
                best, best_lru = i, ln.lru
        if best < 0:
            raise ResourceStall("all cache lines are busy-computing")
        return best

    def _fill(self, addr: int) -> int:
        """Miss path: pick a victim, write back if dirty, DMA the block in."""
        tag = self._align(addr)
        idx = self._victim()
        self._writeback(idx)
        self._invalidate_tag(idx)
        ln = self.lines[idx]
        end = min(tag + self.vlen_bytes, self.memory.size)
        self.data[idx, : end - tag] = self.memory.read(tag, end - tag)
        if end - tag < self.vlen_bytes:
            self.data[idx, end - tag :] = 0
        ln.valid, ln.tag = True, tag       # dirty already cleared by _writeback
        self._tag_to_line[tag] = idx
        self._set_busy(idx, False)
        ln.is_src = ln.is_dst = False
        self.stats.fills += 1
        self._touch(idx)
        return idx

    # ------------------------------------------------------------- host path
    def _host_access_line(self, addr: int, *, for_write: bool) -> int:
        if self.locked_by_ecpu:
            self.stats.host_stalls += 1
            raise CacheLocked("cache is locked by the eCPU")
        idx = self.lookup(addr)
        if idx is not None:
            if self.lines[idx].busy_computing:
                self.stats.host_stalls += 1
                raise LineBusy(f"line for addr {addr:#x} is busy-computing")
            self.stats.hits += 1
            self._touch(idx)
            return idx
        self.stats.misses += 1
        return self._fill(addr)  # fetch-on-write: misses fill even for stores

    def host_read(self, addr: int, n: int) -> np.ndarray:
        out = np.empty(n, dtype=np.uint8)
        pos = 0
        while pos < n:
            a = addr + pos
            idx = self._host_access_line(a, for_write=False)
            off = a - self.lines[idx].tag
            take = min(self.vlen_bytes - off, n - pos)
            out[pos : pos + take] = self.data[idx, off : off + take]
            pos += take
        return out

    def host_write(self, addr: int, buf: np.ndarray) -> None:
        buf = np.ascontiguousarray(buf, dtype=np.uint8).reshape(-1)
        pos = 0
        while pos < buf.size:
            a = addr + pos
            idx = self._host_access_line(a, for_write=True)
            off = a - self.lines[idx].tag
            take = min(self.vlen_bytes - off, buf.size - pos)
            self.data[idx, off : off + take] = buf[pos : pos + take]
            self._set_dirty(idx, True)
            pos += take

    # ----------------------------------------------------------- kernel path
    def claim_vregs(self, vpu: int, n: int) -> list[int]:
        """Claim ``n`` vector registers (cache lines) of ``vpu`` for a kernel.

        Lines are freed (written back if dirty) and marked busy-computing.
        """
        avail = [i for i in self.vpu_lines(vpu) if not self.lines[i].busy_computing]
        if len(avail) < n:
            raise ResourceStall(
                f"VPU{vpu}: need {n} vregs, only {len(avail)} not busy"
            )
        # Prefer invalid lines, then LRU order — the fewest-writebacks choice.
        avail.sort(key=lambda i: (self.lines[i].valid, self.lines[i].lru))
        chosen = avail[:n]
        for i in chosen:
            self._writeback(i)
            self._invalidate_tag(i)
            ln = self.lines[i]
            ln.valid, ln.tag = False, -1
            self._set_busy(i, True)
            ln.is_src = ln.is_dst = False
            self._touch(i)
        return chosen

    def release_vregs(self, line_idxs: list[int]) -> None:
        for i in line_idxs:
            self._invalidate_tag(i)
            self._set_busy(i, False)
            self._set_dirty(i, False)
            ln = self.lines[i]
            ln.is_src = ln.is_dst = False
            ln.valid, ln.tag = False, -1

    # ------------------------------------------------------------- DMA (2D)
    def dma_in_2d(
        self, vpu: int, line_idxs: list[int], addr: int, rows: int,
        row_bytes: int, stride_bytes: int,
    ) -> int:
        """2D DMA main-memory→VPU lines: pack ``rows`` of ``row_bytes`` (strided
        by ``stride_bytes`` in memory) contiguously into the claimed lines.

        Rows the host still holds dirty in *other* cache lines are snooped so
        the DMA always observes the latest data (the controller routes DMA
        requests and serves hits from the cache, §III-A4). Returns bytes moved.
        """
        total = rows * row_bytes
        end = addr + (rows - 1) * stride_bytes + row_bytes
        if rows > 1 and stride_bytes >= row_bytes:
            # Bulk path: one strided numpy copy straight from main memory,
            # then re-read (snoop) only the rows a *dirty* non-busy cache
            # line covers — a clean valid line holds exactly the memory
            # bytes (lines become clean only by copying from/to memory), so
            # serving it from memory is bit-identical.
            if addr < 0 or end > self.memory.size:
                raise IndexError(
                    f"memory read [{addr}, {end}) out of bounds")
            view = np.lib.stride_tricks.as_strided(
                self.memory.data[addr:end], shape=(rows, row_bytes),
                strides=(stride_bytes, 1))
            buf2d = np.ascontiguousarray(view)
            snoop = self._snoop_rows(addr, rows, row_bytes, stride_bytes,
                                     end, dirty_only=True)
            if snoop:
                self._snoop_read_rows(addr, snoop, row_bytes, stride_bytes,
                                      buf2d)
            buf = buf2d.reshape(-1)
        else:
            buf = np.empty(total, dtype=np.uint8)
            for r in range(rows):
                a = addr + r * stride_bytes
                buf[r * row_bytes : (r + 1) * row_bytes] = \
                    self._snooped_read(a, row_bytes)
        self._scatter_to_lines(line_idxs, buf)
        return total

    def dma_out_2d(
        self, vpu: int, line_idxs: list[int], addr: int, rows: int,
        row_bytes: int, stride_bytes: int,
    ) -> int:
        """2D DMA VPU lines→main memory (kernel write-back consolidation).

        Follows fetch-on-write: if a destination row is resident in a normal
        cache line, that line is updated and marked dirty instead of bypassing
        to memory, so pending host reads see the newest data immediately.
        """
        total = rows * row_bytes
        buf = self._gather_from_lines(line_idxs, total)
        end = addr + (rows - 1) * stride_bytes + row_bytes
        if rows > 1 and stride_bytes >= row_bytes:
            # Bulk path (see dma_in_2d): one strided numpy scatter to memory,
            # then route the rows a valid cache line covers through the snoop
            # path so those lines hold the newest data (the bulk write left
            # the same bytes in memory, which the dirty line shadows — the
            # write-back later lands identical data, so no observer can tell
            # this apart from the pure row-by-row path).
            if addr < 0 or end > self.memory.size:
                raise IndexError(
                    f"memory write [{addr}, {end}) out of bounds")
            view = np.lib.stride_tricks.as_strided(
                self.memory.data[addr:end], shape=(rows, row_bytes),
                strides=(stride_bytes, 1))
            buf2d = buf.reshape(rows, row_bytes)
            view[:] = buf2d
            snoop = self._snoop_rows(addr, rows, row_bytes, stride_bytes,
                                     end, dirty_only=False)
            if snoop:
                self._snoop_write_rows(addr, snoop, row_bytes, stride_bytes,
                                       buf2d)
        else:
            for r in range(rows):
                a = addr + r * stride_bytes
                self._snooped_write(a, buf[r * row_bytes:(r + 1) * row_bytes])
        return total

    def _snoop_rows(self, addr: int, rows: int, row_bytes: int,
                    stride_bytes: int, end: int,
                    dirty_only: bool) -> list[int]:
        """Ascending rows of the 2D transfer that touch a valid, non-busy
        cache line (those must route through the snoop path; the rest may
        move in bulk). Reads pass ``dirty_only=True``: a clean line mirrors
        memory, so only dirty lines can serve different bytes. One dict
        probe per aligned block of the bounding span, then pure arithmetic
        to map blocks back to row ranges."""
        get = self._tag_to_line.get
        lines = self.lines
        vlen = self.vlen_bytes
        out: list[int] = []
        last = -1              # tags ascend, so row ranges ascend: merge by
        for tag in range(addr - addr % vlen, end, vlen):   # tracking the max
            idx = get(tag)
            if idx is None or lines[idx].busy_computing \
                    or (dirty_only and not lines[idx].dirty):
                continue
            # Rows r with [addr + r*stride, +row_bytes) ∩ [tag, tag+vlen) ≠ ∅
            r0 = max(last + 1,
                     -(-(tag - addr - row_bytes + 1) // stride_bytes))
            r1 = min(rows - 1, (tag + vlen - 1 - addr) // stride_bytes)
            if r1 >= r0:
                out.extend(range(r0, r1 + 1))
                last = r1
        return out

    def _classify_snoop_rows(self, addr: int, snoop: list[int],
                             row_bytes: int, stride_bytes: int):
        """Split snoop rows into a vectorizable set (row inside one valid,
        non-busy line) and a slow remainder (line-crossing / partly
        uncached rows, served row-by-row)."""
        get = self._tag_to_line.get
        lines = self.lines
        vlen = self.vlen_bytes
        fancy_rows, fancy_idx, fancy_off, slow = [], [], [], []
        for r in snoop:
            a = addr + r * stride_bytes
            off = a % vlen
            if off + row_bytes <= vlen:
                idx = get(a - off)
                if idx is not None and not lines[idx].busy_computing:
                    fancy_rows.append(r)
                    fancy_idx.append(idx)
                    fancy_off.append(off)
                    continue
            slow.append(r)
        return fancy_rows, fancy_idx, fancy_off, slow

    def _snoop_read_rows(self, addr: int, snoop: list[int], row_bytes: int,
                         stride_bytes: int, buf2d: np.ndarray) -> None:
        """Overwrite ``buf2d``'s snoop rows with the cached bytes — one
        fancy-indexed gather for the single-line rows."""
        fancy_rows, fancy_idx, fancy_off, slow = self._classify_snoop_rows(
            addr, snoop, row_bytes, stride_bytes)
        if fancy_rows:
            cols = (np.asarray(fancy_off)[:, None]
                    + np.arange(row_bytes)[None, :])
            buf2d[np.asarray(fancy_rows)] = \
                self.data[np.asarray(fancy_idx)[:, None], cols]
        for r in slow:
            buf2d[r] = self._snooped_read(addr + r * stride_bytes, row_bytes)

    def _snoop_write_rows(self, addr: int, snoop: list[int], row_bytes: int,
                          stride_bytes: int, buf2d: np.ndarray) -> None:
        """Write ``buf2d``'s snoop rows into the covering cache lines — one
        fancy-indexed scatter for the single-line rows (non-overlapping:
        stride >= row_bytes on this path)."""
        fancy_rows, fancy_idx, fancy_off, slow = self._classify_snoop_rows(
            addr, snoop, row_bytes, stride_bytes)
        if fancy_rows:
            cols = (np.asarray(fancy_off)[:, None]
                    + np.arange(row_bytes)[None, :])
            self.data[np.asarray(fancy_idx)[:, None], cols] = \
                buf2d[np.asarray(fancy_rows)]
            for idx in set(fancy_idx):
                self._set_dirty(idx, True)
        for r in slow:
            self._snooped_write(addr + r * stride_bytes, buf2d[r])

    def _snooped_read(self, addr: int, n: int) -> np.ndarray:
        out = np.empty(n, dtype=np.uint8)
        pos = 0
        while pos < n:
            a = addr + pos
            idx = self.lookup(a)
            off = a % self.vlen_bytes
            take = min(self.vlen_bytes - off, n - pos)
            if idx is not None and not self.lines[idx].busy_computing:
                out[pos : pos + take] = self.data[idx, off : off + take]
            else:
                out[pos : pos + take] = self.memory.read(a, take)
            pos += take
        return out

    def _snooped_write(self, addr: int, buf: np.ndarray) -> None:
        pos = 0
        n = buf.size
        while pos < n:
            a = addr + pos
            idx = self.lookup(a)
            off = a % self.vlen_bytes
            take = min(self.vlen_bytes - off, n - pos)
            if idx is not None and not self.lines[idx].busy_computing:
                self.data[idx, off : off + take] = buf[pos : pos + take]
                self._set_dirty(idx, True)
            else:
                self.memory.write(a, buf[pos : pos + take])
            pos += take

    def _scatter_to_lines(self, line_idxs: list[int], buf: np.ndarray) -> None:
        if buf.size > len(line_idxs) * self.vlen_bytes:
            raise ValueError("operand larger than claimed vector registers")
        pos = 0
        for i in line_idxs:
            take = min(self.vlen_bytes, buf.size - pos)
            if take <= 0:
                break
            self.data[i, :take] = buf[pos : pos + take]
            pos += take

    def _gather_from_lines(self, line_idxs: list[int], n: int) -> np.ndarray:
        out = np.empty(n, dtype=np.uint8)
        pos = 0
        for i in line_idxs:
            take = min(self.vlen_bytes, n - pos)
            if take <= 0:
                break
            out[pos : pos + take] = self.data[i, :take]
            pos += take
        return out

    # ---------------------------------------------------------------- debug
    def flush_all(self) -> None:
        for i, ln in enumerate(self.lines):
            if ln.busy_computing:
                raise LineBusy("cannot flush while kernels are in flight")
            self._writeback(i)
            self._invalidate_tag(i)
            ln.valid, ln.tag = False, -1
