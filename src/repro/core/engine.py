"""ArcaneEngine — trace-time software decode of the xmnmc ISA (production path).

The simulator (`core.runtime`) interprets instructions against the cache model;
models can't afford a Python interpreter per training step. The engine keeps
the paper's *mechanism* — complex instructions, software decode through the
kernel-library registry, renamed dependency dispatch — but applies it when the
step function is **traced**: every model-level matrix operation

  1. is *encoded* as a genuine xmnmc instruction word (bit-exact, the same
     encoder the simulator uses),
  2. is *software-decoded* through a ``KernelLibrary``-style registry that maps
     func5 → executor (Pallas micro-program on TPU, blocked-jnp reference
     elsewhere),
  3. lands in the traced program as one fused kernel invocation, with the
     instruction word retained in the engine's trace log (the "micro-program"
     the eCPU would have run).

Because XLA's dataflow + donation replace the AT/lock machinery at runtime,
what survives of §III is the *discipline*: fused VMEM-resident kernels and
WAR/WAW-free operand versioning (functional arrays are renamed by
construction — the paper's renaming applied at the IR level).

Width suffixes are extended to float dtypes (the ISA is software-defined —
reprogramming the decoder is the point): .w ↦ f32/i32, .h ↦ bf16/i16, .b ↦ i8.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.encoding import ElemWidth, encode_xmk
from repro.core.isa import fx_encode
from repro import kernels


def _width_of(dtype) -> ElemWidth:
    dt = jnp.dtype(dtype)
    if dt.itemsize >= 4:
        return ElemWidth.W
    if dt.itemsize == 2:
        return ElemWidth.H
    return ElemWidth.B


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    word: int            # encoded xmnmc instruction
    mnemonic: str
    shapes: tuple
    flops: int


class ArcaneEngine:
    """Dispatch facade used by every model layer.

    backend: "pallas"  — Pallas kernels (TPU; interpret-mode on CPU),
             "ref"     — blocked-jnp reference path (pjit-partitionable; used
                         by the multi-pod dry-run),
             "auto"    — pallas on TPU, ref elsewhere.
    """

    def __init__(self, backend: str = "auto", *, attn_block_q: int = 256,
                 attn_block_k: int = 256, gemm_block: tuple = (128, 128, 128),
                 record: bool = False):
        if backend == "auto":
            backend = "pallas" if jax.default_backend() == "tpu" else "ref"
        if backend not in ("pallas", "ref"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.attn_block_q = attn_block_q
        self.attn_block_k = attn_block_k
        self.gemm_block = gemm_block
        self.record = record
        self.trace: list[TraceEntry] = []
        # attention backend name differs: blocked-jnp ref is "chunked"
        self._attn_backend = "pallas" if backend == "pallas" else "chunked"

    # ------------------------------------------------------------- recording
    def _log(self, func5: int, dtype, shapes, flops: int, **kw) -> None:
        if not self.record:
            return
        off = encode_xmk(func5, _width_of(dtype), md=0, **kw)
        self.trace.append(TraceEntry(word=off.word, mnemonic=off.instr.mnemonic,
                                     shapes=tuple(shapes), flops=flops))

    # ------------------------------------------------------------------ ops
    def gemm(self, x: jax.Array, w: jax.Array, c: Optional[jax.Array] = None,
             *, alpha: float = 1.0, beta: float = 1.0,
             out_dtype=None) -> jax.Array:
        """xmk0 over arbitrary leading dims: (..., k) @ (k, n) [+ beta*c]."""
        lead = x.shape[:-1]
        k = x.shape[-1]
        n = w.shape[-1]
        m = 1
        for s in lead:
            m *= s
        self._log(0, x.dtype, (x.shape, w.shape), 2 * m * k * n,
                  alpha=fx_encode(min(max(alpha, -127), 127)),
                  beta=fx_encode(min(max(beta, -127), 127)))
        x2 = x.reshape(m, k)
        c2 = c.reshape(m, n) if c is not None else None
        if self.backend == "ref":
            out = jnp.dot(x2, w, preferred_element_type=jnp.float32)
            if alpha != 1.0:
                out = alpha * out
            if c2 is not None:
                out = out + beta * c2.astype(out.dtype)
            out = out.astype(out_dtype or x.dtype)
        else:
            bm, bn, bk = self.gemm_block
            out = kernels.gemm(x2, w, c2, alpha=alpha, beta=beta,
                               block_m=bm, block_n=bn, block_k=bk,
                               out_dtype=out_dtype or x.dtype)
        return out.reshape(*lead, n)

    def leakyrelu(self, x: jax.Array, *, negative_slope: float = 0.01) -> jax.Array:
        self._log(1, x.dtype, (x.shape,), int(x.size))
        if self.backend == "ref":
            return jnp.where(x >= 0, x, negative_slope * x)
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        return kernels.leakyrelu(x2, negative_slope=negative_slope).reshape(shape)

    def maxpool(self, x: jax.Array, *, win: int = 2,
                stride: Optional[int] = None) -> jax.Array:
        self._log(2, x.dtype, (x.shape,), int(x.size))
        if self.backend == "ref":
            from repro.kernels.maxpool.ref import maxpool_ref
            return maxpool_ref(x, win=win, stride=stride)
        return kernels.maxpool(x, win=win, stride=stride)

    def conv_layer(self, x: jax.Array, f: jax.Array, *,
                   negative_slope: float = 0.0) -> jax.Array:
        cch, h, w = x.shape
        nf, _, kh, kw = f.shape
        self._log(4, x.dtype, (x.shape, f.shape),
                  2 * nf * cch * (h - kh + 1) * (w - kw + 1) * kh * kw)
        backend = "pallas" if self.backend == "pallas" else "ref"
        return kernels.conv_layer(x, f, negative_slope=negative_slope,
                                  backend=backend)

    def attention(self, q, k, v, *, causal=True, window=None, softcap=None,
                  scale=None, kv_len=None) -> jax.Array:
        b, hq, sq, d = q.shape
        skv = k.shape[2]
        self._log(5, q.dtype, (q.shape, k.shape), 4 * b * hq * sq * skv * d)
        return kernels.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_len=kv_len, block_q=self.attn_block_q,
            block_k=self.attn_block_k, backend=self._attn_backend)

    def decode_attention(self, q, k, v, lengths, *, softcap=None,
                         scale=None, window=None) -> jax.Array:
        b, hq, d = q.shape
        s = k.shape[2]
        self._log(6, q.dtype, (q.shape, k.shape), 4 * b * hq * s * d)
        backend = "pallas" if self.backend == "pallas" else "ref"
        return kernels.decode_attention(q, k, v, lengths, softcap=softcap,
                                        scale=scale, window=window,
                                        block_k=self.attn_block_k,
                                        backend=backend)


_DEFAULT: Optional[ArcaneEngine] = None


def default_engine() -> ArcaneEngine:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = ArcaneEngine()
    return _DEFAULT


def set_default_engine(engine: ArcaneEngine) -> None:
    global _DEFAULT
    _DEFAULT = engine
