"""Bucketed byte-interval index over :class:`StridedRegion` footprints.

Every aliasing decision in the scheduler stack — hazard admission sweeps,
Address Table host-access checks, WAR dispatch gating, dirty-resident flush
ordering, cross-instruction reuse invalidation — asks the same question: *which
of these tracked footprints could share a byte with this one?* Answering it by
pairwise scans made each of those sites O(live) per query and the program-level
cost O(live²); this module centralises the question behind an index so a query
pays only for its candidates.

Design: a region's *bounding interval* ``[start, end)`` is hashed into
fixed-size address buckets (``1 << bucket_bits`` bytes each). An item is
recorded in every bucket its bounding interval touches; items spanning more
than ``coarse_limit`` buckets go to a coarse overflow set that every query
scans (keeps inserts O(min(span, coarse_limit))). A query gathers the
candidate keys from the buckets its own bounding interval touches (plus the
coarse set), then confirms each candidate with the **exact** strided-region
algebra (:meth:`StridedRegion.overlaps`) — bucketing is a pure accelerator, it
never changes an answer. Queries and inserts are O(buckets touched +
candidates); the exact confirmation keeps the "column strips interleave
without touching" property the region algebra guarantees.

Determinism: :meth:`query` returns keys in sorted order, so callers that pick
"the first hit" see the same hit regardless of bucket-hash iteration order.
Keys within one index must be mutually orderable (ints, or same-shape tuples).

``brute_force_queries()`` switches every index to exhaustive candidate scans —
the pre-index behaviour. It exists for two consumers: the oracle tests (the
indexed and brute answers must be identical on any operation sequence) and
``benchmarks/bench_scheduler.py``'s baseline mode (measuring what the index
buys). The switch changes *wall-clock only*, never results.
"""
from __future__ import annotations

import contextlib
from typing import Hashable, Iterator, Optional

from repro.core.regions import StridedRegion, overlaps_cached

#: Module-level switch flipped by :func:`brute_force_queries`; when True every
#: AliasIndex query scans all items (exact confirmation still applies).
_BRUTE = False


@contextlib.contextmanager
def brute_force_queries() -> Iterator[None]:
    """Run all AliasIndex queries as exhaustive scans (pre-index baseline)."""
    global _BRUTE
    prev = _BRUTE
    _BRUTE = True
    try:
        yield
    finally:
        _BRUTE = prev


class AliasIndex:
    """Incremental interval index with exact strided-overlap confirmation.

    ``bucket_bits`` sets the bucket granularity (default 4 KiB — one LLC line
    span at the paper's geometry, a good fit for kernel-operand footprints);
    ``coarse_limit`` caps the buckets one item or query may touch before it
    falls back to the coarse path.
    """

    def __init__(self, bucket_bits: int = 12, coarse_limit: int = 128):
        self._bits = bucket_bits
        self._coarse_limit = coarse_limit
        self._buckets: dict[int, set[Hashable]] = {}
        self._coarse: set[Hashable] = set()
        self._regions: dict[Hashable, StridedRegion] = {}
        # Profiling counters (PipelineReport.alias_queries aggregates these).
        self.queries = 0
        self.candidates_checked = 0

    # ---------------------------------------------------------- maintenance
    def __len__(self) -> int:
        return len(self._regions)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._regions

    def region(self, key: Hashable) -> StridedRegion:
        return self._regions[key]

    def _span(self, region: StridedRegion) -> range:
        return range(region.start >> self._bits,
                     ((region.end - 1) >> self._bits) + 1)

    def insert(self, key: Hashable, region: StridedRegion) -> None:
        """Track ``region`` under ``key`` (replaces any previous region)."""
        if key in self._regions:
            self.discard(key)
        self._regions[key] = region
        span = self._span(region)
        if len(span) > self._coarse_limit:
            self._coarse.add(key)
            return
        for b in span:
            bucket = self._buckets.get(b)
            if bucket is None:
                bucket = self._buckets[b] = set()
            bucket.add(key)

    def remove(self, key: Hashable) -> None:
        """Stop tracking ``key``; raises ``KeyError`` if absent."""
        region = self._regions.pop(key)
        if key in self._coarse:
            self._coarse.discard(key)
            return
        for b in self._span(region):
            bucket = self._buckets[b]
            bucket.discard(key)
            if not bucket:
                del self._buckets[b]

    def discard(self, key: Hashable) -> None:
        """Stop tracking ``key`` if present."""
        if key in self._regions:
            self.remove(key)

    def clear(self) -> None:
        self._buckets.clear()
        self._coarse.clear()
        self._regions.clear()

    # --------------------------------------------------------------- queries
    def _candidates(self, region: StridedRegion):
        """Candidate key collection (a set, or a borrowed read-only one)."""
        span = self._span(region)
        if len(span) > self._coarse_limit:
            return self._regions
        get = self._buckets.get
        buckets = [b for b in map(get, span) if b]
        if not self._coarse and len(buckets) == 1:
            return buckets[0]          # borrowed — query() only iterates it
        cands: set[Hashable] = set(self._coarse)
        for b in buckets:
            cands |= b
        return cands

    def query(self, region: StridedRegion) -> list[Hashable]:
        """Keys whose footprint shares at least one byte with ``region``
        (exact), in ascending key order."""
        self.queries += 1
        if not self._regions:
            return []
        if _BRUTE:
            # Baseline mode is the *pre-index* cost model: full scan with
            # uncached exact decisions (the memo is also a PR-5 addition).
            self.candidates_checked += len(self._regions)
            return self.brute_query(region)
        cands = self._candidates(region)
        self.candidates_checked += len(cands)
        regions = self._regions
        return sorted(k for k in cands
                      if overlaps_cached(regions[k], region))

    def query_interval(self, start: int, end: int) -> list[Hashable]:
        """Keys whose footprint touches the flat byte interval ``[start,
        end)``, in ascending key order. Empty intervals match nothing."""
        if end <= start:
            self.queries += 1
            return []
        return self.query(StridedRegion(addr=start, rows=1,
                                        row_bytes=end - start,
                                        stride_bytes=end - start))

    def brute_query(self, region: StridedRegion) -> list[Hashable]:
        """Exhaustive-scan reference answer (the oracle the tests compare
        against; also what every query does under ``brute_force_queries``)."""
        return sorted(k for k, r in self._regions.items()
                      if r.overlaps(region))
