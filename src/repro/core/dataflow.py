"""Per-operand dataflow descriptors — kernel-aware DMA→compute gating.

The pipelined C-RT scheduler (:mod:`repro.sim.pipeline`) models NM-Carus-style
intra-instruction pipelining: each source operand streams into the VPU as a
tile-indexed DMA activity train, and the kernel's compute is split into pieces
that start as tiles land. *Which* tiles a compute piece actually needs is a
property of the kernel's dataflow, not of the DMA stream order: output row *i*
of a GEMM needs row *i* of A but **all** of B, whereas an elementwise kernel
needs only row *i* of each operand (Neural Cache's operand-blocked dataflow;
NM-Carus pipelines per operand at sub-instruction granularity).

Each kernel in the library therefore declares one :class:`OperandFlow` per
source operand. A flow carries **two axis policies** — one per matrix
dimension — each drawn from:

* :data:`ELEMENTWISE` — compute piece *i* (of *P*) needs the operand's
  rows/cols up to the proportional share ``ceil((i+1)·extent/P)``.
* :data:`FULL` — the whole axis must land before the first piece (GEMM's B
  along rows, conv weights along both axes).
* :func:`windowed(w)` — proportional share **plus** ``w`` lookahead (conv /
  maxpool windows).

The 1D constants/constructors keep their PR-3 meaning (column axis FULL);
:func:`TILED` combines a row-axis policy with a column-axis policy so the
scheduler's 2D tile trains (``pipeline: {tiling: ...}``) can gate an output
tile ``(i, j)`` on exactly the operand tiles it reads — GEMM output tile
``(i, j)`` needs A-band *i* and B-column-tile *j*, not all of B.

``blocks=B`` marks a row-stacked operand (e.g. the 3-channel conv-layer input,
three H-row channel planes stacked into one 3H-row matrix): every output row
reads a window from *each* plane, so the C-RT programs ``B`` interleaved 2D
DMA descriptors, streaming the planes round-robin — after a fraction *f* of
the transfer, a fraction *f* of every plane has landed, and windowed gating
applies per plane instead of degenerating to FULL on the stacked layout.

Kernels that register no descriptor get :data:`FULL` on every operand — the
conservative (sound) default; only declared dataflow earns overlap.

Descriptors change **timing only**. Functional DMA and compute still execute
atomically in dependency order, so serial and pipelined outputs remain
bit-identical regardless of the gating policy.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Callable, Optional, Sequence


class FlowKind(enum.Enum):
    ELEMENTWISE = "elementwise"
    FULL = "full"
    WINDOWED = "windowed"


def _share(kind: FlowKind, window: int, piece: int, n_pieces: int,
           extent: int) -> int:
    """Units of one axis that must have landed before ``piece`` starts."""
    if kind is FlowKind.FULL:
        return extent
    need = math.ceil((piece + 1) * extent / max(n_pieces, 1))
    if kind is FlowKind.WINDOWED:
        need += window
    return min(extent, need)


@dataclasses.dataclass(frozen=True)
class OperandFlow:
    """How one source operand's DMA tiles gate compute pieces.

    ``kind``/``window_rows`` describe the row axis (the PR-3 1D policy);
    ``col_kind``/``window_cols`` describe the column axis and default to FULL
    — a 1D flow is exactly a 2D flow whose column policy is FULL.
    """

    kind: FlowKind
    window_rows: int = 0      # WINDOWED lookahead beyond the proportional share
    blocks: int = 1           # row-stacked planes streamed round-robin
    col_kind: FlowKind = FlowKind.FULL
    window_cols: int = 0

    def __post_init__(self):
        if self.window_rows < 0:
            raise ValueError(f"window_rows must be >= 0, got {self.window_rows}")
        if self.window_cols < 0:
            raise ValueError(f"window_cols must be >= 0, got {self.window_cols}")
        if self.blocks < 1:
            raise ValueError(f"blocks must be >= 1, got {self.blocks}")
        if self.kind is not FlowKind.WINDOWED and self.window_rows:
            raise ValueError(f"window_rows only applies to WINDOWED, "
                             f"got {self.kind}")
        if self.col_kind is not FlowKind.WINDOWED and self.window_cols:
            raise ValueError(f"window_cols only applies to WINDOWED, "
                             f"got {self.col_kind}")

    def rows_required(self, piece: int, n_pieces: int, block_rows: int) -> int:
        """Rows of each block that must have landed before ``piece`` starts."""
        return _share(self.kind, self.window_rows, piece, n_pieces, block_rows)

    def cols_required(self, piece: int, n_pieces: int, cols: int) -> int:
        """Columns that must have landed before column piece ``piece``."""
        return _share(self.col_kind, self.window_cols, piece, n_pieces, cols)


#: Piece *i* needs chunk *i* of the operand (row-for-row streaming).
ELEMENTWISE = OperandFlow(FlowKind.ELEMENTWISE)
#: Every chunk before any piece — the sound default for undeclared kernels.
FULL = OperandFlow(FlowKind.FULL)


def windowed(window_rows: int, *, blocks: int = 1) -> OperandFlow:
    """Piece *i* needs its proportional rows plus ``window_rows`` lookahead."""
    return OperandFlow(FlowKind.WINDOWED, window_rows=window_rows,
                       blocks=blocks)


def TILED(rows: OperandFlow, cols: OperandFlow) -> OperandFlow:
    """Combine a row-axis policy with a column-axis policy into one 2D flow.

    ``rows`` contributes its kind/window/blocks as the row-axis behaviour;
    ``cols`` is reinterpreted along the column axis (its ``window_rows``
    becomes the column lookahead). E.g. GEMM's B is ``TILED(FULL,
    ELEMENTWISE)`` — every row of B before any piece, but only the column
    tiles the output tile's columns project onto.
    """
    if cols.blocks != 1 or cols.col_kind is not FlowKind.FULL:
        raise ValueError("TILED cols policy must be a plain 1-axis flow")
    return OperandFlow(rows.kind, window_rows=rows.window_rows,
                       blocks=rows.blocks, col_kind=cols.kind,
                       window_cols=cols.window_rows)


#: Signature of a kernel's dataflow hook: (src_shapes, params, width) ->
#: one OperandFlow per source operand.
DataflowFn = Callable[..., Sequence[OperandFlow]]


def resolve(dataflow: Optional[DataflowFn],
            src_shapes: Sequence[tuple[int, int]], params: dict,
            width) -> tuple[OperandFlow, ...]:
    """Resolve a kernel's per-operand descriptor at decode time.

    ``None`` (kernel registered without a descriptor) yields FULL for every
    operand — never optimistic. A descriptor returning the wrong arity is a
    kernel-registration bug and raises ``ValueError``.
    """
    if dataflow is None:
        return (FULL,) * len(src_shapes)
    flows = tuple(dataflow(src_shapes, params, width))
    if len(flows) != len(src_shapes):
        raise ValueError(
            f"dataflow descriptor returned {len(flows)} operand flows for "
            f"{len(src_shapes)} source operands")
    for f in flows:
        if not isinstance(f, OperandFlow):
            raise ValueError(f"dataflow descriptor must return OperandFlow "
                             f"instances, got {type(f).__name__}")
    return flows
