"""Per-operand dataflow descriptors — kernel-aware DMA→compute gating.

The pipelined C-RT scheduler (:mod:`repro.sim.pipeline`) models NM-Carus-style
intra-instruction pipelining: each source operand streams into the VPU as a
row-chunked DMA activity train, and the kernel's compute is split into pieces
that start as chunks land. *Which* chunks a compute piece actually needs is a
property of the kernel's dataflow, not of the DMA stream order: output row *i*
of a GEMM needs row *i* of A but **all** of B, whereas an elementwise kernel
needs only row *i* of each operand (Neural Cache's operand-blocked dataflow;
NM-Carus pipelines per operand at sub-instruction granularity).

Each kernel in the library therefore declares one :class:`OperandFlow` per
source operand:

* :data:`ELEMENTWISE` — compute piece *i* (of *P*) needs the operand's rows up
  to the proportional share ``ceil((i+1)·rows/P)`` — chunk *i* when the chunk
  counts line up.
* :data:`FULL` — every chunk must land before the first piece (GEMM's B,
  conv's weights).
* :func:`windowed(w)` — piece *i* needs the proportional share **plus** ``w``
  lookahead rows (conv/maxpool row windows).

``blocks=B`` marks a row-stacked operand (e.g. the 3-channel conv-layer input,
three H-row channel planes stacked into one 3H-row matrix): every output row
reads a window from *each* plane, so the C-RT programs ``B`` interleaved 2D
DMA descriptors, streaming the planes round-robin — after a fraction *f* of
the transfer, a fraction *f* of every plane has landed, and windowed gating
applies per plane instead of degenerating to FULL on the stacked layout.

Kernels that register no descriptor get :data:`FULL` on every operand — the
conservative (sound) default; only declared dataflow earns overlap.

Descriptors change **timing only**. Functional DMA and compute still execute
atomically in dependency order, so serial and pipelined outputs remain
bit-identical regardless of the gating policy.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Callable, Optional, Sequence


class FlowKind(enum.Enum):
    ELEMENTWISE = "elementwise"
    FULL = "full"
    WINDOWED = "windowed"


@dataclasses.dataclass(frozen=True)
class OperandFlow:
    """How one source operand's DMA chunks gate compute pieces."""

    kind: FlowKind
    window_rows: int = 0      # WINDOWED lookahead beyond the proportional share
    blocks: int = 1           # row-stacked planes streamed round-robin

    def __post_init__(self):
        if self.window_rows < 0:
            raise ValueError(f"window_rows must be >= 0, got {self.window_rows}")
        if self.blocks < 1:
            raise ValueError(f"blocks must be >= 1, got {self.blocks}")
        if self.kind is not FlowKind.WINDOWED and self.window_rows:
            raise ValueError(f"window_rows only applies to WINDOWED, "
                             f"got {self.kind}")

    def rows_required(self, piece: int, n_pieces: int, block_rows: int) -> int:
        """Rows of each block that must have landed before ``piece`` starts."""
        if self.kind is FlowKind.FULL:
            return block_rows
        share = math.ceil((piece + 1) * block_rows / max(n_pieces, 1))
        if self.kind is FlowKind.WINDOWED:
            share += self.window_rows
        return min(block_rows, share)


#: Piece *i* needs chunk *i* of the operand (row-for-row streaming).
ELEMENTWISE = OperandFlow(FlowKind.ELEMENTWISE)
#: Every chunk before any piece — the sound default for undeclared kernels.
FULL = OperandFlow(FlowKind.FULL)


def windowed(window_rows: int, *, blocks: int = 1) -> OperandFlow:
    """Piece *i* needs its proportional rows plus ``window_rows`` lookahead."""
    return OperandFlow(FlowKind.WINDOWED, window_rows=window_rows,
                       blocks=blocks)


#: Signature of a kernel's dataflow hook: (src_shapes, params, width) ->
#: one OperandFlow per source operand.
DataflowFn = Callable[..., Sequence[OperandFlow]]


def resolve(dataflow: Optional[DataflowFn],
            src_shapes: Sequence[tuple[int, int]], params: dict,
            width) -> tuple[OperandFlow, ...]:
    """Resolve a kernel's per-operand descriptor at decode time.

    ``None`` (kernel registered without a descriptor) yields FULL for every
    operand — never optimistic. A descriptor returning the wrong arity is a
    kernel-registration bug and raises ``ValueError``.
    """
    if dataflow is None:
        return (FULL,) * len(src_shapes)
    flows = tuple(dataflow(src_shapes, params, width))
    if len(flows) != len(src_shapes):
        raise ValueError(
            f"dataflow descriptor returned {len(flows)} operand flows for "
            f"{len(src_shapes)} source operands")
    for f in flows:
        if not isinstance(f, OperandFlow):
            raise ValueError(f"dataflow descriptor must return OperandFlow "
                             f"instances, got {type(f).__name__}")
    return flows
