"""Near-memory Vector Processing Unit model (NM-Carus instances, paper §III).

Each VPU owns a slice of the LLC data array as its vector register file and
executes the vector micro-programs the kernel bodies expand into. The
simulator executes the micro-program semantics with numpy; the *cycle model*
captures the datapath geometry the paper synthesizes:

  * ``lanes`` 32-bit lanes per VPU (2 / 4 / 8 in Table II);
  * packed-SIMD within a lane: a lane retires ``4 / elem_bytes`` element ops
    per cycle (int8 runs 4× faster than int32 — the source of the paper's
    8-bit advantage);
  * MACs count as one datapath op (the MXU analogue on the TPU target);
  * DMA moves ``dma_bytes_per_cycle`` between memory and the register file.

The same geometry drives the Fig. 3 / Fig. 4 reproduction benchmarks.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.cache import ArcaneCache
from repro.core.encoding import ElemWidth
from repro.core.isa import KernelCost, KernelSpec, KernelLibrary
from repro.core.matrix import np_dtype


@dataclasses.dataclass(frozen=True)
class VPUGeometry:
    lanes: int = 4
    dma_bytes_per_cycle: int = 4     # 32-bit bus, one beat per cycle
    decode_cycles: int = 350         # SW decode + preamble in the eCPU ISR
    schedule_cycles: int = 120       # queue push/pop + VPU selection
    issue_cycles_per_vins: int = 4   # eCPU cost to issue one vector instruction
    vlen_bytes: int = 1024           # vector length == LLC line length, bytes

    def compute_cycles(self, cost: KernelCost, width: ElemWidth) -> int:
        simd = 4 // width.nbytes                 # packed elems per 32-bit lane
        per_cycle = max(1, self.lanes * simd)
        datapath_ops = cost.macs + cost.elementwise
        # issue overhead: one vector instruction per ~vl elements chunk
        vl_elems = self.vlen_bytes // width.nbytes
        n_vins = max(1, math.ceil(datapath_ops / max(vl_elems, 1)))
        return math.ceil(datapath_ops / per_cycle) + n_vins * self.issue_cycles_per_vins

    def dma_cycles(self, nbytes: int, rows: int = 1) -> int:
        # per-row address-generation overhead of the 2D auto-increment DMA
        return math.ceil(nbytes / self.dma_bytes_per_cycle) + 4 * rows


@dataclasses.dataclass
class ResidentMatrix:
    """A matrix currently materialised in a VPU's register file."""

    phys_id: int
    vpu: int
    line_idxs: list[int]
    rows: int
    cols: int
    width: ElemWidth
    dirty: bool = False      # result not written back yet


class VPU:
    """One near-memory vector unit bound to its LLC line slice."""

    def __init__(self, index: int, cache: ArcaneCache, geometry: VPUGeometry,
                 library: KernelLibrary):
        self.index = index
        self.cache = cache
        self.geometry = geometry
        self.library = library

    # ------------------------------------------------------------- data path
    def lines_needed(self, rows: int, cols: int, width: ElemWidth) -> int:
        nbytes = rows * cols * width.nbytes
        return max(1, math.ceil(nbytes / self.cache.vlen_bytes))

    def load_matrix(self, resident: ResidentMatrix, buf: np.ndarray) -> None:
        self.cache._scatter_to_lines(
            resident.line_idxs, np.ascontiguousarray(buf).view(np.uint8).reshape(-1)
        )

    def read_matrix(self, resident: ResidentMatrix) -> np.ndarray:
        dt = np_dtype(resident.width)
        n = resident.rows * resident.cols * dt.itemsize
        raw = self.cache._gather_from_lines(resident.line_idxs, n)
        return raw.view(dt).reshape(resident.rows, resident.cols).copy()

    # ------------------------------------------------------------- execution
    def execute(self, spec: KernelSpec, sources: list[ResidentMatrix],
                dest: ResidentMatrix) -> int:
        """Run the micro-program on register-file-resident operands.

        Returns modeled compute cycles. Raises if an operand is not resident
        on *this* VPU — the scheduler must have allocated it here first.
        """
        for r in (*sources, dest):
            if r.vpu != self.index:
                raise RuntimeError(
                    f"operand phys{r.phys_id} resident on VPU{r.vpu}, "
                    f"kernel dispatched to VPU{self.index}"
                )
        kdef = self.library.lookup(spec.func5)
        src_arrays = [self.read_matrix(r) for r in sources]
        out = kdef.body(src_arrays, spec.params, spec.width)
        if tuple(out.shape) != spec.dst_shape:
            raise RuntimeError(
                f"{spec.name}: body produced {out.shape}, preamble promised "
                f"{spec.dst_shape}"
            )
        self.load_matrix(dest, out.astype(np_dtype(spec.width), casting="unsafe"))
        dest.dirty = True
        return self.geometry.compute_cycles(spec.cost, spec.width)
