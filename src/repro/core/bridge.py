"""CV-X-IF bridge + host-side programming API (paper §III-B, Listing 1).

The bridge samples the offloaded instruction's opcode/func5 and the three
operand registers, raises the eCPU "interrupt" (a decode call here), and
relays the accept/reject outcome back over the CV-X-IF. The host then commits
or kills; committed operations complete out-of-order while the host continues.

`ArcaneCoprocessor` is the application-facing wrapper providing the intrinsics
used in the paper's Listing 1 (`_xmr_w`, `_gemm_w`, `_conv_layer_w`, ...) plus
typed helpers for examples/benchmarks. Matrix data lives in simulated main
memory; loads/stores go through the cache with full hazard checking.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import numpy as np

from repro.core.encoding import (ElemWidth, Offload, encode_xmk, encode_xmr)
from repro.core.isa import KernelError, fx_encode
from repro.core.matrix import np_dtype
from repro.core.runtime import CacheRuntime


class XifResult(enum.Enum):
    ACCEPT = "accept"
    REJECT = "reject"


@dataclasses.dataclass
class OffloadRecord:
    offload: Offload
    result: XifResult
    committed: bool = False
    killed: bool = False


class Bridge:
    """Models the offload/accept/commit/kill handshake."""

    def __init__(self, runtime: CacheRuntime):
        self.runtime = runtime
        self.log: list[OffloadRecord] = []

    def offload(self, off: Offload) -> OffloadRecord:
        try:
            off.instr  # decode raises on malformed words
            rec = OffloadRecord(offload=off, result=XifResult.ACCEPT)
        except Exception:
            rec = OffloadRecord(offload=off, result=XifResult.REJECT)
            self.log.append(rec)
            return rec
        self.log.append(rec)
        return rec

    def commit(self, rec: OffloadRecord) -> None:
        """Host commits: the eCPU decodes and queues; execution is OoO."""
        if rec.result is not XifResult.ACCEPT:
            raise RuntimeError("cannot commit a rejected offload")
        try:
            self.runtime.decode(rec.offload)
            rec.committed = True
        except KernelError:
            rec.killed = True
            raise

    def kill(self, rec: OffloadRecord) -> None:
        rec.killed = True  # bridge idles on kill acknowledgment


class ArcaneCoprocessor:
    """Host-CPU view of the ARCANE LLC (the Listing-1 programming model)."""

    def __init__(self, runtime: Optional[CacheRuntime] = None, **rt_kwargs):
        self.rt = runtime or CacheRuntime(**rt_kwargs)
        self.bridge = Bridge(self.rt)
        self._heap = 64  # bump allocator over simulated main memory

    # ---------------------------------------------------------------- memory
    def malloc(self, nbytes: int, align: int = 64) -> int:
        self._heap = (self._heap + align - 1) // align * align
        addr = self._heap
        self._heap += nbytes
        if self._heap > self.rt.memory.size:
            raise MemoryError("simulated main memory exhausted")
        return addr

    def place(self, arr: np.ndarray, width: ElemWidth) -> int:
        """Host-store an array into fresh main memory; returns its address.

        Goes through the cache (host write path) — a direct backdoor write to
        ``MainMemory`` would be incoherent with lines already caching the
        surrounding block (line-granule aliasing).
        """
        arr = np.ascontiguousarray(arr, dtype=np_dtype(width))
        addr = self.malloc(arr.nbytes)
        self.rt.host_store(addr, arr.view(np.uint8).reshape(-1))
        return addr

    def gather(self, addr: int, rows: int, cols: int, width: ElemWidth) -> np.ndarray:
        """Host load of a matrix (hazard-checked, through the cache)."""
        raw = self.rt.host_load(addr, rows * cols * width.nbytes)
        return raw.view(np_dtype(width)).reshape(rows, cols).copy()

    def store(self, addr: int, arr: np.ndarray, width: ElemWidth) -> None:
        arr = np.ascontiguousarray(arr, dtype=np_dtype(width))
        self.rt.host_store(addr, arr.view(np.uint8).reshape(-1))

    # -------------------------------------------------------------- offloads
    def _issue(self, off: Offload) -> None:
        rec = self.bridge.offload(off)
        if rec.result is XifResult.REJECT:
            raise RuntimeError(f"CV-X-IF rejected {off.word:#010x}")
        self.bridge.commit(rec)

    def xmr(self, width: ElemWidth, md: int, addr: int, rows: int, cols: int,
            stride: int = 0) -> None:
        self._issue(encode_xmr(width, addr, stride, md, cols, rows))

    def xmk(self, n: int, width: ElemWidth, md: int, ms1: int = 0, ms2: int = 0,
            ms3: int = 0, alpha: int = 0, beta: int = 0) -> None:
        self._issue(encode_xmk(n, width, md, ms1, ms2, ms3, alpha, beta))

    def barrier(self) -> None:
        self.rt.barrier()

    # --------------------------------------------- Listing-1 style intrinsics
    def _xmr(self, width, md, addr, stride, rows, cols):
        self.xmr(width, md, addr, rows, cols, stride)

    def _xmr_w(self, md, addr, stride, rows, cols):
        self._xmr(ElemWidth.W, md, addr, stride, rows, cols)

    def _xmr_h(self, md, addr, stride, rows, cols):
        self._xmr(ElemWidth.H, md, addr, stride, rows, cols)

    def _xmr_b(self, md, addr, stride, rows, cols):
        self._xmr(ElemWidth.B, md, addr, stride, rows, cols)

    def _gemm(self, width, md, ms1, ms2, ms3, alpha=1.0, beta=0.0):
        self.xmk(0, width, md, ms1=ms1, ms2=ms2, ms3=ms3,
                 alpha=fx_encode(alpha), beta=fx_encode(beta))

    def _gemm_w(self, md, ms1, ms2, ms3, alpha=1.0, beta=0.0):
        self._gemm(ElemWidth.W, md, ms1, ms2, ms3, alpha, beta)

    def _leakyrelu(self, width, md, ms1, alpha=0.0):
        self.xmk(1, width, md, ms1=ms1, alpha=fx_encode(alpha))

    def _maxpool(self, width, md, ms1, stride, win_size):
        # Table I: stride/win_size travel in rs1's halves.
        self.xmk(2, width, md, ms1=ms1, alpha=stride, beta=win_size)

    def _conv2d(self, width, md, ms1, ms2):
        self.xmk(3, width, md, ms1=ms1, ms2=ms2)

    def _conv_layer(self, width, md, ms1, ms2):
        self.xmk(4, width, md, ms1=ms1, ms2=ms2)

    def _conv_layer_w(self, md, ms1, ms2):
        self._conv_layer(ElemWidth.W, md, ms1, ms2)

    def _conv_layer_h(self, md, ms1, ms2):
        self._conv_layer(ElemWidth.H, md, ms1, ms2)

    def _conv_layer_b(self, md, ms1, ms2):
        self._conv_layer(ElemWidth.B, md, ms1, ms2)
