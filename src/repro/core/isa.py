"""The software-defined `xmnmc` matrix ISA — kernel library (paper §IV).

Only two instruction *types* exist: ``xmr`` (matrix reserve) and ``xmkN``
(matrix kernel, N ∈ [0, 30], selected by ``func5``). What each ``xmkN`` *does*
is software: the Kernel Decoder looks the func5 up in this registry (O(1)) and
runs the registered micro-program. Users extend the ISA by registering new
kernels before C-RT "compilation" — here, at import/config time — with
:func:`register_kernel`; no hardware (or framework) change required.

Each kernel definition carries:
  * ``preamble``  — shape/param validation, destination shape inference
                    (runs in the decoder's interrupt context);
  * ``body``      — the vector micro-program (numpy for the simulator; the
                    production engine swaps in the Pallas implementation from
                    ``repro.kernels`` — same signature, same semantics);
  * ``cost``      — op counts for the cycle/roofline models.

Built-ins follow Table I:
  xmk0 GeMM (α, β) · xmk1 LeakyReLU (α) · xmk2 MaxPool (stride, win)
  xmk3 2D Conv · xmk4 3-channel 2D Conv Layer (conv+maxpool+ReLU, fused)

Integer semantics: element arithmetic wraps at the operand width (hardware
registers); α/β are signed Q8.8 fixed-point scalars for the scaling kernels
(a common choice for integer NMC datapaths) — documented per kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.dataflow import (ELEMENTWISE, FULL, OperandFlow, TILED,
                                 windowed)
from repro.core.encoding import ElemWidth, NUM_XMK
from repro.core.matrix import np_dtype


class KernelError(ValueError):
    """Preamble rejected the operation — bridge answers 'kill'."""


@dataclasses.dataclass(frozen=True)
class KernelCost:
    """Op counts for the cycle model (simulator) and roofline (benchmarks)."""

    macs: int = 0          # multiply-accumulate ops (2 OPs each, as in §V-C)
    elementwise: int = 0   # compare/select/add/shift style ops
    in_bytes: int = 0
    out_bytes: int = 0

    @property
    def ops(self) -> int:
        return 2 * self.macs + self.elementwise


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Decoded, validated kernel instance ready for scheduling."""

    func5: int
    name: str
    width: ElemWidth
    src_shapes: tuple[tuple[int, int], ...]
    dst_shape: tuple[int, int]
    params: dict
    cost: KernelCost
    # Per-source-operand DMA→compute gating policy, resolved at decode time
    # (FULL for every operand when the kernel registers no descriptor).
    dataflow: tuple[OperandFlow, ...] = ()


@dataclasses.dataclass(frozen=True)
class KernelDef:
    func5: int
    name: str
    n_sources: int
    # preamble(src_shapes, params, width) -> (dst_shape, cost); raises KernelError.
    preamble: Callable[[Sequence[tuple[int, int]], dict, ElemWidth], tuple[tuple[int, int], KernelCost]]
    # body(sources, params, width) -> destination ndarray.
    body: Callable[[Sequence[np.ndarray], dict, ElemWidth], np.ndarray]
    doc: str = ""
    # dataflow(src_shapes, params, width) -> one OperandFlow per source: how
    # DMA chunks of each operand gate compute pieces in the pipelined
    # scheduler (see repro.core.dataflow). None -> FULL on every operand.
    dataflow: Optional[Callable[[Sequence[tuple[int, int]], dict, ElemWidth],
                                Sequence[OperandFlow]]] = None


class KernelLibrary:
    """func5 → KernelDef registry. O(1) decode; user-extensible (§IV-A2)."""

    def __init__(self):
        self._defs: list[Optional[KernelDef]] = [None] * NUM_XMK

    def register(self, kdef: KernelDef, *, allow_override: bool = False) -> None:
        if not 0 <= kdef.func5 < NUM_XMK:
            raise ValueError(f"func5 {kdef.func5} outside xmk space [0, {NUM_XMK})")
        if self._defs[kdef.func5] is not None and not allow_override:
            raise ValueError(f"xmk{kdef.func5} already bound to "
                             f"{self._defs[kdef.func5].name}")
        self._defs[kdef.func5] = kdef

    def lookup(self, func5: int) -> KernelDef:
        if not 0 <= func5 < NUM_XMK or self._defs[func5] is None:
            raise KernelError(f"xmk{func5}: no kernel registered")
        return self._defs[func5]

    def names(self) -> dict[int, str]:
        return {i: d.name for i, d in enumerate(self._defs) if d is not None}


def register_kernel(
    library: "KernelLibrary", func5: int, name: str, n_sources: int, doc: str = ""
):
    """Decorator pair: ``@register_kernel(lib, 5, "mykernel", 2)`` on the body,
    with ``preamble=`` supplied via the returned registrar."""

    def wrap(body, preamble):
        library.register(KernelDef(func5=func5, name=name, n_sources=n_sources,
                                   preamble=preamble, body=body, doc=doc))
        return body

    return wrap


# ---------------------------------------------------------------------------
# Fixed-point helpers (α/β are signed Q8.8 in the integer datapath).
Q = 8


def _fx(v: int) -> float:
    """Interpret a 16-bit operand half as signed Q8.8."""
    v &= 0xFFFF
    if v >= 0x8000:
        v -= 0x10000
    return v / (1 << Q)


def fx_encode(x: float) -> int:
    """Encode a float scalar into the 16-bit Q8.8 operand half."""
    v = int(round(x * (1 << Q)))
    if not -0x8000 <= v <= 0x7FFF:
        raise KernelError(f"scalar {x} out of Q8.8 range")
    return v & 0xFFFF


def _wrap(x: np.ndarray, width: ElemWidth) -> np.ndarray:
    """Wrap accumulator results back to the operand width (two's complement
    truncation, i.e. what the hardware register write does)."""
    dt = np_dtype(width)
    return np.asarray(x).astype(np.int64).astype(dt, casting="unsafe")


# ---------------------------------------------------------------------------
# Built-in kernels (Table I).

def _gemm_preamble(shapes, params, width):
    (m, k), (k2, n) = shapes[0], shapes[1]
    if k != k2:
        raise KernelError(f"GeMM inner dims mismatch: {shapes[0]} x {shapes[1]}")
    if len(shapes) > 2 and shapes[2] != (m, n):
        raise KernelError(f"GeMM accumulator shape {shapes[2]} != {(m, n)}")
    eb = width.nbytes
    cost = KernelCost(
        macs=m * k * n,
        elementwise=2 * m * n,  # alpha scale + beta*C add
        in_bytes=(m * k + k * n + (m * n if len(shapes) > 2 else 0)) * eb,
        out_bytes=m * n * eb,
    )
    return (m, n), cost


def _gemm_body(sources, params, width):
    a, b = sources[0], sources[1]
    acc = a.astype(np.int64) @ b.astype(np.int64)
    alpha = _fx(params.get("alpha", fx_encode(1.0)))
    beta = _fx(params.get("beta", fx_encode(0.0)))
    out = alpha * acc
    if len(sources) > 2 and beta != 0.0:
        out = out + beta * sources[2].astype(np.int64)
    return _wrap(np.round(out), width)


def _leakyrelu_preamble(shapes, params, width):
    (m, n) = shapes[0]
    eb = width.nbytes
    return (m, n), KernelCost(elementwise=2 * m * n, in_bytes=m * n * eb,
                              out_bytes=m * n * eb)


def _leakyrelu_body(sources, params, width):
    x = sources[0].astype(np.int64)
    alpha = _fx(params.get("alpha", fx_encode(0.0)))
    return _wrap(np.where(x >= 0, x, np.round(alpha * x)), width)


def _maxpool_preamble(shapes, params, width):
    (m, n) = shapes[0]
    win = params.get("win_size", 2)
    stride = params.get("stride", win)
    if win <= 0 or stride <= 0:
        raise KernelError("maxpool window/stride must be positive")
    if m < win or n < win:
        raise KernelError(f"maxpool window {win} larger than input {shapes[0]}")
    om = (m - win) // stride + 1
    on = (n - win) // stride + 1
    eb = width.nbytes
    return (om, on), KernelCost(elementwise=om * on * win * win,
                                in_bytes=m * n * eb, out_bytes=om * on * eb)


def _maxpool_body(sources, params, width):
    x = sources[0]
    win = params.get("win_size", 2)
    stride = params.get("stride", win)
    m, n = x.shape
    om = (m - win) // stride + 1
    on = (n - win) // stride + 1
    out = np.empty((om, on), dtype=x.dtype)
    for i in range(om):
        for j in range(on):
            out[i, j] = x[i * stride : i * stride + win,
                          j * stride : j * stride + win].max()
    return out


def _conv2d_valid(x: np.ndarray, f: np.ndarray) -> np.ndarray:
    """Valid 2D cross-correlation in int64 (what CNN stacks call conv)."""
    m, n = x.shape
    km, kn = f.shape
    om, on = m - km + 1, n - kn + 1
    out = np.zeros((om, on), dtype=np.int64)
    xl = x.astype(np.int64)
    fl = f.astype(np.int64)
    for di in range(km):
        for dj in range(kn):
            out += fl[di, dj] * xl[di : di + om, dj : dj + on]
    return out


def _conv2d_preamble(shapes, params, width):
    (m, n), (km, kn) = shapes[0], shapes[1]
    if km > m or kn > n:
        raise KernelError(f"filter {shapes[1]} larger than input {shapes[0]}")
    om, on = m - km + 1, n - kn + 1
    eb = width.nbytes
    return (om, on), KernelCost(macs=om * on * km * kn,
                                in_bytes=(m * n + km * kn) * eb,
                                out_bytes=om * on * eb)


def _conv2d_body(sources, params, width):
    return _wrap(_conv2d_valid(sources[0], sources[1]), width)


def _convlayer_preamble(shapes, params, width):
    """3-channel conv layer (xmk4): input (3·H, W) channel-stacked, filter
    (3·k, k) channel-stacked; fused conv → 2×2 maxpool → ReLU (§IV-A)."""
    (m3, n), (km3, kn) = shapes[0], shapes[1]
    if m3 % 3 or km3 % 3:
        raise KernelError("xmk4 expects 3 channel-stacked rows (rows % 3 == 0)")
    m, km = m3 // 3, km3 // 3
    if km > m or kn > n:
        raise KernelError("filter larger than input")
    cm, cn = m - km + 1, n - kn + 1
    if cm < 2 or cn < 2:
        raise KernelError("conv output smaller than 2x2 pool window")
    om, on = cm // 2, cn // 2
    eb = width.nbytes
    cost = KernelCost(
        macs=3 * cm * cn * km * kn,
        elementwise=om * on * 4 + om * on,  # pool compares + relu
        in_bytes=(m3 * n + km3 * kn) * eb,
        out_bytes=om * on * eb,
    )
    return (om, on), cost


def _convlayer_body(sources, params, width):
    x3, f3 = sources[0], sources[1]
    m = x3.shape[0] // 3
    km = f3.shape[0] // 3
    acc = None
    for c in range(3):
        part = _conv2d_valid(x3[c * m : (c + 1) * m], f3[c * km : (c + 1) * km])
        acc = part if acc is None else acc + part
    # maxpool 2x2 stride 2 on the accumulator, then ReLU, then width wrap.
    cm, cn = acc.shape
    om, on = cm // 2, cn // 2
    pooled = acc[: om * 2, : on * 2].reshape(om, 2, on, 2).max(axis=(1, 3))
    return _wrap(np.maximum(pooled, 0), width)


# ---------------------------------------------------------------------------
# Per-operand dataflow descriptors (pipelined-scheduler gating; §IV-B timing).
# Each flow carries a row-axis and a column-axis policy (TILED); the column
# axis only becomes visible when the scheduler runs with 2D tiling enabled —
# with a single column tile per operand the column policy is vacuous and the
# gating reduces exactly to the 1D row-train model.

def _gemm_dataflow(shapes, params, width):
    # Output tile (i, j) = A-band i @ B-column-tile j (+ beta*C tile (i, j)):
    # A streams row-for-row and is read across all its columns (the inner
    # dimension); B needs every row but only the output tile's column tile;
    # the accumulator streams tile-for-tile.
    return (ELEMENTWISE, TILED(FULL, ELEMENTWISE)) \
        + (TILED(ELEMENTWISE, ELEMENTWISE),) * (len(shapes) - 2)


def _leakyrelu_dataflow(shapes, params, width):
    return (TILED(ELEMENTWISE, ELEMENTWISE),)


def _maxpool_dataflow(shapes, params, width):
    # Output element (i, j) reads the input window at (i*stride, j*stride):
    # the overhang beyond the proportional share is at most `win` on each
    # axis.
    win = params.get("win_size", 2)
    return (TILED(windowed(win), windowed(win)),)


def _conv2d_dataflow(shapes, params, width):
    # Valid conv: output tile (i, j) reads input rows i .. i+km-1 and cols
    # j .. j+kn-1; the filter is read in full by every output element.
    km, kn = shapes[1]
    return (TILED(windowed(km), windowed(kn)), FULL)


def _convlayer_dataflow(shapes, params, width):
    # 3-channel-stacked input (3H rows = three H-row planes): every output
    # row reads a k-row window from EACH plane, so the planes stream as three
    # round-robin-interleaved DMA trains; the 2x2 pool consumes two conv
    # rows/cols per output element, hence the +2 lookahead on top of the
    # filter window on both axes.
    km = shapes[1][0] // 3
    kn = shapes[1][1]
    return (TILED(windowed(km + 2, blocks=3), windowed(kn + 2)), FULL)


def default_library() -> KernelLibrary:
    lib = KernelLibrary()
    lib.register(KernelDef(0, "gemm", 3, _gemm_preamble, _gemm_body,
                           "D = alpha * ms1 @ ms2 + beta * ms3 (Q8.8 scalars)",
                           dataflow=_gemm_dataflow))
    lib.register(KernelDef(1, "leakyrelu", 1, _leakyrelu_preamble, _leakyrelu_body,
                           "D = x >= 0 ? x : alpha * x (alpha Q8.8)",
                           dataflow=_leakyrelu_dataflow))
    lib.register(KernelDef(2, "maxpool", 1, _maxpool_preamble, _maxpool_body,
                           "D = maxpool(ms1, win_size, stride)",
                           dataflow=_maxpool_dataflow))
    lib.register(KernelDef(3, "conv2d", 2, _conv2d_preamble, _conv2d_body,
                           "D = conv2d_valid(ms1, ms2)",
                           dataflow=_conv2d_dataflow))
    lib.register(KernelDef(4, "conv_layer", 2, _convlayer_preamble, _convlayer_body,
                           "D = relu(maxpool2x2(conv3ch(ms1, ms2))) — fused",
                           dataflow=_convlayer_dataflow))
    return lib
