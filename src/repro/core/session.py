"""Re-entrant runtime sessions — the open-loop execution protocol.

Every entry point into the simulator used to be closed-batch: hand a
:class:`~repro.core.program.KernelProgram` over once, run it to completion,
read the makespan. A :class:`RuntimeSession` instead keeps the runtime's
clock **open** between programs, so work can be injected at arbitrary sim
times while earlier work is still in flight — the execution model a serving
scenario needs (requests arrive mid-run; KV-cache state stays resident in
the cache under the real AT-capacity and flush rules between steps).

The protocol (implemented by both runtimes):

  * ``session.issue(prog, at=t)``   — place any unplaced buffers, issue the
    tape, and admit it at sim time ``t`` (default: now). Returns an
    :class:`IssueHandle` whose ``on_done`` callback fires at the sim time
    the program's last kernel retires.
  * ``session.post(t, fn)``        — inject an external event: ``fn(now)``
    runs when the clock reaches ``t`` (e.g. a request arrival that issues
    a prefill program).
  * ``session.advance(until=t)``   — process everything due by ``t``,
    leaving later work in flight.
  * ``session.drain()``            — run everything (chained callbacks
    included) to completion and flush deferred results.

On the pipelined runtime the session clock is the persistent event
timeline; on the serial runtime it is modeled-cycles-so-far plus injected
idle. A closed session (``open_loop=False``) reproduces the legacy batch
path exactly — :func:`repro.core.program.run_program` is now a thin wrapper
over one — and the differential fuzzer asserts bit-identity.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.program import (KernelProgram, _as_cop, issue_program,
                                place_program)


@dataclasses.dataclass
class IssueHandle:
    """One issued program's lifecycle: the kernel ids it decoded into and
    the sim time its last kernel retired (``None`` while in flight).

    ``on_done(t)`` fires exactly once, re-entrantly from inside the
    scheduler at the retire point — the hook continuous-batching drivers
    chain their next step from."""

    program: KernelProgram
    addrs: dict[str, int]
    issued_at: int
    on_done: Optional[Callable[[int], None]] = None
    kernel_ids: tuple[int, ...] = ()
    done_at: Optional[int] = None
    _outstanding: int = 0
    _sealed: bool = False

    @property
    def done(self) -> bool:
        return self.done_at is not None

    def _add(self, kid: int) -> None:
        self.kernel_ids += (kid,)
        self._outstanding += 1

    def _retired(self, t: int) -> None:
        self._outstanding -= 1
        self._maybe_done(t)

    def _seal(self, t: int) -> None:
        """All kernels are captured; completion may now be declared. (Queue
        backpressure can retire early kernels while later ops are still
        being issued — completion must wait for the full tape.)"""
        self._sealed = True
        self._maybe_done(t)

    def _maybe_done(self, t: int) -> None:
        if self._sealed and self._outstanding == 0 and self.done_at is None:
            self.done_at = t
            if self.on_done is not None:
                self.on_done(t)


class RuntimeSession:
    """A re-entrant execution session over one runtime.

    ``open_loop=True`` (default) keeps the clock open: issues admit work at
    the current sim time without running it; ``advance``/``drain`` move the
    clock. ``open_loop=False`` is the legacy batch discipline (queue
    backpressure drains eagerly) — what :func:`run_program` wraps.
    """

    def __init__(self, rt_or_cop, *, open_loop: bool = True,
                 validate: bool = True):
        self.cop = _as_cop(rt_or_cop)
        self.rt = self.cop.rt
        self.validate = validate
        self.open_loop = bool(open_loop)
        if self.open_loop:
            self.rt._session_open = True

    # ------------------------------------------------------------- protocol
    def now(self) -> int:
        """The session's current sim time."""
        return self.rt.session_now()

    def post(self, t: int, fn: Callable[[int], None]) -> None:
        """Schedule ``fn(now)`` to run at sim time ``t`` (clamped to now)."""
        self.rt.session_post(t, fn)

    def issue(self, prog: KernelProgram, *, at: Optional[int] = None,
              addrs: Optional[dict[str, int]] = None,
              on_done: Optional[Callable[[int], None]] = None) -> IssueHandle:
        """Issue ``prog`` at sim time ``at`` (default: now).

        ``addrs`` pre-places named buffers (shared weights, a request's KV
        buffers from an earlier step) — only buffers not in it are placed.
        The passed mapping is updated **in place** (and the handle aliases
        it): an ``on_done`` callback can fire re-entrantly from inside this
        very call on the synchronous serial runtime, and a chained issue
        sharing the mapping must already see this program's placements, not
        re-place (and silently fork) the live buffers. ``at`` in the future
        first advances the session there."""
        if self.validate:
            prog.validate(self.rt.library)
        if at is not None and at > self.rt.session_now():
            self.advance(until=at)
        placed = place_program(self.cop, prog, prior=addrs)
        if addrs is not None:
            addrs.update(placed)
            placed = addrs
        addrs = placed
        h = IssueHandle(program=prog, addrs=addrs,
                        issued_at=self.rt.session_now(), on_done=on_done)

        def captured(kid: int) -> None:
            h._add(kid)
            self.rt._retire_watchers.setdefault(kid, []).append(h._retired)

        # Save/restore, not set/clear: a retire callback firing during a
        # backpressure stall can issue *another* program re-entrantly while
        # this one is mid-issue — the outer program's capture hook must be
        # intact when its remaining ops decode.
        prev = self.rt._issue_capture
        self.rt._issue_capture = captured
        try:
            issue_program(self.cop, prog, addrs, barrier=False)
        finally:
            self.rt._issue_capture = prev
        if self.open_loop:
            # Admit now so decode bookings anchor at the issue time; the
            # events run at the next advance/drain (or in the enclosing
            # event loop, when issued from a callback).
            self.rt.run_pending()
        h._seal(self.rt.session_now())
        return h

    def advance(self, *, until: int) -> None:
        """Process everything due by sim time ``until``; later work stays
        in flight and the clock lands on ``until``."""
        self.rt.session_advance(int(until))

    def drain(self) -> None:
        """Run all remaining work — posted events, chained callbacks, and
        deferred write-backs — to completion."""
        self.rt.session_drain()
