"""Hazard checking and logical-matrix renaming (paper §IV-B1).

The Kernel Decoder must cope with out-of-order communication with the host: an
``xmr`` may rebind a logical matrix register while an older kernel that named
the same register is still queued. Physically copying or stalling would erase
the benefit of deferred allocation, so — exactly like an OoO core — the decoder
*renames*: every ``xmr`` mints a fresh physical binding (see
:class:`repro.core.matrix.MatrixMap`), and queued kernels capture the physical
bindings (not the logical indices) at decode time. WAR/WAW on logical registers
then vanish by construction; only true RAW dependencies between kernels remain,
and those are expressed as edges in a dependency DAG used by both the simulator
scheduler and the trace-time production engine (buffer-donation ordering).

Memory-aliasing edges between distinct physical bindings are decided with the
*exact* 2D region algebra (:mod:`repro.core.regions`, via
:meth:`MatrixBinding.overlaps`): unequal-stride interleavings that never share
a byte produce no edge, so strip-mined workloads schedule concurrently.

Tracker state is bounded: when a kernel completes, every per-binding record
(last writer, reader set, captured binding) whose physical id is no longer
referenced by a pending kernel — and not *pinned* by the runtime for a
deferred cache-resident result — is pruned, so long-running programs see
O(live) admission cost and memory, not O(history).

Host-side hazards against main memory regions are handled by the
:class:`repro.core.address_table.AddressTable`; this module covers
kernel↔kernel dependencies.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.alias_index import AliasIndex
from repro.core.matrix import MatrixBinding


@dataclasses.dataclass(frozen=True)
class KernelDeps:
    """Dependency summary for one decoded kernel instance."""

    kernel_id: int
    sources: tuple[int, ...]       # physical ids read
    destination: int               # physical id written
    depends_on: tuple[int, ...]    # kernel_ids that must complete first


class DependencyTracker:
    """Builds the kernel-level dependency DAG under renaming.

    After renaming, two queued kernels conflict iff:
      * RAW — a later kernel reads the physical destination of an earlier one;
      * output/anti conflicts on the *same physical* destination (possible when
        a program reuses a destination register without re-reserving it —
        renaming only happens at ``xmr``) — kept as WAW/WAR edges;
      * memory aliasing — distinct physical bindings whose main-memory
        footprints overlap (the AT-level view of the same hazard), decided
        exactly by the 2D region algebra.
    """

    def __init__(self):
        self._pending: dict[int, KernelDeps] = {}
        self._writer_of: dict[int, int] = {}   # phys_id -> kernel_id (last writer)
        self._readers_of: dict[int, set[int]] = {}
        self._bindings: dict[int, MatrixBinding] = {}
        self._refs: dict[int, int] = {}        # phys_id -> pending kernels using it
        self._pinned: set[int] = set()         # runtime-held (cache-resident) ids
        # Footprints written by *pending* kernels, keyed by phys_id: the
        # admission alias sweep queries this instead of scanning every live
        # writer record (O(hits) admission instead of O(live) per kernel).
        self._alias_index = AliasIndex()
        self._next_kernel_id = 0
        self._completed_count = 0

    # ------------------------------------------------------------------ api
    def admit(
        self,
        sources: Sequence[MatrixBinding],
        destination: MatrixBinding,
    ) -> KernelDeps:
        kid = self._next_kernel_id
        self._next_kernel_id += 1

        deps: set[int] = set()
        for b in (*sources, destination):
            self._bindings[b.phys_id] = b

        # RAW: read a pending kernel's destination.
        for src in sources:
            w = self._writer_of.get(src.phys_id)
            if w is not None and w in self._pending:
                deps.add(w)
        # WAW: same physical destination written twice without renaming.
        w = self._writer_of.get(destination.phys_id)
        if w is not None and w in self._pending:
            deps.add(w)
        # WAR: we overwrite something a pending kernel still reads.
        for r in self._readers_of.get(destination.phys_id, ()):
            if r in self._pending:
                deps.add(r)
        # Memory aliasing between distinct physical bindings (exact 2D
        # footprint intersection): query the pending-writer footprint index
        # with the destination and every source instead of sweeping all live
        # writer records — the index holds exactly the regions a pending
        # kernel will write.
        aliased: set[int] = set(self._alias_index.query(destination.region))
        for s in sources:
            aliased.update(self._alias_index.query(s.region))
        for other_pid in aliased:
            if other_pid == destination.phys_id:
                continue
            writer = self._writer_of.get(other_pid)
            if writer is not None and writer in self._pending:
                deps.add(writer)

        rec = KernelDeps(
            kernel_id=kid,
            sources=tuple(s.phys_id for s in sources),
            destination=destination.phys_id,
            depends_on=tuple(sorted(deps)),
        )
        self._pending[kid] = rec
        self._writer_of[destination.phys_id] = kid
        self._alias_index.insert(destination.phys_id, destination.region)
        for s in sources:
            self._readers_of.setdefault(s.phys_id, set()).add(kid)
        for pid in {*rec.sources, rec.destination}:
            self._refs[pid] = self._refs.get(pid, 0) + 1
        return rec

    def binding(self, phys_id: int) -> Optional[MatrixBinding]:
        """Binding captured at admission (outlives matrix-map renaming)."""
        return self._bindings.get(phys_id)

    def writer_of(self, phys_id: int) -> Optional[int]:
        """Kernel id of the (last) writer of ``phys_id``; admission order of
        writers is the memory write-back order the runtime must preserve."""
        return self._writer_of.get(phys_id)

    def ready(self, kernel_id: int) -> bool:
        rec = self._pending[kernel_id]
        # A dependency is satisfied iff it is no longer pending: kernel ids
        # are admitted once and only leave via complete().
        return all(d not in self._pending for d in rec.depends_on)

    def unmet_deps(self, kernel_id: int) -> tuple[int, ...]:
        """Still-pending dependencies of ``kernel_id`` — the kernels whose
        completion a wakeup-driven scheduler must wait on before re-examining
        this one (empty ⇔ :meth:`ready`)."""
        rec = self._pending[kernel_id]
        return tuple(d for d in rec.depends_on if d in self._pending)

    def runnable(self) -> list[int]:
        return [k for k in self._pending if self.ready(k)]

    def complete(self, kernel_id: int) -> None:
        rec = self._pending.pop(kernel_id)
        self._completed_count += 1
        # The written footprint leaves the pending-writer index unless a
        # later pending kernel re-wrote the same physical binding (WAW
        # without renaming keeps the newer writer's entry live).
        if self._writer_of.get(rec.destination) == kernel_id:
            self._alias_index.discard(rec.destination)
        for pid in {*rec.sources, rec.destination}:
            readers = self._readers_of.get(pid)
            if readers is not None:
                readers.discard(kernel_id)
            self._refs[pid] -= 1
            self._maybe_prune(pid)

    # ------------------------------------------------------ residency pins
    def pin(self, phys_id: int) -> None:
        """Runtime holds a cache-resident result for ``phys_id``: keep its
        binding and write-order stamp alive past the writer's completion
        (deferred write-backs replay admission order via ``writer_of``)."""
        self._pinned.add(phys_id)

    def unpin(self, phys_id: int) -> None:
        """Residency dropped — prune the records if nothing pending uses them."""
        self._pinned.discard(phys_id)
        self._maybe_prune(phys_id)

    def _maybe_prune(self, phys_id: int) -> None:
        if self._refs.get(phys_id, 0) > 0 or phys_id in self._pinned:
            return
        w = self._writer_of.get(phys_id)
        if w is not None and w in self._pending:
            return
        self._refs.pop(phys_id, None)
        self._writer_of.pop(phys_id, None)
        self._readers_of.pop(phys_id, None)
        self._bindings.pop(phys_id, None)

    # ------------------------------------------------------------- introspect
    def pending_count(self) -> int:
        return len(self._pending)

    def completed_count(self) -> int:
        return self._completed_count

    def tracked_state_size(self) -> int:
        """Entries held across all per-binding maps (bounded-growth metric)."""
        return (len(self._writer_of) + len(self._bindings) + len(self._refs)
                + sum(len(s) for s in self._readers_of.values()))

    def has_cycle(self) -> bool:
        """DAG invariant (property-tested): admission can never create a cycle
        because edges always point from earlier to later kernel ids."""
        return any(
            d >= kid for kid, rec in self._pending.items() for d in rec.depends_on
        )
