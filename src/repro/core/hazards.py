"""Hazard checking and logical-matrix renaming (paper §IV-B1).

The Kernel Decoder must cope with out-of-order communication with the host: an
``xmr`` may rebind a logical matrix register while an older kernel that named
the same register is still queued. Physically copying or stalling would erase
the benefit of deferred allocation, so — exactly like an OoO core — the decoder
*renames*: every ``xmr`` mints a fresh physical binding (see
:class:`repro.core.matrix.MatrixMap`), and queued kernels capture the physical
bindings (not the logical indices) at decode time. WAR/WAW on logical registers
then vanish by construction; only true RAW dependencies between kernels remain,
and those are expressed as edges in a dependency DAG used by both the simulator
scheduler and the trace-time production engine (buffer-donation ordering).

Host-side hazards against main memory regions are handled by the
:class:`repro.core.address_table.AddressTable`; this module covers
kernel↔kernel dependencies.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.matrix import MatrixBinding


@dataclasses.dataclass(frozen=True)
class KernelDeps:
    """Dependency summary for one decoded kernel instance."""

    kernel_id: int
    sources: tuple[int, ...]       # physical ids read
    destination: int               # physical id written
    depends_on: tuple[int, ...]    # kernel_ids that must complete first


class DependencyTracker:
    """Builds the kernel-level dependency DAG under renaming.

    After renaming, two queued kernels conflict iff:
      * RAW — a later kernel reads the physical destination of an earlier one;
      * output/anti conflicts on the *same physical* destination (possible when
        a program reuses a destination register without re-reserving it —
        renaming only happens at ``xmr``) — kept as WAW/WAR edges;
      * memory aliasing — distinct physical bindings whose main-memory
        footprints overlap (the AT-level view of the same hazard).
    """

    def __init__(self):
        self._completed: set[int] = set()
        self._pending: dict[int, KernelDeps] = {}
        self._writer_of: dict[int, int] = {}   # phys_id -> kernel_id (last writer)
        self._readers_of: dict[int, set[int]] = {}
        self._bindings: dict[int, MatrixBinding] = {}
        self._next_kernel_id = 0

    # ------------------------------------------------------------------ api
    def admit(
        self,
        sources: Sequence[MatrixBinding],
        destination: MatrixBinding,
    ) -> KernelDeps:
        kid = self._next_kernel_id
        self._next_kernel_id += 1

        deps: set[int] = set()
        for b in (*sources, destination):
            self._bindings[b.phys_id] = b

        # RAW: read a pending kernel's destination.
        for src in sources:
            w = self._writer_of.get(src.phys_id)
            if w is not None and w not in self._completed:
                deps.add(w)
        # WAW: same physical destination written twice without renaming.
        w = self._writer_of.get(destination.phys_id)
        if w is not None and w not in self._completed:
            deps.add(w)
        # WAR: we overwrite something a pending kernel still reads.
        for r in self._readers_of.get(destination.phys_id, ()):
            if r not in self._completed:
                deps.add(r)
        # Memory aliasing between distinct physical bindings (footprint overlap).
        for other_pid, writer in list(self._writer_of.items()):
            if writer in self._completed or other_pid == destination.phys_id:
                continue
            other = self._bindings[other_pid]
            if other.overlaps(destination) or any(s.overlaps(other) for s in sources):
                deps.add(writer)

        rec = KernelDeps(
            kernel_id=kid,
            sources=tuple(s.phys_id for s in sources),
            destination=destination.phys_id,
            depends_on=tuple(sorted(deps)),
        )
        self._pending[kid] = rec
        self._writer_of[destination.phys_id] = kid
        for s in sources:
            self._readers_of.setdefault(s.phys_id, set()).add(kid)
        return rec

    def binding(self, phys_id: int) -> Optional[MatrixBinding]:
        """Binding captured at admission (outlives matrix-map renaming)."""
        return self._bindings.get(phys_id)

    def writer_of(self, phys_id: int) -> Optional[int]:
        """Kernel id of the (last) writer of ``phys_id``; admission order of
        writers is the memory write-back order the runtime must preserve."""
        return self._writer_of.get(phys_id)

    def ready(self, kernel_id: int) -> bool:
        rec = self._pending[kernel_id]
        return all(d in self._completed for d in rec.depends_on)

    def runnable(self) -> list[int]:
        return [k for k in self._pending if self.ready(k)]

    def complete(self, kernel_id: int) -> None:
        self._pending.pop(kernel_id)
        self._completed.add(kernel_id)

    def pending_count(self) -> int:
        return len(self._pending)

    def has_cycle(self) -> bool:
        """DAG invariant (property-tested): admission can never create a cycle
        because edges always point from earlier to later kernel ids."""
        return any(
            d >= kid for kid, rec in self._pending.items() for d in rec.depends_on
        )
