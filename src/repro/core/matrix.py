"""Logical matrix registers and deferred (xmr) operand bindings.

``xmr`` binds an address + shape to a logical matrix register *without moving
data* (paper §IV-A1). Allocation into VPU-local layout is deferred until a kernel
consumes the operand, which lets the Matrix Allocator pick a kernel-dependent
layout. The binding therefore is pure metadata.

Physical bindings are versioned: the hazard checker renames a logical register to
a fresh physical binding when an ``xmr`` would overwrite a reservation still in
use by a pending kernel (paper §IV-B1), which removes WAR/WAW hazards exactly the
way register renaming does in an OoO core.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Optional

import numpy as np

from repro.core.encoding import ElemWidth, NUM_MATRIX_REGS
from repro.core.regions import StridedRegion

_WIDTH_TO_NP = {
    ElemWidth.W: np.int32,
    ElemWidth.H: np.int16,
    ElemWidth.B: np.int8,
}


def np_dtype(width: ElemWidth) -> np.dtype:
    return np.dtype(_WIDTH_TO_NP[width])


@dataclasses.dataclass(frozen=True)
class MatrixBinding:
    """One versioned physical binding of a logical matrix register."""

    phys_id: int            # unique physical tag (renaming target)
    logical: int            # logical register the program named (m0..m31)
    addr: int               # base byte address in main memory
    rows: int
    cols: int
    stride: int             # row stride in *elements* (>= cols)
    width: ElemWidth

    def __post_init__(self):
        if not 0 <= self.logical < NUM_MATRIX_REGS:
            raise ValueError(f"logical register m{self.logical} out of range")
        if self.rows <= 0 or self.cols <= 0:
            raise ValueError("matrix dimensions must be positive")
        if self.stride < self.cols:
            raise ValueError(f"stride {self.stride} < cols {self.cols}")

    @property
    def elem_bytes(self) -> int:
        return self.width.nbytes

    @property
    def row_bytes(self) -> int:
        return self.cols * self.elem_bytes

    @property
    def stride_bytes(self) -> int:
        return self.stride * self.elem_bytes

    @property
    def nbytes(self) -> int:
        """Bytes of *useful* data (effective dims — what the allocator moves)."""
        return self.rows * self.cols * self.elem_bytes

    @property
    def start(self) -> int:
        return self.addr

    @property
    def end(self) -> int:
        """One past the last byte touched in the strided memory footprint."""
        return self.addr + (self.rows - 1) * self.stride_bytes + self.row_bytes

    @property
    def shape(self) -> tuple[int, int]:
        return (self.rows, self.cols)

    @functools.cached_property
    def region(self) -> StridedRegion:
        """Exact 2D byte footprint of this binding in main memory.

        Cached: bindings are frozen, and ``overlaps`` sits in the admission
        and dispatch sweeps (``cached_property`` writes the instance dict
        directly, which frozen dataclasses permit)."""
        return StridedRegion(addr=self.addr, rows=self.rows,
                             row_bytes=self.row_bytes,
                             stride_bytes=self.stride_bytes)

    def overlaps(self, other: "MatrixBinding") -> bool:
        """Exact: True iff the two strided 2D footprints share a byte.

        Interval intersection is necessary but not sufficient: column strips
        of the same row-major array interleave in the flat address space
        without aliasing — the case the strip-mined conv tiling emits.
        Treating those as overlapping would serialize every strip through
        false WAW edges, so the decision is delegated to the exact
        region algebra (:mod:`repro.core.regions`), which also handles
        unequal strides and bands that wrap the stride period.
        """
        return self.region.overlaps(other.region)

    def overlaps_range(self, start: int, end: int) -> bool:
        """Exact: True iff the footprint touches flat interval [start, end)."""
        return self.region.overlaps_interval(start, end)


class MatrixMap:
    """Logical→physical matrix register map with renaming (the C-RT 'matrix map').

    Statically sized (paper §IV-B: static allocation philosophy): the number of
    logical registers is fixed at construction; physical ids grow monotonically
    because a *binding* is metadata only — there is no physical storage to
    exhaust until a kernel allocates cache lines.
    """

    def __init__(self, num_regs: int = NUM_MATRIX_REGS):
        self.num_regs = num_regs
        self._map: list[Optional[MatrixBinding]] = [None] * num_regs
        self._phys_counter = itertools.count()

    def reserve(
        self,
        logical: int,
        addr: int,
        rows: int,
        cols: int,
        stride: int,
        width: ElemWidth,
    ) -> MatrixBinding:
        """Execute an ``xmr``: bind (rename) ``logical`` to a fresh physical tag."""
        if not 0 <= logical < self.num_regs:
            raise ValueError(f"logical register m{logical} out of range")
        binding = MatrixBinding(
            phys_id=next(self._phys_counter),
            logical=logical,
            addr=addr,
            rows=rows,
            cols=cols,
            stride=stride,
            width=width,
        )
        self._map[logical] = binding
        return binding

    def lookup(self, logical: int) -> MatrixBinding:
        b = self._map[logical]
        if b is None:
            raise KeyError(f"m{logical} has no live reservation (missing xmr)")
        return b

    def live_bindings(self) -> list[MatrixBinding]:
        return [b for b in self._map if b is not None]
