"""Benchmark runner — one section per paper table/figure + the roofline.

Emits ``name,us_per_call,derived`` CSV lines: for the cycle-model benchmarks
us_per_call is modeled microseconds at the paper's 250 MHz clock; for wall
benchmarks it is host wall time; for the roofline it is the per-step
lower-bound microseconds on the target pod.

``repro`` must be importable (installed, or ``PYTHONPATH=src``); the cycle-
model sections are jax-free, and the jax wall-clock section is skipped when
jax is unavailable. Run as ``python benchmarks/run.py`` or
``python -m benchmarks.run`` from the repo root.
"""
from __future__ import annotations

import os
import sys

CLOCK_HZ = 250e6


def _sections():
    """Import the sibling drivers whether we run as a package module or a
    bare script (no repo-root sys.path hack: only the benchmarks dir)."""
    try:
        from benchmarks import (fig3_overhead, fig4_speedup, roofline,
                                sota_throughput, table2_area)
    except ImportError:
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        import fig3_overhead, fig4_speedup, roofline, sota_throughput, \
            table2_area
    return fig3_overhead, fig4_speedup, roofline, sota_throughput, table2_area


def main() -> None:
    fig3_overhead, fig4_speedup, roofline, sota_throughput, table2_area = \
        _sections()

    print("# === Fig.4: conv-layer speedups (modeled cycles @250MHz) ===")
    rows, res = fig4_speedup.main([])   # explicit argv: don't eat run.py's
    for r in rows:
        if r["size"] in (64, 256) and r["width"] == "b" and r["lanes"] == 8:
            pass  # headline rows already validated above

    print("# === Fig.3: phase overheads ===")
    fig3_overhead.main([])

    print("# === Table II: lanes / resource trade-off ===")
    table2_area.main([])

    print("# === SOTA comparison (BLADE / Intel CNC) ===")
    sota_throughput.main([])

    print("# === Wall-clock: fused vs unfused conv layer (CPU host) ===")
    try:
        import jax  # noqa: F401 — the only section that needs it
    except ImportError:
        print("wallclock_conv,skipped,jax not installed "
              "(scheduler-only toolchain)")
    else:
        _fused_vs_unfused()

    print("# === Roofline: baseline (from dry-run artifacts) ===")
    if os.path.isdir("results/dryrun") and os.listdir("results/dryrun"):
        roofline.main([])
    else:
        print("roofline,skipped,run `python -m repro.launch.dryrun --all` first")

    print("# === Roofline: optimized (post-§Perf) ===")
    if os.path.isdir("results/dryrun_optimized") and \
            os.listdir("results/dryrun_optimized"):
        rows = roofline.run("results/dryrun_optimized", quiet=True)
        roofline.write_csv(rows, "results/roofline_optimized.csv")
        base = {(r["arch"], r["shape"]): r
                for r in roofline.run(quiet=True)}
        for r in rows:
            b = base.get((r["arch"], r["shape"]))
            gain = (b["step_lower_bound_s"] / r["step_lower_bound_s"]
                    if b and r["step_lower_bound_s"] else float("nan"))
            print(f"roofline_opt,{r['arch']}|{r['shape']},"
                  f"{r['step_lower_bound_s']*1e6:.0f},"
                  f"dom={r['dominant']} rf={r['roofline_fraction']:.2f} "
                  f"gain_vs_baseline={gain:.2f}x")


def _fused_vs_unfused():
    """The ARCANE thesis on this host: one fused program vs op-by-op with
    materialised intermediates."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    try:
        from benchmarks.common import emit, time_fn
    except ImportError:
        from common import emit, time_fn

    rng = np.random.default_rng(0)
    for n in (64, 128):
        x = jnp.asarray(rng.standard_normal((3, n, n)), jnp.float32)
        f = jnp.asarray(rng.standard_normal((4, 3, 3, 3)), jnp.float32)

        def conv_steps(x, f, barrier):
            bar = (jax.lax.optimization_barrier if barrier
                   else (lambda t: t))
            outs = []
            for i in range(f.shape[0]):
                acc = jnp.zeros((n - 2, n - 2), jnp.float32)
                for c in range(3):
                    for di in range(3):
                        for dj in range(3):
                            acc = acc + f[i, c, di, dj] * jax.lax.slice(
                                x[c], (di, dj), (di + n - 2, dj + n - 2))
                            acc = bar(acc)
                outs.append(acc)
            y = bar(jnp.stack(outs))
            ph, pw = (n - 2) // 2, (n - 2) // 2
            pooled = bar(y[:, :ph * 2, :pw * 2]
                         .reshape(4, ph, 2, pw, 2).max(axis=(2, 4)))
            return jnp.where(pooled >= 0, pooled, 0.1 * pooled)

        # identical computation; the ONLY difference is whether XLA may fuse
        # across ops (VMEM residency) or must materialise each intermediate
        fused = jax.jit(lambda x, f: conv_steps(x, f, barrier=False))

        def unfused_steps(x, f):
            return conv_steps(x, f, barrier=True)

        unfused = jax.jit(unfused_steps, donate_argnums=())
        tf = time_fn(fused, x, f)
        tu = time_fn(unfused, x, f)
        emit(f"wallclock_conv_{n}", tf,
             f"fused; unfused={tu:.1f}us ratio={tu / tf:.2f}x "
             f"(CPU host caches hide materialisation at these sizes — the "
             f"TPU-target effect is in the roofline sections)")


if __name__ == "__main__":
    main()
