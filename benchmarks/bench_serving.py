"""Serving-under-load benchmark: latency percentiles over offered load.

The closed-batch benchmarks measure one tape's makespan; this driver runs
the continuous-batching scenario (``repro.sim.serving``) — Poisson request
arrivals posted onto an open runtime session, slot admission, per-request
prefill tapes, batched decode steps — and reports what a *client* sees:

* **TTFT** (time to first token, modeled cycles): arrival → prefill
  completion, queue wait included. p50 and p99 per load point.
* **TPOT** (time per output token): mean inter-token gap after the first.
* **goodput** — completed-request tokens per kilo-cycle — plus wall-clock
  tokens/sec for the simulator-throughput view.

The sweep crosses offered load (mean inter-arrival gap) × runtime
configuration (VPU count, reuse/tiling knobs), so the knee of the latency
curve is visible per config: at low load p99 TTFT ≈ an unloaded prefill,
and it inflates as queueing dominates.

``--floor N`` is the CI gate: exit nonzero if p99 TTFT **at the lowest
offered load** exceeds ``N`` cycles for any config — low-load latency is
arrival-pattern-insensitive, so a committed ceiling only trips on a real
scheduling regression. Rows carry ``conservation_ok`` (per-kernel stall
accounting must add up across idle gaps) and the document uses the shared
``BENCH_*.json`` envelope; CI validates both.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.sim import PipelinedRuntime
from repro.sim.serving import (ServingConfig, ServingDriver, bursty_arrivals,
                               poisson_arrivals)
from repro.sim.trace import Tracer

#: Request-count presets (requests per load point).
SCALES = {"small": 6, "medium": 16, "large": 40}

#: Mean inter-arrival gaps (cycles), highest gap = lowest load first. The
#: floor gate reads the first entry's rows.
LOADS = {"small": [60_000, 20_000, 6_000],
         "medium": [60_000, 20_000, 6_000, 2_000],
         "large": [80_000, 30_000, 10_000, 3_000, 1_000]}

#: Runtime configurations swept per load point.
CONFIGS = {
    "4vpu": dict(n_vpus=4, queue_capacity=16),
    "8vpu-reuse": dict(n_vpus=8, vregs_per_vpu=64, queue_capacity=16,
                       reuse=True, tiling=(4, 16)),
}


def _runtime(**kw) -> PipelinedRuntime:
    # Metrics ON (unlike bench_scheduler): the RequestLog feeds TTFT/TPOT
    # through the runtime's SchedulerMetrics, and CI checks conservation.
    kw.setdefault("tracer", Tracer(enabled=False))
    kw.setdefault("metrics", True)
    return PipelinedRuntime(**kw)


def run_point(config: str, mean_gap: int, n_requests: int, *,
              arrivals: str = "poisson", seed: int = 0) -> dict:
    """One (config, load) cell: fresh runtime, fresh driver, one run."""
    cfg = ServingConfig(kv_max=24, slots=4)
    if arrivals == "poisson":
        reqs = poisson_arrivals(n_requests, mean_gap,
                                prompt_range=(3, 8), new_range=(2, 5),
                                seed=seed)
    else:
        reqs = bursty_arrivals(n_requests, max(2, n_requests // 3),
                               mean_gap * 3, prompt_range=(3, 8),
                               new_range=(2, 5), seed=seed)
    rt = _runtime(**CONFIGS[config])
    drv = ServingDriver(rt, cfg)
    t0 = time.perf_counter()
    s = drv.run(reqs)
    seconds = time.perf_counter() - t0
    makespan = drv.session.now()
    return {
        "config": config,
        "arrivals": arrivals,
        "mean_gap": mean_gap,
        "requests": s["requests"],
        "finished": s["finished"],
        "tokens": s["tokens_generated"],
        "steps": drv.steps_issued,
        "ttft_p50": s["ttft_p50"],
        "ttft_p99": s["ttft_p99"],
        "tpot_p50": s["tpot_p50"],
        "tpot_p99": s["tpot_p99"],
        "queue_wait_p99": s["queue_wait_p99"],
        "goodput_tokens_per_kcycle": s["goodput_tokens_per_kcycle"],
        "makespan": makespan,
        "seconds": seconds,
        "tokens_per_wall_sec": (s["tokens_generated"] / seconds
                                if seconds else float("inf")),
        "conservation_ok": rt.metrics.stalls.conservation_ok(),
    }


def main(argv=None):
    p = argparse.ArgumentParser(
        description="Continuous-batching serving benchmark "
                    "(offered load x runtime config)")
    p.add_argument("--scale", choices=sorted(SCALES), default="medium",
                   help="requests per load point "
                        f"({', '.join(f'{k}={v}' for k, v in SCALES.items())})")
    p.add_argument("--configs", nargs="+", choices=sorted(CONFIGS),
                   default=sorted(CONFIGS))
    p.add_argument("--arrivals", choices=("poisson", "bursty"),
                   default="poisson")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--floor", type=float, default=None,
                   help="fail (exit 1) if p99 TTFT at the lowest offered "
                        "load exceeds this many cycles for any config")
    p.add_argument("--out-json", default=None, metavar="PATH",
                   help="write all rows + summary (BENCH_serving.json)")
    args = p.parse_args(argv)

    n = SCALES[args.scale]
    loads = LOADS[args.scale]
    rows, failed = [], []
    for config in args.configs:
        for gap in loads:
            r = run_point(config, gap, n, arrivals=args.arrivals,
                          seed=args.seed)
            rows.append(r)
            print(f"bench_serving,{config},{args.arrivals},gap={gap},"
                  f"ttft_p50={r['ttft_p50']:.0f},ttft_p99={r['ttft_p99']:.0f},"
                  f"tpot_p50={r['tpot_p50']:.0f},"
                  f"goodput={r['goodput_tokens_per_kcycle']},"
                  f"tok/s={r['tokens_per_wall_sec']:.0f},"
                  f"conserved={r['conservation_ok']}")
            if not r["conservation_ok"]:
                failed.append((config, gap, "stall conservation violated"))
        low = next(r for r in rows
                   if r["config"] == config and r["mean_gap"] == loads[0])
        if args.floor is not None and low["ttft_p99"] > args.floor:
            failed.append((config, loads[0],
                           f"low-load ttft_p99 {low['ttft_p99']:.0f} "
                           f"> floor {args.floor:.0f}"))

    summary = {
        c: {"low_load_ttft_p99":
                next(r["ttft_p99"] for r in rows
                     if r["config"] == c and r["mean_gap"] == loads[0]),
            "high_load_ttft_p99":
                next(r["ttft_p99"] for r in rows
                     if r["config"] == c and r["mean_gap"] == loads[-1]),
            "peak_goodput_tokens_per_kcycle":
                max(r["goodput_tokens_per_kcycle"] for r in rows
                    if r["config"] == c)}
        for c in args.configs
    }

    if args.out_json:
        # Same trick as bench_scheduler: make `common` importable whether
        # this runs as a script or as the `benchmarks.bench_serving` module.
        sys.path.insert(0, __file__.rsplit("/", 1)[0])
        from common import bench_doc, write_bench_json
        doc = bench_doc(
            "bench_serving",
            config={"scale": args.scale, "requests_per_point": n,
                    "loads": loads, "configs": list(args.configs),
                    "arrivals": args.arrivals, "seed": args.seed,
                    "floor": args.floor},
            rows=rows, summary=summary)
        write_bench_json(args.out_json, doc)
        print(f"bench_serving,json,{args.out_json}")

    if failed:
        for config, gap, why in failed:
            print(f"bench_serving,FAIL,{config},gap={gap},{why}",
                  file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
