"""Shared benchmark helpers: wall-clock timing, CSV emission, and the
versioned BENCH JSON envelope every driver's ``--out-json`` writes."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Optional

#: Version of the shared BENCH_*.json envelope. Bump when the envelope's
#: required keys change shape (the metrics report embedded under
#: ``metrics_report`` carries its own schema_version).
BENCH_SCHEMA_VERSION = 1


class BenchSchemaError(ValueError):
    """A BENCH JSON document does not satisfy the shared envelope."""


def bench_doc(benchmark: str, *, config: dict, rows: list,
              summary: Optional[dict] = None,
              metrics_report: Optional[dict] = None,
              **extra: Any) -> dict:
    """Build (and validate) one BENCH document in the shared envelope:
    ``schema_version`` + ``benchmark`` + the run ``config`` + per-point
    ``rows`` + an optional ``summary`` and embedded metrics report. Extra
    benchmark-specific keys ride along at the top level."""
    doc = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "benchmark": benchmark,
        "config": config,
        "rows": rows,
        "summary": summary,
        "metrics_report": metrics_report,
        **extra,
    }
    validate_bench_doc(doc)
    return doc


def validate_bench_doc(doc: Any) -> dict:
    """Validate the shared envelope; returns ``doc`` or raises
    :class:`BenchSchemaError` naming the offending key."""
    if not isinstance(doc, dict):
        raise BenchSchemaError(f"BENCH doc must be a mapping, got {type(doc)}")
    ver = doc.get("schema_version")
    if ver != BENCH_SCHEMA_VERSION:
        raise BenchSchemaError(
            f"schema_version must be {BENCH_SCHEMA_VERSION}, got {ver!r}")
    name = doc.get("benchmark")
    if not isinstance(name, str) or not name:
        raise BenchSchemaError(f"benchmark must be a non-empty str, got {name!r}")
    if not isinstance(doc.get("config"), dict):
        raise BenchSchemaError("config must be a mapping")
    rows = doc.get("rows")
    if not isinstance(rows, list) or any(not isinstance(r, dict) for r in rows):
        raise BenchSchemaError("rows must be a list of mappings")
    for key in ("summary", "metrics_report"):
        if key in doc and doc[key] is not None and not isinstance(doc[key], dict):
            raise BenchSchemaError(f"{key} must be a mapping or null")
    mrep = doc.get("metrics_report")
    if mrep is not None and "schema_version" not in mrep:
        raise BenchSchemaError("metrics_report missing its schema_version")
    return doc


def write_bench_json(path: str, doc: dict) -> str:
    """Validate ``doc`` and write it to ``path`` (creating parent dirs)."""
    validate_bench_doc(doc)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (µs) of a jax callable (block_until_ready)."""
    import jax    # deferred: scheduler benchmarks import this module jax-free

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
