"""Figure 4 reproduction: 3-channel conv-layer speedup vs scalar CPU baseline.

Two complementary measurements:

1. **Modeled cycles** (the paper's own axis): the C-RT simulator executes the
   `xmk4` conv layer through the full offload pipeline (decode → allocate →
   compute → write back) with the VPU cycle model (lanes × packed-SIMD,
   DMA bus, eCPU issue overhead); the scalar baseline models a CV32E40X-class
   in-order core (3 cycles/MAC inner loop + per-element load/store for the
   pool/ReLU passes), and the packed-SIMD baseline a CV32E40PX-class core
   (XCVPULP: 4/elem_bytes MACs/cycle + SIMD compare, with per-iteration
   re-load overhead that caps its scaling, as the paper observes at 8.6×).

2. **Wall-clock corroboration** on this host: the fused conv-layer instruction
   (one jitted program, one memory residency) vs an op-by-op unfused jnp
   baseline with forced intermediate materialisation.

Paper anchors: int8 3×3 256² 8-lane ≈ 30×; int8 7×7 256² ≈ 84×; XCVPULP peaks
≈ 8.6×; ARCANE loses below ~64² inputs. The model reproduces those regimes.
"""
from __future__ import annotations

from repro.core import (ArcaneCoprocessor, ElemWidth, issue_program,
                        place_program)
from repro.core.isa import KernelCost
from repro.core.vpu import VPUGeometry


def scalar_cpu_cycles(cost: KernelCost, width: ElemWidth) -> int:
    """CV32E40X-class scalar core: RV32IMC, 32-bit datapath.

    Conv inner loop ≈ ld+ld+mac with addressing folded → ~3 cycles/MAC
    (unrolled); elementwise ops (pool compares, ReLU) ≈ ld+op+st ≈ 3 cycles.
    Width does not help a 32-bit scalar core (the paper's 'worst-case 32-bit
    workload' framing).
    """
    return 3 * cost.macs + 3 * cost.elementwise


def packed_simd_cycles(cost: KernelCost, width: ElemWidth) -> int:
    """CV32E40PX-class (XCVPULP): packed-SIMD MACs within 32-bit registers +
    HW loops, but every operand still moves through the register file: the
    per-element load amortises poorly (the 'repeated data loading' overhead
    that caps its scaling in §V-C)."""
    simd = 4 // width.nbytes
    mac_cycles = cost.macs / simd + cost.macs / 2   # compute + ld overhead
    elem_cycles = cost.elementwise / simd + cost.elementwise / 2
    return int(mac_cycles + elem_cycles)


def tiled_conv_layer(h: int, w: int, k: int, width: ElemWidth,
                     vregs: int = 64, vlen: int = 1024):
    """The conv layer as column strips that fit the VPU register file
    (exactly what the C-RT macro-kernel does for operands larger than the
    vector register capacity): input strips are strided ``xmr`` bindings
    (stride = image width), each strip is one xmk4 instruction, destination
    strips write back through the strided 2D DMA. Since the IR refactor this
    is :func:`repro.lower.lower_cnn` — the same strip-miner the model-level
    benchmarks and examples use — returning the program instead of issuing
    inline."""
    from repro.lower import CNNSpec, lower_cnn
    spec = CNNSpec(name=f"fig4-{width.suffix}{k}-{h}x{w}",
                   h=h, w=w, k=k, width=width)
    return lower_cnn(spec, vregs_per_vpu=vregs, vlen_bytes=vlen)


def arcane_cycles(h: int, w: int, k: int, width: ElemWidth, lanes: int,
                  scheduler: str = "serial",
                  row_chunk: int | None = None,
                  dataflow: bool = True,
                  tiling: tuple[int, int] | None = None,
                  reuse: bool = False,
                  profile: bool = False,
                  metrics_report: bool = False
                  ) -> tuple[int, dict, dict | None, dict | None]:
    """Run the (strip-mined) xmk4 conv layer through the C-RT simulator;
    return total modeled cycles + phase split.

    ``scheduler`` selects the C-RT variant: ``"serial"`` (the original
    one-kernel-at-a-time loop; total = sum of phase cycles) or
    ``"pipelined"`` (repro.sim event-driven scheduler; total = makespan of
    the overlapped schedule — DMA/compute overlap across VPUs).

    Config: 4 VPUs × 64 KiB (64 vregs × 1 KiB) — a 256 KiB LLC, 2× the
    paper's 128 KiB (the paper's NM-Carus micro-programs additionally reuse
    registers row-by-row inside one instruction, which our strip model
    conservatively replaces with more strips; the larger register file
    compensates — deviation noted in EXPERIMENTS §Paper-validation)."""
    rt_kwargs = dict(n_vpus=4, vregs_per_vpu=64, vlen_bytes=1024, lanes=lanes)
    if scheduler == "pipelined":
        from repro.sim import PipelinedRuntime
        if row_chunk is not None:
            rt_kwargs["row_chunk"] = row_chunk
        rt_kwargs["dataflow"] = dataflow
        rt_kwargs["tiling"] = tiling
        rt_kwargs["reuse"] = reuse
        cop = ArcaneCoprocessor(runtime=PipelinedRuntime(**rt_kwargs))
    elif scheduler == "serial":
        cop = ArcaneCoprocessor(memory=None, **rt_kwargs)
    else:
        raise ValueError(f"unknown scheduler {scheduler!r}")
    prog = tiled_conv_layer(h, w, k, width)
    addrs = place_program(cop, prog)    # host stores: untimed
    cop.rt.stats.reset()          # measure the offload path only
    import time as _time
    wall0 = _time.perf_counter()
    issue_program(cop, prog, addrs)
    wall = _time.perf_counter() - wall0
    s = cop.rt.stats
    total = cop.rt.sim_time if scheduler == "pipelined" else s.total_cycles
    mrep = cop.rt.metrics_report() if metrics_report else None
    if not profile:
        return total, s.shares(), None, mrep
    # Simulator self-profiling (the --profile flag): wall-clock seconds the
    # run burned, events the pipelined engine processed, and AliasIndex
    # queries served across the scheduler stack.
    prof = {"wall_seconds": wall,
            "kernels_run": s.kernels_run,
            "instr_per_sec": s.kernels_run / wall if wall else 0.0,
            "alias_queries": cop.rt.alias_queries_served()}
    if scheduler == "pipelined":
        rep = cop.rt.report()
        prof["sim_seconds"] = rep.sim_seconds
        prof["events_processed"] = rep.events_processed
        prof["events_per_sec"] = (rep.events_processed / wall
                                  if wall else 0.0)
    return total, s.shares(), prof, mrep


def conv_cost(h: int, w: int, k: int, width: ElemWidth) -> KernelCost:
    from repro.core.isa import _convlayer_preamble
    _, cost = _convlayer_preamble([(3 * h, w), (3 * k, k)], {}, width)
    return cost


def run(sizes=(16, 32, 64, 128, 256), filters=(3, 5, 7), lanes=(2, 4, 8),
        widths=(ElemWidth.B, ElemWidth.H, ElemWidth.W), quiet=False,
        scheduler="serial", row_chunk=None, dataflow=True, tiling=None,
        reuse=False, profile=False):
    rows = []
    for width in widths:
        for k in filters:
            for n in sizes:
                if n <= k * 2:
                    continue
                cost = conv_cost(n, n, k, width)
                scalar = scalar_cpu_cycles(cost, width)
                simd = packed_simd_cycles(cost, width)
                for ln in lanes:
                    arc, shares, prof, _ = arcane_cycles(
                        n, n, k, width, ln, scheduler, row_chunk, dataflow,
                        tiling, reuse, profile)
                    row = {
                        "width": width.suffix, "filter": k, "size": n,
                        "lanes": ln, "cycles": arc,
                        "speedup_vs_scalar": scalar / arc,
                        "speedup_vs_simd": simd / arc,
                        "simd_vs_scalar": scalar / simd,
                    }
                    if scheduler == "pipelined":
                        row["tiling"] = list(tiling) if tiling else None
                        row["reuse"] = reuse
                        serial_arc, _, _, _ = arcane_cycles(n, n, k, width,
                                                            ln, "serial")
                        row["serial_cycles"] = serial_arc
                        row["concurrency_speedup"] = serial_arc / arc
                    if prof is not None:
                        row["profile"] = prof
                        if not quiet:
                            eps = prof.get("events_per_sec")
                            print(f"fig4_profile,{width.suffix}{k} {n} "
                                  f"{ln}lane,wall={prof['wall_seconds']:.3f}s,"
                                  f"ips={prof['instr_per_sec']:.0f},"
                                  f"aq={prof['alias_queries']}"
                                  + (f",eps={eps:.0f}" if eps else ""))
                    rows.append(row)
                    if not quiet:
                        extra = (f" concurrency={row['concurrency_speedup']:.2f}x"
                                 if scheduler == "pipelined" else "")
                        print(f"fig4,int{8*width.nbytes} {k}x{k} {n}x{n} "
                              f"{ln}lane,{arc},speedup_scalar="
                              f"{scalar/arc:.1f}x simd={scalar/simd:.1f}x"
                              + extra)
    return rows


def validate(rows) -> dict:
    """Check the paper's qualitative + quantitative anchors."""
    def pick(w, k, n, ln):
        for r in rows:
            if (r["width"], r["filter"], r["size"], r["lanes"]) == (w, k, n, ln):
                return r
        raise KeyError((w, k, n, ln))

    res = {}
    r = pick("b", 3, 256, 8)
    res["int8_3x3_256_8lane_vs_scalar"] = r["speedup_vs_scalar"]
    r7 = pick("b", 7, 256, 8)
    res["int8_7x7_256_8lane_vs_scalar"] = r7["speedup_vs_scalar"]
    res["paper_30x_band"] = 15 <= res["int8_3x3_256_8lane_vs_scalar"] <= 60
    res["paper_84x_band"] = 42 <= res["int8_7x7_256_8lane_vs_scalar"] <= 170
    small = pick("b", 3, 16, 8)
    large = pick("b", 3, 256, 8)
    # paper: XCVPULP outperforms ARCANE at small inputs — the advantage
    # must collapse by >2x going 256² → 16²
    res["small_input_advantage_collapses"] = (
        small["speedup_vs_simd"] < 0.55 * large["speedup_vs_simd"])
    res["simd_caps_below_10x"] = max(
        r["simd_vs_scalar"] for r in rows) < 10.0
    res["monotone_in_lanes"] = (
        pick("b", 3, 256, 8)["speedup_vs_scalar"]
        > pick("b", 3, 256, 4)["speedup_vs_scalar"]
        > pick("b", 3, 256, 2)["speedup_vs_scalar"])
    res["int8_beats_int32"] = (res["int8_3x3_256_8lane_vs_scalar"]
                               > pick("w", 3, 256, 8)["speedup_vs_scalar"])
    return res


def metrics_report_point(size: int, k: int, width: ElemWidth, lanes: int,
                         scheduler: str, row_chunk=None, dataflow=True,
                         tiling=None, reuse=False) -> tuple[int, dict]:
    """Re-run one sweep point with the metrics layer and return
    ``(total_cycles, metrics_report)`` — the ``--report`` payload shared by
    the fig3/fig4 drivers."""
    total, _, _, mrep = arcane_cycles(size, size, k, width, lanes, scheduler,
                                      row_chunk, dataflow, tiling, reuse,
                                      metrics_report=True)
    return total, mrep


def print_metrics_report(mrep: dict, total: int, prefix: str = "fig4_report",
                         scheduler: str = "pipelined") -> None:
    """Emit the stall-attribution + critical-path breakdown as CSV-ish lines
    (same style as the other fig outputs). For pipelined runs, asserts the
    critical path's segments tile the makespan exactly."""
    print(f"{prefix},conservation_ok,{mrep['conservation_ok']}")
    assert mrep["conservation_ok"], "stall-cycle conservation violated"
    for name, agg in sorted(mrep["kernels"].items()):
        stalls = ",".join(f"{b}={c}" for b, c in agg["stalls"].items() if c)
        print(f"{prefix},stall,{name},count={agg['count']},"
              f"busy={agg['busy']},latency={agg['latency']}"
              + ("," + stalls if stalls else ""))
    cp = mrep.get("critical_path")
    if cp is None:
        print(f"{prefix},critical_path,none (serial scheduler has no "
              f"event timeline)")
        return
    print(f"{prefix},critical_path,total={cp['total']},"
          f"makespan={cp['makespan']},cp_cycles={cp['cp_cycles']},"
          f"idle={cp['idle_cycles']}")
    assert cp["covers_makespan"] and cp["total"] == total, \
        f"critical path total {cp['total']} != makespan {total}"
    for res, d in list(cp["by_resource"].items())[:6]:
        print(f"{prefix},cp_resource,{res},{d['cycles']},"
              f"{100 * d['fraction']:.1f}%")
    for seg in cp["top_segments"][:3]:
        print(f"{prefix},cp_segment,{seg['resource']},{seg['phase']},"
              f"{seg['name']},{seg['cycles']}")


def main(argv=None):
    import argparse
    p = argparse.ArgumentParser(description="Fig. 4 reproduction benchmark")
    p.add_argument("--scheduler", choices=("serial", "pipelined"),
                   default="serial",
                   help="C-RT scheduler: the original serial loop or the "
                        "repro.sim event-driven pipelined one (also reports "
                        "the modeled concurrency speedup vs serial)")
    p.add_argument("--row-chunk", type=int, default=None,
                   help="intra-instruction pipelining granularity of the "
                        "pipelined scheduler (rows per DMA chunk; 0 disables "
                        "chunking; default: the runtime's builtin default)")
    p.add_argument("--dataflow", choices=("on", "off"), default="on",
                   help="kernel-aware per-operand DMA->compute gating in the "
                        "pipelined scheduler (off: legacy concatenated-"
                        "stream gating, for A/B comparison)")
    p.add_argument("--tile", type=int, nargs=2, default=None,
                   metavar=("ROWS", "COLS"),
                   help="2D tile trains in the pipelined scheduler: rows per "
                        "band (0: inherit --row-chunk) and cols per tile "
                        "(0: whole rows); requires --dataflow on")
    p.add_argument("--reuse", choices=("on", "off"), default="off",
                   help="cross-instruction operand reuse in the pipelined "
                        "scheduler: skip DMA-in trains whose region is "
                        "already modeled resident and clean on the dispatch "
                        "VPU (strip-mined weight re-fetch elimination)")
    p.add_argument("--sizes", type=int, nargs="+",
                   default=(16, 32, 64, 128, 256),
                   help="square input sizes to sweep")
    p.add_argument("--filters", type=int, nargs="+", default=(3, 5, 7),
                   help="filter sizes to sweep")
    p.add_argument("--lanes", type=int, nargs="+", default=(2, 4, 8),
                   help="VPU lane counts to sweep")
    p.add_argument("--widths", nargs="+", choices=("b", "h", "w"),
                   default=("b", "h", "w"),
                   help="element widths to sweep (int8/int16/int32)")
    p.add_argument("--out-json", default=None, metavar="PATH",
                   help="write rows + concurrency summary as JSON "
                        "(the CI BENCH_pipeline.json artifact)")
    p.add_argument("--profile", action="store_true",
                   help="record simulator self-profiling per point (wall "
                        "seconds, events processed, alias queries served) — "
                        "printed and added to the --out-json rows")
    p.add_argument("--report", action="store_true",
                   help="after the sweep, re-run the largest point with the "
                        "metrics layer and print the per-kernel stall "
                        "attribution + critical-path breakdown (embedded in "
                        "--out-json as metrics_report)")
    p.add_argument("--verbose", action="store_true",
                   help="print per-point rows in addition to the summary")
    args = p.parse_args(argv)

    width_of = {"b": ElemWidth.B, "h": ElemWidth.H, "w": ElemWidth.W}
    rows = run(sizes=tuple(args.sizes), filters=tuple(args.filters),
               lanes=tuple(args.lanes),
               widths=tuple(width_of[w] for w in args.widths),
               quiet=not args.verbose, scheduler=args.scheduler,
               row_chunk=args.row_chunk, dataflow=args.dataflow == "on",
               tiling=tuple(args.tile) if args.tile else None,
               reuse=args.reuse == "on", profile=args.profile)
    summary = None
    if args.scheduler == "pipelined":
        speedups = [r["concurrency_speedup"] for r in rows]
        summary = {
            "points": len(rows),
            "concurrency_speedup_min": min(speedups),
            "concurrency_speedup_mean": sum(speedups) / len(speedups),
            "concurrency_speedup_max": max(speedups),
        }
        print(f"fig4_pipelined,points,{summary['points']}")
        print(f"fig4_pipelined,concurrency_speedup_max,"
              f"{summary['concurrency_speedup_max']:.2f}")
        print(f"fig4_pipelined,concurrency_speedup_mean,"
              f"{summary['concurrency_speedup_mean']:.2f}")
        assert all(r["cycles"] <= r["serial_cycles"] for r in rows), \
            "pipelined makespan exceeded the serial schedule"
        res = None
    else:
        # Paper anchors need the full-size corners; skip validation on
        # restricted sweeps (e.g. a small-shape --report run).
        res = None
        if ({16, 256} <= set(args.sizes) and {3, 7} <= set(args.filters)
                and {2, 4, 8} <= set(args.lanes)
                and {"b", "w"} <= set(args.widths)):
            res = validate(rows)
            for k, v in res.items():
                val = f"{v:.1f}" if isinstance(v, float) else v
                print(f"fig4_validate,{k},{val}")
    profile_summary = None
    if args.profile:
        profs = [r["profile"] for r in rows if "profile" in r]
        wall = sum(p["wall_seconds"] for p in profs)
        instr = sum(p["kernels_run"] for p in profs)
        profile_summary = {
            "points": len(profs),
            "wall_seconds": wall,
            "instructions": instr,
            "instr_per_sec": instr / wall if wall else 0.0,
            "alias_queries": sum(p["alias_queries"] for p in profs),
            "events_processed": sum(p.get("events_processed", 0)
                                    for p in profs),
        }
        print(f"fig4_profile,total,wall={wall:.2f}s,"
              f"ips={profile_summary['instr_per_sec']:.0f},"
              f"aq={profile_summary['alias_queries']},"
              f"events={profile_summary['events_processed']}")
    mrep = None
    if args.report:
        # Largest point of the sweep: max size × max filter × max lanes on
        # the first width — the configuration whose makespan the breakdown
        # explains.
        size, k, ln = max(args.sizes), max(args.filters), max(args.lanes)
        wsuf = args.widths[0]
        total, mrep = metrics_report_point(
            size, k, width_of[wsuf], ln, args.scheduler,
            row_chunk=args.row_chunk, dataflow=args.dataflow == "on",
            tiling=tuple(args.tile) if args.tile else None,
            reuse=args.reuse == "on")
        print(f"fig4_report,point,{wsuf} {k}x{k} {size}x{size} {ln}lane "
              f"{args.scheduler}")
        print_metrics_report(mrep, total, scheduler=args.scheduler)
    if args.out_json:
        import sys
        sys.path.insert(0, __file__.rsplit("/", 1)[0])
        from common import bench_doc, write_bench_json
        doc = bench_doc(
            "fig4_speedup",
            config={"scheduler": args.scheduler, "row_chunk": args.row_chunk,
                    "dataflow": args.dataflow,
                    "tiling": list(args.tile) if args.tile else None,
                    "reuse": args.reuse, "sizes": list(args.sizes),
                    "filters": list(args.filters), "lanes": list(args.lanes),
                    "widths": list(args.widths)},
            rows=rows, summary=summary, metrics_report=mrep,
            validate=res, profile_summary=profile_summary)
        write_bench_json(args.out_json, doc)
        print(f"fig4,wrote,{args.out_json}")
    return rows, res


if __name__ == "__main__":
    main()
