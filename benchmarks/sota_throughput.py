"""§V-C state-of-the-art comparison: peak throughput vs BLADE / Intel CNC.

Reproduces the paper's peak-GOPS comparison (scaled to 330 MHz-class
embedded-SRAM clocks as the paper does; ARCANE runs at 265 MHz there) and
adds this framework's TPU-target numbers: the roofline GOPS of the fused
conv-layer Pallas kernel on one v5e core, showing what the same "compute in
the cache" idea buys when the cache is VMEM and the VPU is the MXU.
"""
from __future__ import annotations

import argparse
import sys


def _peak_bf16() -> float:
    """One v5e core's bf16 peak. ``repro.kernels.common`` imports jax at
    module scope; deferring (with the same constant as fallback) keeps this
    driver usable on the scheduler-only toolchain."""
    try:
        from repro.kernels.common import PEAK_BF16
        return PEAK_BF16
    except ImportError:
        return 197e12


ARCANE_CLOCK = 265e6
PAPER = {
    # name: (peak GOPS, area mm², note)
    "BLADE (65nm, scaled)": (5.3, 0.58, "bit-line IMC, basic ops only"),
    "Intel CNC (Intel 4)": (25.0, 1.92, "MAC only"),
}


def arcane_peak_gops(lanes: int = 8) -> float:
    return lanes * 4 * 2 * ARCANE_CLOCK / 1e9


def run(quiet: bool = False):
    rows = []
    a_peak = arcane_peak_gops()
    # ARCANE LLC *subsystem* area (the paper's §V-C comparison unit: BLADE is
    # "3.18× smaller than ARCANE" with BLADE at 0.58 mm² → 1.85 mm²; the full
    # SoC including the host MCU is 3.34 mm², Table II)
    a_area = 3.18 * 0.58
    rows.append({"system": "ARCANE (this repro, 8-lane)", "gops": a_peak,
                 "area_mm2": a_area, "gops_per_mm2": a_peak / a_area})
    for name, (gops, area, note) in PAPER.items():
        rows.append({"system": name, "gops": gops, "area_mm2": area,
                     "gops_per_mm2": gops / area})
    # TPU target: one v5e core, int8 ops ≈ 2x bf16 peak on the MXU
    tpu_int8 = 2 * _peak_bf16() / 1e9
    rows.append({"system": "TPU v5e core (target, int8)", "gops": tpu_int8,
                 "area_mm2": float("nan"), "gops_per_mm2": float("nan")})
    if not quiet:
        for r in rows:
            print(f"sota,{r['system']},{r['gops']:.1f},GOPS "
                  f"({r['gops_per_mm2']:.1f} GOPS/mm2)" if r["area_mm2"] ==
                  r["area_mm2"] else f"sota,{r['system']},{r['gops']:.1f},GOPS")
    return rows


def validate(rows) -> dict:
    by = {r["system"]: r for r in rows}
    ours = by["ARCANE (this repro, 8-lane)"]
    blade = by["BLADE (65nm, scaled)"]
    cnc = by["Intel CNC (Intel 4)"]
    return {
        # paper: 17.0 GOPS peak, ~3.2x BLADE, CNC 1.47x faster than ARCANE
        "peak_close_to_17gops": abs(ours["gops"] - 17.0) < 1.0,
        "blade_ratio_3p2": abs(ours["gops"] / blade["gops"] - 3.2) < 0.3,
        "cnc_ratio_1p47": abs(cnc["gops"] / ours["gops"] - 1.47) < 0.15,
        "area_efficiency_close_to_blade":
            abs(ours["gops_per_mm2"] - blade["gops_per_mm2"])
            < 0.15 * blade["gops_per_mm2"],
    }


def main(argv=None):
    p = argparse.ArgumentParser(
        description="§V-C peak-throughput comparison (BLADE / Intel CNC)")
    p.add_argument("--out-json", default=None, metavar="PATH",
                   help="write rows + validation as BENCH_sota.json")
    args = p.parse_args(argv)
    rows = run(quiet=True)
    res = validate(rows)
    for k, v in res.items():
        print(f"sota_validate,{k},{v}")
    if args.out_json:
        sys.path.insert(0, __file__.rsplit("/", 1)[0])
        from common import bench_doc, write_bench_json
        doc = bench_doc("sota_throughput",
                        config={"arcane_clock_hz": ARCANE_CLOCK},
                        rows=rows, summary={"validate": res})
        write_bench_json(args.out_json, doc)
        print(f"sota,wrote,{args.out_json}")
    return rows


if __name__ == "__main__":
    main()
